"""Fig. 9: raw throughput of bulk bitwise operations.

Derived columns report the modeled GB/s for Skylake / GTX 745 / Buddy at
1, 2, 4 banks, plus the Buddy-vs-baseline ratios the paper headlines
(3.8-9.1x vs Skylake, 2.7-6.4x vs GTX one-bank; 10.9-25.6x abstract).
us_per_call is the wall time of the *functional* fused op on this host
(32 MB operands, the paper's microbenchmark size) — it validates that the
op actually runs; the derived model numbers are the paper-comparable part.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, emit, time_call
from repro.core import timing
from repro.kernels import ref

OPS = ["not", "and", "or", "nand", "nor", "xor", "xnor"]
N_BYTES = 32 << 20  # 32 MB vectors, as in §7


def run() -> list[Row]:
    rows: list[Row] = []
    table = timing.throughput_table(banks_list=(1, 2, 4))
    table_tfaw = timing.throughput_table(banks_list=(4,), respect_tfaw=True)

    rng = np.random.default_rng(0)
    words = N_BYTES // 4
    a = rng.integers(0, 2**32, (words,), dtype=np.uint32)
    b = rng.integers(0, 2**32, (words,), dtype=np.uint32)

    for op in OPS:
        args = (a,) if op == "not" else (a, b)
        us = time_call(lambda *xs: ref.bitwise(op, *xs), *args)
        t = table[op]
        derived = (
            f"sky={t['skylake']:.2f}GB/s gtx={t['gtx745']:.2f}GB/s "
            f"b1={t['buddy_1bank']:.1f} b2={t['buddy_2bank']:.1f} "
            f"b4={t['buddy_4bank']:.1f} "
            f"b4_tfaw={table_tfaw[op]['buddy_4bank']:.1f} "
            f"b1/gtx={t['buddy_1bank'] / t['gtx745']:.1f}x "
            f"b1/sky={t['buddy_1bank'] / t['skylake']:.1f}x "
            f"b4/gtx={t['buddy_4bank'] / t['gtx745']:.1f}x"
        )
        rows.append((f"fig9/{op}", us, derived))

    r1g = [t["buddy_1bank"] / t["gtx745"] for t in table.values()]
    r4g = [t["buddy_4bank"] / t["gtx745"] for t in table.values()]
    rows.append(("fig9/summary", 0.0,
                 f"b1-vs-gtx={min(r1g):.1f}-{max(r1g):.1f}x(paper:2.7-6.4) "
                 f"b4-vs-gtx={min(r4g):.1f}-{max(r4g):.1f}x(paper:10.9-25.6)"))
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
