"""Fig. 9: raw throughput of bulk bitwise operations, 1 bank vs N banks.

Derived columns report the modeled GB/s for Skylake / GTX 745 / Buddy at
1, 2, 4 banks, plus the Buddy-vs-baseline ratios the paper headlines
(3.8-9.1x vs Skylake, 2.7-6.4x vs GTX one-bank; 10.9-25.6x abstract).
us_per_call is the wall time of the *functional* fused op on this host
(32 MB operands, the paper's microbenchmark size) — it validates that the
op actually runs; the derived model numbers are the paper-comparable part.

New in the bank-parallel engine: every op also runs the SAME 32 MB workload
end-to-end at 1 bank and at N>1 banks — functionally through the banked
kernel grid (`banks=` dispatch, bit-identity checked against the 1-bank
result) and through the controller schedule model
(`core.bankgroup.pipeline_latency_ns`, inter-bank copy overlapped with
compute). The e2e rows report the modeled makespan of both configurations
and the bank-parallel speedup — the multi-bank configuration is strictly
faster on bulk inputs (pipelining hides per-bank compute behind the shared
transfer stream).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, emit, smoke_mode, time_call, \
    write_bench_json
from repro.core import bankgroup, compiler, timing
from repro.kernels import ref
from repro.ops import bitwise as obw

OPS = ["not", "and", "or", "nand", "nor", "xor", "xnor"]
N_BYTES = 32 << 20  # 32 MB vectors, as in §7
E2E_BANKS = 8       # the N>1 bank-parallel configuration measured e2e

_FNS = {
    "not": obw.bitwise_not, "and": obw.bitwise_and, "or": obw.bitwise_or,
    "nand": obw.bitwise_nand, "nor": obw.bitwise_nor,
    "xor": obw.bitwise_xor, "xnor": obw.bitwise_xnor,
}


def run(e2e_banks: int = E2E_BANKS, n_bytes: int = N_BYTES) -> list[Row]:
    # the schedule model always uses the full paper-size workload so the
    # modeled rows (and BENCH json) are identical in smoke mode — that is
    # what lets the CI perf gate compare them against committed baselines;
    # only the functionally-executed operands shrink under BENCH_SMOKE=1
    model_bytes = n_bytes
    if smoke_mode():
        n_bytes = min(n_bytes, 2 << 20)
    rows: list[Row] = []
    table = timing.throughput_table(banks_list=(1, 2, 4))
    table_tfaw = timing.throughput_table(banks_list=(4,), respect_tfaw=True)

    rng = np.random.default_rng(0)
    words = n_bytes // 4
    a = rng.integers(0, 2**32, (words,), dtype=np.uint32)
    b = rng.integers(0, 2**32, (words,), dtype=np.uint32)
    n_blocks = model_bytes // timing.DDR3_1600.row_bytes  # row-granular

    for op in OPS:
        args = (a,) if op == "not" else (a, b)
        us = time_call(lambda *xs: ref.bitwise(op, *xs), *args)
        t = table[op]
        derived = (
            f"sky={t['skylake']:.2f}GB/s gtx={t['gtx745']:.2f}GB/s "
            f"b1={t['buddy_1bank']:.1f} b2={t['buddy_2bank']:.1f} "
            f"b4={t['buddy_4bank']:.1f} "
            f"b4_tfaw={table_tfaw[op]['buddy_4bank']:.1f} "
            f"b1/gtx={t['buddy_1bank'] / t['gtx745']:.1f}x "
            f"b1/sky={t['buddy_1bank'] / t['skylake']:.1f}x "
            f"b4/gtx={t['buddy_4bank'] / t['gtx745']:.1f}x"
        )
        rows.append((f"fig9/{op}", us, derived))

    # -- end-to-end: same workload, 1 bank vs N banks ------------------------
    jrows: list[dict] = []
    for op in OPS:
        args = (a,) if op == "not" else (a, b)
        fn = _FNS[op]
        out1 = np.asarray(fn(*args, banks=1, use_kernel=False))
        usn = time_call(lambda *xs: fn(*xs, banks=e2e_banks), *args,
                        iters=3, warmup=1)
        outn = np.asarray(fn(*args, banks=e2e_banks))
        assert np.array_equal(out1, outn), f"bank-parallel mismatch: {op}"

        srcs = ["D0"] if op == "not" else ["D0", "D1"]
        prog = compiler.op_program(op, srcs, "D2")
        s1 = bankgroup.pipeline_latency_ns(n_blocks, 1, prog)
        sn = bankgroup.pipeline_latency_ns(n_blocks, e2e_banks, prog)
        speedup = s1.total_ns / sn.total_ns
        if e2e_banks > 1:
            assert speedup > 1.0, f"bank-parallel not faster: {op}"
        rows.append((
            f"fig9_e2e/{op}", usn,
            f"b1_ms={s1.total_ns / 1e6:.2f} "
            f"b{e2e_banks}_ms={sn.total_ns / 1e6:.2f} "
            f"b{e2e_banks}_gbps="
            f"{bankgroup.banked_throughput_gbps(n_blocks, e2e_banks, prog):.1f} "
            f"bank_speedup={speedup:.1f}x blocks={n_blocks} "
            f"bitwise_match=yes"))
        jrows.append({
            "name": f"fig9_e2e/{op}",
            "bytes": model_bytes,
            "modeled_ns": sn.total_ns,
            "speedup": speedup,
            "modeled_ns_1bank": s1.total_ns,
            "n_banks": e2e_banks,
            "gbps": bankgroup.banked_throughput_gbps(n_blocks, e2e_banks,
                                                     prog),
        })
    write_bench_json("fig9_throughput", jrows)

    r1g = [t["buddy_1bank"] / t["gtx745"] for t in table.values()]
    r4g = [t["buddy_4bank"] / t["gtx745"] for t in table.values()]
    rows.append(("fig9/summary", 0.0,
                 f"b1-vs-gtx={min(r1g):.1f}-{max(r1g):.1f}x(paper:2.7-6.4) "
                 f"b4-vs-gtx={min(r4g):.1f}-{max(r4g):.1f}x(paper:10.9-25.6)"))
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
