"""CI perf-regression gate: compare BENCH_*.json runs against baselines.

Replaces the old existence/parseability-only CI check with an actual
comparison. For every benchmark json present in the baseline directory,
rows are matched by ``name`` against the freshly generated run and every
shared metric is compared with a tolerance band:

  * wall-clock-like metrics (``*_us`` / ``*_ns``, lower is better) and
    throughput-like metrics (``gbps`` / ``qps`` / ``*speedup*`` /
    ``*hit_rate*``, higher is better) FAIL the gate when they regress by
    more than ``FAIL_RATIO`` (2x) and WARN beyond ``WARN_RATIO`` (1.3x);
  * rows are only compared when their size/configuration fields
    (``bytes``, ``n_cmds``, ``n_chips``, ...) agree — CI smoke runs shrink
    operands, and comparing a 256 KB wall time against a committed 8 MB
    baseline would be noise, so mismatched rows are reported as skipped;
    measured-bandwidth metrics (``*gbps`` / ``*hbm_frac``) and
    wall-clock-derived metrics (``*wall_us`` / ``*wall_qps`` /
    ``pipeline_speedup``) are additionally skipped when either row ran
    in Pallas interpret mode (``interpret: true``) — off-TPU they
    measure the interpreter, not HBM or real serving overlap
    (deterministic *modeled* rows keep full-size workloads even in smoke
    mode — see `benchmarks/cluster_scaling.py` — and are always compared);
  * a baseline row missing from the current run is a coverage regression
    and fails the gate, as does a missing or unparseable json — except
    when the two runs differ in smoke mode (the payload records it):
    smoke runs drop cases by design, so cross-mode missing rows only
    count as skipped.

Usage:
    python benchmarks/perf_gate.py --baseline <dir> [--current <dir>] \
        [bench ...]

Exit status 0 = all comparisons within the band, 1 = any failure.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Tuple

FAIL_RATIO = 2.0
WARN_RATIO = 1.3

#: per-row fail-ratio overrides, tighter than the global band. The
#: telemetry-disabled serving path may not regress more than 3%: the
#: whole observability layer rides on no-op guards, and this row is the
#: gate that keeps them honest (same-host full runs only — smoke runs
#: shrink the workload, so the SIZE_KEYS check skips the comparison).
ROW_FAIL_RATIOS = {"obs_overhead/serve_disabled": 1.03}

#: benches every CI run must produce (bare names, without BENCH_/.json)
REQUIRED = ["fig9_throughput", "serve_qps", "serve_loop", "optimizer",
            "arith_throughput", "vm_dispatch", "vm_stream",
            "cluster_scaling", "reliability", "obs_overhead"]

#: configuration fields that must agree for metric comparison to be fair
SIZE_KEYS = ("bytes", "row_words", "n_cmds", "n_rows", "n_banks",
             "n_chips", "n_blocks", "n_bits", "n_values", "n_queries",
             "block_cols", "n_grid_blocks")

#: metrics only meaningful on real hardware: measured-bandwidth numbers
#: from a Pallas-interpret-mode run (row carries ``interpret: true``)
#: reflect the interpreter, not HBM, and are never compared cross-run
BANDWIDTH_KEYS = ("gbps", "hbm_frac")

#: wall-clock-derived metrics (as opposed to deterministic modeled-ns
#: ones): from an interpret-mode run they time the Pallas interpreter on
#: whatever CPU CI landed on, so — like bandwidth — they are only
#: compared between real-hardware runs. ``*_wall_us`` spellings and the
#: serving loop's wall-side throughput/pipelining numbers qualify;
#: modeled ``qps`` / ``*_ns`` stay gated everywhere.
WALL_KEYS = ("wall_us", "wall_qps", "pipeline_speedup")


def _lower_better(key: str) -> bool:
    return key.endswith("_us") or key.endswith("_ns")


def _higher_better(key: str) -> bool:
    return (key == "gbps" or key.endswith("qps") or "speedup" in key
            or "hit_rate" in key
            or any(key.endswith(s) for s in BANDWIDTH_KEYS))


def _bandwidth(key: str) -> bool:
    return any(key.endswith(s) for s in BANDWIDTH_KEYS)


def _wall(key: str) -> bool:
    return any(key == s or key.endswith(s) for s in WALL_KEYS)


def load_payload(path: pathlib.Path) -> Tuple[Dict[str, dict], bool]:
    """(rows by name, was-a-smoke-run) of one BENCH_*.json."""
    payload = json.loads(path.read_text())
    rows = payload.get("rows") or []
    if not rows:
        raise ValueError(f"{path}: empty rows")
    return {r["name"]: r for r in rows}, bool(payload.get("smoke"))


def load_rows(path: pathlib.Path) -> Dict[str, dict]:
    return load_payload(path)[0]


def comparable(base: dict, cur: dict) -> bool:
    """Same workload configuration on both sides?"""
    return all(base[k] == cur[k] for k in SIZE_KEYS
               if k in base and k in cur)


def compare_rows(name: str, base: dict, cur: dict
                 ) -> Tuple[List[str], List[str], int]:
    """Compare one row pair; returns (failures, warnings, n_compared)."""
    fails: List[str] = []
    warns: List[str] = []
    n = 0
    fail_ratio = ROW_FAIL_RATIOS.get(name, FAIL_RATIO)
    warn_ratio = min(WARN_RATIO, fail_ratio)
    # mirror of the wall-row policy for measured bandwidth: a row produced
    # in Pallas interpret mode measured the interpreter, not HBM
    interp = bool(base.get("interpret")) or bool(cur.get("interpret"))
    for key in sorted(set(base) & set(cur)):
        b, c = base[key], cur[key]
        if not isinstance(b, (int, float)) or not isinstance(c, (int, float)):
            continue
        if interp and (_bandwidth(key) or _wall(key)):
            continue
        if _lower_better(key):
            ratio = c / b if b > 0 else (1.0 if c <= 0 else float("inf"))
        elif _higher_better(key):
            ratio = b / c if c > 0 else (1.0 if b <= 0 else float("inf"))
        else:
            continue
        n += 1
        msg = (f"{name}.{key}: baseline {b:.6g} -> current {c:.6g} "
               f"({ratio:.2f}x worse)")
        if ratio > fail_ratio:
            fails.append(msg)
        elif ratio > warn_ratio:
            warns.append(msg)
    return fails, warns, n


def run_gate(baseline_dir: pathlib.Path, current_dir: pathlib.Path,
             benches: List[str]) -> Tuple[List[str], List[str], int, int]:
    """Gate `benches`; returns (failures, warnings, compared, skipped)."""
    fails: List[str] = []
    warns: List[str] = []
    compared = skipped = 0
    for bench in benches:
        fname = f"BENCH_{bench}.json"
        bpath, cpath = baseline_dir / fname, current_dir / fname
        if not bpath.exists():
            # nothing committed to compare against (e.g. a brand-new
            # benchmark): presence of the current file is still required
            if not cpath.exists():
                fails.append(f"{fname}: missing from current run")
            continue
        try:
            base_rows, base_smoke = load_payload(bpath)
        except Exception as e:
            fails.append(f"{fname}: unreadable baseline ({e})")
            continue
        try:
            cur_rows, cur_smoke = load_payload(cpath)
        except Exception as e:
            fails.append(f"{fname}: missing/unparseable current run ({e})")
            continue
        same_mode = base_smoke == cur_smoke
        for name, base in sorted(base_rows.items()):
            cur = cur_rows.get(name)
            if cur is None:
                # smoke runs legitimately drop cases a full baseline has;
                # only same-mode runs must cover every baseline row
                if same_mode:
                    fails.append(f"{name}: row missing from current run "
                                 f"(coverage regression)")
                else:
                    skipped += 1
                continue
            if not comparable(base, cur):
                skipped += 1
                continue
            f, w, n = compare_rows(name, base, cur)
            fails.extend(f)
            warns.extend(w)
            compared += n
    return fails, warns, compared, skipped


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, type=pathlib.Path,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--current", default=pathlib.Path("."),
                    type=pathlib.Path,
                    help="directory holding the fresh run (default: .)")
    ap.add_argument("benches", nargs="*", default=None,
                    help=f"bench names to gate (default: {REQUIRED})")
    args = ap.parse_args(argv)
    benches = args.benches or REQUIRED
    fails, warns, compared, skipped = run_gate(
        args.baseline, args.current, benches)
    for msg in warns:
        print(f"WARN  {msg}")
    for msg in fails:
        print(f"FAIL  {msg}")
    print(f"perf gate: {compared} metrics compared, {skipped} rows skipped "
          f"(size or smoke-mode mismatch), {len(warns)} warnings, "
          f"{len(fails)} failures")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
