"""Fig. 10: bitmap-index query performance (paper §8.1).

us_per_call: functional query execution (reduced size) on this host.
derived: modeled end-to-end baseline/Buddy times and speedup per (m, n).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row, emit, time_call
from repro.apps import bitmap_index


def run() -> list[Row]:
    rows: list[Row] = []

    # functional path (reduced m so the host run is quick)
    db = bitmap_index.UserDatabase.synthetic(jax.random.PRNGKey(0),
                                             m_users=1 << 16, n_weeks=4)
    us = time_call(lambda d: bitmap_index.weekly_active_query(d)[0], db,
                   iters=3)
    rows.append(("fig10/functional_m=64k_n=4", us, "query executes on ops layer"))

    sps = []
    for m in (8 << 20, 16 << 20, 32 << 20):
        for n in (2, 4, 6, 8):
            tb = bitmap_index.query_time_ns(m, n, use_buddy=False)
            tbd = bitmap_index.query_time_ns(m, n, use_buddy=True)
            sp = tb / tbd
            sps.append(sp)
            rows.append((f"fig10/m={m >> 20}M_n={n}", 0.0,
                         f"base={tb / 1e6:.2f}ms buddy={tbd / 1e6:.2f}ms "
                         f"speedup={sp:.1f}x"))
    rows.append(("fig10/summary", 0.0,
                 f"avg_speedup={np.mean(sps):.1f}x (paper: 6.0x)"))
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
