"""§8.4 sketched applications: masked init, XOR crypto, DNA mapping, Bloom.

These validate the functional path and report the modeled Buddy win for the
dominant bulk-bitwise portion of each workload.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, emit, time_call
from repro.apps.cost import DEFAULT_APP_SYSTEM
from repro.ops import (BloomFilter, field_mask, masked_fill_constant,
                       xor_encrypt)
from repro.ops import dna


def run() -> list[Row]:
    rows: list[Row] = []
    sys = DEFAULT_APP_SYSTEM
    rng = np.random.default_rng(0)

    # masked init: clear alpha of 8M RGBA pixels (2 ops: and+or chain)
    n = 1 << 23
    pixels = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    mask = field_mask(32, 24, 8, n)
    us = time_call(masked_fill_constant, pixels, mask, 0, iters=3)
    bits = n * 32
    sp = sys.cpu_bitwise_ns("and", bits) / sys.buddy_op_ns("and", bits,
                                                           dependent=False)
    rows.append(("extra/masked_init_8Mpx", us, f"modeled_speedup={sp:.1f}x"))

    # XOR encryption of 32 MB
    pt = jnp.asarray(rng.integers(0, 2**32, 1 << 23, dtype=np.uint32))
    us = time_call(xor_encrypt, pt, 0x1234567, iters=3)
    sp = sys.cpu_bitwise_ns("xor", 1 << 28) / sys.buddy_op_ns(
        "xor", 1 << 28, dependent=False)
    rows.append(("extra/xor_encrypt_32MB", us, f"modeled_speedup={sp:.1f}x"))

    # DNA exact matching: 100k-base genome, 16-base read
    genome = rng.integers(0, 4, 100_000)
    read = genome[5000:5016]
    us = time_call(lambda g, r: dna.find_matches(g, r).words, genome, read,
                   iters=3)
    # ~4 bulk ops per read base over the genome planes
    n_ops = 4 * len(read)
    sp = (n_ops * sys.cpu_bitwise_ns("and", 100_000)) / \
        (n_ops * sys.buddy_op_ns("and", 100_000))
    rows.append(("extra/dna_match_100kb", us, f"modeled_speedup={sp:.1f}x"))

    # Bloom-filter merge (union of 16 shard filters, 1 Mbit each)
    filters = [BloomFilter.create(1 << 20).insert(
        jnp.asarray(rng.integers(0, 2**31, 1000), jnp.uint32))
        for _ in range(16)]
    us = time_call(lambda f0: f0.merge(*filters[1:]).bits.words, filters[0],
                   iters=3)
    sp = 15 * sys.cpu_bitwise_ns("or", 1 << 20) / \
        (15 * sys.buddy_op_ns("or", 1 << 20))
    rows.append(("extra/bloom_merge_16x1Mbit", us, f"modeled_speedup={sp:.1f}x"))
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
