"""Continuous-serving benchmark: sustained QPS, SLO, pipelining.

Replays seeded open-loop Poisson traces (`poisson_arrivals`, same tenant
catalog as BENCH_serve_qps) through `service.server.ServingLoop` and
reports:

  * **saturation** — offered load far beyond capacity, no SLO: the loop's
    sustained modeled QPS with full slot-packing ticks (the serving-side
    throughput ceiling), plus mean tick occupancy;
  * **rated load** — ~60% of saturation with the SLO armed: p99 sojourn
    must land under the target with nothing shed (the "p99 under SLO at
    rated load" acceptance row);
  * **overload** — 3x rated with the same SLO: admission control sheds,
    and the p99 of what *was served* still holds under the target;
  * **open- vs closed-loop serving** — the same rated trace served
    round-based (the closed-loop `query_batch` shape of
    BENCH_serve_qps at equal resources: collect a capacity-sized round,
    dispatch it only when the previous round has completed AND the
    round's last query has arrived): the serving loop's greedy
    slot-packing keeps the device busy with partial ticks, so its
    sustained modeled QPS must be strictly above the closed-loop
    baseline;
  * **pipelining** — the rated trace replayed serially (plan tick N,
    run tick N, plan tick N+1, ...) and pipelined (host planning of
    tick N+1 overlapped with device execution of tick N); the wall-side
    split is reported per mode.

Bit-identity is asserted inline: every query served by the loop must
match the sequential unbatched reference exactly.

Modeled metrics (qps / p50_ns / p99_ns / occupancy / shed_frac /
open_loop_speedup) are deterministic and perf-gated everywhere;
wall-side metrics (``*wall_qps`` / ``pipeline_speedup``) carry the
``interpret`` flag and are only gated between real-hardware runs — in
Pallas interpret mode both pipeline stages are GIL-bound Python, so the
overlap they measure is the interpreter's, not the host/device split
(see benchmarks/perf_gate.py).

Writes BENCH_serve_loop.json (machine-readable trajectory tracking).
"""
from __future__ import annotations

import time

from benchmarks.common import Row, emit, smoke_mode, write_bench_json
from repro.kernels.common import use_interpret
from repro.service import (SloConfig, WorkloadSpec, build_service,
                           poisson_arrivals, results_bit_identical,
                           run_queries_unbatched)

N_BANKS = 8


def _served_bit_identical(svc, arrivals, rep) -> None:
    served = [r for r in rep.records if r.status == "served"]
    ref = run_queries_unbatched(svc.catalog,
                                [arrivals[r.index].query for r in served])
    assert results_bit_identical([r.result for r in served], ref.results), \
        "serving-loop results differ from sequential unbatched reference"


def _replay(svc, arrivals, *, slo=None, depth=4, pipeline=True):
    loop = svc.serve_loop(depth=depth, slo=slo, pipeline=pipeline)
    t0 = time.perf_counter()
    rep = loop.run_trace(arrivals)
    wall_us = (time.perf_counter() - t0) * 1e6
    return rep, wall_us


def _closed_loop_qps(svc, arrivals, round_size):
    """Round-based closed-loop serving of an open-loop trace, modeled.

    The pre-loop serving shape at equal resources: queries accumulate
    into capacity-sized rounds, and round k dispatches as one
    `query_batch` only once round k-1 has completed AND the round's own
    last query has arrived (a closed-loop server cannot see into the
    future of its arrival stream). Returns (sustained modeled QPS,
    results in stream order).
    """
    t_free = None
    results = []
    for i in range(0, len(arrivals), round_size):
        chunk = arrivals[i:i + round_size]
        ready = max(a.t_ns for a in chunk)
        start = ready if t_free is None else max(t_free, ready)
        rep = svc.query_batch([a.query for a in chunk])
        t_free = start + rep.makespan_ns
        results.extend(rep.results)
    duration_ns = t_free - min(a.t_ns for a in arrivals)
    return len(arrivals) / (duration_ns / 1e9), results


def run(spec: WorkloadSpec = WorkloadSpec()) -> list[Row]:
    if smoke_mode():
        spec = WorkloadSpec(n_tenants=2, n_weeks=2, domain_bits=1 << 10,
                            n_queries=64, seed=spec.seed)
    n_arrivals = max(64, spec.n_queries)
    interp = use_interpret()
    rows: list[Row] = []
    jrows: list[dict] = []

    def fresh():
        return build_service(spec, n_banks=N_BANKS)

    # -- saturation: offered load >> capacity, no SLO ------------------------
    svc = fresh()
    sat_arrivals = poisson_arrivals(spec, svc, rate_qps=1e9,
                                    n_arrivals=n_arrivals)
    sat, _ = _replay(svc, sat_arrivals)
    _served_bit_identical(svc, sat_arrivals, sat)
    assert len(sat.shed) == 0, "no SLO, nothing may shed"
    sat_qps = sat.sustained_qps
    rows.append((
        f"serve_loop/saturated{n_arrivals}", 0.0,
        f"qps={sat_qps:.0f} ticks={len(sat.ticks)} "
        f"occ={sat.occupancy_mean:.2f} "
        f"p99_us={sat.sojourn_percentile_ns(99) / 1e3:.1f} "
        f"bitwise_match=yes"))
    jrows.append({
        "name": f"serve_loop/saturated{n_arrivals}",
        "n_queries": n_arrivals, "n_banks": N_BANKS,
        "qps": sat_qps,
        "occupancy": sat.occupancy_mean,
        "modeled_ns": sat.duration_ns,
        "interpret": interp,
    })

    # -- rated load: 60% of saturation, SLO armed ----------------------------
    rated_qps = 0.6 * sat_qps
    # calibrate the target from an unarmed rated-load probe: 3x its p99
    # leaves headroom for estimation noise at rated load, yet sits low
    # enough that the 3x-rated overload trace genuinely breaches it
    svc = fresh()
    rated_arrivals = poisson_arrivals(spec, svc, rate_qps=rated_qps,
                                      n_arrivals=n_arrivals)
    probe, _ = _replay(svc, rated_arrivals)
    slo = SloConfig(p99_ns=max(3 * probe.sojourn_percentile_ns(99), 1e4))
    svc = fresh()
    rated, _ = _replay(svc, rated_arrivals, slo=slo)
    _served_bit_identical(svc, rated_arrivals, rated)
    p50, p99 = (rated.sojourn_percentile_ns(50),
                rated.sojourn_percentile_ns(99))
    assert rated.shed_frac == 0.0, \
        f"rated load shed {rated.shed_frac:.2f} of the offered queries"
    assert p99 <= slo.p99_ns, \
        f"rated-load p99 {p99:.0f}ns breaches SLO {slo.p99_ns:.0f}ns"
    rows.append((
        f"serve_loop/rated{n_arrivals}", 0.0,
        f"offered={rated_qps:.0f} served_qps={rated.sustained_qps:.0f} "
        f"p50_us={p50 / 1e3:.1f} p99_us={p99 / 1e3:.1f} "
        f"slo_us={slo.p99_ns / 1e3:.1f} shed=0 "
        f"occ={rated.occupancy_mean:.2f} slo_ok=yes"))
    jrows.append({
        "name": f"serve_loop/rated{n_arrivals}",
        "n_queries": n_arrivals, "n_banks": N_BANKS,
        "qps": rated.sustained_qps,
        "p50_ns": p50, "p99_ns": p99,
        "slo_target_ns": slo.p99_ns,
        "shed_frac": rated.shed_frac,
        "occupancy": rated.occupancy_mean,
        "interpret": interp,
    })

    # -- overload: 3x rated, same SLO — admission control must engage --------
    svc = fresh()
    over_arrivals = poisson_arrivals(spec, svc, rate_qps=3 * rated_qps,
                                     n_arrivals=n_arrivals)
    over, _ = _replay(svc, over_arrivals, slo=slo)
    _served_bit_identical(svc, over_arrivals, over)
    over_p99 = over.sojourn_percentile_ns(99)
    assert over.shed_frac > 0.0, \
        "3x-rated overload did not trip admission control"
    assert over_p99 <= slo.p99_ns, \
        f"overload p99-of-served {over_p99:.0f}ns breaches SLO: " \
        "admission control failed to protect the served population"
    rows.append((
        f"serve_loop/overload{n_arrivals}", 0.0,
        f"offered={3 * rated_qps:.0f} served_qps={over.sustained_qps:.0f} "
        f"shed_frac={over.shed_frac:.2f} "
        f"p99_us={over_p99 / 1e3:.1f} slo_ok=yes"))
    jrows.append({
        "name": f"serve_loop/overload{n_arrivals}",
        "n_queries": n_arrivals, "n_banks": N_BANKS,
        "qps": over.sustained_qps,
        "p99_ns": over_p99,
        "shed_frac": over.shed_frac,
        "interpret": interp,
    })

    # -- open-loop slot-packing vs round-based closed-loop, modeled ----------
    # equal resources: same service, same scheduler, same trace; the
    # closed-loop side serves capacity-sized query_batch rounds (the
    # BENCH_serve_qps shape), the loop packs partial ticks greedily
    loop_qps = rated.sustained_qps
    svc = fresh()
    closed_qps, closed_results = _closed_loop_qps(
        svc, rated_arrivals, round_size=N_BANKS * 4)
    assert results_bit_identical(rated.results(), closed_results), \
        "serving-loop results differ from closed-loop round results"
    open_loop_speedup = loop_qps / closed_qps
    assert loop_qps > closed_qps, \
        f"serving loop {loop_qps:.0f} sustained qps not above the " \
        f"closed-loop baseline {closed_qps:.0f} at equal resources"
    rows.append((
        f"serve_loop/vs_closed{n_arrivals}", 0.0,
        f"loop_qps={loop_qps:.0f} closed_qps={closed_qps:.0f} "
        f"open_loop_speedup={open_loop_speedup:.2f}x bitwise_match=yes"))
    jrows.append({
        "name": f"serve_loop/vs_closed{n_arrivals}",
        "n_queries": n_arrivals, "n_banks": N_BANKS,
        "qps": loop_qps,
        "closed_qps": closed_qps,
        "open_loop_speedup": open_loop_speedup,
    })

    # -- pipelined vs serial host planning, wall clock -----------------------
    # reported, not asserted: in interpret mode both stages are GIL-bound
    # Python, so the overlap is only meaningful on real hardware (the
    # perf gate compares these keys between real-hardware runs only)
    svc_s = fresh()
    serial, serial_us = _replay(svc_s, rated_arrivals, pipeline=False)
    svc_p = fresh()
    piped, piped_us = _replay(svc_p, rated_arrivals, pipeline=True)
    assert results_bit_identical(piped.results(), serial.results()), \
        "pipelined loop results differ from serial loop results"
    speedup = serial_us / piped_us
    plan_ms = sum(t.plan_wall_us for t in piped.ticks) / 1e3
    rows.append((
        f"serve_loop/pipeline{n_arrivals}", piped_us,
        f"serial_ms={serial_us / 1e3:.0f} piped_ms={piped_us / 1e3:.0f} "
        f"speedup={speedup:.2f}x plan_ms={plan_ms:.0f} "
        f"interpret={'yes' if interp else 'no'} bitwise_match=yes"))
    jrows.append({
        "name": f"serve_loop/pipeline{n_arrivals}",
        "n_queries": n_arrivals, "n_banks": N_BANKS,
        "pipeline_speedup": speedup,
        "serial_wall_qps": len(serial.served) / (serial_us / 1e6),
        "loop_wall_qps": len(piped.served) / (piped_us / 1e6),
        "interpret": interp,
    })

    write_bench_json("serve_loop", jrows)
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
