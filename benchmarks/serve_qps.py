"""Query-serving benchmark: QPS, latency percentiles, plan-cache hit rate.

Replays the synthetic multi-tenant §8 stream (bitmap-index weekly-activity
queries, BitWeaving range-scan predicates, set intersections —
`repro.service.workload`) through the batching scheduler and reports:

  * modeled QPS and p50/p99 latency of the 8-bank batched configuration,
  * the plan-cache hit rate over the repeated-query stream (> 50%),
  * the 8-bank vs 1-bank modeled throughput ratio (>= 3x, measured with
    the optimizer off — it is a bank-parallelism claim, and the
    optimizer's CSE strips the redundant work that parallelizes), and
  * the optimized vs unoptimized 8-bank makespan ratio (opt_speedup,
    trajectory-gated) plus the hard never-more-AAPs contract.

Correctness is asserted inline: the batched scheduler's results must be
bit-identical to sequential unbatched execution (fresh per-query compile,
one engine run per query), for every query in the stream.

Writes BENCH_serve_qps.json (machine-readable trajectory tracking).
"""
from __future__ import annotations

import time

from benchmarks.common import Row, emit, smoke_mode, write_bench_json
from repro.service import (WorkloadSpec, build_service, query_stream,
                           results_bit_identical, run_queries_unbatched)

N_BANKS = 8


def run(spec: WorkloadSpec = WorkloadSpec()) -> list[Row]:
    if smoke_mode():
        spec = WorkloadSpec(n_tenants=2, n_weeks=2, domain_bits=1 << 10,
                            n_queries=64, seed=spec.seed)
    assert spec.n_queries >= 64, "stream must exercise a real batch"
    rows: list[Row] = []
    jrows: list[dict] = []
    stream_bytes = spec.n_queries * spec.domain_bits // 8

    # -- batched, 8 banks ----------------------------------------------------
    svc = build_service(spec, n_banks=N_BANKS)
    queries = query_stream(spec, svc)
    t0 = time.perf_counter()
    rep = svc.query_batch(queries)
    wall_us = (time.perf_counter() - t0) * 1e6

    # -- unoptimized pair: the raw bank-parallelism claim --------------------
    # the optimizer's CSE strips redundant (parallelizable) work, which
    # flattens the bank-scaling curve; the >= 3x substrate claim is about
    # bank parallelism, so it is measured with the optimizer off
    svc8u = build_service(spec, n_banks=N_BANKS, optimize=False)
    rep8u = svc8u.query_batch(query_stream(spec, svc8u))
    svc1u = build_service(spec, n_banks=1, optimize=False)
    rep1 = svc1u.query_batch(query_stream(spec, svc1u))

    # -- sequential unbatched reference: bit-identity ------------------------
    ref = run_queries_unbatched(svc.catalog, queries)
    assert results_bit_identical(rep.results, ref.results), \
        "batched results differ from sequential unbatched reference"
    assert results_bit_identical(rep.results, rep8u.results), \
        "optimized results differ from unoptimized results"
    assert results_bit_identical(rep.results, rep1.results), \
        "8-bank results differ from 1-bank results"

    stats = svc.stats()
    hit_rate = stats["plan_cache_hit_rate"]
    speedup = rep1.makespan_ns / rep8u.makespan_ns
    opt_speedup = rep8u.makespan_ns / rep.makespan_ns
    assert hit_rate > 0.5, f"plan-cache hit rate {hit_rate:.2f} <= 0.5"
    assert speedup >= 3.0, f"8-bank speedup {speedup:.2f}x < 3x"
    # the optimizer's hard contract is the AAP (bandwidth/energy) total —
    # modeled makespan may trade a few % of bus time for shared planes,
    # so it is reported (opt_speedup) and perf-gated, not asserted
    assert rep.total_aaps <= rep8u.total_aaps, \
        f"optimizer emitted more AAPs: {rep.total_aaps} > {rep8u.total_aaps}"

    p50, p99 = rep.latency_percentile_ns(50), rep.latency_percentile_ns(99)
    rows.append((
        f"serve_qps/stream{spec.n_queries}", wall_us,
        f"qps={rep.qps:.0f} p50_us={p50 / 1e3:.1f} p99_us={p99 / 1e3:.1f} "
        f"hit_rate={hit_rate:.2f} plans={int(stats['plans_cached'])} "
        f"b1_ms={rep1.makespan_ns / 1e6:.3f} "
        f"b{N_BANKS}_ms={rep.makespan_ns / 1e6:.3f} "
        f"bank_speedup={speedup:.1f}x opt_speedup={opt_speedup:.2f}x "
        f"bitwise_match=yes"))
    jrows.append({
        "name": f"serve_qps/stream{spec.n_queries}",
        "bytes": stream_bytes,
        "modeled_ns": rep.makespan_ns,
        "speedup": speedup,
        "qps": rep.qps,
        "p50_ns": p50,
        "p99_ns": p99,
        "plan_cache_hit_rate": hit_rate,
        "opt_speedup": opt_speedup,
        "n_banks": N_BANKS,
        "energy_nj": stats["total_energy_nj"],
    })

    # per-tenant latency breakdown (multi-tenant fairness signal)
    tenants = sorted({q.tenant for q in queries})
    for t in tenants:
        lats = sorted(r.latency_ns for r, q in zip(rep.results, queries)
                      if q.tenant == t)
        rows.append((
            f"serve_qps/tenant_{t}", 0.0,
            f"n={len(lats)} p50_us={lats[len(lats) // 2] / 1e3:.1f} "
            f"max_us={lats[-1] / 1e3:.1f}"))

    write_bench_json("serve_qps", jrows)
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
