"""Table 3: energy of bulk bitwise operations (nJ/KB), derived from
per-command energies x Fig. 8 command counts — the table itself is never
hard-coded, so this benchmark is a genuine consistency check."""
from __future__ import annotations

from benchmarks.common import Row, emit
from repro.core import energy

PAPER = {"not": (93.7, 1.6), "and": (137.9, 3.2), "or": (137.9, 3.2),
         "nand": (137.9, 4.0), "nor": (137.9, 4.0),
         "xor": (137.9, 5.5), "xnor": (137.9, 5.5)}


def run() -> list[Row]:
    rows: list[Row] = []
    t = energy.energy_table()
    for op, e in t.items():
        pd, pb = PAPER[op]
        rows.append((
            f"table3/{op}", 0.0,
            f"ddr3={e['ddr3']:.1f}nJ/KB(paper {pd}) "
            f"buddy={e['buddy']:.2f}nJ/KB(paper {pb}) "
            f"reduction={e['reduction']:.1f}x"))
    reds = [e["reduction"] for e in t.values()]
    rows.append(("table3/summary", 0.0,
                 f"reduction={min(reds):.1f}-{max(reds):.1f}x "
                 f"(paper: 25.1-59.5x)"))
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
