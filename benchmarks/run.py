"""Aggregate benchmark runner: one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [section ...]
Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys
import time

from benchmarks.common import emit

SECTIONS = [
    "fig9_throughput",
    "table1_tra",
    "table3_energy",
    "fig10_bitmap",
    "fig11_bitweaving",
    "fig12_setops",
    "serve_qps",
    "serve_loop",
    "optimizer",
    "arith_throughput",
    "vm_dispatch",
    "vm_stream",
    "cluster_scaling",
    "reliability",
    "obs_overhead",
    "extra_apps",
    "perf_summary",
]


def main(argv: list = None) -> None:
    want = sys.argv[1:] if argv is None else list(argv)
    # a typo'd section name used to be silently skipped (the run printed
    # only the CSV header and exited 0) — reject unknown names instead
    unknown = [w for w in want if w not in SECTIONS]
    if unknown:
        print(f"unknown section(s): {', '.join(unknown)}", file=sys.stderr)
        print(f"valid sections: {', '.join(SECTIONS)}", file=sys.stderr)
        raise SystemExit(2)
    want = want or SECTIONS
    print("name,us_per_call,derived")
    for section in SECTIONS:
        if section not in want:
            continue
        mod = __import__(f"benchmarks.{section}", fromlist=["run"])
        t0 = time.perf_counter()
        rows = mod.run()
        emit(rows)
        dt = time.perf_counter() - t0
        print(f"{section}/_section_total,{dt * 1e6:.0f},")


if __name__ == "__main__":
    main()
