"""Cost-based optimizer benchmark: optimized vs unoptimized pipeline.

Serves the same streams through two otherwise-identical services — one
with the cost-based planning pipeline (`service.optimizer`: reordering
compile-off, per-plan backend choice, cross-query CSE), one with
``optimize=False`` (the plain canonicalize/compile/cache pipeline, the
pre-optimizer behavior) — and reports modeled AAP totals, makespan, and
energy for both sides:

  * the §8 multi-tenant workload stream (`repro.service.workload`), whose
    repeated weekly OR-trees and every-week AND-of-weeks overlap enough
    for the sharing pass to pay on its own, and
  * a high-overlap dashboard batch (>= 50% of the queries apply one
    shared filter subexpression — the many-panels-one-dashboard shape),
    where the modeled-AAP reduction must clear 1.3x (the gated claim).
    This case is built on a fixed-size dedicated catalog so its rows are
    deterministic and identical in smoke and full mode.

Correctness is asserted inline: both sides bit-identical to each other
and to the sequential unbatched reference, on every stream.

Writes BENCH_optimizer.json; `aap_speedup` rows are perf-gated
(`benchmarks/perf_gate.py`, higher is better).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, emit, smoke_mode, write_bench_json
from repro.service import (POPCOUNT, Query, QueryService, WorkloadSpec,
                           build_service, query_stream,
                           results_bit_identical, run_queries_unbatched)

N_BANKS = 8

#: the gated floor on the high-overlap batch's modeled-AAP reduction
MIN_OVERLAP_AAP_SPEEDUP = 1.3

#: the high-overlap batch: fixed size regardless of smoke mode
OVERLAP_DOMAIN = 2048
OVERLAP_QUERIES = 32


def _overlap_service(optimize: bool) -> QueryService:
    rng = np.random.default_rng(42)
    svc = QueryService(n_banks=N_BANKS, optimize=optimize)
    for name in [f"f{i}" for i in range(3)] + [f"p{i}" for i in range(10)]:
        svc.register_bits(name, rng.random(OVERLAP_DOMAIN) < 0.4)
    return svc


def _overlap_batch() -> list:
    """A dashboard batch: 24 of 32 panels apply one shared base filter.

    `(f0 | f1) & f2` is the dashboard's audience filter; each panel ANDs
    it with its own vector — the cross-query CSE shape: the shared
    sub-DAG compiles once into a `$cse` plane every panel references.
    """
    queries = [Query(f"((f0 | f1) & f2) & p{i % 10}", POPCOUNT)
               for i in range(24)]
    queries += [Query(f"p{i} & ~p{i + 1}", POPCOUNT) for i in range(8)]
    assert len(queries) == OVERLAP_QUERIES
    return queries


def _serve(svc, queries):
    t0 = time.perf_counter()
    rep = svc.query_batch(queries)
    wall_us = (time.perf_counter() - t0) * 1e6
    return rep, wall_us


def run(spec: WorkloadSpec = WorkloadSpec()) -> list[Row]:
    if smoke_mode():
        spec = WorkloadSpec(n_tenants=2, n_weeks=2, domain_bits=1 << 10,
                            n_queries=64, seed=spec.seed)
    rows: list[Row] = []
    jrows: list[dict] = []

    svc_opt = build_service(spec, n_banks=N_BANKS)
    svc_plain = build_service(spec, n_banks=N_BANKS, optimize=False)
    cases = [
        ("workload", spec.domain_bits, svc_opt, svc_plain,
         query_stream(spec, svc_opt), query_stream(spec, svc_plain)),
        ("overlap", OVERLAP_DOMAIN, _overlap_service(True),
         _overlap_service(False), _overlap_batch(), _overlap_batch()),
    ]

    for name, domain, s_opt, s_plain, q_opt, q_plain in cases:
        rep_o, wall_o = _serve(s_opt, q_opt)
        rep_p, wall_p = _serve(s_plain, q_plain)
        ref = run_queries_unbatched(s_opt.catalog, q_opt)
        assert results_bit_identical(rep_o.results, ref.results), \
            f"{name}: optimized differs from unbatched reference"
        assert results_bit_identical(rep_o.results, rep_p.results), \
            f"{name}: optimized differs from unoptimized"
        assert rep_o.total_aaps <= rep_p.total_aaps, \
            f"{name}: optimizer emitted more AAPs"
        aap_speedup = rep_p.total_aaps / rep_o.total_aaps
        makespan_speedup = rep_p.makespan_ns / rep_o.makespan_ns
        if name == "overlap":
            assert aap_speedup >= MIN_OVERLAP_AAP_SPEEDUP, (
                f"high-overlap AAP reduction {aap_speedup:.2f}x < "
                f"{MIN_OVERLAP_AAP_SPEEDUP}x")
        rows.append((
            f"optimizer/{name}{len(q_opt)}", wall_o,
            f"aaps={rep_o.total_aaps} unopt_aaps={rep_p.total_aaps} "
            f"aap_speedup={aap_speedup:.2f}x "
            f"makespan_speedup={makespan_speedup:.2f}x "
            f"cse_planes={rep_o.n_cse_planes} "
            f"opt_ms={rep_o.makespan_ns / 1e6:.3f} "
            f"unopt_ms={rep_p.makespan_ns / 1e6:.3f} bitwise_match=yes"))
        jrows.append({
            "name": f"optimizer/{name}{len(q_opt)}",
            "bytes": len(q_opt) * domain // 8,
            "n_queries": len(q_opt),
            "n_banks": N_BANKS,
            "total_aaps": rep_o.total_aaps,
            "baseline_aaps": rep_p.total_aaps,
            "aap_speedup": aap_speedup,
            "makespan_speedup": makespan_speedup,
            "n_cse_planes": rep_o.n_cse_planes,
            "modeled_ns": rep_o.makespan_ns,
            "unopt_modeled_ns": rep_p.makespan_ns,
        })

    write_bench_json("optimizer", jrows)
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
