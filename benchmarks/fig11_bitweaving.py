"""Fig. 11: BitWeaving column-scan speedup (paper §8.2).

us_per_call: the fused vertical-scan on this host (functional validation).
derived: modeled Buddy-vs-BitWeaving speedup across (b, r), including the
cache-exit jumps the paper highlights.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, emit, time_call
from repro.apps import bitweaving


def run() -> list[Row]:
    rows: list[Row] = []

    rng = np.random.default_rng(0)
    vals = rng.integers(0, 2**12, 1 << 16, dtype=np.uint64).astype(np.uint32)
    us = time_call(
        lambda v: bitweaving.scan_query(v, 12, 500, 2500)[0],
        jnp.asarray(vals), iters=3)
    rows.append(("fig11/functional_r=64k_b=12", us, "fused scan kernel"))

    sps = []
    for b in (1, 4, 8, 12, 16, 24, 32):
        for r_log in (20, 23, 25):
            r = 1 << r_log
            sp = bitweaving.speedup(r, b)
            sps.append(sp)
            rows.append((f"fig11/b={b}_r=2^{r_log}", 0.0,
                         f"speedup={sp:.1f}x"))
    rows.append(("fig11/summary", 0.0,
                 f"range={min(sps):.1f}-{max(sps):.1f}x avg={np.mean(sps):.1f}x "
                 f"(paper: 1.8-11.8x avg 7.0x)"))
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
