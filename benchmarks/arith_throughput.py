"""Bit-serial arithmetic throughput: maj3-adder microprograms vs word-serial.

The SIMDRAM-style layer's headline trade: an n-bit in-DRAM ADD costs O(n)
AAPs per row-block but computes 65536 elements at once without moving a
byte over the channel, while a word-serial processor streams
read-a + read-b + write-result per element through the memory bus. For each
op (ADD, SUB, LT-column, LT-const, SUM) this benchmark reports

  * the microprogram's AAP count and modeled per-block latency/energy
    (`core.timing` / `core.energy`),
  * modeled elements/s at 1 bank and at N banks (the bank-parallel
    pipeline of `core.bankgroup.pipeline_latency_ns`), and
  * the ratio against the word-serial baseline (Skylake-class streaming
    bandwidth over the bytes each element must move, `core.timing`).

Correctness is asserted inline: every op's engine execution (1 bank and
N banks) is bit-identical to the NumPy reference on the measured operands.
`us_per_call` is the wall time of the Pallas/jnp fast path on this host.

Writes BENCH_arith_throughput.json at the repo root.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, emit, smoke_mode, time_call, \
    write_bench_json
from repro.core import arith_compiler, bankgroup, timing
from repro.core.bitplane import ROW_BITS
from repro.ops import arith as oar
from repro.ops.predicate import VerticalColumn

N_BITS = 8
N_VALUES = 1 << 19          # elements per operand column = 8 row-blocks
E2E_BANKS = 8


def _word_serial_ns(n_values: int, n_bits: int, n_operands: int) -> float:
    """Baseline: a streaming processor moves every element over the bus.

    Each element moves `n_operands` reads + 1 write of ceil(n_bits/8)
    bytes at Skylake-class effective streaming bandwidth (the same fitted
    baseline as Fig. 9, `core.timing.SKYLAKE`).
    """
    bytes_per_elem = (n_bits + 7) // 8 * (n_operands + 1)
    gbps = timing.SKYLAKE.effective_bw_gbps
    return n_values * bytes_per_elem / gbps  # bytes / (GB/s) == ns


def run(n_values: int = N_VALUES, e2e_banks: int = E2E_BANKS) -> list[Row]:
    # like fig9: the latency/energy model always runs at the full operand
    # size so the BENCH json rows stay deterministic and identical in
    # smoke mode (the CI perf gate diffs them against committed
    # baselines); only the functionally-executed columns shrink
    model_values = n_values
    if smoke_mode():
        n_values = min(n_values, 1 << 12)
    rows: list[Row] = []
    jrows: list[dict] = []
    rng = np.random.default_rng(0)
    M = 1 << N_BITS
    av = rng.integers(0, M, n_values, dtype=np.uint32)
    bv = rng.integers(0, M, n_values, dtype=np.uint32)
    a = VerticalColumn.encode(av, N_BITS)
    b = VerticalColumn.encode(bv, N_BITS)
    # one 8KB row covers ROW_BITS elements per bit-plane
    n_blocks = max(1, -(-model_values // ROW_BITS))
    k_const = M // 3

    def planes_of(col):
        return np.asarray(col.planes)

    cases = [
        ("add", arith_compiler.ripple_add_program(N_BITS).program, 2,
         lambda: oar.add_columns(a, b),
         lambda banks: planes_of(oar.add_columns_dram(a, b, n_banks=banks)),
         planes_of(oar.add_columns(a, b, use_kernel=False))),
        ("sub", arith_compiler.ripple_sub_program(N_BITS).program, 2,
         lambda: oar.sub_columns(a, b),
         lambda banks: planes_of(oar.sub_columns_dram(a, b, n_banks=banks)),
         planes_of(oar.sub_columns(a, b, use_kernel=False))),
        ("lt_col", arith_compiler.compile_lt_columns(N_BITS).program, 2,
         lambda: oar.lt_columns(a, b),
         lambda banks: np.asarray(
             oar.lt_columns_dram(a, b, n_banks=banks).words),
         np.asarray(oar.lt_columns(a, b, use_kernel=False).words)),
        ("lt_const", arith_compiler.compile_lt_const(
            N_BITS, k_const).program, 1,
         lambda: oar.lt_const(a, k_const),
         lambda banks: np.asarray(
             oar.lt_const_dram(a, k_const, n_banks=banks).words),
         np.asarray(oar.lt_const(a, k_const, use_kernel=False).words)),
        ("sum", arith_compiler.plane_readout_program(N_BITS).program, 1,
         lambda: oar.sum_column(a),
         lambda banks: np.asarray([oar.sum_column_dram(a, n_banks=banks)]),
         np.asarray([int(av.sum())])),
    ]

    for name, prog, n_ops, fast, dram, expect in cases:
        # bit-identity: engine path (1 and N banks) == NumPy-backed reference
        for banks in (1, e2e_banks):
            got = dram(banks)
            assert np.array_equal(got, expect), \
                f"{name}: engine@{banks}banks != reference"

        us = time_call(lambda: fast(), iters=3, warmup=1)
        s1 = bankgroup.pipeline_latency_ns(n_blocks, 1, prog)
        sn = bankgroup.pipeline_latency_ns(n_blocks, e2e_banks, prog)
        base_ns = _word_serial_ns(model_values, N_BITS, n_ops)
        eps_n = model_values / sn.total_ns      # elements/ns

        eps_base = model_values / base_ns
        energy = _program_energy(prog) * n_blocks
        speedup = s1.total_ns / sn.total_ns if e2e_banks > 1 else 1.0
        rows.append((
            f"arith/{name}", us,
            f"aaps={prog.n_aap} b1_us={s1.total_ns / 1e3:.1f} "
            f"b{e2e_banks}_us={sn.total_ns / 1e3:.1f} "
            f"geps_b{e2e_banks}={eps_n:.2f} "
            f"vs_word_serial={eps_n / eps_base:.2f}x "
            f"bank_speedup={speedup:.1f}x nj={energy:.0f} "
            f"bit_identity=yes"))
        jrows.append({
            "name": f"arith/{name}",
            "bytes": model_values * ((N_BITS + 7) // 8),
            "n_bits": N_BITS,
            "n_values": model_values,
            "aaps": prog.n_aap,
            "modeled_ns": sn.total_ns,
            "modeled_ns_1bank": s1.total_ns,
            "word_serial_ns": base_ns,
            "speedup": eps_n / eps_base,
            "bank_speedup": speedup,
            "energy_nj": energy,
            "n_banks": e2e_banks,
        })
    write_bench_json("arith_throughput", jrows)
    return rows


def _program_energy(prog) -> float:
    from repro.core.energy import DEFAULT_ENERGY, program_energy_nj

    return program_energy_nj(prog, DEFAULT_ENERGY)


if __name__ == "__main__":
    emit(run(), header=True)
