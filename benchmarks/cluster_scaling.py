"""Cluster scaling: bulk-bitwise throughput across chips x banks.

The 2019 in-DRAM bulk-bitwise execution engine (Seshadri & Mutlu) extends
the paper's bank-level scaling argument across chips: every chip
contributes its own internal buses, banks, and sense amplifiers, so bulk
bitwise throughput scales near-linearly with the chip count as long as
operands never cross a chip boundary. `core.cluster.ChipCluster` is that
layer; this benchmark reports both sides of it:

  * **modeled** rows: `cluster_latency_ns` makespans for a fixed 32 MB
    workload at 1/2/4/8 chips x 8 banks — per-chip copy/compute pipelines
    in parallel plus the log2-depth reduction tree. These rows are
    deterministic and use the SAME workload in smoke mode, so the CI perf
    gate (`benchmarks/perf_gate.py`) compares them against the committed
    baseline exactly.
  * **measured** rows: wall-clock of the sharded shard_map VM dispatch on
    however many host devices are visible (CI forces 8 with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; chip counts
    beyond the visible device count are reported as modeled only), with
    bit-identity against the single-chip oracle asserted on every run.

Acceptance gates: modeled makespan strictly improves with each chip
doubling, and 8 chips are >= 4x over 1 chip end-to-end.
"""
from __future__ import annotations

import os
import sys

if "jax" not in sys.modules:    # must precede any jax import to take effect
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from benchmarks.common import (Row, emit, measure_wall, smoke_mode,
                               write_bench_json)
from repro.core import compiler, engine, timing
from repro.core.cluster import (ChipCluster, cluster_latency_ns,
                                cluster_throughput_gbps)

OPS = ["and", "xor"]
CHIPS = (1, 2, 4, 8)
N_BANKS = 8
MODEL_BYTES = 32 << 20      # fixed even in smoke: gate-comparable rows
MEASURE_BYTES = 8 << 20
SMOKE_MEASURE_BYTES = 256 << 10
GATE_MIN_8CHIP_SPEEDUP = 4.0


def _program(op: str):
    srcs = ["D0"] if op == "not" else ["D0", "D1"]
    return compiler.op_program(op, srcs, "D2")


def run() -> list[Row]:
    smoke = smoke_mode()
    n_dev = len(jax.devices())
    rows: list[Row] = []
    jrows: list[dict] = []

    # -- modeled scaling (deterministic; identical in smoke mode) ------------
    n_blocks = MODEL_BYTES // timing.DDR3_1600.row_bytes
    for op in OPS:
        prog = _program(op)
        base_ns = cluster_latency_ns(n_blocks, 1, N_BANKS, prog).total_ns
        prev_ns = None
        for chips in CHIPS:
            sched = cluster_latency_ns(n_blocks, chips, N_BANKS, prog)
            gbps = cluster_throughput_gbps(n_blocks, chips, N_BANKS, prog)
            speedup = base_ns / sched.total_ns
            if prev_ns is not None:
                assert sched.total_ns < prev_ns, \
                    f"{op}: no gain at {chips} chips"
            prev_ns = sched.total_ns
            rows.append((
                f"cluster_scaling/modeled_{op}_c{chips}", 0.0,
                f"modeled_ms={sched.total_ns / 1e6:.2f} "
                f"gbps={gbps:.1f} speedup={speedup:.2f}x "
                f"reduce_ns={sched.reduce_ns:.0f} blocks={n_blocks}"))
            jrows.append({
                "name": f"cluster_scaling/modeled_{op}_c{chips}",
                "bytes": MODEL_BYTES,
                "n_chips": chips,
                "n_banks": N_BANKS,
                "n_blocks": n_blocks,
                "modeled_ns": sched.total_ns,
                "reduce_ns": sched.reduce_ns,
                "speedup": speedup,
                "gbps": gbps,
            })
        final = base_ns / prev_ns
        assert final >= GATE_MIN_8CHIP_SPEEDUP, \
            f"{op}: {CHIPS[-1]}-chip speedup {final:.1f}x < " \
            f"{GATE_MIN_8CHIP_SPEEDUP}x"

    # -- measured: the sharded shard_map VM dispatch on real devices ---------
    meas_bytes = SMOKE_MEASURE_BYTES if smoke else MEASURE_BYTES
    words = meas_bytes // 4
    rng = np.random.default_rng(0)
    data = {"D0": rng.integers(0, 1 << 32, words, dtype=np.uint32),
            "D1": rng.integers(0, 1 << 32, words, dtype=np.uint32)}
    prog = _program("and")
    oracle = np.asarray(engine.execute(prog, data, outputs=["D2"])["D2"])
    measured = [c for c in CHIPS if c <= n_dev]
    for chips in measured:
        cl = ChipCluster.create(chips, n_banks=N_BANKS, max_chips=CHIPS[-1])
        out = np.asarray(cl.execute(prog, data, outputs=["D2"])["D2"])
        assert np.array_equal(out, oracle), f"{chips}-chip mismatch"
        w = measure_wall(
            lambda: cl.execute(prog, data, outputs=["D2"])["D2"],
            iters=3 if smoke else 5)
        rows.append((
            f"cluster_scaling/measured_and_c{chips}", w["wall_steady_us"],
            f"first_us={w['wall_first_us']:.0f} chips={chips} "
            f"devices={n_dev} bytes={meas_bytes} bit_identity=yes"))
        jrows.append({
            "name": f"cluster_scaling/measured_and_c{chips}",
            "bytes": meas_bytes,
            "n_chips": chips,
            "n_banks": N_BANKS,
            **{k: round(v, 1) for k, v in w.items()},
        })
    if len(measured) < len(CHIPS):
        # no silent caps: say what was dropped and why
        rows.append((
            "cluster_scaling/coverage", 0.0,
            f"measured_chips={measured} (only {n_dev} devices visible; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            f"before jax imports to measure all of {list(CHIPS)})"))

    write_bench_json("cluster_scaling", jrows)
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
