"""Telemetry overhead benchmark: the serving hot loop with the sink off/on.

Replays the serve_qps multi-tenant stream (`repro.service.workload`)
through three identically-configured services that differ only in their
`repro.obs.Telemetry` sink:

  * ``serve_disabled`` — `NULL_TELEMETRY`: tracing and metering both off,
    the zero-allocation path every instrumentation site must preserve.
    This is the row `benchmarks/perf_gate.py` holds to a **1.03x** fail
    ratio (vs the committed same-host baseline): the telemetry layer may
    not cost the disabled hot loop more than 3%.
  * ``serve_default`` — the `QueryService` default (metrics on, tracing
    off): counter adds on the dispatch loop, no span machinery.
  * ``serve_enabled`` — full `Telemetry()`: span tree + modeled timeline
    per batch, tracer reset between iterations so event lists don't grow
    across the measurement.

The ``overhead`` row reports the in-run steady-state ratios (same host,
back-to-back, so they are comparable in a way cross-host wall numbers are
not). Writes BENCH_obs_overhead.json.
"""
from __future__ import annotations

from benchmarks.common import (Row, emit, measure_wall, smoke_mode,
                               write_bench_json)
from repro.obs import NULL_TELEMETRY, Telemetry
from repro.service import WorkloadSpec, build_service, query_stream

N_BANKS = 8


def _serve_wall(spec: WorkloadSpec, telemetry, reset_trace: bool):
    svc = build_service(spec, n_banks=N_BANKS, telemetry=telemetry)
    queries = query_stream(spec, svc)

    def step():
        if reset_trace:
            svc.telemetry.reset_trace()
        return svc.query_batch(queries).makespan_ns

    return measure_wall(step)


def run(spec: WorkloadSpec = WorkloadSpec()) -> list[Row]:
    if smoke_mode():
        spec = WorkloadSpec(n_tenants=2, n_weeks=2, domain_bits=1 << 10,
                            n_queries=64, seed=spec.seed)
    stream_bytes = spec.n_queries * spec.domain_bits // 8
    size = {"bytes": stream_bytes, "n_queries": spec.n_queries,
            "n_banks": N_BANKS}

    disabled = _serve_wall(spec, NULL_TELEMETRY, reset_trace=False)
    default = _serve_wall(spec, None, reset_trace=False)
    enabled = _serve_wall(spec, Telemetry(), reset_trace=True)

    default_ratio = default["wall_steady_us"] / disabled["wall_steady_us"]
    enabled_ratio = enabled["wall_steady_us"] / disabled["wall_steady_us"]

    rows: list[Row] = []
    jrows: list[dict] = []
    for name, wall in (("serve_disabled", disabled),
                       ("serve_default", default),
                       ("serve_enabled", enabled)):
        rows.append((
            f"obs_overhead/{name}", wall["wall_steady_us"],
            f"first_us={wall['wall_first_us']:.0f} "
            f"steady_us={wall['wall_steady_us']:.0f} "
            f"n_queries={spec.n_queries}"))
        jrows.append({"name": f"obs_overhead/{name}", **size, **wall})
    rows.append((
        "obs_overhead/overhead", 0.0,
        f"default_ratio={default_ratio:.3f} "
        f"enabled_ratio={enabled_ratio:.3f}"))
    jrows.append({"name": "obs_overhead/overhead", **size,
                  "default_ratio": default_ratio,
                  "enabled_ratio": enabled_ratio})

    write_bench_json("obs_overhead", jrows)
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
