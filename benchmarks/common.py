"""Benchmark harness helpers: timing + CSV row emission + JSON results.

Every benchmark module exposes run() -> list of (name, us_per_call, derived)
rows, where `derived` is the paper-comparable figure (speedup, GB/s, nJ/KB,
...). run.py aggregates and prints the combined CSV. Benchmarks that track
the perf trajectory across PRs additionally write machine-readable
`BENCH_<name>.json` files via `write_bench_json` (deterministic modeled
numbers only — wall times vary by host and stay in the CSV).
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax

Row = Tuple[str, float, str]

BENCH_DIR = pathlib.Path(__file__).resolve().parent
REPO_ROOT = BENCH_DIR.parent


def smoke_mode() -> bool:
    """CI smoke runs (BENCH_SMOKE=1) shrink operand sizes / iteration
    counts so every benchmark still executes end-to-end in seconds."""
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def write_bench_json(bench: str, rows: List[Dict],
                     directory: Optional[pathlib.Path] = None) -> pathlib.Path:
    """Write BENCH_<bench>.json: machine-readable per-row results.

    Each row is a dict with at least `name`; perf rows carry `bytes`,
    `modeled_ns`, and `speedup` so successive PRs can diff the trajectory.
    The file lands in `benchmarks/` AND is mirrored at the repo root —
    cross-PR trajectory tooling reads the root copies.
    """
    payload = {"bench": bench, "rows": rows}
    text = json.dumps(payload, indent=2, sort_keys=True) + "\n"
    path = pathlib.Path(directory or BENCH_DIR) / f"BENCH_{bench}.json"
    path.write_text(text)
    if directory is None:
        (REPO_ROOT / path.name).write_text(text)
    return path


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(rows: List[Row], header: bool = False) -> None:
    if header:
        print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
