"""Benchmark harness helpers: timing + CSV row emission + JSON results.

Every benchmark module exposes run() -> list of (name, us_per_call, derived)
rows, where `derived` is the paper-comparable figure (speedup, GB/s, nJ/KB,
...). run.py aggregates and prints the combined CSV. Benchmarks that track
the perf trajectory across PRs additionally write machine-readable
`BENCH_<name>.json` files via `write_bench_json`. Rows carry deterministic
modeled numbers (`modeled_ns`, `speedup`, ...) and — since the lowered-VM
work — may also carry *measured* wall-clock fields from `measure_wall`
(`wall_first_us` = trace+compile+run of the first call, `wall_steady_us` =
median steady-state dispatch), so the JSON tracks real speed alongside
modeled speed. Wall fields vary by host; trajectory tooling should compare
their *ratios* (e.g. interpreter vs VM), not absolute values.
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax

Row = Tuple[str, float, str]

BENCH_DIR = pathlib.Path(__file__).resolve().parent
REPO_ROOT = BENCH_DIR.parent


def smoke_mode() -> bool:
    """CI smoke runs (BENCH_SMOKE=1) shrink operand sizes / iteration
    counts so every benchmark still executes end-to-end in seconds."""
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def write_bench_json(bench: str, rows: List[Dict],
                     directory: Optional[pathlib.Path] = None) -> pathlib.Path:
    """Write BENCH_<bench>.json: machine-readable per-row results.

    Each row is a dict with at least `name`; perf rows carry `bytes`,
    `modeled_ns`, and `speedup` (plus optional `wall_*_us` measured
    fields) so successive PRs can diff the trajectory. The file lands at
    the repo root — the single copy cross-PR trajectory tooling and CI
    read (the old `benchmarks/` mirror is gone). The payload records
    whether the run was a smoke run: `benchmarks/perf_gate.py` only
    treats a baseline row missing from the current run as a coverage
    regression when both runs are the same mode (smoke runs legitimately
    drop cases).
    """
    payload = {"bench": bench, "rows": rows, "smoke": smoke_mode()}
    text = json.dumps(payload, indent=2, sort_keys=True) + "\n"
    path = pathlib.Path(directory or REPO_ROOT) / f"BENCH_{bench}.json"
    path.write_text(text)
    return path


def measure_wall(fn: Callable, *args, iters: int = 5) -> Dict[str, float]:
    """Measured wall-clock of `fn(*args)`: first call vs steady state.

    `wall_first_us` is the cold first call — for a jitted path that is
    trace + compile + one run; for an eager path it equals a normal call.
    `wall_steady_us` is the median of `iters` subsequent calls (the
    per-dispatch cost once caches are warm). Every call blocks on the
    result, so device work is fully accounted.
    """
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    first = (time.perf_counter() - t0) * 1e6
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return {"wall_first_us": first,
            "wall_steady_us": times[len(times) // 2]}


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(rows: List[Row], header: bool = False) -> None:
    if header:
        print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
