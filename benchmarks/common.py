"""Benchmark harness helpers: timing + CSV row emission.

Every benchmark module exposes run() -> list of (name, us_per_call, derived)
rows, where `derived` is the paper-comparable figure (speedup, GB/s, nJ/KB,
...). run.py aggregates and prints the combined CSV.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(rows: List[Row], header: bool = False) -> None:
    if header:
        print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
