"""Benchmark harness helpers: timing + CSV row emission + JSON results.

Every benchmark module exposes run() -> list of (name, us_per_call, derived)
rows, where `derived` is the paper-comparable figure (speedup, GB/s, nJ/KB,
...). run.py aggregates and prints the combined CSV. Benchmarks that track
the perf trajectory across PRs additionally write machine-readable
`BENCH_<name>.json` files via `write_bench_json` (deterministic modeled
numbers only — wall times vary by host and stay in the CSV).
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax

Row = Tuple[str, float, str]

BENCH_DIR = pathlib.Path(__file__).resolve().parent


def write_bench_json(bench: str, rows: List[Dict],
                     directory: Optional[pathlib.Path] = None) -> pathlib.Path:
    """Write BENCH_<bench>.json: machine-readable per-row results.

    Each row is a dict with at least `name`; perf rows carry `bytes`,
    `modeled_ns`, and `speedup` so successive PRs can diff the trajectory.
    """
    path = pathlib.Path(directory or BENCH_DIR) / f"BENCH_{bench}.json"
    payload = {"bench": bench, "rows": rows}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(rows: List[Row], header: bool = False) -> None:
    if header:
        print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
