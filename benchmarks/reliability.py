"""Reliability modes: TRA fault rates and the cost of mitigating them.

The 2024 DDR4 characterization (arXiv:2402.18736) behind
`core.errors.TRAErrorModel` makes analog MAJ-of-3 a probabilistic
primitive; this benchmark quantifies both halves of the reliability story
the service exposes as `QueryService(reliability=...)`:

  * **fault-rate** rows: raw bit-error rate of seeded injection vs the
    residual rate after k=3 majority voting, at several per-bit flip
    probabilities — deterministic (fixed keys), so the vote's correction
    factor is a stable trajectory number.
  * **modeled** rows: scheduler-timeline latency/energy/qps of the same
    query batch under ``none`` / ``vote`` / ``ecc`` — the mitigation
    overhead the paper-style cost model charges (k x AAP compute + one
    vote AAP per output plane; transfers are not repeated). Fixed
    workload even in smoke mode, so the CI perf gate
    (`benchmarks/perf_gate.py`) compares these rows exactly.
  * **measured** rows: wall-clock of the mitigated VM dispatch (operands
    shrink under ``BENCH_SMOKE=1``; the gate skips mismatched sizes).

Acceptance gates: every mode is bit-identical to the unmitigated service
at rate 0, and voting strictly reduces the injected bit-error rate.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (Row, emit, measure_wall, smoke_mode,
                               write_bench_json)
from repro.core import compiler, engine, errors, lowering
from repro.core.errors import ReliabilityConfig, TRAErrorModel
from repro.service import Query, QueryService, results_bit_identical

MODES = ("none", "vote", "ecc")
#: modeled workload — fixed even in smoke mode: gate-comparable rows
N_BITS = 1536
N_QUERIES = 24
#: fault-rate workload (fixed): one maj3 program over this many words
FAULT_WORDS = 512
FAULT_PROBS = (1e-4, 1e-3)
#: measured workload — shrinks in smoke mode (gate skips size mismatches)
MEAS_BITS = 1 << 16
SMOKE_MEAS_BITS = 1 << 11

_QUERY_SHAPES = ["a & b", "a | c & ~d", "(a ^ b) | (c & d)", "b ^ d"]


def _batch() -> list:
    return [Query(_QUERY_SHAPES[i % len(_QUERY_SHAPES)])
            for i in range(N_QUERIES)]


def _service(n_bits: int, mode: str) -> QueryService:
    rel = (None if mode == "none" else ReliabilityConfig(
        mode=mode, model=TRAErrorModel(p_flip=0.0)))
    rng = np.random.default_rng(5)
    svc = QueryService(n_banks=8, reliability=rel)
    for n in "abcd":
        svc.register_bits(n, rng.integers(0, 2, n_bits).astype(bool),
                          group="t0")
    return svc


def _bit_error_rate(a: dict, b: dict, outs: list) -> float:
    total = diff = 0
    for o in outs:
        x, y = np.asarray(a[o]), np.asarray(b[o])
        diff += int(np.unpackbits((x ^ y).view(np.uint8)).sum())
        total += x.size * 32
    return diff / total


def run() -> list[Row]:
    smoke = smoke_mode()
    rows: list[Row] = []
    jrows: list[dict] = []

    # -- fault rates: raw injection vs k=3 vote (deterministic) --------------
    program = compiler.maj3_program("D0", "D1", "D2", "D3")
    lp = lowering.lower(program)
    rng = np.random.default_rng(0)
    data = {f"D{i}": rng.integers(0, 1 << 32, FAULT_WORDS, dtype=np.uint32)
            for i in range(3)}
    outs = ["D3"]
    clean = engine.execute(program, data, outputs=outs, lowered=False)
    for p in FAULT_PROBS:
        model = TRAErrorModel(p_flip=p)
        raw = errors.execute_injected(lp, data, outputs=outs, model=model,
                                      key=jax.random.PRNGKey(1))
        voted = errors.execute_voted(lp, data, outs, model=model,
                                     key=jax.random.PRNGKey(1))
        raw_rate = _bit_error_rate(clean, raw, outs)
        voted_rate = _bit_error_rate(clean, voted, outs)
        assert raw_rate > 0.0, f"p={p}: injection drew no faults"
        assert voted_rate < raw_rate, \
            f"p={p}: vote did not reduce the error rate"
        corr = ("complete" if voted_rate == 0.0
                else f"{raw_rate / voted_rate:.0f}x")
        rows.append((
            f"reliability/fault_rate_p{p:g}", 0.0,
            f"raw_ber={raw_rate:.2e} voted_ber={voted_rate:.2e} "
            f"correction={corr} words={FAULT_WORDS}"))
        jrows.append({
            "name": f"reliability/fault_rate_p{p:g}",
            "n_bits": FAULT_WORDS * 32,
            "raw_bit_error_rate": raw_rate,
            "voted_bit_error_rate": voted_rate,
        })

    # -- modeled mitigation overhead (fixed workload; gate-compared) ---------
    batch = _batch()
    reports = {}
    for mode in MODES:
        svc = _service(N_BITS, mode)
        reports[mode] = svc.query_batch(batch)
    for mode in MODES:
        rep = reports[mode]
        assert results_bit_identical(reports["none"].results, rep.results), \
            f"{mode}: not bit-identical to the unmitigated service at rate 0"
        energy = sum(r.energy_nj for r in rep.results)
        overhead = rep.makespan_ns / reports["none"].makespan_ns
        rows.append((
            f"reliability/modeled_{mode}", 0.0,
            f"modeled_ms={rep.makespan_ns / 1e6:.3f} qps={rep.qps:.0f} "
            f"energy_uj={energy / 1e3:.2f} overhead={overhead:.2f}x "
            f"queries={N_QUERIES}"))
        jrows.append({
            "name": f"reliability/modeled_{mode}",
            "n_bits": N_BITS,
            "n_queries": N_QUERIES,
            "modeled_ns": rep.makespan_ns,
            "qps": rep.qps,
            "energy_nj": energy,
            "latency_overhead": overhead,
        })
    # fault-free ecc dual-runs (2x), vote always runs k=3 (3x)
    assert reports["vote"].makespan_ns > reports["ecc"].makespan_ns \
        > reports["none"].makespan_ns

    # -- measured: wall-clock of the mitigated dispatch ----------------------
    meas_bits = SMOKE_MEAS_BITS if smoke else MEAS_BITS
    for mode in MODES:
        svc = _service(meas_bits, mode)
        w = measure_wall(lambda s=svc: s.query_batch(batch),
                         iters=3 if smoke else 5)
        rows.append((
            f"reliability/measured_{mode}", w["wall_steady_us"],
            f"first_us={w['wall_first_us']:.0f} bits={meas_bits} "
            f"queries={N_QUERIES}"))
        jrows.append({
            "name": f"reliability/measured_{mode}",
            "n_bits": meas_bits,
            "n_queries": N_QUERIES,
            **{k: round(v, 1) for k, v in w.items()},
        })

    write_bench_json("reliability", jrows)
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
