"""Streamed-plane megakernel: measured GB/s vs the HBM roofline.

The rebuilt Pallas VM (`kernels.vm`) streams the plane tensor HBM→VMEM in
``block_cols``-wide grid blocks — Pallas double-buffers the block stream
across grid steps, so operands wider than VMEM execute with copy/compute
overlap — and folds every bank/query batch axis into the leading grid
axis of ONE launch (no per-slice `jax.vmap`). This benchmark measures the
two claims that rebuild makes:

  * **streaming**: steady-state dispatch over operands spanning >= 4 word
    grid blocks, reported as effective GB/s against the shared HBM
    roofline constant (`repro.hw.HBM_BW` — the same denominator the
    dry-run roofline analysis prices against).
  * **fused reduction**: count-only analytics (`reduce="popcount"`) keep
    the output planes in VMEM scratch — only ``(n_out, batch)`` int32
    counts reach HBM — so the fused path's traffic is the plane read
    alone. `writeback_saved_bytes` records the HBM writeback the
    materialize path pays and the fused path skips.

Bit-identity gates (always enforced, every mode): fused popcounts must
equal popcount-of-materialized-planes exactly, and the aggregate epilogue
must equal the float32-weighted count sum. The operand must genuinely
span >= 4 grid blocks or the run aborts — a single-block "stream" would
measure nothing.

Wall-clock rows carry an `interpret` flag: off-TPU the kernel runs in
Pallas interpret mode, where GB/s reflects the interpreter, not HBM —
`benchmarks/perf_gate.py` only compares bandwidth metrics between runs of
equal operand size with the flag unset on both sides.

Writes BENCH_vm_stream.json at the repo root.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Row, emit, measure_wall, smoke_mode, \
    write_bench_json
from repro.core import arith_compiler, compiler, lowering
from repro.core.commands import Program
from repro.hw import HBM_BW
from repro.kernels import vm as vmk
from repro.kernels.common import LANE, pick_block, round_up, use_interpret
from repro.ops.popcount import popcount_words

FULL_WORDS = 8192           # 4 x DEFAULT_BLOCK_COLS grid blocks
FULL_BLOCK = vmk.DEFAULT_BLOCK_COLS
FULL_BATCH = 8
SMOKE_WORDS = 512           # 4 x 128-wide blocks, CPU-friendly
SMOKE_BLOCK = 128
SMOKE_BATCH = 4
MIN_GRID_BLOCKS = 4


def _ortree_program() -> tuple:
    """(D0&D1) | (D2&D3) | ~D4 — a count-only boolean filter."""
    cmds = []
    for prog in (compiler.and_program("D0", "D1", "A0"),
                 compiler.and_program("D2", "D3", "A1"),
                 compiler.not_program("D4", "A2"),
                 compiler.or_program("A0", "A1", "A3"),
                 compiler.or_program("A3", "A2", "OUT")):
        cmds.extend(prog.commands)
    return Program(cmds, "ortree"), ["D0", "D1", "D2", "D3", "D4"], ["OUT"]


def _add8_program() -> tuple:
    res = arith_compiler.ripple_add_program(8)
    ins = [f"X{j}" for j in range(8)] + [f"Y{j}" for j in range(8)]
    return res.program, ins, list(res.outputs)


def run() -> list[Row]:
    smoke = smoke_mode()
    words = SMOKE_WORDS if smoke else FULL_WORDS
    block_cols = SMOKE_BLOCK if smoke else FULL_BLOCK
    batch = SMOKE_BATCH if smoke else FULL_BATCH
    iters = 3 if smoke else 5
    interp = use_interpret()
    rng = np.random.default_rng(0)
    rows: list[Row] = []
    jrows: list[dict] = []

    bw = pick_block(words, block_cols, LANE)
    n_blocks = round_up(words, bw) // bw
    assert n_blocks >= MIN_GRID_BLOCKS, (
        f"operand spans only {n_blocks} grid block(s) "
        f"(words={words}, block_cols={block_cols}); the streaming "
        f"benchmark needs >= {MIN_GRID_BLOCKS}")

    for name, (prog, ins, outs) in (("ortree", _ortree_program()),
                                    ("add8", _add8_program())):
        lp = lowering.lower(prog)
        data = {k: jnp.asarray(rng.integers(0, 1 << 32, (batch, words),
                                            dtype=np.uint32))
                for k in ins}
        plane = lowering.make_plane(lp, data, words, batch=(batch,))
        out_idx = tuple(lp.row_index(o) for o in outs)

        def mat():
            return vmk.vm_megakernel(lp.table, plane, out_idx,
                                     block_cols=block_cols)

        def fused():
            return vmk.vm_megakernel(lp.table, plane, out_idx,
                                     block_cols=block_cols,
                                     reduce="popcount")

        def agg():
            return vmk.vm_megakernel(lp.table, plane, out_idx,
                                     block_cols=block_cols,
                                     reduce="aggregate")

        # bit-identity: fused counts == popcount of materialized planes,
        # aggregate == the float32-weighted count sum
        planes = mat()
        counts = fused()
        ref = popcount_words(planes, axis=-1)
        assert counts.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref)), \
            f"{name}: fused popcount diverges from materialize+popcount"
        want = np.zeros(batch, np.float32)
        for j in range(len(outs)):
            want += np.asarray(ref[j], np.float32) * float(1 << j)
        np.testing.assert_allclose(np.asarray(agg()), want, rtol=1e-6)

        w_mat = measure_wall(mat, iters=iters)
        w_fused = measure_wall(fused, iters=iters)

        plane_bytes = int(plane.size) * 4          # HBM read per dispatch
        writeback = len(outs) * batch * words * 4  # materialize-only write
        mat_bytes = plane_bytes + writeback
        mat_gbps = mat_bytes / (w_mat["wall_steady_us"] * 1e-6) / 1e9
        fused_gbps = plane_bytes / (w_fused["wall_steady_us"] * 1e-6) / 1e9

        rows.append((
            f"vm_stream/{name}", w_fused["wall_steady_us"],
            f"blocks={n_blocks} fused_gbps={fused_gbps:.2f} "
            f"hbm_frac={fused_gbps * 1e9 / HBM_BW:.3f} "
            f"mat_gbps={mat_gbps:.2f} "
            f"saved_kb={writeback / 1024:.0f} "
            f"interpret={'yes' if interp else 'no'} bit_identity=yes"))
        jrows.append({
            "name": f"vm_stream/{name}",
            "bytes": plane_bytes,
            "n_cmds": lp.n_cmds,
            "n_rows": lp.n_rows,
            "row_words": words,
            "batch": batch,
            "block_cols": block_cols,
            "n_grid_blocks": n_blocks,
            "interpret": interp,
            "mat_first_us": round(w_mat["wall_first_us"], 1),
            "mat_steady_us": round(w_mat["wall_steady_us"], 1),
            "mat_gbps": round(mat_gbps, 3),
            "mat_hbm_frac": round(mat_gbps * 1e9 / HBM_BW, 4),
            "fused_first_us": round(w_fused["wall_first_us"], 1),
            "fused_steady_us": round(w_fused["wall_steady_us"], 1),
            "fused_gbps": round(fused_gbps, 3),
            "fused_hbm_frac": round(fused_gbps * 1e9 / HBM_BW, 4),
            "writeback_saved_bytes": writeback,
        })

    write_bench_json("vm_stream", jrows)
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
