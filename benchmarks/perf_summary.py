"""§Perf artifacts as a benchmark section: reads the recorded hillclimb
measurements (results/perf_*.json, produced by the dry-run perf pass) and
reports the before/after deltas. Regenerate the underlying JSONs with the
commands in EXPERIMENTS.md §Perf."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def _load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return None
    return json.load(open(path))


def run():
    rows = []
    pairs = [
        ("A4_gradient_collective",
         "perf_A4_qwen8b_puredp_adamw_dp.json",
         "perf_A4_qwen8b_puredp_majority_dp.json",
         "collective_bytes",
         "majority-vote 1-bit vs f32 all-reduce (pure-DP 256)"),
        ("A5_gradient_collective_multipod",
         "perf_A5_qwen8b_mp_puredp_adamw.json",
         "perf_A5_qwen8b_mp_puredp_majority.json",
         "collective_bytes",
         "majority-vote 1-bit vs f32 all-reduce (pure-DP 2 pods x 256)"),
        ("C1_decode_seqshard",
         "perf_C0_qwen06b_decode_baseline2.json",
         "perf_C1_qwen06b_decode_seqshard.json",
         "collective_bytes",
         "sequence-sharded KV cache vs flat-KV resharding"),
        ("B1_moe_constraints",
         None,   # baseline lives in the main sweep
         "perf_B1_llama4_prefill_moeconstraints.json",
         "hlo_flops",
         "expert-sharding constraints vs GSPMD replication"),
    ]
    for name, base_f, opt_f, key, desc in pairs:
        if base_f is None:
            base = _load("cell_llama4_maverick_400b_a17b_prefill_32k.json")
            # NB: current sweep baseline may already include the fix; the
            # recorded pre-fix value is in EXPERIMENTS.md §Perf (9.2e18)
            base_v = 9.245e18
        else:
            base = _load(base_f)
            base_v = base[key] if base else None
        opt = _load(opt_f)
        if opt is None or base_v is None:
            rows.append((f"perf/{name}", 0.0, "missing results/ artifacts"))
            continue
        opt_v = opt[key]
        rows.append((f"perf/{name}", 0.0,
                     f"{desc}: {key} {base_v:.3e} -> {opt_v:.3e} "
                     f"({base_v / max(opt_v, 1e-9):.1f}x)"))
    return rows
