"""Fig. 12: set operations — RB-tree vs SIMD bitset vs Buddy (paper §8.3)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, emit, time_call
from repro.apps import bitset as app
from repro.ops import BitSet


def run() -> list[Row]:
    rows: list[Row] = []

    # functional: k=15 unions over the paper's 2^19 domain
    rng = np.random.default_rng(0)
    domain = 1 << 19
    sets = [BitSet.from_elements(
        jnp.asarray(rng.integers(0, domain, 1024, dtype=np.int64)), domain)
        for _ in range(15)]
    us = time_call(lambda s0: s0.union(*sets[1:]).cardinality(), sets[0],
                   iters=3)
    rows.append(("fig12/functional_union_k=15", us, "bitvector set ops"))

    grid = app.figure12_grid()
    for m, c in grid.items():
        rows.append((f"fig12/elems={m}", 0.0,
                     f"rb={c.rbtree_ns / 1e3:.1f}us "
                     f"bitset={c.bitset_ns / 1e3:.1f}us "
                     f"buddy={c.buddy_ns / 1e3:.2f}us "
                     f"vs_rb={c.buddy_vs_rbtree:.1f}x "
                     f"vs_bitset={c.buddy_vs_bitset:.1f}x"))
    big = [c.buddy_vs_rbtree for m, c in grid.items() if m >= 64]
    rows.append(("fig12/summary", 0.0,
                 f"rb_wins_at_16={grid[16].buddy_vs_rbtree < 1} "
                 f"buddy_vs_rb_64plus={min(big):.1f}-{max(big):.1f}x "
                 f"(paper: ~3x from 64 elements)"))
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
