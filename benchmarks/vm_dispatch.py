"""Dispatch cost of the executor stack: interpreter vs scan-VM vs megakernel.

The lowered register-machine executor (`core.lowering` / `kernels.vm`)
exists to kill two wall-clock costs the micro-op interpreter pays on every
program (paper §7 dense-AAP-stream dispatch, SIMDRAM µProgram sequencer):

  * **trace/compile**: the interpreter unrolls one traced jnp op per
    micro-op, so jitting a 32-bit ripple add means tracing and compiling a
    multi-thousand-op jaxpr — O(program length). The scan VM's jaxpr is
    constant-size (the opcode table is data), so trace+compile is O(1).
  * **steady-state dispatch**: un-jitted, the interpreter re-issues every
    micro-op eagerly per call (how `engine.execute(lowered=False)` actually
    runs); the lowered paths are one cached executable per program shape —
    one launch per dispatch.

This benchmark *measures* both on the PR 3 arithmetic microprograms with
operands resident on device, asserting bit-identity across all four paths:

  interp_eager   engine.execute(lowered=False), per-micro-op dispatch
  interp_jit     the same unrolled interpreter under jax.jit
  scan_vm        lowered table through the jax.lax.scan VM (default path)
  megakernel     lowered table through the Pallas VM (plane in VMEM)

Trace and compile are timed separately and symmetrically through the AOT
API (``jit(f).lower(args)`` then ``.compile()``, `time.perf_counter`);
first-call/steady-state wall times come from
`benchmarks/common.py:measure_wall` (every call `block_until_ready`).

Acceptance gates (the steady-state one is enforced by CI in BENCH_SMOKE=1
mode): the scan VM's trace+compile must beat the jitted interpreter's by
>= 5x on the 32-bit add, and its steady-state dispatch must not be slower
than the interpreter's. Writes BENCH_vm_dispatch.json at the repo root.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, emit, measure_wall, smoke_mode, \
    write_bench_json
from repro.core import arith_compiler, engine, lowering

ROW_WORDS = 2048            # one 8KB row (65536 bits) per plane
SMOKE_WORDS = 128
GATE_TRACE_SPEEDUP = 5.0    # scan-VM trace+compile vs jitted interpreter
GATE_PROGRAM = "add32"      # acceptance program for the 5x trace gate
SMOKE_GATE_PROGRAM = "add8"  # CI smoke gates steady-state on the 8-bit add


def _programs(smoke: bool):
    cases = [("add8", arith_compiler.ripple_add_program(8)),
             ("sub8", arith_compiler.ripple_sub_program(8)),
             ("add32", arith_compiler.ripple_add_program(32))]
    if smoke:
        # keep add8 (steady-state gate) and add32 (trace/compile gate)
        cases = [c for c in cases if c[0] in ("add8", "add32")]
    return cases


def _aot(fn, *args) -> dict:
    """Trace and compile `jit(fn)` separately (AOT API); returns the times
    plus the jitted callable for steady-state measurement.

    Steady state is measured on the plain jitted callable rather than the
    AOT `compiled` object: an executable lowered from a closure over
    device-resident constants (the opcode table) cannot be invoked with
    the original signature on this jax version.
    """
    jitted = jax.jit(fn)
    t0 = time.perf_counter()
    traced = jitted.lower(*args)
    t1 = time.perf_counter()
    traced.compile()
    t2 = time.perf_counter()
    return {"trace_us": (t1 - t0) * 1e6, "compile_us": (t2 - t1) * 1e6,
            "jitted": jitted}


def run() -> list[Row]:
    smoke = smoke_mode()
    words = SMOKE_WORDS if smoke else ROW_WORDS
    iters = 3 if smoke else 5
    rng = np.random.default_rng(0)
    rows: list[Row] = []
    jrows: list[dict] = []
    gates: dict[str, dict] = {}

    for name, res in _programs(smoke):
        n_bits = len(res.outputs)
        data = {f"X{j}": jnp.asarray(rng.integers(0, 1 << 32, words,
                                                  dtype=np.uint32))
                for j in range(n_bits)}
        data.update({f"Y{j}": jnp.asarray(rng.integers(0, 1 << 32, words,
                                                       dtype=np.uint32))
                     for j in range(n_bits)})
        outs = list(res.outputs)
        prog = res.program
        lp = lowering.lower(prog)
        metrics: dict[str, float] = {}
        values: dict[str, np.ndarray] = {}

        def record(pname, out):
            values[pname] = np.stack([np.asarray(out[o]) for o in outs])

        # interp_eager: per-call micro-op dispatch, as the service ran
        # before the VM existed
        fn = lambda: engine.execute(prog, data, outputs=outs,  # noqa: E731
                                    lowered=False)
        w = measure_wall(fn, iters=iters)
        metrics.update({f"interp_eager_{k[5:]}": v for k, v in w.items()})
        record("interp_eager", fn())

        # interp_jit: the unrolled interpreter's natural jitted form,
        # trace / compile timed via the AOT API
        aot = _aot(lambda d: engine.execute(prog, d, outputs=outs,
                                            lowered=False), data)
        w = measure_wall(aot["jitted"], data, iters=iters)
        metrics["interp_jit_trace_us"] = aot["trace_us"]
        metrics["interp_jit_compile_us"] = aot["compile_us"]
        metrics["interp_jit_steady_us"] = w["wall_steady_us"]
        record("interp_jit", aot["jitted"](data))

        # lowered paths: trace / compile of the PRODUCTION dispatch
        # executable (core.lowering._dispatch), steady-state through
        # engine.execute exactly as the engine/service dispatch it
        for pname, backend in (("scan_vm", "scan"),
                               ("megakernel", "pallas")):
            metrics.update({f"{pname}_{k}": v for k, v in
                            lowering.aot_compile_timings(
                                lp, data, outs, backend).items()})
            fn = lambda: engine.execute(prog, data, outputs=outs,  # noqa
                                        lowered=True, backend=backend)
            w = measure_wall(fn, iters=iters)
            metrics[f"{pname}_steady_us"] = w["wall_steady_us"]
            record(pname, fn())

        for pname in ("interp_jit", "scan_vm", "megakernel"):
            assert np.array_equal(values[pname], values["interp_eager"]), \
                f"{name}/{pname} diverges from the interpreter oracle"

        tc_interp = (metrics["interp_jit_trace_us"]
                     + metrics["interp_jit_compile_us"])
        tc_scan = (metrics["scan_vm_trace_us"]
                   + metrics["scan_vm_compile_us"])
        trace_speedup = tc_interp / tc_scan
        steady_speedup = (metrics["interp_eager_steady_us"]
                          / metrics["scan_vm_steady_us"])
        gates[name] = {"trace_speedup": trace_speedup,
                       "steady_speedup": steady_speedup}
        rows.append((
            f"vm_dispatch/{name}", metrics["scan_vm_steady_us"],
            f"cmds={lp.n_cmds} rows={lp.n_rows} "
            f"trace_compile_x={trace_speedup:.1f} "
            f"steady_x={steady_speedup:.1f} "
            f"mega_steady_us={metrics['megakernel_steady_us']:.0f} "
            f"bit_identity=yes"))
        jrows.append({
            "name": f"vm_dispatch/{name}",
            "bytes": words * 4 * n_bits,
            "n_cmds": lp.n_cmds,
            "n_rows": lp.n_rows,
            "row_words": words,
            "trace_compile_speedup": trace_speedup,
            "steady_speedup_vs_eager": steady_speedup,
            **{k: round(v, 1) for k, v in metrics.items()},
        })

    write_bench_json("vm_dispatch", jrows)

    # acceptance gates: trace/compile O(1) must pay off >=5x on the 32-bit
    # add; lowered steady-state must never lose to the interpreter
    if not smoke and GATE_PROGRAM in gates:
        t = gates[GATE_PROGRAM]["trace_speedup"]
        assert t >= GATE_TRACE_SPEEDUP, (
            f"{GATE_PROGRAM}: scan-VM trace+compile only {t:.1f}x faster "
            f"than the unrolled interpreter (need >= {GATE_TRACE_SPEEDUP}x)")
    gate_prog = SMOKE_GATE_PROGRAM if smoke else GATE_PROGRAM
    s = gates[gate_prog]["steady_speedup"]
    assert s >= 1.0, (
        f"{gate_prog}: lowered steady-state dispatch is SLOWER than the "
        f"interpreter ({s:.2f}x) — the VM lost its reason to exist")
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
