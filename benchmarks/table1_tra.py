"""Table 1: TRA latency/reliability under process variation (SPICE-lite).

Derived column: modeled latency per case/variation vs the paper's value,
plus Monte-Carlo failure rates at increasing variation sigma.
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, emit, time_call
from repro.core import spice

PAPER = {
    "0s0w0w": [16.4, 16.3, 16.3, 16.4, 16.3, 16.2],
    "1s0w0w": [18.3, 18.6, 18.8, 19.1, 19.7, None],
    "0s1w1w": [24.9, 25.0, 25.2, 25.3, 25.4, 25.7],
    "1s1w1w": [22.5, 22.3, 22.2, 22.2, 22.2, 22.1],
}


def run() -> list[Row]:
    rows: list[Row] = []
    t = spice.table1()
    for case, entries in t.items():
        cells = []
        for (v, e), pv in zip(entries.items(), PAPER[case]):
            got = "FAIL" if e["fails"] else f"{e['latency_ns']:.1f}"
            ref = "FAIL" if pv is None else f"{pv}"
            cells.append(f"{int(v * 100)}%:{got}(paper {ref})")
        rows.append((f"table1/{case}", 0.0, " ".join(cells)))

    for sigma in (0.02, 0.06, 0.10, 0.25):
        us = time_call(spice.monte_carlo_tra, jax.random.PRNGKey(0),
                       100_000, sigma, iters=3)
        mc = spice.monte_carlo_tra(jax.random.PRNGKey(0), 100_000, sigma)
        rows.append((f"table1/montecarlo_sigma={sigma}", us,
                     f"fail_rate={float(mc['failure_rate']):.2e} "
                     f"mean_lat={float(mc['mean_latency_ns']):.1f}ns"))
    return rows


if __name__ == "__main__":
    emit(run(), header=True)
