"""Seeded-random stand-in for `hypothesis` when it is not installed.

The property-test modules use a small, fixed subset of the hypothesis API:
`given`, `settings`, and the strategies `integers`, `booleans`, `lists`,
`sampled_from`, `data` (plus `.map`). This module re-implements exactly that
subset over a deterministically seeded numpy Generator, so the core
invariants still execute as plain example-based tests in environments
without hypothesis (no shrinking, no adaptive search — just N seeded random
examples per test, reproducible across runs).

Usage (in the test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st
"""
from __future__ import annotations

import types
import zlib

import numpy as np

# Cap fallback example counts: hypothesis's own max_examples is tuned for its
# fast C-backed generation; the simple fallback keeps suites quick.
_MAX_FALLBACK_EXAMPLES = 5


class _Strategy:
    """A draw function wrapper supporting .map (the only combinator used)."""

    def __init__(self, draw):
        self._draw = draw

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))


def _integers(min_value=0, max_value=(1 << 32) - 1):
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def _lists(elements, min_size=0, max_size=10):
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements._draw(rng) for _ in range(size)]

    return _Strategy(draw)


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


class _DataObject:
    """Interactive draw handle (the `st.data()` strategy)."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy._draw(self._rng)


def _data():
    return _Strategy(lambda rng: _DataObject(rng))


strategies = types.SimpleNamespace(
    integers=_integers,
    booleans=_booleans,
    lists=_lists,
    sampled_from=_sampled_from,
    data=_data,
)


def settings(max_examples=20, deadline=None, **_kw):
    """Records max_examples on the (already `given`-wrapped) test."""

    def deco(fn):
        fn._fallback_max_examples = min(max_examples, _MAX_FALLBACK_EXAMPLES)
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Runs the test once per seeded example with drawn arguments."""

    def deco(fn):
        # No functools.wraps: it would expose the original signature via
        # __wrapped__ and pytest would demand fixtures for the drawn params.
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples",
                        _MAX_FALLBACK_EXAMPLES)
            base = zlib.crc32(fn.__name__.encode())
            for example in range(n):
                rng = np.random.default_rng((base, example))
                drawn = [s._draw(rng) for s in arg_strategies]
                drawn_kw = {k: s._draw(rng)
                            for k, s in kw_strategies.items()}
                fn(*drawn, **drawn_kw)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
