"""Expression compiler: arbitrary DAGs lower to correct AAP programs with
CSE + dead-store elimination."""
import numpy as np
import pytest

from repro.core import compiler, engine
from repro.core.compiler import Expr, maj

RNG = np.random.default_rng(7)
W = 16


def rows(n):
    return {f"D{i}": RNG.integers(0, 2**32, W, dtype=np.uint32) for i in range(n)}


def run(expr, data):
    res = compiler.compile_expr(expr, "OUT")
    out = engine.execute(res.program, data, outputs=["OUT"])["OUT"]
    return np.asarray(out), res


def test_simple_ops_via_expr():
    data = rows(2)
    a, b = Expr.of("D0"), Expr.of("D1")
    for e, oracle in [
        (a & b, data["D0"] & data["D1"]),
        (a | b, data["D0"] | data["D1"]),
        (a ^ b, data["D0"] ^ data["D1"]),
        (~a, ~data["D0"]),
    ]:
        out, _ = run(e, data)
        np.testing.assert_array_equal(out, oracle)


def test_nested_expression():
    data = rows(4)
    a, b, c, d = (Expr.of(f"D{i}") for i in range(4))
    expr = (a & b) | ~(c ^ d)
    out, _ = run(expr, data)
    oracle = (data["D0"] & data["D1"]) | ~(data["D2"] ^ data["D3"])
    np.testing.assert_array_equal(out, oracle)


def test_majority_expr():
    data = rows(3)
    a, b, c = (Expr.of(f"D{i}") for i in range(3))
    out, _ = run(maj(a, b, c), data)
    A, B, C = data["D0"], data["D1"], data["D2"]
    np.testing.assert_array_equal(out, (A & B) | (B & C) | (C & A))


def test_cse_shares_subexpressions():
    data = rows(2)
    a, b = Expr.of("D0"), Expr.of("D1")
    shared = a & b
    expr = (shared ^ a) | (shared ^ b)
    out, res = run(expr, data)
    A, B = data["D0"], data["D1"]
    np.testing.assert_array_equal(out, ((A & B) ^ A) | ((A & B) ^ B))
    # CSE: (a&b) computed once -> program has exactly one 'and' four-AAP block
    # Total: and(4) + xor(7) + xor(7) + or(4) = 22 AAP-ish commands; without
    # CSE the and would appear twice (+4).
    n_cmds = len(res.program.commands)
    assert n_cmds <= 22, f"CSE failed: {n_cmds} commands"


def test_dead_store_elim_writes_root_directly():
    data = rows(2)
    expr = Expr.of("D0") & Expr.of("D1")
    res = compiler.compile_expr(expr, "OUT")
    # root materialized straight into OUT: last command's target addr is OUT
    last = res.program.commands[-1]
    assert last.addr2 == "OUT"
    # and no temp rows were needed at all
    assert res.n_temp_rows == 0


def test_temp_recycling():
    data = rows(8)
    es = [Expr.of(f"D{i}") for i in range(8)]
    # balanced tree of ands: ((0&1)&(2&3)) & ((4&5)&(6&7))
    expr = ((es[0] & es[1]) & (es[2] & es[3])) & ((es[4] & es[5]) & (es[6] & es[7]))
    out, res = run(expr, data)
    oracle = data["D0"]
    for i in range(1, 8):
        oracle = oracle & data[f"D{i}"]
    np.testing.assert_array_equal(out, oracle)
    # naive would allocate 6 temps; recycling should keep it to <= 3
    assert res.n_temp_rows <= 3


def test_aap_counts_match_paper():
    """Fig. 8 command counts: and/or=4 AAP, nand/nor=5 AAP, not=2 AAP,
    xor/xnor=5 AAP + 2 AP."""
    for op, (naap, nap) in {
        "and": (4, 0), "or": (4, 0), "nand": (5, 0), "nor": (5, 0),
        "xor": (5, 2), "xnor": (5, 2),
    }.items():
        p = compiler.op_program(op, ["D0", "D1"], "D2")
        assert (p.n_aap, p.n_ap) == (naap, nap), op
    p = compiler.op_program("not", ["D0"], "D1")
    assert (p.n_aap, p.n_ap) == (2, 0)
