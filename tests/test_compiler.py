"""Expression compiler: arbitrary DAGs lower to correct AAP programs with
CSE + dead-store elimination, and the fusion pass emits strictly smaller
programs with bit-identical semantics."""
import numpy as np

from repro.core import compiler, engine
from repro.core.compiler import Expr, compile_expr_fused, fuse_expr, maj

RNG = np.random.default_rng(7)
W = 16


def rows(n):
    return {f"D{i}": RNG.integers(0, 2**32, W, dtype=np.uint32) for i in range(n)}


def run(expr, data):
    res = compiler.compile_expr(expr, "OUT")
    out = engine.execute(res.program, data, outputs=["OUT"])["OUT"]
    return np.asarray(out), res


def test_simple_ops_via_expr():
    data = rows(2)
    a, b = Expr.of("D0"), Expr.of("D1")
    for e, oracle in [
        (a & b, data["D0"] & data["D1"]),
        (a | b, data["D0"] | data["D1"]),
        (a ^ b, data["D0"] ^ data["D1"]),
        (~a, ~data["D0"]),
    ]:
        out, _ = run(e, data)
        np.testing.assert_array_equal(out, oracle)


def test_nested_expression():
    data = rows(4)
    a, b, c, d = (Expr.of(f"D{i}") for i in range(4))
    expr = (a & b) | ~(c ^ d)
    out, _ = run(expr, data)
    oracle = (data["D0"] & data["D1"]) | ~(data["D2"] ^ data["D3"])
    np.testing.assert_array_equal(out, oracle)


def test_majority_expr():
    data = rows(3)
    a, b, c = (Expr.of(f"D{i}") for i in range(3))
    out, _ = run(maj(a, b, c), data)
    A, B, C = data["D0"], data["D1"], data["D2"]
    np.testing.assert_array_equal(out, (A & B) | (B & C) | (C & A))


def test_cse_shares_subexpressions():
    data = rows(2)
    a, b = Expr.of("D0"), Expr.of("D1")
    shared = a & b
    expr = (shared ^ a) | (shared ^ b)
    out, res = run(expr, data)
    A, B = data["D0"], data["D1"]
    np.testing.assert_array_equal(out, ((A & B) ^ A) | ((A & B) ^ B))
    # CSE: (a&b) computed once -> program has exactly one 'and' four-AAP block
    # Total: and(4) + xor(7) + xor(7) + or(4) = 22 AAP-ish commands; without
    # CSE the and would appear twice (+4).
    n_cmds = len(res.program.commands)
    assert n_cmds <= 22, f"CSE failed: {n_cmds} commands"


def test_dead_store_elim_writes_root_directly():
    expr = Expr.of("D0") & Expr.of("D1")
    res = compiler.compile_expr(expr, "OUT")
    # root materialized straight into OUT: last command's target addr is OUT
    last = res.program.commands[-1]
    assert last.addr2 == "OUT"
    # and no temp rows were needed at all
    assert res.n_temp_rows == 0


def test_temp_recycling():
    data = rows(8)
    es = [Expr.of(f"D{i}") for i in range(8)]
    # balanced tree of ands: ((0&1)&(2&3)) & ((4&5)&(6&7))
    expr = ((es[0] & es[1]) & (es[2] & es[3])) & ((es[4] & es[5]) & (es[6] & es[7]))
    out, res = run(expr, data)
    oracle = data["D0"]
    for i in range(1, 8):
        oracle = oracle & data[f"D{i}"]
    np.testing.assert_array_equal(out, oracle)
    # naive would allocate 6 temps; recycling should keep it to <= 3
    assert res.n_temp_rows <= 3


def test_aap_counts_match_paper():
    """Fig. 8 command counts: and/or=4 AAP, nand/nor=5 AAP, not=2 AAP,
    xor/xnor=5 AAP + 2 AP."""
    for op, (naap, nap) in {
        "and": (4, 0), "or": (4, 0), "nand": (5, 0), "nor": (5, 0),
        "xor": (5, 2), "xnor": (5, 2),
    }.items():
        p = compiler.op_program(op, ["D0", "D1"], "D2")
        assert (p.n_aap, p.n_ap) == (naap, nap), op
    p = compiler.op_program("not", ["D0"], "D1")
    assert (p.n_aap, p.n_ap) == (2, 0)


# ---------------------------------------------------------------------------
# fusion pass
# ---------------------------------------------------------------------------


def _random_exprs(n_rows=4):
    """A zoo of composite DAGs over D0..D{n-1} with their jnp oracles."""
    es = [Expr.of(f"D{i}") for i in range(n_rows)]
    a, b, c, d = es

    def o(data):
        return [data[f"D{i}"] for i in range(n_rows)]

    return [
        (~(a ^ b), lambda A, B, C, D: ~(A ^ B)),
        ((a & b) | (b & c) | (c & a), lambda A, B, C, D: (A & B) | (B & C) | (C & A)),
        (a & ~b, lambda A, B, C, D: A & ~B),
        (~(a & b), lambda A, B, C, D: ~(A & B)),
        (~a & ~b, lambda A, B, C, D: ~(A | B)),
        ((a & ~b) | (~a & b), lambda A, B, C, D: A ^ B),
        ((a & b) | (~a & ~b), lambda A, B, C, D: ~(A ^ B)),
        (((a & b) | ~(c ^ d)) ^ (a | ~d),
         lambda A, B, C, D: ((A & B) | ~(C ^ D)) ^ (A | ~D)),
        (~~~(a | (b & ~c)), lambda A, B, C, D: ~(A | (B & ~C))),
        (maj(a ^ b, b | c, ~d), lambda A, B, C, D:
         ((A ^ B) & (B | C)) | ((B | C) & ~D) | (~D & (A ^ B))),
    ]


def test_fused_equals_unfused_on_random_inputs():
    """Regression: fusion must never change semantics."""
    rng = np.random.default_rng(123)
    for trial in range(3):
        data = {f"D{i}": rng.integers(0, 2**32, W, dtype=np.uint32)
                for i in range(4)}
        A, B, C, D = (data[f"D{i}"] for i in range(4))
        for expr, oracle in _random_exprs():
            r_u = compiler.compile_expr(expr, "OUT")
            r_f = compile_expr_fused(expr, "OUT")
            out_u = np.asarray(
                engine.execute(r_u.program, data, outputs=["OUT"])["OUT"])
            out_f = np.asarray(
                engine.execute(r_f.program, data, outputs=["OUT"])["OUT"])
            np.testing.assert_array_equal(out_f, out_u)
            np.testing.assert_array_equal(out_f, oracle(A, B, C, D))
            assert len(r_f.program.commands) <= len(r_u.program.commands)


def test_fusion_strictly_fewer_commands_xnor_and_maj3():
    """Acceptance: fused < unfused for xnor and maj3 composite forms."""
    a, b, c = Expr.of("D0"), Expr.of("D1"), Expr.of("D2")
    xnor = ~(a ^ b)
    r_u, r_f = compiler.compile_expr(xnor, "OUT"), compile_expr_fused(xnor, "OUT")
    assert len(r_f.program.commands) < len(r_u.program.commands)
    assert r_f.program.n_aap < r_u.program.n_aap
    # fused xnor is exactly the Fig. 8 primitive: 5 AAP + 2 AP
    assert (r_f.program.n_aap, r_f.program.n_ap) == (5, 2)

    maj3 = (a & b) | (b & c) | (c & a)
    r_u, r_f = compiler.compile_expr(maj3, "OUT"), compile_expr_fused(maj3, "OUT")
    assert len(r_f.program.commands) < len(r_u.program.commands)
    # fused majority is one native TRA program: 4 AAPs
    assert (r_f.program.n_aap, r_f.program.n_ap) == (4, 0)


def test_fuse_expr_rewrites():
    a, b, c = Expr.of("D0"), Expr.of("D1"), Expr.of("D2")
    assert fuse_expr(~(a ^ b)).op == "xnor"
    assert fuse_expr(~(a & b)).op == "nand"
    assert fuse_expr(~(a | b)).op == "nor"
    assert fuse_expr(~~a).op == "row"
    assert fuse_expr(a & ~b).op == "andnot"
    assert fuse_expr(~a & ~b).op == "nor"
    assert fuse_expr(~a | ~b).op == "nand"
    assert fuse_expr((a & ~b) | (~a & b)).op == "xor"
    assert fuse_expr((a & b) | (~a & ~b)).op == "xnor"
    m = fuse_expr((a & b) | (b & c) | (c & a))
    assert m.op == "maj3" and len(m.args) == 3
    # non-majority 3-term or must NOT collapse
    nm = fuse_expr((a & b) | (b & c) | (a & b))
    assert nm.op != "maj3"


def test_algebraic_simplification_to_single_copy():
    """Regression (issue 3): a & a and a | (a & b) are 1-AAP copies of a."""
    a, b = Expr.of("D0"), Expr.of("D1")
    for expr in (a & a, a | a, a | (a & b), (a & b) | a,
                 a & (a | b), (a | b) & a, a | (b & a)):
        r = compile_expr_fused(expr, "OUT")
        assert len(r.program.commands) == 1, expr
        assert (r.program.n_aap, r.program.n_ap) == (1, 0), expr
        assert r.n_temp_rows == 0
        data = rows(2)
        out = np.asarray(
            engine.execute(r.program, data, outputs=["OUT"])["OUT"])
        np.testing.assert_array_equal(out, data["D0"])


def test_algebraic_simplification_rewrites():
    a, b, c = Expr.of("D0"), Expr.of("D1"), Expr.of("D2")
    assert fuse_expr(a & a).op == "row"
    assert fuse_expr(a | a).op == "row"
    assert fuse_expr(a | (a & b)).op == "row"
    assert fuse_expr(a & (a | b)).op == "row"
    # post-fusion andnot spelling of absorption: a | (a & ~b) = a
    assert fuse_expr(a | (a & ~b)).op == "row"
    # nested: absorption exposes idempotence one level up
    assert fuse_expr((a | (a & b)) & a).op == "row"
    # shrink rules compose with the primitive rewrites
    assert fuse_expr(~(a | (a & b))).op == "not"
    assert fuse_expr(((a & b) | (a & b)) | c).op == "or"
    # non-matching shapes must survive: a | (b & c) is irreducible
    assert fuse_expr(a | (b & c)).op == "or"
    # a | (~a & b) is NOT absorption (simplifies to a | b, a different DAG;
    # we only apply the shrink-to-operand laws)
    assert fuse_expr(a | (~a & b)).op == "or"


def test_simplified_exprs_bit_identical_and_never_longer():
    """The never-more-AAPs-than-unfused invariant holds on shrink forms."""
    rng = np.random.default_rng(42)
    a, b, c = Expr.of("D0"), Expr.of("D1"), Expr.of("D2")
    cases = [
        (a & a, lambda A, B, C: A & A),
        (a | (a & b), lambda A, B, C: A | (A & B)),
        (a & (a | b), lambda A, B, C: A & (A | B)),
        ((a ^ b) | ((a ^ b) & c), lambda A, B, C: (A ^ B) | ((A ^ B) & C)),
        ((a & a) ^ b, lambda A, B, C: A ^ B),
        (maj(a | a, b, c), lambda A, B, C: (A & B) | (B & C) | (C & A)),
    ]
    for trial in range(3):
        data = {f"D{i}": rng.integers(0, 2**32, W, dtype=np.uint32)
                for i in range(3)}
        A, B, C = (data[f"D{i}"] for i in range(3))
        for expr, oracle in cases:
            r_u = compiler.compile_expr(expr, "OUT")
            r_f = compile_expr_fused(expr, "OUT")
            assert len(r_f.program.commands) <= len(r_u.program.commands)
            out = np.asarray(
                engine.execute(r_f.program, data, outputs=["OUT"])["OUT"])
            np.testing.assert_array_equal(out, oracle(A, B, C))


def test_peephole_forwards_dead_temps():
    """Chained ops route intermediates through B-group rows directly."""
    a, b, c = Expr.of("D0"), Expr.of("D1"), Expr.of("D2")
    expr = (a & b) & c
    r_u = compiler.compile_expr(expr, "OUT")
    r_f = compile_expr_fused(expr, "OUT")
    assert len(r_f.program.commands) < len(r_u.program.commands)
    assert r_f.n_temp_rows == 0  # the D-group round-trip disappeared
    data = {f"D{i}": np.arange(W, dtype=np.uint32) * (i + 3) for i in range(3)}
    out = np.asarray(engine.execute(r_f.program, data, outputs=["OUT"])["OUT"])
    oracle = data["D0"] & data["D1"] & data["D2"]
    np.testing.assert_array_equal(out, oracle)


def test_andnot_program_semantics():
    data = {"D0": RNG.integers(0, 2**32, W, dtype=np.uint32),
            "D1": RNG.integers(0, 2**32, W, dtype=np.uint32)}
    prog = compiler.op_program("andnot", ["D0", "D1"], "D2")
    out = engine.execute(prog, data, outputs=["D2"])["D2"]
    np.testing.assert_array_equal(np.asarray(out), data["D0"] & ~data["D1"])
    # sources preserved
    rows_after = engine.execute(prog, data)
    np.testing.assert_array_equal(np.asarray(rows_after["D0"]), data["D0"])
    np.testing.assert_array_equal(np.asarray(rows_after["D1"]), data["D1"])


def test_fused_range_scan_matches_ref():
    """Multi-term predicate DAGs (ops.predicate) compile fused + correct."""
    from repro.kernels import ref
    from repro.ops.predicate import compile_range_scan, range_scan_expr

    rng = np.random.default_rng(5)
    n_bits, n = 6, 64
    vals = rng.integers(0, 1 << n_bits, (n,), dtype=np.uint32)
    planes = np.asarray(ref.bit_transpose(vals, n_bits))
    data = {f"P{j}": planes[j] for j in range(n_bits)}
    for lo, hi in [(0, 63), (5, 40), (17, 17), (40, 5)]:
        r_f = compile_range_scan(n_bits, lo, hi)
        r_u = compiler.compile_expr(range_scan_expr(n_bits, lo, hi), "OUT")
        assert len(r_f.program.commands) <= len(r_u.program.commands)
        out = np.asarray(
            engine.execute(r_f.program, data, outputs=["OUT"])["OUT"])
        np.testing.assert_array_equal(
            out, np.asarray(ref.bitweaving_scan(planes, lo, hi, n_bits)))
