"""Property tests on model-level invariants.

Runs under hypothesis when available; otherwise falls back to seeded-random
example generation (`_hypothesis_fallback`) so the invariants are always
exercised.
"""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config, reduced
from repro.models import build
from repro.models.layers import chunked_attention
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)


@settings(max_examples=8, deadline=None)
@given(split=st.integers(min_value=4, max_value=28),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_causality_prefix_logits_invariant(split, seed):
    """Causal LM: logits at position split-1 must not depend on any token at
    positions >= split (checked via full prefill with perturbed suffix)."""
    cfg = reduced(get_config("qwen3_0p6b"))
    bundle = build(cfg)
    params = bundle.init(KEY)
    key = jax.random.PRNGKey(seed)
    S = 32
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    toks2 = toks.at[:, split:].set(
        jax.random.randint(jax.random.fold_in(key, 1), (1, S - split), 0,
                           cfg.vocab_size))
    # prefill over the prefix only gives the reference next-token logits
    ref, _ = jax.jit(bundle.prefill)(params, {"tokens": toks[:, :split]})
    got, _ = jax.jit(bundle.prefill)(params, {"tokens": toks2[:, :split]})
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(got, np.float32), atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       qc=st.sampled_from([8, 16, 32]),
       kc=st.sampled_from([8, 16, 32]))
def test_chunked_attention_chunk_size_invariant(seed, qc, kc):
    """Online-softmax output must not depend on the chunking schedule."""
    key = jax.random.PRNGKey(seed)
    B, S, H, KV, hd = 1, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    ref = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    got = chunked_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       chunk=st.sampled_from([4, 8, 16, 64]))
def test_ssd_chunk_size_invariant(seed, chunk):
    """Chunked SSD must be exact w.r.t. the chunk size (it's an algebraic
    re-association of the same linear recurrence)."""
    key = jax.random.PRNGKey(seed)
    B, S, H, P, N = 1, 32, 2, 4, 4
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    a_log = jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N))
    y_ref, s_ref = ssd_chunked(x, dt, a_log, Bm, Cm, chunk=32)
    y, s = ssd_chunked(x, dt, a_log, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s),
                               rtol=2e-2, atol=2e-2)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16))
def test_loss_mask_zero_positions_ignored(seed):
    """Masked label positions must not change the loss."""
    cfg = reduced(get_config("qwen3_0p6b"))
    bundle = build(cfg)
    params = bundle.init(KEY)
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    mask = jnp.ones((2, 16), jnp.float32).at[:, -4:].set(0.0)
    labels1 = jax.random.randint(jax.random.fold_in(key, 1), (2, 16), 0,
                                 cfg.vocab_size)
    labels2 = labels1.at[:, -4:].set(
        jax.random.randint(jax.random.fold_in(key, 2), (2, 4), 0,
                           cfg.vocab_size))
    l1, _ = jax.jit(bundle.loss)(params, {"tokens": toks, "labels": labels1,
                                          "mask": mask})
    l2, _ = jax.jit(bundle.loss)(params, {"tokens": toks, "labels": labels2,
                                          "mask": mask})
    assert abs(float(l1) - float(l2)) < 1e-5
