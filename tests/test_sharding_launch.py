"""Logical sharding resolution, cell construction, and (subprocess) the
multi-device distributed pieces: majority all-reduce, compressed train step,
reduced-config cell lowering on an 8-device host mesh."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (DEFAULT_RULES, constrain, resolve_spec,
    strip_axes)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the subprocess tests drive explicit-sharding APIs (jax.sharding.AxisType,
# jax.shard_map) that this container's JAX does not ship yet
requires_new_jax = pytest.mark.skipif(
    not (hasattr(jax.sharding, "AxisType") and hasattr(jax, "shard_map")),
    reason="needs jax>=0.6 (jax.sharding.AxisType / jax.shard_map)")


class FakeMesh:
    """Just enough mesh interface for resolve_spec (axis names + shape)."""

    def __init__(self, **axes):
        import numpy as _np
        self.axis_names = tuple(axes)
        self.devices = _np.empty(tuple(axes.values()), object)


def test_resolve_spec_basic():
    m = FakeMesh(data=16, model=16)
    assert resolve_spec((256, 4096), ("batch", "seq"), m, DEFAULT_RULES) \
        == P("data", None)
    assert resolve_spec((8192, 16384), ("fsdp", "mlp"), m, DEFAULT_RULES) \
        == P("data", "model")


def test_resolve_spec_multi_axis_batch():
    m = FakeMesh(pod=2, data=16, model=16)
    spec = resolve_spec((256, 128), ("batch", None), m, DEFAULT_RULES)
    assert spec == P(("pod", "data"), None)


def test_resolve_spec_divisibility_fallback():
    m = FakeMesh(data=16, model=16)
    # kv_heads=8 cannot shard 16 ways -> replicated
    assert resolve_spec((1024, 8, 128), ("fsdp", "kv_heads", "head_dim"),
                        m, DEFAULT_RULES) == P("data", None, None)
    # batch=1 (long_500k decode) -> replicated
    assert resolve_spec((1, 524288), ("batch", "seq"), m,
                        DEFAULT_RULES) == P(None, None)
    # kv_flat=1024 divides 16
    assert resolve_spec((32, 1024), (None, "kv_flat"), m,
                        DEFAULT_RULES) == P(None, "model")


def test_resolve_spec_no_axis_reuse():
    m = FakeMesh(data=4, model=4)
    # two logical names mapping to "model": second one must NOT reuse it
    spec = resolve_spec((64, 64), ("heads", "mlp"), m, DEFAULT_RULES)
    assert spec == P("model", None)


def test_strip_axes():
    rules = strip_axes(DEFAULT_RULES, ("data", "pod"))
    assert rules["batch"] == ()
    assert rules["vocab"] == ("model",)


def test_constrain_identity_outside_context():
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)
    assert y is x


def test_param_spec_trees_cover_all_leaves():
    """Every param leaf of every arch has a logical spec of matching rank."""
    from repro.configs.base import ARCH_IDS, get_config, reduced
    from repro.models import build
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        shapes, specs = build(cfg).abstract()
        flat_p = jax.tree.leaves(shapes)
        flat_s = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, tuple))
        assert len(flat_p) == len(flat_s), arch
        for p, s in zip(flat_p, flat_s):
            assert len(s) == p.ndim, (arch, p.shape, s)


def test_full_config_abstract_no_alloc():
    """abstract() on the FULL kimi-k2 1T config must not allocate."""
    from repro.configs.base import get_config
    from repro.models import build
    shapes, specs = build(get_config("kimi_k2_1t_a32b")).abstract()
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    assert n > 0.9e12   # ~1T params


def test_input_specs_all_cells():
    from repro.configs.base import SHAPES, cells, get_config
    from repro.models import input_specs
    for arch, shape in cells():
        cfg = get_config(arch)
        specs = input_specs(cfg, SHAPES[shape])
        assert all(hasattr(l, "shape")
                   for l in jax.tree.leaves(specs)), (arch, shape)


_SUBPROC_CELL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {repo!r} + "/src")
    import jax
    from repro.configs.base import ShapeConfig
    from repro.launch.cells import build_cell
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    shape = ShapeConfig("t", 64, 8, {kind!r})
    cell = build_cell({arch!r}, "train_4k", mesh, reduce_config=True,
                      shape_override=shape)
    compiled = cell.lower().compile()
    print("COMPILED_OK", compiled.cost_analysis() is not None)
""")


@requires_new_jax
@pytest.mark.parametrize("arch,kind", [("qwen3_0p6b", "train"),
                                       ("mamba2_1p3b", "decode"),
                                       ("kimi_k2_1t_a32b", "train")])
def test_cell_lowers_on_host_mesh(arch, kind):
    """Reduced-config cells lower+compile on an 8-device host mesh (the
    full-size 512-device version is exercised by launch/dryrun.py)."""
    code = _SUBPROC_CELL.format(repo=REPO, arch=arch, kind=kind)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420)
    assert "COMPILED_OK" in r.stdout, r.stderr[-2000:]


@requires_new_jax
def test_majority_allreduce_subprocess():
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {REPO!r} + "/src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.signum import majority_allreduce, pack_tree, unpack_tree
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        D = 8
        xs = jax.random.normal(jax.random.PRNGKey(0), (D, 333))
        def worker(x):
            packed, meta = pack_tree({{"g": x[0]}}, use_kernel=False)
            agg = majority_allreduce(packed, "data", use_kernel=False)
            return unpack_tree(agg, meta, use_kernel=False)["g"][None]
        f = jax.jit(jax.shard_map(worker, mesh=mesh, in_specs=P("data"),
                                  out_specs=P("data"), axis_names={{"data"}},
                                  check_vma=False))
        out = np.asarray(f(xs))
        neg = (np.asarray(xs) < 0).sum(0)
        expect = np.where(neg * 2 > D, -1.0, 1.0)
        for d in range(D):
            assert np.array_equal(out[d], expect), d
        print("MAJORITY_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300)
    assert "MAJORITY_OK" in r.stdout, r.stderr[-2000:]
