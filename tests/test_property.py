"""Property tests on the system's core invariants.

Runs under hypothesis when available; otherwise falls back to seeded-random
example generation (`_hypothesis_fallback`) so the invariants are always
exercised.
"""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import compiler, engine
from repro.core.bitplane import pack_bits, unpack_bits
from repro.core.compiler import Expr, maj
from repro.kernels import ref

words_st = st.integers(min_value=0, max_value=2**32 - 1)


def row_st(n=8):
    return st.lists(words_st, min_size=n, max_size=n).map(
        lambda xs: np.asarray(xs, np.uint32))


@settings(max_examples=30, deadline=None)
@given(row_st(), row_st())
def test_engine_equals_jnp_all_ops(a, b):
    """Every Fig. 8 AAP program == the corresponding word-level op."""
    oracles = {"and": a & b, "or": a | b, "xor": a ^ b,
               "nand": ~(a & b), "nor": ~(a | b), "xnor": ~(a ^ b)}
    for op, exp in oracles.items():
        prog = compiler.op_program(op, ["D0", "D1"], "D2")
        out = engine.execute(prog, {"D0": a, "D1": b}, outputs=["D2"])["D2"]
        np.testing.assert_array_equal(np.asarray(out), exp, err_msg=op)


@settings(max_examples=30, deadline=None)
@given(row_st(), row_st(), row_st())
def test_tra_majority_identity(a, b, c):
    """TRA's defining identity: MAJ(A,B,C) = C(A+B) + notC(AB) (paper §3.1)."""
    maj_ = (a & b) | (b & c) | (c & a)
    rewritten = (c & (a | b)) | (~c & (a & b))
    np.testing.assert_array_equal(maj_, rewritten)
    prog = compiler.op_program("maj3", ["D0", "D1", "D2"], "D3")
    out = engine.execute(prog, {"D0": a, "D1": b, "D2": c}, outputs=["D3"])["D3"]
    np.testing.assert_array_equal(np.asarray(out), maj_)


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_compiled_expression_equals_numpy(data):
    """Random expression DAGs: compiler+engine == direct numpy evaluation."""
    n_leaves = data.draw(st.integers(2, 5))
    leaves = {f"D{i}": data.draw(row_st()) for i in range(n_leaves)}

    def gen_expr(depth):
        if depth == 0 or data.draw(st.booleans()):
            name = data.draw(st.sampled_from(sorted(leaves)))
            return Expr.of(name), leaves[name]
        op = data.draw(st.sampled_from(["and", "or", "xor", "not", "maj"]))
        if op == "not":
            e, v = gen_expr(depth - 1)
            return ~e, ~v
        if op == "maj":
            e1, v1 = gen_expr(depth - 1)
            e2, v2 = gen_expr(depth - 1)
            e3, v3 = gen_expr(depth - 1)
            return maj(e1, e2, e3), (v1 & v2) | (v2 & v3) | (v3 & v1)
        e1, v1 = gen_expr(depth - 1)
        e2, v2 = gen_expr(depth - 1)
        if op == "and":
            return e1 & e2, v1 & v2
        if op == "or":
            return e1 | e2, v1 | v2
        return e1 ^ e2, v1 ^ v2

    expr, expected = gen_expr(3)
    res = compiler.compile_expr(expr, "OUT")
    out = engine.execute(res.program, leaves, outputs=["OUT"])["OUT"]
    np.testing.assert_array_equal(np.asarray(out), expected)
    # sources never modified
    post = engine.execute(res.program, leaves)
    for name, val in leaves.items():
        np.testing.assert_array_equal(np.asarray(post[name]), val)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=200))
def test_pack_unpack_roundtrip_property(bits):
    arr = np.asarray(bits, bool)
    packed = pack_bits(jnp.asarray(arr))
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(packed, len(bits))), arr)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 9), st.integers(0, 2**32 - 1))
def test_majority_k_threshold_properties(k, seed):
    """majority(planes, t) is monotone in t; t=1 == OR; t=k == AND."""
    rng = np.random.default_rng(seed)
    planes = jnp.asarray(rng.integers(0, 2**32, (k, 4), dtype=np.uint32))
    all_or = np.asarray(ref.majority_k(planes, threshold=1))
    all_and = np.asarray(ref.majority_k(planes, threshold=k))
    acc_or = np.zeros(4, np.uint32)
    acc_and = np.full(4, 0xFFFFFFFF, np.uint32)
    for p in np.asarray(planes):
        acc_or |= p
        acc_and &= p
    np.testing.assert_array_equal(all_or, acc_or)
    np.testing.assert_array_equal(all_and, acc_and)
    prev = all_or
    for t in range(2, k + 1):
        cur = np.asarray(ref.majority_k(planes, threshold=t))
        assert (cur & ~prev).sum() == 0  # monotone: t up -> bits only drop
        prev = cur


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 16), st.integers(0, 2**31 - 1))
def test_bitweaving_scan_property(n_bits, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 2**n_bits, 64, dtype=np.uint64).astype(np.uint32)
    lo = int(rng.integers(0, 2**n_bits))
    hi = int(rng.integers(0, 2**n_bits))
    planes = ref.bit_transpose(jnp.asarray(vals), n_bits)
    got = np.asarray(unpack_bits(
        ref.bitweaving_scan(planes, lo, hi, n_bits), 64))
    np.testing.assert_array_equal(got, (vals >= lo) & (vals <= hi))
