"""Charge-sharing model must reproduce Table 1's structure and values."""
import jax
import pytest

from repro.core import spice

PAPER_TABLE1 = {
    "0s0w0w": [16.4, 16.3, 16.3, 16.4, 16.3, 16.2],
    "1s0w0w": [18.3, 18.6, 18.8, 19.1, 19.7, None],  # None = Fail
    "0s1w1w": [24.9, 25.0, 25.2, 25.3, 25.4, 25.7],
    "1s1w1w": [22.5, 22.3, 22.2, 22.2, 22.2, 22.1],
}


def test_eq1_sign_structure():
    """Eq. 1: delta > 0 iff k >= 2 (the majority condition)."""
    for k in range(4):
        d = spice.eq1_deviation(k)
        assert (d > 0) == (k >= 2), (k, d)


def test_eq1_closed_form_matches_general_model():
    import jax.numpy as jnp

    p = spice.DEFAULT_SPICE
    for k in range(4):
        vals = jnp.array([1.0] * k + [0.0] * (3 - k))
        caps = jnp.full((3,), p.c_cell_ff)
        d = float(spice.bitline_deviation(vals, caps, p))
        assert d == pytest.approx(spice.eq1_deviation(k), rel=1e-6)


def test_table1_latencies_within_5pct():
    t = spice.table1()
    for case, paper_vals in PAPER_TABLE1.items():
        for (v, entry), pv in zip(t[case].items(), paper_vals):
            if pv is None:
                assert entry["fails"], f"{case}@{v} should fail"
            else:
                assert not entry["fails"], f"{case}@{v} should pass"
                assert entry["latency_ns"] == pytest.approx(pv, rel=0.05), \
                    (case, v, entry["latency_ns"], pv)


def test_first_failure_at_25pct_1s0w0w_only():
    t = spice.table1()
    fails = [(c, v) for c, row in t.items() for v, e in row.items() if e["fails"]]
    assert fails == [("1s0w0w", 0.25)]


def test_latency_monotonic_in_variation_for_contested_cases():
    t = spice.table1()
    for case in ("1s0w0w", "0s1w1w"):
        lats = [e["latency_ns"] for e in t[case].values() if not e["fails"]]
        assert all(b >= a for a, b in zip(lats, lats[1:])), (case, lats)


def test_monte_carlo_reliable_at_moderate_variation():
    """TRA works under significant process variation (paper conclusion);
    this justifies the digital-majority abstraction in core.engine."""
    mc = spice.monte_carlo_tra(jax.random.PRNGKey(0), 50_000, 0.06)
    assert float(mc["failure_rate"]) == 0.0


def test_monte_carlo_fails_at_extreme_variation():
    mc = spice.monte_carlo_tra(jax.random.PRNGKey(1), 50_000, 0.25)
    assert float(mc["failure_rate"]) > 0.0


def test_fully_refreshed_assumption_documented():
    """§3.4: copies happen just before TRA (1us << 64ms refresh), so cells
    are fully charged; the model's cells are binary {0, VDD} accordingly."""
    # charge leakage of 1us/64ms of a refresh interval is < 0.002% of VDD —
    # negligible vs the smallest sensed deviation we model.
    leak_frac = 1e-6 / 64e-3
    assert leak_frac < 1e-4
