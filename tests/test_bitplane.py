import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitplane import (BitVector, pack_bits, unpack_bits, n_words,
                                 tail_mask)


@pytest.mark.parametrize("n", [1, 31, 32, 33, 64, 100, 4096])
def test_pack_unpack_roundtrip(n):
    rng = np.random.default_rng(n)
    bits = rng.integers(0, 2, n).astype(bool)
    packed = pack_bits(jnp.asarray(bits))
    assert packed.shape == (n_words(n),)
    assert packed.dtype == jnp.uint32
    out = np.asarray(unpack_bits(packed, n))
    np.testing.assert_array_equal(out, bits)


def test_pack_batched():
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, (4, 7, 65)).astype(bool)
    packed = pack_bits(jnp.asarray(bits))
    assert packed.shape == (4, 7, 3)
    np.testing.assert_array_equal(np.asarray(unpack_bits(packed, 65)), bits)


def test_lsb_first_order():
    bits = np.zeros(32, bool)
    bits[0] = True  # logical element 0 -> LSB
    assert int(pack_bits(jnp.asarray(bits))[0]) == 1
    bits = np.zeros(33, bool)
    bits[32] = True
    packed = pack_bits(jnp.asarray(bits))
    assert int(packed[0]) == 0 and int(packed[1]) == 1


def test_tail_mask():
    m = tail_mask(33)
    assert m[0] == 0xFFFFFFFF and m[1] == 1


def test_bitvector_logic_matches_numpy():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 2, 100).astype(bool)
    b = rng.integers(0, 2, 100).astype(bool)
    c = rng.integers(0, 2, 100).astype(bool)
    va, vb, vc = (BitVector.from_bits(jnp.asarray(x)) for x in (a, b, c))
    np.testing.assert_array_equal(np.asarray((va & vb).to_bits()), a & b)
    np.testing.assert_array_equal(np.asarray((va | vb).to_bits()), a | b)
    np.testing.assert_array_equal(np.asarray((va ^ vb).to_bits()), a ^ b)
    np.testing.assert_array_equal(np.asarray((~va).to_bits()), ~a)
    maj = (a & b) | (b & c) | (c & a)
    np.testing.assert_array_equal(np.asarray(va.majority(vb, vc).to_bits()), maj)


def test_bitvector_invert_keeps_padding_zero():
    v = BitVector.from_bits(jnp.asarray(np.ones(33, bool)))
    inv = ~v
    # bits beyond n_bits must stay zero so popcounts are exact
    assert int(inv.words[1]) == 0
    assert int(inv.popcount()) == 0


def test_zeros_ones_popcount():
    assert int(BitVector.zeros(100).popcount()) == 0
    assert int(BitVector.ones(100).popcount()) == 100
