"""Cost-based optimizer: reordering compile-off, backend choice, cross-
query CSE, bounded LRU plan cache, the explain() surface, and range-scan
parity through the general optimizer path (ISSUE: optimizer tentpole)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitplane import unpack_bits
from repro.core.commands import AAP, AP, Program
from repro.core.compiler import Expr, compile_expr_fused, expr_key
from repro.core.energy import program_energy_nj
from repro.core.timing import DDR3_1600, program_latency_ns
from repro.ops.predicate import between_scan
from repro.service import (MATERIALIZE, CostParams, Planner, PlanCache,
                           Query, QueryService, canonicalize, choose_backend,
                           cost_program, parse_any, reorder_expr,
                           run_queries_unbatched)
from repro.service.optimizer import QueryOptimizer
from repro.service.planner import ArithQuery

RNG = np.random.default_rng(11)


def _bits(n=200, p=0.5):
    return RNG.random(n) < p


def _svc(n=200, names=("a", "b", "c", "d"), **kw):
    svc = QueryService(n_banks=4, **kw)
    vecs = {}
    for name in names:
        vecs[name] = _bits(n)
        svc.register_bits(name, vecs[name])
    return svc, vecs


def _opt_planner(**kw):
    opt = QueryOptimizer(params=CostParams(device="cpu"), **kw)
    return Planner(cache=PlanCache(optimizer=opt))


# -- reordering + compile-off ------------------------------------------------


def test_operand_order_variants_share_one_plan():
    planner = _opt_planner()
    p1 = planner.plan("c & (a | b)")
    p2 = planner.plan("(b | a) & c")
    assert p1.plan is p2.plan
    assert planner.compile_count == 1
    assert len(planner.cache) == 1
    # bindings permuted so IN{i} still backs the right catalog row
    svc, vecs = _svc(64, names=("a", "b", "c"))
    r1 = svc.query("c & (a | b)")
    r2 = svc.query("(b | a) & c")
    expect = int((vecs["c"] & (vecs["a"] | vecs["b"])).sum())
    assert r1.value == expect
    assert r2.value == expect


def test_reorder_never_more_aaps():
    planner = _opt_planner()
    for q in ("a & b & a", "a ^ b ^ a", "(a | b) & (b | a)",
              "maj(a, b, c) | a | maj(a, b, c)", "~a & ~a", "a | a | a"):
        bp = planner.plan(q)
        assert bp.plan.n_aaps_unopt is not None
        assert bp.plan.n_aaps <= bp.plan.n_aaps_unopt, q


def test_xor_parity_cancellation():
    planner = _opt_planner()
    bp = planner.plan("a ^ b ^ a")
    assert bp.bindings == ["b"]
    assert bp.plan.n_inputs == 1
    # semantics: a ^ b ^ a == b
    svc, vecs = _svc(96, names=("a", "b"))
    r = svc.query("a ^ b ^ a")
    assert r.value == int(vecs["b"].sum())


def test_reorder_full_cancellation_left_to_compiler():
    # a ^ a cancels to nothing; reorder must leave the node intact
    e = parse_any("a ^ a")
    assert expr_key(reorder_expr(e)) == expr_key(e)


def test_plain_pipeline_unchanged_without_optimizer():
    planner = Planner()        # no optimizer attached
    p1 = planner.plan("c & (a | b)")
    p2 = planner.plan("(b | a) & c")
    assert p1.plan is not p2.plan      # old behavior: two distinct shapes
    assert planner.compile_count == 2
    assert p1.plan.backend is None and p1.plan.cost is None


# -- cost model --------------------------------------------------------------


def test_cost_program_consistent_with_models():
    prog = Program([AAP("a", "b"), AP("T0"), AAP("b", "OUT")])
    c = cost_program(prog, n_inputs=2, n_outputs=1, params=CostParams())
    assert c.n_aaps == prog.n_aap and c.n_aps == prog.n_ap
    assert c.latency_ns == program_latency_ns(prog, DDR3_1600)
    assert c.energy_nj == pytest.approx(program_energy_nj(prog))
    assert c.xfer_ns == DDR3_1600.aap_ns * 3
    assert c.total_ns == pytest.approx(c.xfer_ns + c.latency_ns)
    # amortized view divides by the parallel slots
    c8 = cost_program(prog, 2, 1, CostParams(n_banks=8, n_chips=2))
    assert c8.amortized_ns == pytest.approx(c8.total_ns / 16)
    # multi-block operands scale serial totals linearly
    c3 = cost_program(prog, 2, 1, CostParams(n_blocks=3))
    assert c3.total_ns == pytest.approx(3 * c.total_ns)
    assert c3.total_energy_nj == pytest.approx(3 * c.total_energy_nj)


def test_backend_selection_thresholds():
    tiny = compile_expr_fused(Expr.of("IN0"), "OUT").program  # a copy
    assert len(tiny.commands) <= 2
    assert choose_backend(tiny, "cpu") == "interp"
    assert choose_backend(tiny, "tpu") == "interp"
    # a long program: wide OR tree clears the megakernel threshold
    e = Expr.of("IN0")
    for i in range(1, 32):
        e = e | (Expr.of(f"IN{i}") & ~Expr.of(f"IN{(i + 1) % 32}"))
    big = compile_expr_fused(e, "OUT").program
    assert len(big.commands) >= 48
    assert choose_backend(big, "tpu") == "pallas"
    assert choose_backend(big, "gpu") == "pallas"
    assert choose_backend(big, "cpu") == "scan"    # interpret-mode pallas
    mid = compile_expr_fused(
        (Expr.of("IN0") | Expr.of("IN1")) & ~Expr.of("IN2"), "OUT").program
    assert 2 < len(mid.commands) < 48
    assert choose_backend(mid, "tpu") == "scan"


def test_plan_records_backend_and_cost():
    svc, vecs = _svc()
    bp = svc.planner.plan("a & b")
    assert bp.plan.backend in ("interp", "scan", "pallas")
    assert bp.plan.cost is not None
    assert bp.plan.cost.n_aaps == bp.plan.n_aaps


# -- cross-query CSE ---------------------------------------------------------


def test_cse_shares_overlapping_subexpression():
    svc, vecs = _svc()
    queries = [Query("(a & b) | c"), Query("(a & b) | d"),
               Query("(a & b) ^ d", MATERIALIZE)]
    rep = svc.query_batch(queries)
    assert rep.n_cse_planes >= 1
    assert rep.total_aaps < rep.baseline_aaps
    # bit-identical to the sequential unoptimized oracle
    ref = run_queries_unbatched(svc.catalog, queries)
    assert rep.results[0].value == ref.results[0].value
    assert rep.results[1].value == ref.results[1].value
    np.testing.assert_array_equal(np.asarray(rep.results[2].value),
                                  np.asarray(ref.results[2].value))
    # numpy ground truth
    ab = vecs["a"] & vecs["b"]
    assert rep.results[0].value == int((ab | vecs["c"]).sum())
    assert rep.results[1].value == int((ab | vecs["d"]).sum())
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(jnp.asarray(rep.results[2].value), 200)),
        ab ^ vecs["d"])
    assert svc.stats()["cse_planes"] == rep.n_cse_planes


def test_cse_energy_accounting_consistent():
    svc, _ = _svc()
    rep = svc.query_batch([Query("(a & b) | c"), Query("(a & b) | d"),
                           Query("(a & b) & ~c")])
    # the shared plane's energy is charged exactly once, folded into the
    # per-result energies the stats total is the sum of
    assert svc.stats()["total_energy_nj"] == pytest.approx(
        sum(r.energy_nj for r in rep.results))
    assert rep.total_aaps <= rep.baseline_aaps


def test_cse_not_applied_when_it_loses():
    svc, vecs = _svc()
    # no shared interior subexpression -> no planes, identical AAP totals
    rep = svc.query_batch([Query("a & b"), Query("c | d")])
    assert rep.n_cse_planes == 0
    assert rep.total_aaps == rep.baseline_aaps
    assert rep.results[0].value == int((vecs["a"] & vecs["b"]).sum())


def test_cse_disabled_without_optimizer():
    svc, vecs = _svc(optimize=False)
    rep = svc.query_batch([Query("(a & b) | c"), Query("(a & b) | d")])
    assert rep.n_cse_planes == 0
    ab = vecs["a"] & vecs["b"]
    assert rep.results[0].value == int((ab | vecs["c"]).sum())
    assert rep.results[1].value == int((ab | vecs["d"]).sum())


# -- satellite: tokenizer hyphen disambiguation ------------------------------


def test_hyphenated_catalog_name_stays_boolean_leaf():
    svc = QueryService(n_banks=4)
    bits = _bits(128)
    svc.register_bits("weekly-total", bits)
    r = svc.query("weekly-total")
    assert r.value == int(bits.sum())


def test_tight_hyphen_between_columns_is_subtraction():
    svc = QueryService(n_banks=4)
    a = RNG.integers(0, 128, 96, dtype=np.uint32)
    b = RNG.integers(0, 128, 96, dtype=np.uint32)
    svc.register_column("colA", jnp.asarray(a), 8)
    svc.register_column("colB", jnp.asarray(b), 8)
    # both readings of the satellite regression: spaced and tight
    expect = int(((a - b) % 256).sum())
    assert svc.query("sum(colA - colB)").value == expect
    assert svc.query("sum(colA-colB)").value == expect


def test_registered_name_beats_column_split():
    # "colA-colB" registered as ONE bitvector wins over the sub reading
    cols = {"colA": 8, "colB": 8}
    aq = parse_any("colA-colB", columns=cols, names=set())
    assert isinstance(aq, ArithQuery) and aq.op == "sub"
    e = parse_any("colA-colB", columns=cols, names={"colA-colB"})
    assert isinstance(e, Expr) and e.op == "row" and e.row == "colA-colB"
    # spaced form always subtracts regardless of registration
    aq2 = parse_any("colA - colB", columns=cols, names={"colA-colB"})
    assert isinstance(aq2, ArithQuery) and aq2.op == "sub"


def test_hyphen_width_mismatch_raises():
    from repro.service import QueryParseError
    with pytest.raises(QueryParseError):
        parse_any("colA-colB", columns={"colA": 8, "colB": 4}, names=set())


# -- satellite: bounded LRU plan cache ---------------------------------------


def test_plan_cache_lru_eviction_counted():
    cache = PlanCache(capacity=2)
    shapes = ["a & b", "a | b", "a ^ b", "~a & b"]
    planner = Planner(cache=cache)
    for q in shapes:
        planner.plan(q)
    assert len(cache) == 2
    assert cache.evictions == 2
    # least-recently-used went first: the oldest shape recompiles
    planner.plan(shapes[0])
    assert cache.misses == len(shapes) + 1
    # unbounded cache never evicts
    unbounded = PlanCache(capacity=None)
    planner2 = Planner(cache=unbounded)
    for q in shapes:
        planner2.plan(q)
    assert len(unbounded) == len(shapes)
    assert unbounded.evictions == 0


def test_lru_touch_on_hit_protects_hot_plans():
    cache = PlanCache(capacity=2)
    planner = Planner(cache=cache)
    planner.plan("a & b")
    planner.plan("a | b")
    planner.plan("a & b")              # touch: now most-recent
    planner.plan("a ^ b")              # evicts "a | b", not "a & b"
    hits0 = cache.hits
    planner.plan("a & b")
    assert cache.hits == hits0 + 1     # survived the eviction


def test_eviction_counter_in_service_stats():
    svc, _ = _svc(plan_cache_capacity=1)
    svc.query("a & b")
    svc.query("a | b")
    svc.query("a ^ b")
    assert svc.stats()["plan_cache_evictions"] >= 2


# -- satellite: range scans through the general optimizer path ---------------


def test_range_scan_bit_and_cost_identical():
    svc = QueryService(n_banks=4)
    vals = RNG.integers(0, 256, 224, dtype=np.uint32)
    col = svc.register_column("col", jnp.asarray(vals), 8)
    lo, hi = 40, 180
    # bit-for-bit against the old dedicated between-scan kernel (the
    # removed `range_scan_fast` shortcut dispatched to it directly)
    old = np.asarray(between_scan(col.planes, lo, hi, 8))
    r = svc.range_scan("col", lo, hi, mode=MATERIALIZE)
    np.testing.assert_array_equal(np.asarray(r.value), old)
    # cost-for-cost: the optimizer plan never exceeds the plain compile of
    # the same predicate DAG (the cost the removed fast path implied)
    canon, _ = canonicalize(svc.range_scan_query("col", lo, hi))
    plain = compile_expr_fused(canon, "OUT").program
    bp = svc.planner.plan(svc.range_scan_query("col", lo, hi))
    assert bp.plan.n_aaps <= plain.n_aap
    assert bp.plan.n_aaps_unopt == plain.n_aap


# -- explain() surface -------------------------------------------------------


def test_explain_reports_decisions_without_executing():
    svc, _ = _svc()
    served0 = svc.stats()["queries_served"]
    rep = svc.explain([Query("(a & b) | c"), "(a & b) | d", "a ^ b ^ a"])
    assert svc.stats()["queries_served"] == served0   # plan-only
    assert len(rep.plans) == 3
    assert all(p.backend in ("interp", "scan", "pallas")
               for p in rep.plans)
    assert all(p.n_aaps <= p.n_aaps_unopt for p in rep.plans)
    assert rep.total_aaps <= rep.baseline_aaps
    assert rep.aap_reduction >= 1.0
    assert rep.makespan_ns > 0
    # the (a & b) overlap shows up as a shared plane on both consumers
    assert len(rep.cse) >= 1
    sharers = [p for p in rep.plans if p.shared]
    assert len(sharers) >= 2
    text = str(rep)
    assert "backend" in text and "shared plane" in text
    assert "unoptimized" in text


def test_explain_matches_executed_batch_totals():
    svc, _ = _svc()
    queries = [Query("(a & b) | c"), Query("(a & b) | d")]
    rep = svc.explain(queries)
    batch = svc.query_batch(queries)
    assert rep.total_aaps == batch.total_aaps
    assert rep.baseline_aaps == batch.baseline_aaps
    assert len(rep.cse) == batch.n_cse_planes
