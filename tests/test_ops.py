"""ops layer: functional correctness vs numpy/python oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitplane import pack_bits, unpack_bits
from repro.ops import (BitSet, BloomFilter, VerticalColumn, field_mask,
                       masked_fill_constant, masked_init, scan_count,
                       xor_decrypt, xor_encrypt)
from repro.ops import dna

RNG = np.random.default_rng(99)


# -- predicate scans --------------------------------------------------------


@pytest.mark.parametrize("n,nbits", [(100, 8), (1000, 12), (4096, 16)])
def test_scan_count(n, nbits):
    vals = RNG.integers(0, 2**nbits, n, dtype=np.uint64).astype(np.uint32)
    lo, hi = int(2**nbits * 0.2), int(2**nbits * 0.7)
    got = int(scan_count(jnp.asarray(vals), nbits, lo, hi))
    assert got == int(((vals >= lo) & (vals <= hi)).sum())


def test_vertical_column_padding_excluded():
    vals = np.array([5, 10, 3], np.uint32)  # padded to 32 with sentinel
    col = VerticalColumn.encode(jnp.asarray(vals), 8)
    bv = col.scan(0, 255)  # all real values match; padding must not
    assert int(bv.popcount()) == 3


@pytest.mark.parametrize("nbits,lo,hi", [(8, 50, 200), (12, 0, 100),
                                         (10, 1000, 1023), (6, 17, 17)])
def test_between_scan_fused_matches_unfused_ref(nbits, lo, hi):
    """ops.predicate.between_scan (fused kernel path) == the unfused
    reference that evaluates the two bounds in separate plane passes."""
    from repro.kernels import ref
    from repro.ops.predicate import between_scan

    vals = RNG.integers(0, 2**nbits, 256, dtype=np.uint64).astype(np.uint32)
    planes = ref.bit_transpose(jnp.asarray(vals), nbits)
    unfused = np.asarray(ref.bitweaving_scan(planes, lo, hi, nbits))
    fused = np.asarray(between_scan(planes, lo, hi, nbits, use_kernel=True))
    fallback = np.asarray(between_scan(planes, lo, hi, nbits,
                                       use_kernel=False))
    np.testing.assert_array_equal(fused, unfused)
    np.testing.assert_array_equal(fallback, unfused)
    # and both match the direct numpy predicate
    expect = np.asarray(pack_bits(jnp.asarray((vals >= lo) & (vals <= hi))))
    np.testing.assert_array_equal(fused, expect)


# -- set ops ----------------------------------------------------------------


def test_bitset_matches_python_sets():
    domain = 1 << 12
    sets_np = [set(RNG.integers(0, domain, 200).tolist()) for _ in range(4)]
    sets = [BitSet.from_elements(jnp.asarray(sorted(s)), domain)
            for s in sets_np]
    u = sets[0].union(*sets[1:])
    i = sets[0].intersection(*sets[1:])
    d = sets[0].difference(*sets[1:])
    assert set(np.asarray(u.to_elements()).tolist()) == set.union(*sets_np)
    assert set(np.asarray(i.to_elements()).tolist()) == set.intersection(*sets_np)
    assert set(np.asarray(d.to_elements()).tolist()) == \
        sets_np[0] - sets_np[1] - sets_np[2] - sets_np[3]
    assert int(u.cardinality()) == len(set.union(*sets_np))


def test_bitset_insert_contains():
    s = BitSet.empty(256).insert(7).insert(255).insert(7)
    assert int(s.contains(7)) and int(s.contains(255))
    assert not int(s.contains(8))
    assert int(s.cardinality()) == 2


# -- masked init ------------------------------------------------------------


def test_masked_init_field():
    """Clear the 'alpha' byte of 32-bit RGBA pixels, in-memory."""
    n = 64
    pixels = RNG.integers(0, 2**32, n, dtype=np.uint32)
    mask = field_mask(record_bits=32, offset=24, width=8, n_records=n)
    out = masked_fill_constant(jnp.asarray(pixels), mask, 0)
    np.testing.assert_array_equal(np.asarray(out), pixels & 0x00FFFFFF)
    out1 = masked_fill_constant(jnp.asarray(pixels), mask, 1)
    np.testing.assert_array_equal(np.asarray(out1), pixels | 0xFF000000)


def test_masked_init_value():
    n = 32
    data = RNG.integers(0, 2**32, n, dtype=np.uint32)
    value = RNG.integers(0, 2**32, n, dtype=np.uint32)
    mask = field_mask(32, 8, 16, n)
    out = np.asarray(masked_init(jnp.asarray(data), mask, jnp.asarray(value)))
    m = np.uint32(0x00FFFF00)
    np.testing.assert_array_equal(out, (data & ~m) | (value & m))


# -- bloom filter -----------------------------------------------------------


def test_bloom_no_false_negatives():
    bf = BloomFilter.create(1 << 14, k=4)
    keys = jnp.asarray(RNG.integers(0, 2**31, 300, dtype=np.int64), jnp.uint32)
    bf = bf.insert(keys)
    assert bool(bf.query(keys).all())


def test_bloom_false_positive_rate_reasonable():
    m, k, n = 1 << 16, 4, 2000
    bf = BloomFilter.create(m, k=k).insert(
        jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761))
    probe = jnp.arange(10_000, dtype=jnp.uint32) + jnp.uint32(1 << 20)
    fp = float(bf.query(probe).mean())
    theo = (1 - np.exp(-k * n / m)) ** k
    assert fp < 4 * theo + 0.01, (fp, theo)


def test_bloom_merge_is_union():
    a = BloomFilter.create(1 << 12).insert(jnp.arange(0, 100, dtype=jnp.uint32))
    b = BloomFilter.create(1 << 12).insert(jnp.arange(100, 200, dtype=jnp.uint32))
    m = a.merge(b)
    assert bool(m.query(jnp.arange(200, dtype=jnp.uint32)).all())


# -- crypto -----------------------------------------------------------------


def test_xor_encrypt_roundtrip_and_diffusion():
    pt = RNG.integers(0, 2**32, 512, dtype=np.uint32)
    ct = xor_encrypt(jnp.asarray(pt), 0xDEADBEEF)
    assert not np.array_equal(np.asarray(ct), pt)
    back = xor_decrypt(ct, 0xDEADBEEF)
    np.testing.assert_array_equal(np.asarray(back), pt)
    # wrong key fails
    bad = xor_decrypt(ct, 0xDEADBEEE)
    assert not np.array_equal(np.asarray(bad), pt)
    # keystream is balanced-ish
    from repro.ops.popcount import popcount_words
    from repro.ops.crypto import keystream

    ks = keystream(1, (4096,))
    density = int(popcount_words(ks)) / (4096 * 32)
    assert 0.48 < density < 0.52


# -- DNA matching -----------------------------------------------------------


def _rand_seq(n):
    return "".join(RNG.choice(list("ACGT"), n))


def test_dna_exact_match_vs_python():
    genome = _rand_seq(2000)
    read = genome[777:777 + 12]
    got = set(np.nonzero(np.asarray(
        dna.find_matches(genome, read).to_bits()))[0].tolist())
    exp = {i for i in range(len(genome) - len(read) + 1)
           if genome[i:i + len(read)] == read}
    assert got == exp and 777 in got


def test_dna_no_match():
    genome = "ACGT" * 100
    assert int(dna.find_matches(genome, "AAAAAAAAAA").popcount()) == 0


def test_dna_with_mismatches():
    genome = _rand_seq(3000)
    read = list(genome[1500:1516])
    mutated = read.copy()
    mutated[5] = "A" if read[5] != "A" else "C"
    mutated = "".join(mutated)
    exact = dna.find_matches(genome, mutated)
    assert int(exact.popcount()) == 0   # 1 mismatch: no exact hit
    approx = dna.find_matches_with_mismatches(genome, mutated, max_mismatch=1)
    bits = np.asarray(approx.to_bits())
    assert bits[1500]  # found despite 1 mismatch
    # oracle check of the full approximate-match set
    g = np.asarray([{"A": 0, "C": 1, "G": 2, "T": 3}[c] for c in genome])
    r = np.asarray([{"A": 0, "C": 1, "G": 2, "T": 3}[c] for c in mutated])
    L = len(r)
    exp = np.asarray([(g[i:i + L] != r).sum() <= 1
                      for i in range(len(g) - L + 1)])
    np.testing.assert_array_equal(bits, exp)


def test_dna_shift_down():
    from repro.core.bitplane import pack_bits

    bits = RNG.integers(0, 2, 200).astype(bool)
    w = pack_bits(jnp.asarray(bits))
    for k in (0, 1, 31, 32, 33, 64, 150):
        shifted = dna.shift_down(w, k)
        exp = np.zeros(224, bool)
        exp[:200 - k] = bits[k:]
        np.testing.assert_array_equal(
            np.asarray(unpack_bits(shifted, 224)), exp, err_msg=f"k={k}")
