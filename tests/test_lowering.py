"""Lowered register-machine executor: encoding, scan VM, megakernel.

The lowered paths must be bit-identical to the micro-op interpreter (the
oracle) on every program, the scan VM's jaxpr must not grow with program
length, and `engine.execute` must surface friendly errors instead of bare
KeyErrors. Randomized cross-checking lives in test_property_lowering.py.
"""
import numpy as np
import pytest

from repro.core import compiler, engine, lowering
from repro.core.arith_compiler import ripple_add_program, ripple_sub_program
from repro.core.commands import AAP, AP, Program
from repro.core.engine import BuddyError, Subarray
from repro.kernels.vm import vm_megakernel

W = 8


def _data(rows, seed=0, words=W):
    rng = np.random.default_rng(seed)
    return {r: rng.integers(0, 1 << 32, words, dtype=np.uint32)
            for r in rows}


def _run_interp(program, data):
    return engine.execute(program, data, lowered=False)


def _assert_rows_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=k)


# ---------------------------------------------------------------------------
# encoding
# ---------------------------------------------------------------------------


def test_lowered_layout_reserved_rows_first():
    lp = lowering.lower(compiler.and_program("D0", "D1", "D2"))
    assert lp.row_names[:8] == lowering.FIXED_ROWS
    assert lp.row_names[8] == lowering.SINK
    assert lp.table.shape[1] == 5
    assert lp.n_cmds == 4


def test_lower_memoizes_on_commands():
    p1 = compiler.xor_program("D0", "D1", "D2")
    p2 = Program(list(p1.commands), "other comment")
    assert lowering.lower(p1) is lowering.lower(p2)


def test_lowered_reads_and_writes():
    lp = lowering.lower(compiler.and_program("D0", "D1", "D2"))
    assert "D0" in lp.reads and "D1" in lp.reads
    assert "D2" in lp.writes and "D2" not in lp.reads


def test_lowering_rejects_dual_wordline_first_activate():
    # B8 raises 2 wordlines from precharged state: analog-undefined, the
    # interpreter raises at run time, the lowerer at compile time
    with pytest.raises(BuddyError):
        lowering.lower(Program([AAP("B8", "D0")]))


# ---------------------------------------------------------------------------
# bit-identity with the interpreter
# ---------------------------------------------------------------------------

PROGRAMS = {
    "and": (compiler.and_program("D0", "D1", "D2"), ("D0", "D1")),
    "xor": (compiler.xor_program("D0", "D1", "D2"), ("D0", "D1")),
    "xnor": (compiler.xnor_program("D0", "D1", "D2"), ("D0", "D1")),
    "not": (compiler.not_program("D0", "D1"), ("D0",)),
    "maj3": (compiler.maj3_program("D0", "D1", "D2", "D3"),
             ("D0", "D1", "D2")),
    "andnot": (compiler.andnot_program("D0", "D1", "D2"), ("D0", "D1")),
    "copy": (compiler.copy_program("D0", "D1"), ("D0",)),
    "ap_tra": (Program([AAP("D0", "B0"), AAP("D1", "B1"), AAP("D2", "B2"),
                        AP("B12"), AAP("B0", "D3")]),
               ("D0", "D1", "D2")),
}


@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_scan_vm_matches_interpreter_all_rows(name):
    program, inputs = PROGRAMS[name]
    data = _data(inputs, seed=hash(name) % 1000)
    ref = _run_interp(program, data)
    got = engine.execute(program, data, lowered=True)
    _assert_rows_equal(ref, got)


@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_megakernel_matches_interpreter(name):
    program, inputs = PROGRAMS[name]
    data = _data(inputs, seed=hash(name) % 1000)
    ref = _run_interp(program, data)
    got = engine.execute(program, data, lowered=True, backend="pallas")
    _assert_rows_equal(ref, got)


@pytest.mark.parametrize("n_bits", [1, 3, 8])
@pytest.mark.parametrize("sub", [False, True])
def test_arith_microprograms_all_backends(n_bits, sub):
    res = (ripple_sub_program if sub else ripple_add_program)(n_bits)
    rows = [f"X{j}" for j in range(n_bits)] + [f"Y{j}" for j in range(n_bits)]
    data = _data(rows, seed=n_bits)
    ref = engine.execute(res.program, data, outputs=res.outputs,
                         lowered=False)
    for backend in ("scan", "pallas"):
        got = engine.execute(res.program, data, outputs=res.outputs,
                             lowered=True, backend=backend)
        _assert_rows_equal(ref, got)


def test_lowered_banked_and_batched():
    program, inputs = PROGRAMS["maj3"]
    data = _data(inputs, words=24)
    ref = engine.execute(program, data, outputs=["D3"], lowered=False)
    for banks in (2, 4):
        got = engine.execute(program, data, outputs=["D3"], n_banks=banks)
        _assert_rows_equal(ref, got)
    batched = {k: np.stack([v, ~v]) for k, v in data.items()}
    ref_b = engine.execute(program, batched, outputs=["D3"], lowered=False)
    for backend in ("scan", "pallas"):
        got_b = engine.execute(program, batched, outputs=["D3"],
                               lowered=True, backend=backend)
        _assert_rows_equal(ref_b, got_b)


def test_bankgroup_run_lowered_with_extra_batch_dims():
    # built-in rows are (B, W) while batched operands are (B, X, W): the
    # lowered plane build must align on the bank axis, not right-align
    # (regression: ValueError / silent transposition when X == B)
    from repro.core.bankgroup import BankGroup

    program, inputs = PROGRAMS["xor"]
    for x in (3, 2):    # x == n_banks is the silent-mis-broadcast case
        rng = np.random.default_rng(x)
        data = {r: rng.integers(0, 1 << 32, (2, x, 4), dtype=np.uint32)
                for r in inputs}
        g = BankGroup.create(2, 4, data)
        ref = g.run(program, lowered=False).read("D2")
        for backend in ("scan", "pallas"):
            got = g.run(program, backend=backend).read("D2")
            np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_lowered_passthrough_rows_survive():
    # rows in data the program never touches come back unchanged
    program, _ = PROGRAMS["and"]
    data = _data(("D0", "D1", "UNTOUCHED"))
    out = engine.execute(program, data)
    np.testing.assert_array_equal(np.asarray(out["UNTOUCHED"]),
                                  data["UNTOUCHED"])


def test_execute_lowered_matches_subarray_run_state():
    # full-state equivalence against Subarray.run, including designated and
    # DCC rows mutated along the way
    program, inputs = PROGRAMS["xor"]
    data = _data(inputs)
    full = dict(data)
    full["D2"] = np.zeros(W, np.uint32)
    sub = Subarray.create(W, full)
    ref = sub.run(program).rows
    lp = lowering.lower(program)
    plane = lowering.make_plane(lp, data, W)
    out_plane = lowering.run_scan(lp, plane)
    got = lowering.read_rows(lp, out_plane,
                             [n for n in lp.row_names if n != lowering.SINK])
    for k, v in got.items():
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(v),
                                      err_msg=k)


def test_vm_megakernel_output_selection():
    program, inputs = PROGRAMS["xor"]
    data = _data(inputs)
    lp = lowering.lower(program)
    plane = lowering.make_plane(lp, data, W)
    out = vm_megakernel(lp.table, plane, (lp.row_index("D2"),))
    ref = _run_interp(program, data)["D2"]
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref))


# ---------------------------------------------------------------------------
# constant-size executable: the perf contract
# ---------------------------------------------------------------------------


def test_scan_vm_jaxpr_size_independent_of_program_length():
    lp8 = lowering.lower(ripple_add_program(8).program)
    lp32 = lowering.lower(ripple_add_program(32).program)
    assert lp32.n_cmds > 4 * lp8.n_cmds  # genuinely longer program
    j8 = lowering.scan_vm_jaxpr(lp8, (lp8.n_rows, W))
    j32 = lowering.scan_vm_jaxpr(lp32, (lp32.n_rows, W))
    assert len(j8.jaxpr.eqns) == len(j32.jaxpr.eqns)
    # the scan body (first eqn's inner jaxpr) is also identical in size
    b8 = j8.jaxpr.eqns[0].params["jaxpr"].jaxpr.eqns
    b32 = j32.jaxpr.eqns[0].params["jaxpr"].jaxpr.eqns
    assert len(b8) == len(b32)


def test_structurally_distinct_programs_share_executable_shape():
    # add and a same-length command shuffle lower to identical table shapes,
    # which is what keys the VM's jit cache
    lp = lowering.lower(ripple_add_program(8).program)
    renamed = lowering.lower(
        ripple_add_program(8, a_prefix="P", b_prefix="Q",
                           out_prefix="R").program)
    assert lp is not renamed
    assert lp.table.shape == renamed.table.shape
    assert lp.n_rows == renamed.n_rows


# ---------------------------------------------------------------------------
# error handling (the former bare KeyError)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lowered", [True, False])
def test_unknown_output_raises_buddy_error_listing_produced(lowered):
    program, inputs = PROGRAMS["and"]
    data = _data(inputs)
    with pytest.raises(BuddyError) as exc:
        engine.execute(program, data, outputs=["NOT_A_ROW"],
                       lowered=lowered)
    assert "NOT_A_ROW" in str(exc.value)
    assert "D2" in str(exc.value)  # the row the program does produce


def test_unknown_output_raises_banked_too():
    program, inputs = PROGRAMS["and"]
    data = _data(inputs)
    with pytest.raises(BuddyError):
        engine.execute(program, data, outputs=["NOT_A_ROW"], n_banks=2)
