"""Multi-chip sharded execution (`core.cluster.ChipCluster`).

Sharded execution must be bit-identical to the single-chip oracle for
every chip count, bank count, backend, and word count (including uneven
widths that exercise the padding path); the distributed query service must
match the single-process service and the unbatched reference bit-for-bit;
elastic rescale must preserve every registered catalog vector.

Multi-chip cases need forced host devices — the CI multi-device job runs
this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
On a single-device host those cases are covered by the subprocess test at
the bottom (which forces 8 host devices itself), so tier-1 coverage never
degrades.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import compiler, engine, lowering
from repro.core.arith_compiler import ripple_add_program
from repro.core.bitplane import tail_mask
from repro.core.cluster import ChipCluster, ClusterError, cluster_latency_ns
from repro.dist.sharding import CLUSTER_RULES, DEFAULT_RULES
from repro.service import QueryService
from repro.service.scheduler import (MATERIALIZE, Query,
                                     results_bit_identical,
                                     run_queries_unbatched)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_DEV = len(jax.devices())

multichip = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >=2 devices (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=8 before jax imports); "
           "the CI multi-device job runs these in-process")


def _xor_program():
    return compiler.op_program("xor", ["D0", "D1"], "D2")


def _data(rng, n_words, rows=("D0", "D1")):
    return {r: rng.integers(0, 1 << 32, n_words, dtype=np.uint32)
            for r in rows}


# ---------------------------------------------------------------------------
# layout + construction
# ---------------------------------------------------------------------------


def test_create_validates_device_count():
    with pytest.raises(ClusterError, match="xla_force_host_platform"):
        ChipCluster.create(N_DEV + 1)


def test_chips_must_divide_placement():
    with pytest.raises(ClusterError, match="divide"):
        ChipCluster(mesh=None, n_chips=2, n_banks=2, max_chips=3)


def test_default_placement_granularity():
    cl = ChipCluster.create(1, n_banks=2)
    assert cl.max_chips == 8 and cl.sweeps == 8 and cl.local_banks == 16
    assert cl.slots == 16


def test_spec_resolves_through_dist_rules():
    """The chip/bank logical axes live in dist.sharding's rule tables."""
    assert DEFAULT_RULES["chip"] == ("chip",)
    assert DEFAULT_RULES["bank"] == ()
    assert CLUSTER_RULES == {"chip": ("chip",), "bank": ()}
    cl = ChipCluster.create(1, n_banks=2)
    assert cl.spec(3) == P("chip", None, None)
    assert cl.spec(4) == P("chip", None, None, None)


def test_shard_unshard_roundtrip_uneven():
    rng = np.random.default_rng(0)
    cl = ChipCluster.create(1, n_banks=3, max_chips=4)   # 12 slots
    for n_words in (1, 5, 12, 13, 40):
        x = rng.integers(0, 1 << 32, n_words, dtype=np.uint32)
        s = cl.shard_words(jnp.asarray(x))
        assert s.shape == (1, cl.local_banks, cl.local_words(n_words))
        back = np.asarray(cl.unshard_words(s, n_words))
        assert np.array_equal(back, x), n_words


# ---------------------------------------------------------------------------
# sharded execution == single-chip oracle
# ---------------------------------------------------------------------------


def test_single_chip_identity():
    rng = np.random.default_rng(1)
    data = _data(rng, 13)
    ref = engine.execute(_xor_program(), data, lowered=False)
    cl = ChipCluster.create(1, n_banks=4)
    out = cl.execute(_xor_program(), data)
    assert set(out) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]), err_msg=k)


@multichip
@pytest.mark.parametrize("n_chips", sorted({2, min(4, N_DEV), N_DEV}))
def test_multichip_identity(n_chips):
    rng = np.random.default_rng(2)
    data = _data(rng, 29)   # uneven: exercises zero-padding on every layout
    ref = engine.execute(_xor_program(), data, outputs=["D2"],
                         lowered=False)
    cl = ChipCluster.create(n_chips, n_banks=2,
                            max_chips=n_chips * 2)
    out = cl.execute(_xor_program(), data, outputs=["D2"])
    np.testing.assert_array_equal(np.asarray(out["D2"]),
                                  np.asarray(ref["D2"]))


@multichip
@pytest.mark.parametrize("backend", ["scan", "pallas"])
def test_multichip_arith_backends(backend):
    rng = np.random.default_rng(3)
    res = ripple_add_program(8)
    data = _data(rng, 7, rows=[f"X{j}" for j in range(8)]
                 + [f"Y{j}" for j in range(8)])
    ref = engine.execute(res.program, data, outputs=list(res.outputs),
                         lowered=False)
    cl = ChipCluster.create(2, n_banks=2, max_chips=4)
    out = cl.execute(res.program, data, outputs=list(res.outputs),
                     backend=backend)
    for k in res.outputs:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]),
                                      err_msg=f"{backend}/{k}")


@multichip
def test_popcounts_tree_psum():
    rng = np.random.default_rng(4)
    n_words, n_bits = 11, 11 * 32 - 9
    data = _data(rng, n_words)
    cl = ChipCluster.create(2, n_banks=3, max_chips=4)
    lp = lowering.lower(_xor_program())
    sharded = {k: cl.shard_words(jnp.asarray(v, jnp.uint32))
               for k, v in data.items()}
    mask = cl.shard_words(jnp.asarray(tail_mask(n_bits)))
    counts = cl.popcounts(lp, sharded, ["D2"], mask)
    flat = np.asarray(engine.execute(_xor_program(), data,
                                     outputs=["D2"])["D2"])
    flat = flat & np.asarray(tail_mask(n_bits))
    expect = int(np.unpackbits(flat.view(np.uint8)).sum())
    assert counts.shape == (1,) and int(counts[0]) == expect


def test_engine_execute_rejects_interpreter_with_chips():
    rng = np.random.default_rng(5)
    with pytest.raises(ValueError, match="lowered"):
        engine.execute(_xor_program(), _data(rng, 9), n_chips=2,
                       lowered=False)


@multichip
def test_engine_execute_n_chips_param():
    """`engine.execute(n_chips=C)` is the one-shot chips x banks dispatch."""
    rng = np.random.default_rng(5)
    data = _data(rng, 9)
    ref = engine.execute(_xor_program(), data, outputs=["D2"],
                         lowered=False)
    out = engine.execute(_xor_program(), data, outputs=["D2"],
                         n_banks=2, n_chips=2)
    np.testing.assert_array_equal(np.asarray(out["D2"]),
                                  np.asarray(ref["D2"]))


def test_modeled_scaling_monotone():
    prog = _xor_program()
    total = [cluster_latency_ns(512, c, 8, prog).total_ns
             for c in (1, 2, 4, 8)]
    assert all(a > b for a, b in zip(total, total[1:])), total
    # near-linear: 8 chips must be >= 4x over 1 chip on a bulk workload
    assert total[0] / total[-1] >= 4.0


# ---------------------------------------------------------------------------
# distributed service deployment
# ---------------------------------------------------------------------------

N_BITS = 700    # uneven domain: 22 words, tail mask in play


def _build_service(**kw):
    rng = np.random.default_rng(7)
    svc = QueryService(n_banks=4, **kw)
    for t in range(2):
        for d in ("mon", "tue"):
            svc.register_bits(f"t{t}/{d}", rng.integers(0, 2, N_BITS),
                              group=f"t{t}")
    svc.register_column("age", rng.integers(0, 100, N_BITS), 7,
                        group="cols")
    svc.register_column("spend", rng.integers(0, 100, N_BITS), 7,
                        group="cols")
    return svc


_QUERIES = [
    Query("t0/mon & t0/tue"),
    Query("t1/mon | t1/tue ^ t0/mon"),
    Query("age < 30 & t0/mon"),
    Query("sum(age)"),
    Query("age + spend"),
    Query("t0/mon | t1/tue", mode=MATERIALIZE),
    Query("age + spend", mode=MATERIALIZE),
]


@pytest.mark.parametrize("n_chips", [1] + ([2] if N_DEV >= 2 else []))
def test_service_distributed_bit_identical(n_chips):
    base = _build_service()
    dist = _build_service(n_chips=n_chips)
    r0 = base.query_batch(list(_QUERIES))
    r1 = dist.query_batch(list(_QUERIES))
    assert results_bit_identical(r0.results, r1.results)
    ru = run_queries_unbatched(base.catalog, list(_QUERIES))
    assert results_bit_identical(r1.results, ru.results)
    assert r1.n_chips == n_chips


def test_service_records_chip_placement():
    svc = _build_service(n_chips=1)
    for name in svc.catalog.names():
        pl = svc.catalog.placement(name)
        assert pl is not None and pl.n_chips == 1
        assert pl.slots == pl.n_chips * pl.local_banks
    # affinity group members share one layout -> chip-local groups
    pls = {svc.catalog.placement(n) for n in ("t0/mon", "t0/tue")}
    assert len(pls) == 1


@multichip
def test_multichip_service_faster_modeled():
    base = _build_service()
    dist = _build_service(n_chips=2)
    r0 = base.query_batch(list(_QUERIES))
    r1 = dist.query_batch(list(_QUERIES))
    assert r1.makespan_ns < r0.makespan_ns


def test_rescale_requires_distributed_service():
    svc = _build_service()
    with pytest.raises(ValueError, match="n_chips"):
        svc.rescale(2)


def test_rescale_rejects_unpreservable_layout():
    svc = _build_service(n_chips=1, max_chips=8)
    with pytest.raises(ValueError, match="not preservable"):
        svc.rescale(3)


@multichip
def test_rescale_preserves_catalog_and_results():
    svc = _build_service(n_chips=1, max_chips=4)
    svc.materialize("both", "t0/mon & t0/tue", group="t0")
    r_before = svc.query_batch(list(_QUERIES))
    before = {n: np.asarray(svc.catalog.get(n).words)
              for n in svc.catalog.names()}
    plan = svc.rescale(2)
    assert plan.new_mesh_shards == 2
    assert plan.grad_accum == svc.cluster.sweeps
    after = {n: np.asarray(svc.catalog.get(n).words)
             for n in svc.catalog.names()}
    assert before.keys() == after.keys()
    for n in before:
        assert np.array_equal(before[n], after[n]), n
        gathered = np.asarray(svc.cluster.unshard_words(
            svc.catalog.shards(n), before[n].shape[0]))
        assert np.array_equal(gathered, before[n]), n
        assert svc.catalog.placement(n).n_chips == 2
    r_after = svc.query_batch(list(_QUERIES))
    assert results_bit_identical(r_before.results, r_after.results)
    assert svc.stats()["n_chips"] == 2


# ---------------------------------------------------------------------------
# subprocess: the >=2-forced-host-devices acceptance run, independent of
# this process's device count (tier-1 keeps multi-chip coverage everywhere)
# ---------------------------------------------------------------------------


def test_multichip_identity_subprocess():
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {REPO!r} + "/src")
        import numpy as np
        from repro.core import compiler, engine
        from repro.core.cluster import ChipCluster
        from repro.service import QueryService

        rng = np.random.default_rng(0)
        data = {{r: rng.integers(0, 1 << 32, 13, dtype=np.uint32)
                 for r in ("D0", "D1")}}
        prog = compiler.op_program("xor", ["D0", "D1"], "D2")
        ref = np.asarray(engine.execute(prog, data, outputs=["D2"],
                                        lowered=False)["D2"])
        for chips in (2, 4, 8):
            cl = ChipCluster.create(chips, n_banks=2, max_chips=8)
            out = np.asarray(cl.execute(prog, data, outputs=["D2"])["D2"])
            assert np.array_equal(out, ref), chips

        svc = QueryService(n_banks=2, n_chips=2, max_chips=8)
        svc.register_bits("a", rng.integers(0, 2, 97))
        svc.register_bits("b", rng.integers(0, 2, 97))
        n = svc.query("a & b").value
        expect = svc.query("a & b", mode="materialize").value
        assert n == int(np.unpackbits(
            np.asarray(expect, np.uint32).view(np.uint8)).sum())
        svc.rescale(8)
        assert svc.query("a & b").value == n
        print("CLUSTER_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert "CLUSTER_OK" in r.stdout, r.stderr[-2000:]
