"""Bit-serial arithmetic layer: maj3-adder microprograms, Pallas kernels,
ops dispatch, and the service grammar/aggregate path — all bit-identical to
the NumPy reference at 1 and 8 banks."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arith_compiler, engine
from repro.kernels import ref
from repro.ops import arith as oar
from repro.ops.predicate import VerticalColumn
from repro.ops.transpose import from_vertical
from repro.service import (AGGREGATE, MATERIALIZE, ArithQuery, Planner,
                           Query, QueryParseError, QueryService, parse_any,
                           run_queries_unbatched)

RNG = np.random.default_rng(11)


def _cols(n_bits, n, seed=0):
    rng = np.random.default_rng(seed)
    av = rng.integers(0, 1 << n_bits, n, dtype=np.uint32)
    bv = rng.integers(0, 1 << n_bits, n, dtype=np.uint32)
    return (av, bv, VerticalColumn.encode(jnp.asarray(av), n_bits),
            VerticalColumn.encode(jnp.asarray(bv), n_bits))


def _decode(col, n):
    return np.asarray(from_vertical(col.planes, col.n_bits,
                                    use_kernel=False))[:n]


# -- microprograms through the engine ----------------------------------------


@pytest.mark.parametrize("n_bits", [1, 2, 5, 8])
@pytest.mark.parametrize("sub", [False, True])
def test_ripple_program_matches_numpy(n_bits, sub):
    av, bv, a, b = _cols(n_bits, 96, seed=n_bits)
    res = arith_compiler.ripple_add_program(n_bits, sub=sub)
    data = {f"X{j}": a.planes[j] for j in range(n_bits)}
    data.update({f"Y{j}": b.planes[j] for j in range(n_bits)})
    exp = ((av - bv) if sub else (av + bv)) % (1 << n_bits)
    for banks in (1, 8):
        out = engine.execute(res.program, data, outputs=res.outputs,
                             n_banks=banks)
        col = VerticalColumn(jnp.stack([out[o] for o in res.outputs]),
                             n_bits, 96)
        np.testing.assert_array_equal(_decode(col, 96), exp)


def test_adder_aap_cost_is_linear_in_width():
    """O(n) AAPs per column-wide op — the SIMDRAM bit-serial trade."""
    n = {w: arith_compiler.ripple_add_program(w).program.n_aap
         for w in (8, 15, 16)}
    per_bit = n[16] - n[15]
    assert per_bit > 0
    assert n[16] - n[8] == 8 * per_bit
    # sub pays one extra NOT (2 AAPs) per middle bit for ~b
    s = {w: arith_compiler.ripple_sub_program(w).program.n_aap
         for w in (15, 16)}
    assert s[16] - s[15] == per_bit + 2


def test_plane_prefix_collision_rejected():
    with pytest.raises(ValueError):
        arith_compiler.ripple_add_program(3, a_prefix="B")
    with pytest.raises(ValueError):
        arith_compiler.lt_columns_expr(2, a_prefix="T")
    with pytest.raises(ValueError):
        arith_compiler.plane_readout_program(2, in_prefix="DCC")


def test_lt_const_expr_bounds():
    assert arith_compiler.lt_const_expr(4, 0) is None
    assert arith_compiler.lt_const_expr(4, -3) is None
    with pytest.raises(ValueError):
        arith_compiler.lt_const_expr(4, 16)
    assert arith_compiler.lt_const_expr(4, 15) is not None


def test_rename_rows_preserves_semantics():
    res = arith_compiler.ripple_add_program(3)
    ren = arith_compiler.rename_rows(
        res.program, {f"X{j}": f"IN{j}" for j in range(3)}
        | {f"Y{j}": f"IN{3 + j}" for j in range(3)})
    av, bv, a, b = _cols(3, 64, seed=9)
    data = {f"IN{j}": a.planes[j] for j in range(3)}
    data.update({f"IN{3 + j}": b.planes[j] for j in range(3)})
    out = engine.execute(ren, data, outputs=res.outputs)
    col = VerticalColumn(jnp.stack([out[o] for o in res.outputs]), 3, 64)
    np.testing.assert_array_equal(_decode(col, 64), (av + bv) % 8)


# -- kernels vs ref oracles ---------------------------------------------------


@pytest.mark.parametrize("n_bits,rows,words", [(1, 1, 4), (6, 3, 40),
                                               (8, 1, 130), (16, 2, 8)])
def test_bitserial_kernels_match_ref(n_bits, rows, words):
    from repro.kernels import ops as kops

    shape = (n_bits, rows, words)
    a = RNG.integers(0, 2**32, shape, dtype=np.uint32)
    b = RNG.integers(0, 2**32, shape, dtype=np.uint32)
    for sub in (False, True):
        np.testing.assert_array_equal(
            np.asarray(kops.bitserial_add(jnp.asarray(a), jnp.asarray(b),
                                          sub=sub)),
            np.asarray(ref.bitserial_add(a, b, sub=sub)), err_msg=f"sub={sub}")
    np.testing.assert_array_equal(
        np.asarray(kops.bitserial_lt(jnp.asarray(a), jnp.asarray(b))),
        np.asarray(ref.bitserial_lt(a, b)))


# -- ops layer: fast path == dram path == numpy -------------------------------


@pytest.mark.parametrize("n_bits,n", [(1, 40), (7, 200), (8, 224)])
def test_ops_all_paths_bit_identical(n_bits, n):
    av, bv, a, b = _cols(n_bits, n, seed=n)
    M = 1 << n_bits
    cases = [
        (oar.add_columns, oar.add_columns_dram, (av + bv) % M),
        (oar.sub_columns, oar.sub_columns_dram, (av - bv) % M),
    ]
    for fast, dram, exp in cases:
        for uk in (False, True):
            np.testing.assert_array_equal(
                _decode(fast(a, b, use_kernel=uk), n), exp)
        for banks in (1, 8):
            np.testing.assert_array_equal(
                _decode(dram(a, b, n_banks=banks), n), exp)
    np.testing.assert_array_equal(
        np.asarray(oar.lt_columns(a, b).to_bits()), av < bv)
    np.testing.assert_array_equal(
        np.asarray(oar.lt_columns_dram(a, b, n_banks=8).to_bits()), av < bv)
    for k in (0, 1, M // 2, M - 1, M, M + 7):
        np.testing.assert_array_equal(
            np.asarray(oar.lt_const(a, k).to_bits()), av < k, err_msg=str(k))
        np.testing.assert_array_equal(
            np.asarray(oar.lt_const_dram(a, k).to_bits()), av < k)
    assert oar.sum_column(a) == int(av.sum())
    assert oar.sum_column_dram(a, n_banks=8) == int(av.sum())


def test_ops_mismatch_errors():
    _, _, a, _ = _cols(4, 64)
    _, _, c, _ = _cols(5, 64)
    with pytest.raises(ValueError):
        oar.add_columns(a, c)
    _, _, d, _ = _cols(4, 96)
    with pytest.raises(ValueError):
        oar.lt_columns(a, d)


def test_tail_padding_never_leaks():
    """n % 32 != 0: sentinel-tail lanes must not affect counts or sums."""
    n_bits, n = 6, 45
    av, bv, a, b = _cols(n_bits, n, seed=7)
    s = oar.add_columns(a, b)
    assert oar.sum_column(s) == int(((av + bv) % 64).sum())
    assert int(oar.lt_columns(a, b).popcount()) == int((av < bv).sum())


# -- planner grammar ----------------------------------------------------------


def test_parse_any_arith_forms():
    cols = {"a": 8, "b": 8, "c": 4}
    assert parse_any("sum(a)", cols) == ArithQuery("read", ("a",), True)
    assert parse_any("sum(a + b)", cols) == ArithQuery("add", ("a", "b"),
                                                       True)
    assert parse_any("sum(a - b)", cols) == ArithQuery("sub", ("a", "b"),
                                                       True)
    assert parse_any("a + b", cols) == ArithQuery("add", ("a", "b"), False)
    with pytest.raises(QueryParseError):
        parse_any("sum(z)", cols)            # unknown column
    with pytest.raises(QueryParseError):
        parse_any("sum(a + c)", cols)        # width mismatch
    with pytest.raises(QueryParseError):
        parse_any("sum(a)", None)            # no column registry


def test_hyphenated_names_disambiguate_by_registration():
    """Tight `a-b` is disambiguated by longest-match against the catalog:
    a fully registered name stays ONE boolean leaf; an unregistered
    hyphenation whose halves are both registered columns reads as
    subtraction (the old parser mis-read the latter as a phantom leaf)."""
    from repro.core.compiler import Expr

    cols = {"weekly": 4, "total": 4}
    # registered name wins: one boolean leaf even over two column names
    e = parse_any("weekly-total", cols, names={"weekly-total"})
    assert isinstance(e, Expr) and e.op == "row" and e.row == "weekly-total"
    # unregistered hyphenation over two registered columns: subtraction
    assert parse_any("weekly-total", cols, names=set()) == \
        ArithQuery("sub", ("weekly", "total"), False)
    # whitespace before the minus always subtracts
    sub = parse_any("weekly - total", cols)
    assert sub == ArithQuery("sub", ("weekly", "total"), False)
    # same rule inside sum()
    with pytest.raises(QueryParseError):
        parse_any("sum(weekly-total)", cols, names={"weekly-total"})
    assert parse_any("sum(weekly-total)", cols, names=set()) == \
        ArithQuery("sub", ("weekly", "total"), True)
    assert parse_any("sum(weekly - total)", cols) == \
        ArithQuery("sub", ("weekly", "total"), True)


def test_comparison_grammar_expands_planes():
    cols = {"age": 7}
    e = parse_any("age < 30", cols)
    from repro.core.compiler import Expr
    assert isinstance(e, Expr)
    with pytest.raises(QueryParseError):
        parse_any("age < 0", cols)           # constant-false
    with pytest.raises(QueryParseError):
        parse_any("age < 128", cols)         # constant-true
    with pytest.raises(QueryParseError):
        parse_any("nope < 3", cols)


def test_arith_plans_cached_by_shape():
    planner = Planner()
    cols = {"p": 6, "q": 6, "r": 6}
    b1 = planner.plan("sum(p + q)", columns=cols)
    b2 = planner.plan("sum(q + r)", columns=cols)
    assert not b1.cache_hit and b2.cache_hit
    assert b1.plan is b2.plan
    assert b1.bindings[:2] == ["p.b0", "p.b1"]
    assert b2.bindings[6] == "r.b0"
    assert b1.plan.n_inputs == len(b1.bindings) == 12
    assert b1.plan.outputs == tuple(f"OUT{j}" for j in range(6))
    # sum-wrapped and bare forms of the same op share one cache entry
    b3 = planner.plan("p + q", columns=cols)
    assert b3.cache_hit and b3.plan is b1.plan


# -- service end-to-end -------------------------------------------------------


def _arith_service(n=224, seed=3):
    rng = np.random.default_rng(seed)
    svc = QueryService(n_banks=8)
    spend = rng.integers(0, 256, n, dtype=np.uint32)
    refund = rng.integers(0, 256, n, dtype=np.uint32)
    male = rng.random(n) < 0.5
    svc.register_column("spend", jnp.asarray(spend), 8)
    svc.register_column("refund", jnp.asarray(refund), 8)
    svc.register_bits("male", male)
    return svc, spend, refund, male


def test_service_sum_add_sub_lt():
    svc, spend, refund, male = _arith_service()
    assert svc.query("sum(spend)").value == int(spend.sum())
    assert svc.query("sum(spend + refund)").value == \
        int(((spend + refund) % 256).sum())
    assert svc.query("sum(spend - refund)").value == \
        int(((spend - refund) % 256).sum())
    assert svc.query("spend < refund").value == int((spend < refund).sum())
    assert svc.query("spend < 100 & male").value == \
        int(((spend < 100) & male).sum())
    # aggregate mode explicitly
    r = svc.query("spend + refund", mode=AGGREGATE)
    assert r.value == int(((spend + refund) % 256).sum())


def test_service_width1_materialize_keeps_plane_shape():
    """Regression: a 1-bit arithmetic plan still materializes as a
    (1, n_words) plane stack (not a flat vector), batched == unbatched."""
    rng = np.random.default_rng(4)
    n = 96
    p = rng.integers(0, 2, n, dtype=np.uint32)
    q = rng.integers(0, 2, n, dtype=np.uint32)
    svc = QueryService(n_banks=2)
    svc.register_column("p", jnp.asarray(p), 1)
    svc.register_column("q", jnp.asarray(q), 1)
    queries = [Query("p + q", MATERIALIZE), Query("sum(p + q)", AGGREGATE)]
    rep = svc.query_batch(queries)
    assert rep.results[0].value.shape == (1, n // 32)
    ref_rep = run_queries_unbatched(svc.catalog, queries)
    from repro.service import results_bit_identical
    assert results_bit_identical(rep.results, ref_rep.results)
    assert rep.results[1].value == int(((p + q) % 2).sum())
    col = svc.materialize_column("x", "p + q")
    assert col.n_bits == 1
    assert svc.query("sum(x)").value == int(((p + q) % 2).sum())


def test_service_materialize_column_roundtrip():
    svc, spend, refund, _ = _arith_service()
    col = svc.materialize_column("total", "spend + refund")
    assert col.n_bits == 8
    total = (spend + refund) % 256
    assert svc.query("sum(total)").value == int(total.sum())
    assert svc.query("total < 200").value == int((total < 200).sum())


def test_service_arith_cross_tenant_plan_cache_hits():
    rng = np.random.default_rng(0)
    svc = QueryService(n_banks=8)
    vals = {}
    for t in range(4):
        v = rng.integers(0, 64, 96, dtype=np.uint32)
        vals[t] = v
        svc.register_column(f"t{t}/c", jnp.asarray(v), 6)
    results = [svc.query(f"sum(t{t}/c)") for t in range(4)]
    for t, r in enumerate(results):
        assert r.value == int(vals[t].sum())
    assert [r.cache_hit for r in results] == [False, True, True, True]
    assert svc.stats()["plan_cache_misses"] == 1


def test_service_arith_batched_equals_unbatched():
    svc, spend, refund, male = _arith_service()
    queries = [
        Query("sum(spend)", AGGREGATE),
        Query("spend + refund", AGGREGATE),
        Query("sum(refund - spend)", AGGREGATE),
        Query("spend < refund"),
        Query("spend < 77 & male"),
        Query("spend + refund", MATERIALIZE),
        Query("sum(spend)", AGGREGATE),      # repeat: cache + group
    ]
    rep = svc.query_batch(queries)
    ref_rep = run_queries_unbatched(svc.catalog, queries)
    from repro.service import results_bit_identical
    assert results_bit_identical(rep.results, ref_rep.results)
    # 7 queries collapse to 5 plan groups: the two sum(spend) share one,
    # and the aggregate + materialize spend+refund pair shares another
    assert rep.n_plan_groups == 5


def test_plan_n_inputs_matches_bindings_after_simplification():
    """Regression (issue 3): simplification may eliminate a leaf from the
    compiled program; n_inputs must still equal len(bindings)."""
    planner = Planner()
    bp = planner.plan("a | (a & b)")
    assert bp.plan.n_aaps == 1            # simplified to a 1-AAP copy of a
    assert bp.bindings == ["a", "b"]      # eliminated leaf stays bound
    assert bp.plan.n_inputs == len(bp.bindings) == 2
    # and the scheduler serves it correctly end-to-end
    svc = QueryService(n_banks=2)
    rng = np.random.default_rng(1)
    a, b = rng.random(100) < 0.5, rng.random(100) < 0.5
    svc.register_bits("a", a)
    svc.register_bits("b", b)
    assert svc.query("a | (a & b)").value == int(a.sum())
    assert svc.query("a & a").value == int(a.sum())
