"""End-to-end system tests: train with checkpoint/restore + failure
injection, loss actually decreases, elastic restore to a different layout,
serving loop generates, bitmap-filter pipeline feeds training."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import get_config, reduced
from repro.data import SyntheticLM
from repro.data.bitmap_filter import (CorpusCatalog, build_filter,
                                      sample_eligible)
from repro.dist.fault_tolerance import ResilientRunner, SimulatedFailure
from repro.models import build
from repro.optim import adamw, warmup_cosine
from repro.serve.step import generate
from repro.train import make_train_step

KEY = jax.random.PRNGKey(0)


def test_end_to_end_train_ckpt_failure_resume():
    cfg = reduced(get_config("qwen3_0p6b"))
    bundle = build(cfg)
    params = bundle.init(KEY)
    opt = adamw(warmup_cosine(3e-3, 5, 60))
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=11)
    ts = jax.jit(make_train_step(bundle, opt))

    losses = []

    def step_fn(state, step, batch):
        p, s = state
        p, s, m = ts(p, s, jnp.int32(step), batch)
        losses.append(float(m["loss"]))
        return (p, s), m

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2, async_save=False)
        fails = {12: True}

        def injector(step):
            if fails.pop(step, None):
                raise SimulatedFailure("chaos")

        runner = ResilientRunner(step_fn, data.batch, ck, ckpt_every=10)
        state, rep = runner.run((params, opt.init(params)), 30,
                                failure_injector=injector)
        assert rep.failures == 1 and rep.restores >= 1
        assert rep.checkpoints >= 3
        # loss went down over the run
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5
        # a "new job" resumes exactly at the last checkpoint
        runner2 = ResilientRunner(step_fn, data.batch, ck, ckpt_every=10)
        _, rep2 = runner2.run((params, opt.init(params)), 32)
        assert rep2.timeline[0] == "resume@30"


def test_serve_generate_deterministic_greedy():
    cfg = reduced(get_config("qwen3_0p6b"))
    bundle = build(cfg)
    params = bundle.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    t1 = generate(bundle, params, batch, max_new=8)
    t2 = generate(bundle, params, batch, max_new=8)
    assert t1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_bitmap_filter_feeds_training_pipeline():
    """Paper §8.1 as data curation: filter docs, sample only eligible ids."""
    cat = CorpusCatalog.synthetic(KEY, n_docs=10_000)
    bitmap, n_ok = build_filter(cat, require=("lang_en",),
                                exclude=("toxic",),
                                ranges={"n_tokens": (128, 2048)})
    assert 0 < n_ok < 10_000
    ids = sample_eligible(KEY, bitmap, cat.n_docs, batch=64)
    # every sampled id is actually eligible
    from repro.core.bitplane import unpack_bits
    bits = np.asarray(unpack_bits(bitmap, cat.n_docs))
    assert bits[np.asarray(ids)].all()


def test_elastic_restore_changes_layout():
    """Checkpoint saved from one layout restores onto another (leaves are
    stored unsharded; device_put re-lays-out)."""
    cfg = reduced(get_config("qwen3_0p6b"))
    bundle = build(cfg)
    params = bundle.init(KEY)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=False)
        ck.save(1, params)
        _, got, _ = ck.restore(params)   # single-device "new mesh"
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
