"""Optimizers, schedules, train step, checkpointing, fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs.base import get_config, reduced
from repro.data import SyntheticLM
from repro.dist.elastic import plan_rescale
from repro.dist.fault_tolerance import (ResilientRunner, SimulatedFailure,
                                        StragglerMonitor)
from repro.models import build
from repro.optim import adafactor, adamw, clip_by_global_norm, warmup_cosine
from repro.optim.optimizers import sgd
from repro.optim.signum import pack_tree, signum, unpack_tree
from repro.train import make_train_step

KEY = jax.random.PRNGKey(0)


# ---- optimizers on a quadratic --------------------------------------------

def _quadratic_converges(opt, steps=60):
    params = {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array(1.0)}
    st = opt.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    @jax.jit
    def step(p, s, i):
        g = jax.grad(loss_fn)(p)
        return opt.update(g, s, p, i)

    for i in range(steps):
        params, st = step(params, st, jnp.int32(i))
    return float(loss_fn(params))


@pytest.mark.parametrize("name,opt", [
    ("adamw", adamw(lambda s: 0.1, weight_decay=0.0)),
    ("adafactor", adafactor(lambda s: 0.3)),
    ("sgd", sgd(lambda s: 0.05, weight_decay=0.0)),
    # sign steps need a decaying schedule to settle (constant-lr signSGD
    # oscillates in an lr-sized ball around the optimum)
    ("signum", signum(lambda s: 0.2 * 0.92 ** s, weight_decay=0.0)),
])
def test_optimizer_converges_quadratic(name, opt):
    final = _quadratic_converges(opt)
    assert final < 0.5, (name, final)


def test_warmup_cosine_shape():
    f = warmup_cosine(1.0, 10, 100)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert float(f(50)) < 1.0
    assert float(f(100)) <= 0.1 + 1e-6 + 0.9 * 0.0 + 0.11


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 20.0) < 1e-4
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4


# ---- sign pack/unpack round trip -------------------------------------------

def test_pack_unpack_tree_roundtrip():
    tree = {"a": jax.random.normal(KEY, (37,)),
            "b": {"c": jax.random.normal(jax.random.fold_in(KEY, 1), (4, 9))}}
    packed, meta = pack_tree(tree, use_kernel=False)
    signs = unpack_tree(packed, meta, use_kernel=False)
    for k, leaf in (("a", tree["a"]), ("c", tree["b"]["c"])):
        got = signs[k] if k == "a" else signs["b"]["c"]
        ref = np.where(np.asarray(leaf) < 0, -1.0, 1.0)
        np.testing.assert_array_equal(np.asarray(got), ref)


# ---- train step -------------------------------------------------------------

def test_grad_accum_equivalence():
    """accum=2 over a batch == accum=1 on the same batch (same loss value;
    grads averaged identically for per-token-mean losses on equal splits)."""
    cfg = reduced(get_config("qwen3_0p6b"))
    bundle = build(cfg)
    params = bundle.init(KEY)
    data = SyntheticLM(cfg.vocab_size, 16, 4, seed=7)
    batch = data.batch(0)
    opt = sgd(lambda s: 0.0)   # lr=0 isolates metric computation
    s1 = jax.jit(make_train_step(bundle, opt, grad_accum=1))
    s2 = jax.jit(make_train_step(bundle, opt, grad_accum=2))
    _, _, m1 = s1(params, opt.init(params), jnp.int32(0), batch)
    _, _, m2 = s2(params, opt.init(params), jnp.int32(0), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) < 5e-2


# ---- checkpoint / fault tolerance -------------------------------------------

def test_checkpointer_roundtrip_bf16():
    tree = {"w": jnp.ones((3, 5), jnp.bfloat16) * 1.5,
            "s": {"v": jnp.arange(7, dtype=jnp.float32)},
            "i": jnp.int32(42)}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=False)
        ck.save(3, tree, extra={"note": "x"})
        step, got, extra = ck.restore(tree)
        assert step == 3 and extra["note"] == "x"
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpointer_keeps_last_k_and_atomic():
    tree = {"w": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            ck.save(s, tree)
        assert ck.all_steps() == [3, 4]
        assert not [f for f in os.listdir(d) if ".tmp" in f]


def test_resilient_runner_recovers_and_resumes():
    def step_fn(state, step, batch):
        return state + 1, {"loss": jnp.float32(1.0 / (step + 1))}

    def data_fn(step):
        return step

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=3, async_save=False)
        fails = {6: True}

        def injector(step):
            if fails.pop(step, None):
                raise SimulatedFailure("boom")

        runner = ResilientRunner(step_fn, data_fn, ck, ckpt_every=4)
        state, rep = runner.run(jnp.int32(0), 10, failure_injector=injector)
        assert rep.failures == 1 and rep.restores >= 1
        # state counts every executed step incl. replays
        # resume in a "new process"
        runner2 = ResilientRunner(step_fn, data_fn, ck, ckpt_every=4)
        state2, rep2 = runner2.run(jnp.int32(0), 12)
        assert rep2.timeline[0] == "resume@10"
        assert rep2.steps_run == 2


def test_straggler_monitor():
    m = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=1)
    assert not m.observe(0, 1.0)
    assert not m.observe(1, 1.1)
    assert m.observe(2, 5.0)          # 5x the EMA -> straggler
    assert not m.observe(3, 1.0)      # EMA not poisoned by the outlier


def test_elastic_plan_preserves_global_batch():
    p = plan_rescale(global_batch=256, old_mesh_shards=16,
                     new_mesh_shards=8, old_accum=1)
    assert p.grad_accum == 2
    assert 8 * (256 // (16 * 1)) * p.grad_accum == 256


def test_data_pipeline_deterministic():
    d1 = SyntheticLM(1000, 16, 4, seed=3)
    d2 = SyntheticLM(1000, 16, 4, seed=3)
    b1, b2 = d1.batch(5), d2.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d1.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
