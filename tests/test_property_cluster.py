"""Property tests: chip-sharded execution == the single-chip oracle.

Random AAP/AP programs (the generator of test_property_lowering) over
random word counts — including widths that do not divide the slot grid, so
the zero-padding path is always in play — must produce bit-identical rows
when executed on a `ChipCluster` of any (chips x banks) layout; and a
distributed catalog must survive any sequence of elastic rescales with
every registered vector intact.

Multi-chip layouts are exercised in-process when the host exposes >= 2
devices (the CI multi-device job forces 8); on a single device the chip
axis degenerates to 1 and the padding/sweep layout logic is still fully
exercised (sweeps > 1 folds the extra slot rows onto the one chip).
"""
import jax
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from test_property_lowering import _random_program

from repro.core import engine
from repro.core.cluster import ChipCluster
from repro.service import QueryService
from repro.service.scheduler import (Query, results_bit_identical,
                                     run_queries_unbatched)

N_DEV = len(jax.devices())


def _layouts(rng):
    """A random (n_chips, n_banks, max_chips) layout the host can run."""
    n_chips = int(rng.choice([c for c in (1, 2, 4) if c <= N_DEV]))
    n_banks = int(rng.integers(1, 4))
    max_chips = n_chips * int(rng.integers(1, 4))
    return n_chips, n_banks, max_chips


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_sharded_random_programs_match_oracle(seed):
    rng = np.random.default_rng(seed)
    program = _random_program(rng)
    n_words = int(rng.integers(1, 40))      # rarely divides the slot grid
    n_data = int(rng.integers(1, 5))
    data = {f"D{i}": rng.integers(0, 1 << 32, n_words, dtype=np.uint32)
            for i in range(n_data)}
    n_chips, n_banks, max_chips = _layouts(rng)
    cl = ChipCluster.create(n_chips, n_banks=n_banks, max_chips=max_chips)
    ref = engine.execute(program, data, lowered=False)
    out = cl.execute(program, data)
    assert set(ref) == set(out)
    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(ref[k]), np.asarray(out[k]),
            err_msg=f"{k} @ chips={n_chips} banks={n_banks} "
                    f"max={max_chips} words={n_words}")


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_rescale_chain_preserves_every_vector(seed):
    rng = np.random.default_rng(seed)
    n_bits = int(rng.integers(40, 400))
    svc = QueryService(n_banks=int(rng.integers(1, 4)), n_chips=1,
                       max_chips=4)
    names = [f"v{i}" for i in range(int(rng.integers(2, 6)))]
    for n in names:
        svc.register_bits(n, rng.integers(0, 2, n_bits),
                          group=f"g{int(rng.integers(2))}")
    before = {n: np.asarray(svc.catalog.get(n).words) for n in names}
    q = [Query(f"{names[0]} & {names[-1]}"), Query(names[0])]
    r0 = svc.query_batch(list(q))
    chain = [c for c in (2, 4, 1, 2) if c <= N_DEV]
    for chips in chain:
        svc.rescale(chips)
        assert sorted(svc.catalog.names()) == sorted(names)
        for n in names:
            assert np.array_equal(
                np.asarray(svc.catalog.get(n).words), before[n]), n
            gathered = np.asarray(svc.cluster.unshard_words(
                svc.catalog.shards(n), before[n].shape[0]))
            assert np.array_equal(gathered, before[n]), (n, chips)
        r = svc.query_batch(list(q))
        assert results_bit_identical(r0.results, r.results), chips
    ru = run_queries_unbatched(svc.catalog, list(q))
    assert results_bit_identical(r0.results, ru.results)
