"""Per-kernel shape/dtype sweeps asserting exact equality vs ref.py oracles.

Pallas kernels run in interpret mode on CPU (TPU is the compile target);
interpret executes the kernel body per grid cell, so these sweeps exercise
multi-cell grids, padding/tail handling, and block-size overrides.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitplane import unpack_bits
from repro.kernels import ops, ref

RNG = np.random.default_rng(123)


def words(*shape):
    return RNG.integers(0, 2**32, shape, dtype=np.uint32)


# ---------------------------------------------------------------------------
# fused bitwise
# ---------------------------------------------------------------------------

SHAPES = [(1, 128), (8, 128), (3, 100), (16, 384), (17, 999)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("op", ["and", "or", "xor", "nand", "nor", "xnor",
                                "andnot"])
def test_bitwise_binary(op, shape):
    a, b = words(*shape), words(*shape)
    got = np.asarray(ops.bitwise(op, a, b, block_rows=8, block_cols=128))
    exp = np.asarray(ref.bitwise(op, a, b))
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("shape", SHAPES)
def test_bitwise_not_maj3(shape):
    a, b, c = words(*shape), words(*shape), words(*shape)
    np.testing.assert_array_equal(
        np.asarray(ops.bitwise("not", a, block_rows=8, block_cols=128)),
        np.asarray(ref.bitwise("not", a)))
    np.testing.assert_array_equal(
        np.asarray(ops.bitwise("maj3", a, b, c, block_rows=8, block_cols=128)),
        np.asarray(ref.bitwise("maj3", a, b, c)))


def test_bitwise_1d():
    a, b = words(256), words(256)
    np.testing.assert_array_equal(np.asarray(ops.bitwise("xor", a, b)), a ^ b)


# ---------------------------------------------------------------------------
# majority-k (generalized TRA)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 3, 4, 5, 8, 9, 15, 16, 33])
def test_majority_k(k):
    planes = words(k, 8, 128)
    got = np.asarray(ops.majority(jnp.asarray(planes)))
    exp = np.asarray(ref.majority_k(jnp.asarray(planes)))
    np.testing.assert_array_equal(got, exp)


def test_majority3_equals_tra():
    """MAJ3 kernel == the engine's triple-row activation semantics."""
    from repro.core import compiler, engine

    a, b, c = words(64), words(64), words(64)
    prog = compiler.op_program("maj3", ["D0", "D1", "D2"], "D3")
    tra = engine.execute(prog, {"D0": a, "D1": b, "D2": c}, outputs=["D3"])["D3"]
    ker = ops.majority(jnp.stack([a, b, c]))
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(tra))


@pytest.mark.parametrize("k,thresh", [(8, 1), (8, 8), (5, 2), (16, 11)])
def test_majority_custom_threshold(k, thresh):
    planes = words(k, 8, 128)
    got = np.asarray(ops.majority(jnp.asarray(planes), threshold=thresh))
    exp = np.asarray(ref.majority_k(jnp.asarray(planes), threshold=thresh))
    np.testing.assert_array_equal(got, exp)


# ---------------------------------------------------------------------------
# popcount
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(8, 128), (1, 1), (5, 300), (32, 1000)])
def test_popcount(shape):
    x = words(*shape)
    got = int(ops.popcount(x, block_rows=8, block_cols=128))
    exp = int(np.unpackbits(x.view(np.uint8)).sum())
    assert got == exp


def test_popcount_extremes():
    assert int(ops.popcount(np.zeros((8, 128), np.uint32))) == 0
    assert int(ops.popcount(np.full((8, 128), 0xFFFFFFFF, np.uint32))) == 8 * 128 * 32


# ---------------------------------------------------------------------------
# bit transpose (BitWeaving-V layout)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_bits", [1, 4, 7, 12, 16, 32])
@pytest.mark.parametrize("n_vals", [32, 320, 32 * 200])
def test_bit_transpose(n_bits, n_vals):
    vals = RNG.integers(0, 2**n_bits, n_vals, dtype=np.uint64).astype(np.uint32)
    got = np.asarray(ops.bit_transpose(jnp.asarray(vals), n_bits,
                                       block_groups=128))
    exp = np.asarray(ref.bit_transpose(jnp.asarray(vals), n_bits))
    np.testing.assert_array_equal(got, exp)
    # roundtrip
    back = np.asarray(ops.bit_untranspose(jnp.asarray(got), n_bits,
                                          block_groups=128))
    np.testing.assert_array_equal(back, vals)


# ---------------------------------------------------------------------------
# bitweaving predicate scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_bits", [4, 8, 12, 16])
def test_bitweaving_scan_sweep(n_bits):
    n = 32 * 96
    vals = RNG.integers(0, 2**n_bits, n, dtype=np.uint64).astype(np.uint32)
    planes = ref.bit_transpose(jnp.asarray(vals), n_bits)
    lo = int(RNG.integers(0, 2**n_bits // 2))
    hi = int(RNG.integers(lo, 2**n_bits))
    got = ops.bitweaving_scan(planes, lo, hi, n_bits, block_cols=128)
    bits = np.asarray(unpack_bits(got, n))
    np.testing.assert_array_equal(bits, (vals >= lo) & (vals <= hi))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.bitweaving_scan(planes, lo, hi, n_bits)))


def test_bitweaving_scan_edge_constants():
    n_bits, n = 8, 32 * 8
    vals = RNG.integers(0, 256, n, dtype=np.uint64).astype(np.uint32)
    planes = ref.bit_transpose(jnp.asarray(vals), n_bits)
    for lo, hi in [(0, 255), (0, 0), (255, 255), (7, 7)]:
        got = np.asarray(unpack_bits(
            ops.bitweaving_scan(planes, lo, hi, n_bits), n))
        np.testing.assert_array_equal(got, (vals >= lo) & (vals <= hi))


# ---------------------------------------------------------------------------
# sign pack / unpack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1, 32), (8, 320), (5, 32 * 50)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_pack_signs(shape, dtype):
    x = RNG.standard_normal(shape).astype(dtype)
    got = np.asarray(ops.pack_signs(jnp.asarray(x), block_rows=8,
                                    block_words=128))
    exp = np.asarray(ref.pack_signs(jnp.asarray(x)))
    np.testing.assert_array_equal(got, exp)


def test_pack_unpack_roundtrip_signs():
    x = RNG.standard_normal((4, 320)).astype(np.float32)
    x[x == 0] = 1.0
    w = ops.pack_signs(jnp.asarray(x))
    u = np.asarray(ops.unpack_signs(w))
    np.testing.assert_array_equal(u, np.where(x < 0, -1.0, 1.0).astype(np.float32))


def test_pack_signs_negative_zero():
    """IEEE -0.0 has the sign bit set; bitcast path must agree with ref."""
    x = np.array([[0.0, -0.0, 1.0, -1.0] * 8], np.float32)
    got = np.asarray(ops.pack_signs(jnp.asarray(x)))
    exp = np.asarray(ref.pack_signs(jnp.asarray(x)))
    np.testing.assert_array_equal(got, exp)
