"""Continuous-serving runtime tests: `service.server.ServingLoop`.

Covers the PR's acceptance surface: slot-packing occupancy invariants,
SLO admission control (shed + defer), DRR hog-tenant fairness, bit
identity of loop results against the sequential unbatched reference,
live-mode submit()/handle lifecycle, chaos recovery mid-loop, and a
property suite over random traces (no query lost, duplicated, or
reordered within a tenant). The redesigned service surface
(ServiceConfig, submit/flush, deprecation shims) is tested at the
bottom.
"""
import threading

import jax
import numpy as np
import pytest

from repro.dist.fault_tolerance import (ChipFailure, FaultTolerance,
                                        SimulatedFailure)
from repro.obs import Telemetry
from repro.obs.trace import validate_chrome_trace
from repro.service import (DEFER, MATERIALIZE, Arrival, Query, QueryHandle,
                           QueryService, QueryShedError, ServiceConfig,
                           SloConfig, results_bit_identical,
                           run_queries_unbatched)

N_DEV = len(jax.devices())

multichip = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >=2 devices (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=8 before jax imports)")

EXPRS = ["a & b", "a | c", "b ^ d", "~a & c", "a & b & c", "d | ~b",
         "(a ^ b) | (c & d)", "a | b | c | d"]


def _service(n_banks=4, **kwargs):
    svc = QueryService(ServiceConfig(n_banks=n_banks, **kwargs))
    rng = np.random.default_rng(11)     # same catalog for every service
    for n in "abcd":
        svc.register_bits(n, rng.integers(0, 2, 640).astype(bool),
                          group="t")
    return svc


def _trace(n, *, spacing_ns=20_000.0, tenants=("t0", "t1", "t2"),
           priority=lambda i: 0):
    return [Arrival(t_ns=i * spacing_ns,
                    query=Query(EXPRS[i % len(EXPRS)],
                                tenant=tenants[i % len(tenants)]),
                    priority=priority(i))
            for i in range(n)]


def _assert_conserved(arrivals, rep):
    """No query lost or duplicated: every arrival index appears exactly
    once across served + shed records."""
    idx = sorted(r.index for r in rep.records)
    assert idx == list(range(len(arrivals)))


def _assert_tenant_order(rep):
    """Within a tenant, completion order == arrival order (no reorder)."""
    by_tenant = {}
    for r in sorted(rep.served, key=lambda r: (r.complete_ns, r.index)):
        by_tenant.setdefault(r.tenant, []).append(r.arrival_ns)
    for t, seq in by_tenant.items():
        assert seq == sorted(seq), f"tenant {t} served out of order: {seq}"


# ---------------------------------------------------------------------------
# slot packing + determinism
# ---------------------------------------------------------------------------


def test_occupancy_invariants_saturated_burst():
    svc = _service()
    arrivals = _trace(24, spacing_ns=0.0)
    loop = svc.serve_loop(depth=2)          # capacity 8
    rep = loop.run_trace(arrivals)
    assert rep.capacity == 8
    assert len(rep.served) == 24 and not rep.shed
    for t in rep.ticks:
        assert 0 < t.n_queries <= rep.capacity
        assert t.occupancy == t.n_queries / rep.capacity
    # a time-zero burst must pack full ticks while backlogged
    assert [t.n_queries for t in rep.ticks[:-1]] == [8, 8]
    assert rep.occupancy_mean > 0.9
    _assert_conserved(arrivals, rep)
    _assert_tenant_order(rep)


def test_trace_replay_deterministic_and_pipeline_invariant():
    svc = _service()
    arrivals = _trace(20)
    r1 = svc.serve_loop(depth=2).run_trace(arrivals, pipeline=True)
    r2 = svc.serve_loop(depth=2).run_trace(arrivals, pipeline=True)
    r3 = svc.serve_loop(depth=2).run_trace(arrivals, pipeline=False)
    for other in (r2, r3):
        assert [(t.tick, t.start_ns, t.makespan_ns, t.n_queries)
                for t in r1.ticks] == \
               [(t.tick, t.start_ns, t.makespan_ns, t.n_queries)
                for t in other.ticks]
        assert [(r.index, r.status, r.complete_ns) for r in r1.records] == \
               [(r.index, r.status, r.complete_ns) for r in other.records]


def test_loop_results_bit_identical_to_unbatched():
    svc = _service()
    arrivals = _trace(16)
    arrivals[3] = Arrival(t_ns=arrivals[3].t_ns,
                          query=Query("a & ~b", MATERIALIZE, tenant="t0"))
    rep = svc.serve_loop(depth=2).run_trace(arrivals)
    ref = run_queries_unbatched(svc.catalog, [a.query for a in arrivals])
    assert results_bit_identical(rep.results(), ref.results)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_slo_shed_protects_served_p99():
    svc = _service()
    arrivals = _trace(40, spacing_ns=0.0)
    # calibrate the target from an unarmed probe (as the benchmark
    # does): the unthrottled median guarantees a genuine breach while
    # leaving a backlog that still fits under the target
    probe = svc.serve_loop(depth=1, capacity=4).run_trace(arrivals)
    slo = SloConfig(p99_ns=probe.sojourn_percentile_ns(50))
    rep = svc.serve_loop(depth=1, capacity=4, slo=slo).run_trace(arrivals)
    assert rep.shed, "overload must shed"
    assert len(rep.served) + len(rep.shed) == 40
    assert all(r.shed_reason == "slo" for r in rep.shed)
    # the served population keeps the target (that is the point of
    # shedding); EMA estimation error gets a small tolerance
    assert rep.sojourn_percentile_ns(99) <= 1.5 * slo.p99_ns
    assert rep.sojourn_percentile_ns(99) < probe.sojourn_percentile_ns(99)
    _assert_conserved(arrivals, rep)


def test_slo_shed_sacrifices_low_priority_to_rescue_high():
    """Victim selection is lowest-priority-first: shedding stale
    low-priority queries pulls the high-priority queries queued behind
    them under the target, so they serve instead of shedding."""
    svc = _service()
    warm = [Arrival(t_ns=0.0, query=Query(EXPRS[i], tenant="t0"),
                    priority=1) for i in range(4)]
    # probe: tick-0 completion time and the per-query EMA it seeds
    probe = svc.serve_loop(depth=1, capacity=4).run_trace(warm)
    done_ns = max(r.complete_ns for r in probe.served)
    est = done_ns / 4
    # two stale low-priority queries queued since t=0 (irredeemably over
    # a 3*est target once tick 0 completes) ahead of two fresh
    # high-priority queries that fit once the stale ones are dropped
    arrivals = warm + [
        Arrival(t_ns=0.0, query=Query(EXPRS[4], tenant="t0"), priority=0),
        Arrival(t_ns=0.0, query=Query(EXPRS[5], tenant="t0"), priority=0),
        Arrival(t_ns=0.9 * done_ns, query=Query(EXPRS[6], tenant="t0"),
                priority=1),
        Arrival(t_ns=0.9 * done_ns, query=Query(EXPRS[7], tenant="t0"),
                priority=1),
    ]
    loop = svc.serve_loop(depth=1, capacity=4,
                          slo=SloConfig(p99_ns=3 * est))
    # serial mode: pipelined formation would pack the stale queries into
    # tick 1 before tick 0 seeds the EMA the projection needs
    rep = loop.run_trace(arrivals, pipeline=False)
    assert [r.index for r in rep.shed] == [4, 5]
    assert all(r.priority == 0 and r.shed_reason == "slo"
               for r in rep.shed)
    assert sorted(r.index for r in rep.served) == [0, 1, 2, 3, 6, 7]
    _assert_conserved(arrivals, rep)


def test_slo_defer_parks_low_priority_without_loss():
    svc = _service(slo=SloConfig(p99_ns=3e3, policy=DEFER))
    arrivals = _trace(40, spacing_ns=0.0, priority=lambda i: i % 2)
    rep = svc.serve_loop(depth=1, capacity=4).run_trace(arrivals)
    assert not rep.shed and len(rep.served) == 40
    assert rep.deferred_total > 0
    _assert_conserved(arrivals, rep)
    _assert_tenant_order(rep)
    # deferral favors the high-priority class: its average completion
    # lands earlier than the parked class's
    mean = lambda xs: sum(xs) / len(xs)  # noqa: E731
    hi = mean([r.complete_ns for r in rep.served if r.priority == 1])
    lo = mean([r.complete_ns for r in rep.served if r.priority == 0])
    assert hi < lo


def test_deadline_expiry_sheds_regardless_of_policy():
    svc = _service()                         # no SLO at all
    arrivals = [Arrival(t_ns=0.0, query=Query(EXPRS[i % len(EXPRS)],
                                              tenant="t0"),
                        deadline_ns=(None if i < 4 else 1.0))
                for i in range(16)]
    rep = svc.serve_loop(depth=1, capacity=4).run_trace(arrivals)
    # ticks 0 and 1 both form at t=0 (pipelined lookahead), serving 8;
    # everything still queued at the next formation — which happens at
    # modeled now > 0 — is past its 1ns relative deadline
    assert sorted(r.index for r in rep.shed) == list(range(8, 16))
    assert all(r.shed_reason == "deadline" for r in rep.shed)
    _assert_conserved(arrivals, rep)


def test_backpressure_max_queue():
    svc = _service()
    arrivals = _trace(30, spacing_ns=0.0)
    rep = svc.serve_loop(depth=1, capacity=4,
                         max_queue=8).run_trace(arrivals)
    assert any(r.shed_reason == "backpressure" for r in rep.shed)
    _assert_conserved(arrivals, rep)


# ---------------------------------------------------------------------------
# fairness
# ---------------------------------------------------------------------------


def test_drr_fairness_hog_cannot_starve_light_tenant():
    svc = _service()
    hog = [Arrival(t_ns=0.0, query=Query(EXPRS[i % len(EXPRS)],
                                         tenant="hog"))
           for i in range(40)]
    light = [Arrival(t_ns=0.0, query=Query(EXPRS[i % len(EXPRS)],
                                           tenant="light"))
             for i in range(4)]
    rep = svc.serve_loop(depth=1, capacity=8,
                         drr_quantum=4).run_trace(hog + light)
    done = {t: max(r.complete_ns for r in rep.served if r.tenant == t)
            for t in ("hog", "light")}
    # the light tenant drains long before the hog's backlog does
    assert done["light"] < done["hog"]
    light_ticks = {r.tick for r in rep.served if r.tenant == "light"}
    # DRR seats the light tenant in the earliest ticks alongside the hog
    assert min(light_ticks) == 0
    _assert_tenant_order(rep)


# ---------------------------------------------------------------------------
# property suite: random traces
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_trace_conservation_properties(seed):
    rng = np.random.default_rng(seed)
    svc = _service(slo=SloConfig(p99_ns=float(rng.integers(2e3, 2e4)))
                   if seed % 2 else None)
    n = int(rng.integers(10, 40))
    arrivals = [
        Arrival(t_ns=float(rng.integers(0, 200_000)),
                query=Query(EXPRS[int(rng.integers(len(EXPRS)))],
                            tenant=f"t{int(rng.integers(3))}"),
                priority=int(rng.integers(2)))
        for _ in range(n)
    ]
    loop = svc.serve_loop(depth=int(rng.integers(1, 4)),
                          drr_quantum=int(rng.integers(1, 6)))
    rep = loop.run_trace(arrivals)
    ordered = sorted(arrivals, key=lambda a: a.t_ns)
    _assert_conserved(arrivals, rep)
    _assert_tenant_order(rep)
    # served results match the reference for exactly the served subset
    served = [r for r in rep.records if r.status == "served"]
    ref = run_queries_unbatched(svc.catalog,
                                [ordered[r.index].query for r in served])
    assert results_bit_identical([r.result for r in served], ref.results)
    # no handle-style leakage: every shed record names a reason
    assert all(r.shed_reason for r in rep.shed)


# ---------------------------------------------------------------------------
# live mode
# ---------------------------------------------------------------------------


def test_live_submit_resolves_handles():
    svc = _service()
    loop = svc.serve_loop(depth=2)
    loop.start()
    try:
        handles = [svc.submit(EXPRS[i % len(EXPRS)], tenant="t0")
                   for i in range(6)]
        results = [h.result(timeout=60.0) for h in handles]
    finally:
        rep = loop.stop()
    assert all(h.done() for h in handles)
    ref = run_queries_unbatched(
        svc.catalog, [Query(EXPRS[i % len(EXPRS)], tenant="t0")
                      for i in range(6)])
    assert results_bit_identical(results, ref.results)
    assert len(rep.served) == 6
    # after stop() the service's direct path serves again
    assert svc.query("a & b").value == ref.results[0].value


def test_live_stop_without_drain_sheds():
    svc = _service()
    loop = svc.serve_loop(depth=1)
    # stall the loop so the queue cannot drain before stop()
    gate = threading.Event()
    orig = loop.scheduler.plan_queries

    def slow_plan(queries):
        gate.wait(5.0)
        return orig(queries)

    loop.scheduler.plan_queries = slow_plan
    loop.start()
    try:
        handles = [loop.submit(EXPRS[i % 4], tenant="t0")
                   for i in range(8)]
    finally:
        gate.set()
        rep = loop.stop(drain=False)
    shed = [h for h in handles if h.status == "shed"]
    served = [h for h in handles if h.status == "done"]
    assert len(shed) + len(served) == 8
    for h in shed:
        with pytest.raises(QueryShedError, match="shutdown"):
            h.result(timeout=1.0)
    assert len(rep.records) == 8


def test_live_submit_after_stop_raises():
    svc = _service()
    loop = svc.serve_loop()
    loop.start()
    loop.stop()
    with pytest.raises(RuntimeError, match="not accepting"):
        loop.submit("a & b")


# ---------------------------------------------------------------------------
# telemetry integration
# ---------------------------------------------------------------------------


def test_loop_trace_and_metrics():
    tel = Telemetry(trace=True)
    svc = _service(telemetry=tel)
    arrivals = _trace(12, spacing_ns=0.0)
    rep = svc.serve_loop(depth=2).run_trace(arrivals)
    assert not rep.pipelined            # tracing forces serial mode
    payload = tel.tracer.export()
    validate_chrome_trace(payload)
    names = [e["name"] for e in payload["traceEvents"]]
    assert "tick" in names and "tick_plan" in names
    counters = [e for e in payload["traceEvents"] if e["ph"] == "C"]
    assert counters and all(e["name"] == "serve_queue_depth"
                            for e in counters)
    m = tel.metrics
    assert m.counter("serve_admitted_total").value == 12
    assert m.counter("serve_ticks_total").value == len(rep.ticks)
    assert m.histogram("serve_tick_occupancy").count == len(rep.ticks)
    s = svc.stats()
    assert s["serve_ticks"] == len(rep.ticks)
    assert "serve_queue_depth" in s


# ---------------------------------------------------------------------------
# chaos: failures mid-loop
# ---------------------------------------------------------------------------


def test_loop_replays_transient_failure_bit_identical():
    clean_svc = _service()
    arrivals = _trace(12, spacing_ns=0.0)
    clean = clean_svc.serve_loop(depth=2).run_trace(arrivals)

    ft = FaultTolerance(max_replays=2)
    armed = {"live": True}

    def inject(g):
        if armed["live"]:
            armed["live"] = False
            raise SimulatedFailure("transient kernel fault mid-tick")

    ft.failure_injector = inject
    svc = _service(fault_tolerance=ft)
    rep = svc.serve_loop(depth=2).run_trace(arrivals)
    assert ft.failures == 1 and ft.replays == 1
    assert results_bit_identical(rep.results(), clean.results())


@multichip
@pytest.mark.chaos
def test_loop_chip_kill_mid_trace_drains_and_recovers():
    def build(ft=None):
        svc = QueryService(ServiceConfig(n_banks=4, n_chips=2, max_chips=4,
                                         fault_tolerance=ft))
        rng = np.random.default_rng(5)
        for n in "abcd":
            svc.register_bits(n, rng.integers(0, 2, 640).astype(bool),
                              group="t")
        return svc

    arrivals = _trace(12, spacing_ns=0.0)
    clean = build().serve_loop(depth=2).run_trace(arrivals)

    ft = FaultTolerance(max_replays=2)
    armed = {"live": True}

    def inject(g):
        if armed["live"]:
            armed["live"] = False
            raise ChipFailure(1)

    ft.failure_injector = inject
    svc = build(ft)
    rep = svc.serve_loop(depth=2).run_trace(arrivals)
    assert svc.n_chips == 1             # elastic rescale-down happened
    assert any(t.startswith("rescale@") for t in ft.timeline)
    assert results_bit_identical(rep.results(), clean.results())


# ---------------------------------------------------------------------------
# redesigned service surface
# ---------------------------------------------------------------------------


def test_service_config_consolidation_and_shims():
    cfg = ServiceConfig(n_banks=4, slo=SloConfig(p99_ns=1e6))
    svc = QueryService(cfg)
    assert svc.config is cfg and svc.n_banks == 4
    assert svc.serve_loop().slo.p99_ns == 1e6   # config slo is the default
    # keyword shim: deprecated deployment keywords still work, warn once
    with pytest.warns(DeprecationWarning, match="ServiceConfig"):
        svc2 = QueryService(n_banks=4, backend="scan")
    assert svc2.config.backend == "scan"
    # non-deprecated convenience keywords stay silent
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        QueryService(n_banks=4, optimize=False)
    with pytest.raises(TypeError, match="unknown keyword"):
        QueryService(bogus=1)


def test_submit_handle_eager_and_deferred():
    svc = _service()
    h = svc.submit("a & b", tenant="t0")
    assert isinstance(h, QueryHandle) and h.done()
    expect = h.result().value
    # deferred handles park until flush() serves them as one batch
    hs = [svc.submit(e, defer=True) for e in EXPRS[:4]]
    assert not any(h.done() for h in hs)
    rep = svc.flush()
    assert all(h.done() for h in hs)
    assert [h.result() for h in hs] == list(rep.results)
    assert svc.submit("a & b").result().value == expect


def test_query_batch_rides_the_handle_model():
    svc = _service()
    queries = [Query(e, tenant="t0") for e in EXPRS[:5]]
    rep = svc.query_batch(queries)
    ref = run_queries_unbatched(svc.catalog, queries)
    assert results_bit_identical(rep.results, ref.results)


def test_canonical_result_shape_scalar_everywhere():
    svc = _service()
    pop = svc.query("a & b")
    assert pop.scalar == pop.value
    mat = svc.query("a & b", mode=MATERIALIZE)
    assert mat.scalar == pop.value      # free popcount on materialize
    assert mat.planes.ndim == 2 and mat.planes.shape[0] == 1
    assert np.array_equal(mat.words, np.asarray(mat.value))
    with pytest.raises(ValueError):
        pop.planes


