"""Property test: lowered executors == interpreter on random programs.

Random valid AAP/AP command sequences — primitive Fig. 8 programs (TRA
and/or/maj3, DCC-negation not/nand/nor/xor/xnor, RowClone copy/zero/one)
plus raw AAP/AP commands over B-group addresses (TRA addresses, DCC d-/n-
wordlines, designated-row stages) — executed over 1-64 random D-group rows.
The `jax.lax.scan` VM and the Pallas megakernel must reproduce
`Subarray.run` exactly on every row of the final state.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import compiler, engine, lowering
from repro.core.commands import AAP, AP, Program

W = 4
N_ROWS = 8      # D-row pool; programs draw operands from D0..D7

_PRIMS = ["and", "or", "nand", "nor", "xor", "xnor", "maj3", "andnot",
          "not", "copy", "zero", "one"]
# raw addr1 candidates: anything legal as a first ACTIVATE (1 or 3
# wordlines; B8-B11 raise 2 and are analog-undefined from precharge)
_RAW_ADDR1 = [f"D{i}" for i in range(N_ROWS)] + \
    ["B0", "B1", "B2", "B3", "B4", "B5", "B6", "B7",
     "B12", "B13", "B14", "B15", "C0", "C1"]
_RAW_ADDR2 = _RAW_ADDR1 + ["B8", "B9", "B10", "B11"]


def _random_program(rng) -> Program:
    cmds = []
    n = int(rng.integers(1, 12))
    for _ in range(n):
        kind = int(rng.integers(0, 3))
        if kind == 0:       # a primitive op program over random D rows
            op = _PRIMS[int(rng.integers(len(_PRIMS)))]
            rows = [f"D{int(i)}" for i in rng.integers(0, N_ROWS, 4)]
            if op in ("not", "copy"):
                prog = getattr(compiler, f"{op}_program")(rows[0], rows[1])
            elif op in ("zero", "one"):
                prog = getattr(compiler, f"{op}_program")(rows[0])
            elif op == "maj3":
                prog = compiler.maj3_program(*rows)
            else:
                prog = getattr(compiler, f"{op}_program")(*rows[:3])
            cmds.extend(prog.commands)
        elif kind == 1:     # raw AAP over any legal address pair
            a1 = _RAW_ADDR1[int(rng.integers(len(_RAW_ADDR1)))]
            a2 = _RAW_ADDR2[int(rng.integers(len(_RAW_ADDR2)))]
            cmds.append(AAP(a1, a2))
        else:               # raw AP (destructive TRA or a no-op restore)
            cmds.append(AP(_RAW_ADDR1[int(rng.integers(len(_RAW_ADDR1)))]))
    return Program(cmds, "random")


@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_lowered_backends_match_interpreter(seed, n_data):
    rng = np.random.default_rng(seed)
    program = _random_program(rng)
    n_data = min(n_data, N_ROWS)
    data = {f"D{i}": rng.integers(0, 1 << 32, W, dtype=np.uint32)
            for i in range(n_data)}
    ref = engine.execute(program, data, lowered=False)
    scan = engine.execute(program, data, lowered=True, backend="scan")
    assert set(ref) == set(scan)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(scan[k]), err_msg=k)
    # megakernel on the program's written rows (the VMEM-resident path)
    lp = lowering.lower(program)
    outs = [r for r in lp.writes if r != lowering.SINK]
    if outs:
        mega = engine.execute(program, data, outputs=outs,
                              lowered=True, backend="pallas")
        for k in outs:
            np.testing.assert_array_equal(np.asarray(ref[k]),
                                          np.asarray(mega[k]), err_msg=k)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_lowered_banked_matches_interpreter(seed):
    rng = np.random.default_rng(seed)
    program = _random_program(rng)
    data = {f"D{i}": rng.integers(0, 1 << 32, 12, dtype=np.uint32)
            for i in range(4)}
    ref = engine.execute(program, data, lowered=False)
    banked = engine.execute(program, data, n_banks=2, lowered=True)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(banked[k]), err_msg=k)


# -- streamed multi-block megakernel ------------------------------------------
#
# The Pallas VM streams the plane HBM->VMEM in block_cols-wide grid blocks
# and folds batch axes into the launch grid. 520 words at block_cols=128 is
# 5 grid blocks (the last partial) — the properties below pin the streamed
# path to the interpreter/scan oracle across batch layouts, with TRA error
# injection, and through the fused count epilogue.

STREAM_W = 520
STREAM_BLOCK = 128
_BATCHES = [(), (2,), (2, 2)]


def _stream_setup(rng, batch):
    program = _random_program(rng)
    lp = lowering.lower(program)
    data = {f"D{i}": rng.integers(0, 1 << 32, batch + (STREAM_W,),
                                  dtype=np.uint32) for i in range(4)}
    outs = [r for r in lp.writes if r != lowering.SINK]
    return program, lp, data, outs


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_streamed_megakernel_matches_oracle_across_batches(seed):
    from repro.kernels.vm import run_megakernel

    rng = np.random.default_rng(seed)
    batch = _BATCHES[seed % len(_BATCHES)]
    program, lp, data, outs = _stream_setup(rng, batch)
    if not outs:
        return
    ref = engine.execute(program, data, outputs=outs, lowered=False)
    plane = lowering.make_plane(lp, data, STREAM_W, batch=batch)
    got = run_megakernel(lp, plane, tuple(outs), block_cols=STREAM_BLOCK)
    for j, k in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(got[j]),
                                      np.asarray(ref[k]), err_msg=k)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_streamed_megakernel_error_injection_matches_scan(seed):
    """Identical seeded TRA fault masks -> bit-identical faulty state on
    the scan VM and the multi-block streamed megakernel."""
    import jax

    from repro.core.errors import TRAErrorModel, error_planes
    from repro.kernels.vm import run_megakernel

    rng = np.random.default_rng(seed)
    batch = _BATCHES[seed % len(_BATCHES)]
    program, lp, data, outs = _stream_setup(rng, batch)
    if not outs:
        return
    masks = error_planes(lp.table, jax.random.PRNGKey(seed), batch,
                         STREAM_W, TRAErrorModel(p_flip=0.05))
    faulty_scan = lowering.execute_lowered(lp, data, STREAM_W, outs,
                                           backend="scan", errors=masks)
    plane = lowering.make_plane(lp, data, STREAM_W, batch=batch)
    got = run_megakernel(lp, plane, tuple(outs), block_cols=STREAM_BLOCK,
                         errors=masks)
    for j, k in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(got[j]),
                                      np.asarray(faulty_scan[k]), err_msg=k)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_fused_popcount_equals_materialize_then_popcount(seed):
    """reduce="popcount" / "aggregate" on the streamed kernel == popcount
    of the materialized planes, for every random program and batch."""
    from repro.kernels.vm import run_megakernel
    from repro.ops.popcount import popcount_words

    rng = np.random.default_rng(seed)
    batch = _BATCHES[seed % len(_BATCHES)]
    program, lp, data, outs = _stream_setup(rng, batch)
    if not outs:
        return
    plane = lowering.make_plane(lp, data, STREAM_W, batch=batch)
    rows = run_megakernel(lp, plane, tuple(outs), block_cols=STREAM_BLOCK)
    counts = run_megakernel(lp, plane, tuple(outs),
                            block_cols=STREAM_BLOCK, reduce="popcount")
    ref = popcount_words(rows, axis=-1)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref))
    agg = run_megakernel(lp, plane, tuple(outs), block_cols=STREAM_BLOCK,
                         reduce="aggregate")
    want = sum(np.asarray(ref[j], np.float32) * float(1 << j)
               for j in range(len(outs)))
    np.testing.assert_allclose(np.asarray(agg), np.asarray(want), rtol=1e-6)
