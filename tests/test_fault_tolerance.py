"""Fault-tolerance internals: straggler EMA, checkpoint damage recovery,
and the async-save race `ResilientRunner._restore` must never lose.

The serving-path integration (scheduler replay, chip-kill rescale,
serve_stream) lives in tests/test_chaos.py; this file pins the unit-level
contracts those flows stand on.
"""
import json
import os
import shutil
import tempfile
import time

import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.dist.fault_tolerance import (ChipFailure, FaultTolerance,
                                        ResilientRunner, SimulatedFailure,
                                        StragglerMonitor)


# ---------------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------------


def test_straggler_warmup_boundary_exactly_n_equals_warmup():
    # the (n == warmup)-th observation still only seeds the EMA: flagging
    # starts strictly AFTER warmup observations
    m = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=3)
    assert not m.observe(0, 1.0)
    assert not m.observe(1, 100.0)      # n == 2 <= warmup: never flagged
    assert not m.observe(2, 100.0)      # n == 3 == warmup: still seeding
    assert m.observe(3, 10 * m.ema)     # n == 4 > warmup: flagged


def test_straggler_outliers_do_not_update_ema():
    m = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=1)
    m.observe(0, 1.0)
    m.observe(1, 1.0)
    ema = m.ema
    assert m.observe(2, 50.0)           # outlier flagged...
    assert m.ema == ema                 # ...and the EMA is untouched
    assert m.observe(3, 50.0)           # so the next slow step flags too


def test_straggler_alpha_one_tracks_last_observation():
    m = StragglerMonitor(alpha=1.0, threshold=3.0, warmup=1)
    m.observe(0, 2.0)
    assert not m.observe(1, 4.0)        # 4 < 3*2: updates, ema := 4.0
    assert m.ema == 4.0
    assert not m.observe(2, 11.9)       # just under 3*4
    assert m.ema == 11.9


def test_straggler_first_observation_never_flags():
    m = StragglerMonitor(warmup=0)
    assert not m.observe(0, 1e9)        # no EMA yet: nothing to compare


# ---------------------------------------------------------------------------
# Checkpointer damage fallback
# ---------------------------------------------------------------------------


def _save_steps(d, steps):
    ck = Checkpointer(d, keep=len(steps) + 1, async_save=False)
    for s in steps:
        ck.save(s, {"x": np.full(4, s, np.int64)})
    return ck


def _corrupt(d, step, how):
    path = os.path.join(d, f"step_{step:08d}")
    if how == "truncate_leaf":
        leaf = os.path.join(path, "leaf_00000.bin")
        with open(leaf, "wb") as f:
            f.write(b"\x00")            # wrong byte count: reshape fails
    elif how == "missing_leaf":
        os.remove(os.path.join(path, "leaf_00000.bin"))
    elif how == "bad_manifest":
        with open(os.path.join(path, "manifest.json"), "w") as f:
            f.write("{")


@pytest.mark.parametrize("how", ["truncate_leaf", "missing_leaf",
                                 "bad_manifest"])
def test_restore_falls_back_to_next_older_intact_step(how):
    with tempfile.TemporaryDirectory() as d:
        ck = _save_steps(d, [1, 2])
        _corrupt(d, 2, how)
        step, tree, _ = ck.restore({"x": np.zeros(4, np.int64)})
        assert step == 1
        assert int(np.asarray(tree["x"])[0]) == 1


def test_restore_explicit_step_still_raises_on_damage():
    with tempfile.TemporaryDirectory() as d:
        ck = _save_steps(d, [1, 2])
        _corrupt(d, 2, "truncate_leaf")
        with pytest.raises((OSError, ValueError, KeyError)):
            ck.restore({"x": np.zeros(4, np.int64)}, step=2)


def test_restore_all_damaged_raises_filenotfound():
    with tempfile.TemporaryDirectory() as d:
        ck = _save_steps(d, [1])
        _corrupt(d, 1, "missing_leaf")
        with pytest.raises(FileNotFoundError):
            ck.restore({"x": np.zeros(4, np.int64)})


def test_all_steps_skips_tmp_dirs():
    with tempfile.TemporaryDirectory() as d:
        ck = _save_steps(d, [1])
        # a crash mid-save leaves a .tmp dir with a complete-looking
        # manifest; it must never be listed as a restorable step
        tmp = os.path.join(d, "step_00000002.tmp-deadbeef")
        shutil.copytree(os.path.join(d, "step_00000001"), tmp)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": 2, "extra": {}, "leaves": []}, f)
        assert ck.all_steps() == [1]
        assert ck.latest_step() == 1


# ---------------------------------------------------------------------------
# ResilientRunner vs the async save race
# ---------------------------------------------------------------------------


class _SlowCheckpointer(Checkpointer):
    """Async writes stalled long enough to expose restore/save races."""

    def __init__(self, directory, delay=0.15):
        super().__init__(directory, async_save=True)
        self.delay = delay

    def _write(self, step, host_tree, extra):
        time.sleep(self.delay)
        super()._write(step, host_tree, extra)


def _counting_step_fn(log):
    def step_fn(state, step, batch):
        log.append(step)
        return {"n": np.int64(int(state["n"]) + 1)}, {}
    return step_fn


def test_restore_after_failure_waits_for_inflight_save():
    # regression: a failure right after an async save() used to race the
    # background writer — latest_step() saw nothing (or a mid-rename dir)
    # and the runner replayed from scratch instead of the new checkpoint
    with tempfile.TemporaryDirectory() as d:
        log = []
        ck = _SlowCheckpointer(d)
        runner = ResilientRunner(_counting_step_fn(log), lambda s: None,
                                 ck, ckpt_every=2, max_restores=4)
        fails = {"armed": True}

        def inject(step):
            # fire immediately after the step-2 checkpoint is *scheduled*
            if step == 2 and fails["armed"]:
                fails["armed"] = False
                raise SimulatedFailure("crash during in-flight save")

        state, rep = runner.run({"n": np.int64(0)}, 4,
                                failure_injector=inject)
        assert int(state["n"]) == 4
        assert rep.failures == 1
        # the replay resumed from the just-written step-2 checkpoint, NOT
        # from the start: steps 0/1 ran exactly once
        assert "restore@2" in rep.timeline
        assert log == [0, 1, 2, 3]


def test_fresh_runner_resumes_over_partially_written_dir():
    # a crash mid-save leaves a .tmp dir behind; a fresh runner pointed at
    # the directory must resume from the newest *intact* step and ignore it
    with tempfile.TemporaryDirectory() as d:
        log = []
        ck = Checkpointer(d, async_save=False)
        runner = ResilientRunner(_counting_step_fn(log), lambda s: None,
                                 ck, ckpt_every=2)
        runner.run({"n": np.int64(0)}, 2)           # leaves ckpt@2
        tmp = os.path.join(d, "step_00000004.tmp-cafe")
        os.makedirs(tmp)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": 4, "extra": {}, "leaves": []}, f)
        # plus a damaged "complete" step newer than the intact one
        shutil.copytree(os.path.join(d, "step_00000002"),
                        os.path.join(d, "step_00000003"))
        os.remove(os.path.join(d, "step_00000003", "leaf_00000.bin"))
        log2 = []
        runner2 = ResilientRunner(_counting_step_fn(log2), lambda s: None,
                                  Checkpointer(d), ckpt_every=2)
        state, rep = runner2.run({"n": np.int64(0)}, 4)
        assert rep.timeline[0] == "resume@2"
        assert log2 == [2, 3]                       # prefix skipped
        assert int(state["n"]) == 4


# ---------------------------------------------------------------------------
# policy objects
# ---------------------------------------------------------------------------


def test_chip_failure_records_chip_and_is_simulated():
    e = ChipFailure(3)
    assert e.chip == 3 and "chip 3" in str(e)
    assert isinstance(e, SimulatedFailure)
    assert str(ChipFailure(1, "custom")) == "custom"


def test_fault_tolerance_defaults():
    ft = FaultTolerance()
    assert ft.max_replays == 2
    assert ft.timeline == [] and ft.stragglers == []
    assert ft.failures == ft.replays == ft.groups_dispatched == 0
    assert isinstance(ft.monitor, StragglerMonitor)
