"""Engine semantics: every Fig. 8 program must match its jnp oracle, and the
hardware's destructive/TRA/DCC side effects must hold exactly."""
import numpy as np
import pytest

from repro.core import compiler, engine
from repro.core.commands import AAP, AP, Program

RNG = np.random.default_rng(42)
W = 32  # words per row in tests


def rand_row():
    return RNG.integers(0, 2**32, W, dtype=np.uint32)


A, B, C = rand_row(), rand_row(), rand_row()

ORACLES = {
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "nand": lambda a, b: ~(a & b),
    "nor": lambda a, b: ~(a | b),
    "xor": lambda a, b: a ^ b,
    "xnor": lambda a, b: ~(a ^ b),
}


@pytest.mark.parametrize("op", sorted(ORACLES))
def test_binary_programs(op):
    prog = compiler.op_program(op, ["D0", "D1"], "D2")
    out = engine.execute(prog, {"D0": A, "D1": B}, outputs=["D2"])["D2"]
    np.testing.assert_array_equal(np.asarray(out), ORACLES[op](A, B))


def test_not_program():
    prog = compiler.op_program("not", ["D0"], "D1")
    out = engine.execute(prog, {"D0": A}, outputs=["D1"])["D1"]
    np.testing.assert_array_equal(np.asarray(out), ~A)


def test_maj3_program():
    prog = compiler.op_program("maj3", ["D0", "D1", "D2"], "D3")
    out = engine.execute(prog, {"D0": A, "D1": B, "D2": C}, outputs=["D3"])["D3"]
    np.testing.assert_array_equal(np.asarray(out), (A & B) | (B & C) | (C & A))


def test_copy_and_init():
    prog = compiler.copy_program("D0", "D5")
    out = engine.execute(prog, {"D0": A}, outputs=["D5"])["D5"]
    np.testing.assert_array_equal(np.asarray(out), A)
    prog = compiler.zero_program("D0")
    out = engine.execute(prog, {"D0": A}, outputs=["D0"])["D0"]
    assert not np.asarray(out).any()
    prog = compiler.one_program("D0")
    out = engine.execute(prog, {"D0": A}, outputs=["D0"])["D0"]
    assert (np.asarray(out) == 0xFFFFFFFF).all()


def test_source_rows_not_modified():
    """§3.2 issue 3: staging through designated rows preserves sources."""
    for op in ("and", "xor", "nand"):
        prog = compiler.op_program(op, ["D0", "D1"], "D2")
        rows = engine.execute(prog, {"D0": A, "D1": B})
        np.testing.assert_array_equal(np.asarray(rows["D0"]), A)
        np.testing.assert_array_equal(np.asarray(rows["D1"]), B)


def test_tra_is_destructive():
    """Fig. 4 state 3: a raw TRA overwrites all three designated rows."""
    sub = engine.Subarray.create(W, {"D0": A, "D1": B, "D2": C})
    prog = Program([AAP("D0", "B0"), AAP("D1", "B1"), AAP("D2", "B2"),
                    AP("B12")])
    out = sub.run(prog)
    maj = (A & B) | (B & C) | (C & A)
    for t in ("T0", "T1", "T2"):
        np.testing.assert_array_equal(np.asarray(out.rows[t]), maj)


def test_dcc_captures_negation():
    """Fig. 6: activating the n-wordline while the bank is active stores the
    complement of the sensed value into the DCC."""
    sub = engine.Subarray.create(W, {"D0": A, "D9": np.zeros(W, np.uint32)})
    out = sub.run(Program([AAP("D0", "B5")]))
    np.testing.assert_array_equal(np.asarray(out.rows["DCC0"]), ~A)
    # and activating B4 afterwards senses the stored (negated) value
    out2 = out.run(Program([AAP("B4", "D9" )]))
    np.testing.assert_array_equal(np.asarray(out2.rows["D9"]), ~A)


def test_n_wordline_first_activation_senses_complement():
    sub = engine.Subarray.create(W, {"D0": A, "D7": np.zeros(W, np.uint32)})
    sub = sub.run(Program([AAP("D0", "B4")]))  # DCC0 = A
    out = sub.run(Program([AAP("B5", "D7")]))  # sense via n-wordline
    np.testing.assert_array_equal(np.asarray(out.rows["D7"]), ~A)
    # the DCC cell itself must be *restored*, not corrupted
    np.testing.assert_array_equal(np.asarray(out.rows["DCC0"]), A)


def test_dual_address_copies_to_two_rows():
    """B10 zeroes T2 and T3 simultaneously (paper: 'zero out two rows')."""
    sub = engine.Subarray.create(W, {"D0": A})
    out = sub.run(Program([AAP("C0", "B10")]))
    assert not np.asarray(out.rows["T2"]).any()
    assert not np.asarray(out.rows["T3"]).any()


def test_dual_address_first_activation_rejected():
    sub = engine.Subarray.create(W, {"D0": A})
    with pytest.raises(engine.BuddyError):
        sub.run(Program([AP("B10")]))


def test_batched_rows():
    a = RNG.integers(0, 2**32, (4, W), dtype=np.uint32)
    b = RNG.integers(0, 2**32, (4, W), dtype=np.uint32)
    prog = compiler.op_program("xor", ["D0", "D1"], "D2")
    out = engine.execute(prog, {"D0": a, "D1": b}, outputs=["D2"])["D2"]
    np.testing.assert_array_equal(np.asarray(out), a ^ b)


def test_engine_is_jittable():
    import jax

    prog = compiler.op_program("xor", ["D0", "D1"], "D2")

    @jax.jit
    def f(a, b):
        return engine.execute(prog, {"D0": a, "D1": b}, outputs=["D2"])["D2"]

    np.testing.assert_array_equal(np.asarray(f(A, B)), A ^ B)
