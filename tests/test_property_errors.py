"""Property tests: TRA fault injection and mitigation on random programs.

Three invariants over randomized programs/data/fault sites:

  * rate-0 injection is bit-identical to the micro-op interpreter oracle on
    every backend — the injection machinery must be invisible when silent;
  * a fixed PRNG key draws the *same* fault pattern on the scan VM and the
    Pallas megakernel — cross-backend physical determinism;
  * majority vote corrects ANY fault confined to a single replica — any
    command, any word, any bit, any number of voters' worth of margin.

Shrunk counterexamples from development are pinned as explicit regressions
at the bottom.
"""
import numpy as np
import jax

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import engine, errors, lowering
from repro.core.errors import TRAErrorModel

from test_property_lowering import _random_program

W = 4
N_ROWS = 8


def _case(seed):
    """Random (program, data, lowered) with at least one TRA command."""
    rng = np.random.default_rng(seed)
    while True:
        program = _random_program(rng)
        lp = lowering.lower(program)
        if (np.asarray(lp.table)[:, 0] & lowering.KIND_TRA).any():
            break
    data = {f"D{i}": rng.integers(0, 1 << 32, W, dtype=np.uint32)
            for i in range(N_ROWS)}
    return program, data, lp


def _outputs(lp):
    return [r for r in lp.writes if r != lowering.SINK]


@given(st.integers(0, 2**31 - 1), st.sampled_from(["scan", "pallas"]))
@settings(max_examples=20, deadline=None)
def test_rate0_injection_is_bit_identical_to_oracle(seed, backend):
    program, data, lp = _case(seed)
    outs = _outputs(lp)
    if not outs:
        return
    ref = engine.execute(program, data, outputs=outs, lowered=False)
    got = errors.execute_injected(lp, data, outputs=outs, backend=backend,
                                  model=TRAErrorModel(p_flip=0.0),
                                  key=jax.random.PRNGKey(seed & 0xFFFF))
    for k in outs:
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(got[k]), err_msg=k)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_fixed_key_identical_faults_across_backends(seed):
    program, data, lp = _case(seed)
    outs = _outputs(lp)
    if not outs:
        return
    model = TRAErrorModel(p_flip=0.05)
    key = jax.random.PRNGKey(seed & 0xFFFF)
    scan = errors.execute_injected(lp, data, outputs=outs, backend="scan",
                                   model=model, key=key)
    mega = errors.execute_injected(lp, data, outputs=outs, backend="pallas",
                                   model=model, key=key)
    for k in outs:
        np.testing.assert_array_equal(np.asarray(scan[k]),
                                      np.asarray(mega[k]), err_msg=k)


@given(st.integers(0, 2**31 - 1), st.data())
@settings(max_examples=20, deadline=None)
def test_vote_corrects_any_single_replica_fault(seed, data_st):
    program, data, lp = _case(seed)
    outs = _outputs(lp)
    if not outs:
        return
    clean = engine.execute(program, data, outputs=outs, lowered=False)
    cmd = data_st.draw(st.integers(0, lp.n_cmds - 1))
    word = data_st.draw(st.integers(0, W - 1))
    bit = data_st.draw(st.integers(0, 31))
    fault = errors.single_fault_planes(lp.table, (), W, cmd, word, bit)
    faulty = lowering.execute_lowered(lp, data, outputs=outs, errors=fault)
    # the fault may or may not reach an output (later commands can
    # overwrite the poisoned row) — either way the vote must erase it
    voted = errors.vote_outputs([faulty, clean, clean], outs)
    for k in outs:
        np.testing.assert_array_equal(np.asarray(voted[k]),
                                      np.asarray(clean[k]), err_msg=k)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_execute_voted_with_distinct_draws_still_matches_when_rare(seed):
    # one expected flip in ~3e3 words of replica output: overwhelmingly a
    # single-replica event, which k=3 voting corrects exactly
    program, data, lp = _case(seed)
    outs = _outputs(lp)
    if not outs:
        return
    ref = engine.execute(program, data, outputs=outs, lowered=False)
    out = errors.execute_voted(lp, data, outs,
                               model=TRAErrorModel(p_flip=1e-5),
                               key=jax.random.PRNGKey(seed & 0xFFFF))
    for k in outs:
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(out[k]), err_msg=k)


# ---------------------------------------------------------------------------
# pinned shrink regressions
# ---------------------------------------------------------------------------


def test_regression_fault_on_non_tra_command_is_silent():
    # shrunk case: injecting into a RowClone copy (kind bit0 == 0) must be
    # a no-op, not corrupt the copied row
    from repro.core import compiler

    program = compiler.copy_program("D0", "D1")
    lp = lowering.lower(program)
    data = {"D0": np.arange(W, dtype=np.uint32)}
    fault = errors.single_fault_planes(lp.table, (), W, 0, 0, 0)
    out = lowering.execute_lowered(lp, data, outputs=["D1"], errors=fault)
    np.testing.assert_array_equal(np.asarray(out["D1"]), data["D0"])


def test_regression_batched_fault_planes_broadcast():
    # shrunk case: a (n_cmds, 4, words) mask against (2, words) batched
    # data must broadcast the same fault into every batch slice on BOTH
    # backends (the megakernel flattens batch into the vmap axis)
    from repro.core import compiler

    program = compiler.maj3_program("D0", "D1", "D2", "D3")
    lp = lowering.lower(program)
    rng = np.random.default_rng(0)
    data = {f"D{i}": rng.integers(0, 1 << 32, (2, W), dtype=np.uint32)
            for i in range(3)}
    tra = int(np.flatnonzero(
        (np.asarray(lp.table)[:, 0] & lowering.KIND_TRA) != 0)[0])
    fault = errors.single_fault_planes(lp.table, (), W, tra, 1, 3)
    scan = lowering.execute_lowered(lp, data, outputs=["D3"], errors=fault)
    mega = lowering.execute_lowered(lp, data, outputs=["D3"], errors=fault,
                                    backend="pallas")
    clean = engine.execute(program, data, outputs=["D3"], lowered=False)
    np.testing.assert_array_equal(np.asarray(scan["D3"]),
                                  np.asarray(mega["D3"]))
    diff = np.asarray(scan["D3"]) ^ np.asarray(clean["D3"])
    assert (diff[0] == diff[1]).all()   # same fault in every batch slice
    assert diff.any()


def test_regression_key_chain_distinct_replicas():
    # shrunk case: execute_voted replicas must fold distinct sub-keys —
    # identical draws would make the vote powerless against real faults
    program, data, lp = _case(123)
    key = jax.random.PRNGKey(5)
    model = TRAErrorModel(p_flip=0.05)
    batch, row_words = errors._plane_batch(data)
    planes = [errors.error_planes(lp.table, jax.random.fold_in(key, r),
                                  batch, row_words, model)
              for r in range(3)]
    assert not np.array_equal(np.asarray(planes[0]), np.asarray(planes[1]))
    assert not np.array_equal(np.asarray(planes[1]), np.asarray(planes[2]))
