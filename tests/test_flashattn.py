"""Flash-attention Pallas kernel: shape/dtype sweep vs the pure-jnp oracle,
plus gradient checks through the custom VJP (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flashattn import flash_attention, flash_attention_fwd_kernel

KEY = jax.random.PRNGKey(0)


def ref_attn(q, k, v, causal=True):
    B, S, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = np.asarray(q, np.float32).reshape(B, S, KV, G, hd)
    s = np.einsum("bikgd,bjkd->bkgij", qg,
                  np.asarray(k, np.float32)) / np.sqrt(hd)
    if causal:
        s = np.where(np.tril(np.ones((S, Sk), bool)), s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("bkgij,bjkd->bikgd", p, np.asarray(v, np.float32))
    return o.reshape(B, S, H, hd)


@pytest.mark.parametrize("B,S,H,KV,hd,causal,bq,bk", [
    (2, 128, 4, 2, 32, True, 32, 32),
    (2, 128, 4, 2, 32, False, 32, 32),
    (1, 100, 4, 4, 16, False, 32, 32),     # ragged S, MHA
    (1, 80, 8, 2, 64, True, 32, 16),       # ragged, GQA-4, uneven blocks
    (2, 64, 8, 8, 128, True, 64, 64),      # full head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_oracle(B, S, H, KV, hd, causal, bq, bk, dtype):
    q = jax.random.normal(KEY, (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = ref_attn(q, k, v, causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               rtol=tol, atol=tol)


def test_flash_cross_attention_shapes():
    """Sq != Sk (decoder queries over 1600 vision patches)."""
    q = jax.random.normal(KEY, (1, 64, 4, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 100, 4, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 100, 4, 32))
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    assert out.shape == (1, 64, 4, 32)
    ref = ref_attn(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_flash_lse_correct():
    q = jax.random.normal(KEY, (1, 64, 2, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 64, 2, 16))
    _, lse = flash_attention_fwd_kernel(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, block_q=16, block_k=16)
    s = np.einsum("bihd,bjhd->bhij", np.asarray(q), np.asarray(k)) / 4.0
    s = np.where(np.tril(np.ones((64, 64), bool)), s, -1e30)
    ref_lse = np.log(np.exp(s - s.max(-1, keepdims=True)).sum(-1)) \
        + s.max(-1)
    np.testing.assert_allclose(np.asarray(lse), ref_lse, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("KV", [2, 4])
def test_flash_grads_match_autodiff(causal, KV):
    B, S, H, hd = 1, 64, 4, 16
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd))
    do = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, H, hd))

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=causal,
                                       block_q=16, block_k=16) * do)

    def ref_jnp(q, k, v):
        G = H // KV
        qg = q.astype(jnp.float32).reshape(B, S, KV, G, hd)
        s = jnp.einsum("bikgd,bjkd->bkgij", qg,
                       k.astype(jnp.float32)) / np.sqrt(hd)
        if causal:
            s = jnp.where(jnp.tril(jnp.ones((S, S), bool)), s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgij,bjkd->bikgd", p, v.astype(jnp.float32))
        return jnp.sum(o.reshape(B, S, H, hd) * do)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(ref_jnp, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_flash_backend_switch_in_model():
    """Model forward with the flash backend == chunked backend."""
    from repro.configs.base import get_config, reduced
    from repro.models import build
    from repro.models.layers import attention_backend
    cfg = reduced(get_config("qwen3_8b"))
    bundle = build(cfg)
    params = bundle.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size),
             "mask": jnp.ones((2, 64), jnp.float32)}
    l_chunked, _ = jax.jit(bundle.loss)(params, batch)
    with attention_backend("flash"):
        l_flash, _ = jax.jit(bundle.loss)(params, batch)
    assert abs(float(l_chunked) - float(l_flash)) < 2e-2
