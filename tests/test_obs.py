"""Observability layer (`repro.obs`): metrics registry semantics, the
tracer + Chrome-trace schema validator, telemetry-instrumented serving
consistency against `BatchReport`/`stats()`, and the `BatchReport`
percentile edge cases the registry histogram mirrors."""
import json

import numpy as np
import pytest

from repro.core.errors import ReliabilityConfig, TRAErrorModel
from repro.obs import (HISTOGRAM_CAP, MODEL_PID, NULL_METRICS,
                       NULL_TELEMETRY, NULL_TRACER, WALL_PID,
                       MetricsRegistry, Telemetry, Tracer, get_telemetry,
                       set_telemetry, validate_chrome_trace,
                       write_chrome_trace)
from repro.obs.metrics import _NULL_INSTRUMENT
from repro.service import (POPCOUNT, Query, QueryService, WorkloadSpec,
                           build_service, query_stream)
from repro.service.scheduler import BatchReport, QueryResult

RNG = np.random.default_rng(11)


# -- metrics registry -------------------------------------------------------


def test_counter_gauge_histogram_basics():
    m = MetricsRegistry()
    c = m.counter("queries_total")
    c.inc()
    c.inc(3)
    assert c.value == 4.0
    g = m.gauge("ema_s")
    g.set(0.5)
    g.set(0.25)
    assert g.value == 0.25
    h = m.histogram("lat_ns")
    for v in (10.0, 30.0, 20.0):
        h.observe(v)
    assert h.count == 3 and h.total == 60.0 and h.mean == 20.0
    assert h.percentile(50) == 20.0
    assert h.percentile(0) == 10.0 and h.percentile(100) == 30.0


def test_instruments_memoized_by_name_and_labels():
    m = MetricsRegistry()
    assert m.counter("x") is m.counter("x")
    assert m.counter("x", tenant="t0") is m.counter("x", tenant="t0")
    assert m.counter("x", tenant="t0") is not m.counter("x", tenant="t1")
    assert m.counter("x") is not m.counter("y")


def test_snapshot_expands_histograms_and_labels():
    m = MetricsRegistry()
    m.counter("q_total", tenant="t0").inc(2)
    m.gauge("ema").set(1.5)
    m.histogram("lat").observe(7.0)
    s = m.snapshot()
    assert s['q_total{tenant="t0"}'] == 2.0
    assert s["ema"] == 1.5
    assert s["lat_count"] == 1 and s["lat_sum"] == 7.0
    assert s["lat_p50"] == 7.0 and s["lat_p99"] == 7.0


def test_prometheus_text_format():
    m = MetricsRegistry()
    m.counter("q_total").inc(3)
    m.gauge("ema").set(0.5)
    m.histogram("lat").observe(2.0)
    text = m.to_prometheus()
    assert "# TYPE q_total counter" in text
    assert "q_total 3" in text
    assert "# TYPE ema gauge" in text
    assert "# TYPE lat summary" in text
    assert 'lat{quantile="0.50"} 2' in text
    assert 'lat{quantile="0.99"} 2' in text
    assert "lat_sum 2" in text and "lat_count 1" in text
    assert text.endswith("\n")


def test_histogram_cap_keeps_exact_count_and_sum():
    h = MetricsRegistry().histogram("lat")
    for _ in range(HISTOGRAM_CAP + 10):
        h.observe(1.0)
    assert h.count == HISTOGRAM_CAP + 10
    assert h.total == HISTOGRAM_CAP + 10
    assert len(h.samples) == HISTOGRAM_CAP


def test_null_metrics_is_allocation_free_no_op():
    assert NULL_METRICS.counter("x") is _NULL_INSTRUMENT
    assert NULL_METRICS.gauge("y", a="b") is _NULL_INSTRUMENT
    assert NULL_METRICS.histogram("z") is _NULL_INSTRUMENT
    _NULL_INSTRUMENT.inc()
    _NULL_INSTRUMENT.set(3.0)
    _NULL_INSTRUMENT.observe(1.0)
    assert _NULL_INSTRUMENT.value == 0.0
    assert NULL_METRICS.snapshot() == {}
    assert NULL_METRICS.to_prometheus() == "\n"


# -- BatchReport percentiles (and the histogram that mirrors them) ----------


def _report(lats):
    results = [QueryResult(index=i, mode=POPCOUNT, value=0, latency_ns=v,
                           bank=0, cache_hit=False, n_aaps=1, energy_nj=0.0)
               for i, v in enumerate(lats)]
    return BatchReport(results, max(lats, default=0.0), 4, 1)


def test_latency_percentile_empty_report():
    rep = BatchReport([], 0.0, 4, 0)
    for pct in (0, 50, 99, 100):
        assert rep.latency_percentile_ns(pct) == 0.0
    assert rep.qps == 0.0


def test_latency_percentile_single_result():
    rep = _report([42.0])
    for pct in (0, 1, 50, 99, 100):
        assert rep.latency_percentile_ns(pct) == 42.0


def test_latency_percentile_bounds():
    rep = _report([30.0, 10.0, 20.0, 40.0])
    assert rep.latency_percentile_ns(0) == 10.0     # clamps to first
    assert rep.latency_percentile_ns(100) == 40.0   # exactly the last
    assert rep.latency_percentile_ns(50) == 20.0    # nearest-rank
    assert rep.latency_percentile_ns(99) == 40.0


def test_histogram_percentile_matches_batch_report_formula():
    lats = list(RNG.uniform(1.0, 1e6, size=37))
    rep = _report(lats)
    h = MetricsRegistry().histogram("lat")
    for v in lats:
        h.observe(v)
    for pct in (0, 1, 25, 50, 75, 90, 99, 100):
        assert h.percentile(pct) == rep.latency_percentile_ns(pct)


# -- tracer + Chrome-trace schema -------------------------------------------


def test_tracer_span_tree_exports_valid_trace(tmp_path):
    tr = Tracer()
    with tr.span("batch", n_queries=2):
        with tr.span("query", index=0):
            tr.instant("cache_hit")
        tr.model_event("q0", 0.0, 1500.0, "queries", latency_ns=1500.0)
    payload = tr.export()
    validate_chrome_trace(payload)
    names = [e["name"] for e in payload["traceEvents"] if e["ph"] == "B"]
    assert names == ["batch", "query"]
    inst = [e for e in payload["traceEvents"] if e["ph"] == "i"]
    assert inst and inst[0]["s"] == "t"
    # modeled ns land on the trace's microsecond clock, on their own pid
    x = [e for e in payload["traceEvents"] if e["ph"] == "X"]
    assert x[0]["pid"] == MODEL_PID and x[0]["dur"] == 1.5
    path = write_chrome_trace(payload, tmp_path / "t.json")
    loaded = json.loads(path.read_text())
    assert loaded["traceEvents"] == payload["traceEvents"]


def test_tracer_tracks_get_metadata_events():
    tr = Tracer()
    tr.model_event("xfer", 0.0, 10.0, "chip0/bus")
    tr.model_event("xfer", 10.0, 10.0, "chip0/bus")
    metas = [e for e in tr.events if e["ph"] == "M"]
    kinds = {(e["name"], e["pid"]) for e in metas}
    assert ("process_name", WALL_PID) in kinds
    assert ("process_name", MODEL_PID) in kinds
    # one thread_name per distinct track, not per event
    tracks = [e for e in metas if e["name"] == "thread_name"
              and e["args"]["name"] == "chip0/bus"]
    assert len(tracks) == 1


def test_tracer_unmatched_end_raises():
    tr = Tracer()
    with pytest.raises(ValueError):
        tr.end()


def test_validator_rejects_malformed_traces():
    with pytest.raises(ValueError):
        validate_chrome_trace({})
    bad_field = {"traceEvents": [{"name": "a", "ph": "B", "ts": 0.0,
                                  "pid": 1}]}          # no tid
    with pytest.raises(ValueError):
        validate_chrome_trace(bad_field)
    bad_ts = {"traceEvents": [{"name": "a", "ph": "i", "ts": -1.0,
                               "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError):
        validate_chrome_trace(bad_ts)
    no_dur = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0,
                               "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError):
        validate_chrome_trace(no_dur)
    unbalanced = {"traceEvents": [{"name": "a", "ph": "B", "ts": 0.0,
                                   "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError):
        validate_chrome_trace(unbalanced)
    stray_end = {"traceEvents": [{"name": "", "ph": "E", "ts": 0.0,
                                  "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError):
        validate_chrome_trace(stray_end)


def test_null_tracer_and_global_telemetry():
    assert not NULL_TRACER.tracing
    with NULL_TRACER.span("nothing"):
        NULL_TRACER.instant("nope")
        NULL_TRACER.model_event("x", 0.0, 1.0, "t")
    assert NULL_TRACER.events == []
    validate_chrome_trace(NULL_TRACER.export())
    # the process-global defaults to NULL and set/get round-trips
    assert get_telemetry() is NULL_TELEMETRY
    tel = Telemetry()
    prev = set_telemetry(tel)
    try:
        assert prev is NULL_TELEMETRY
        assert get_telemetry() is tel
    finally:
        set_telemetry(prev)
    assert get_telemetry() is NULL_TELEMETRY


def test_telemetry_flag_combinations():
    full = Telemetry()
    assert full.tracing and full.metering
    metrics_only = Telemetry(trace=False)
    assert not metrics_only.tracing and metrics_only.metering
    assert metrics_only.tracer is NULL_TRACER
    assert not NULL_TELEMETRY.tracing and not NULL_TELEMETRY.metering


# -- instrumented serving: trace/metrics vs BatchReport/stats ---------------

SPEC = WorkloadSpec(n_tenants=2, n_weeks=2, domain_bits=1 << 10,
                    n_queries=24, seed=3)


@pytest.fixture(scope="module")
def traced_run():
    svc = build_service(SPEC, n_banks=4, telemetry=Telemetry())
    queries = query_stream(SPEC, svc)
    report = svc.query_batch(queries)
    return svc, queries, report


def test_trace_spans_cover_every_query(traced_run):
    svc, queries, _ = traced_run
    events = svc.telemetry.tracer.events
    b_names = [e["name"] for e in events if e["ph"] == "B"]
    assert b_names.count("batch") == 1
    assert b_names.count("query") == len(queries)
    assert b_names.count("parse") + b_names.count("plan_cache") > 0
    # plan-group dispatch/readout spans appear once per group
    report = traced_run[2]
    assert b_names.count("group") == report.n_plan_groups
    assert b_names.count("dispatch") == report.n_plan_groups
    assert b_names.count("readout") == report.n_plan_groups


def test_trace_modeled_latencies_match_batch_report(traced_run):
    svc, queries, report = traced_run
    events = svc.telemetry.tracer.events
    summary = {e["name"]: e for e in events
               if e["ph"] == "X" and e["name"].startswith("q")
               and "latency_ns" in e.get("args", {})}
    assert len(summary) == len(queries)
    for r in report.results:
        ev = summary[f"q{r.index}"]
        assert ev["args"]["latency_ns"] == r.latency_ns
        assert ev["args"]["energy_nj"] == r.energy_nj
        assert ev["dur"] == r.latency_ns / 1e3
    # per-chip bus/bank timeline events exist and are schema-valid
    tracks = {e["tid"] for e in events
              if e["ph"] == "X" and e["name"] in ("xfer", "compute")}
    assert tracks
    validate_chrome_trace(svc.export_chrome_trace())


def test_metrics_registry_consistent_with_stats(traced_run):
    svc, queries, report = traced_run
    m = svc.telemetry.metrics
    s = svc.stats()
    assert s["queries_served"] == len(queries)
    assert m.counter("queries_total").value == len(queries)
    assert m.counter("batches_total").value == 1
    assert s["batches"] == 1
    assert s["total_modeled_ns"] == report.makespan_ns
    assert s["total_energy_nj"] == pytest.approx(
        sum(r.energy_nj for r in report.results))
    hits = m.counter("plan_cache_hits_total").value
    misses = m.counter("plan_cache_misses_total").value
    assert hits == svc.planner.cache.hits
    assert misses == svc.planner.cache.misses
    assert s["modeled_latency_p50_ns"] == report.latency_percentile_ns(50)
    assert s["modeled_latency_p99_ns"] == report.latency_percentile_ns(99)
    assert m.counter("aaps_total").value > 0
    # per-tenant series exist for every tenant in the stream and sum to
    # the global counter
    tenants = {q.tenant for q in queries}
    per_tenant = sum(m.counter("tenant_queries_total", tenant=t).value
                     for t in tenants)
    assert per_tenant == len(queries)
    prom = svc.prometheus()
    assert "queries_total" in prom and "tenant_queries_total" in prom


def test_stats_registry_matches_legacy_fallback():
    # the same workload served with metering on and fully off must agree
    # on every shared legacy key — the registry keys are true aliases
    on = build_service(SPEC, n_banks=4)              # default: metrics on
    off = build_service(SPEC, n_banks=4, telemetry=NULL_TELEMETRY)
    for svc in (on, off):
        svc.query_batch(query_stream(SPEC, svc))
    s_on, s_off = on.stats(), off.stats()
    for key in ("queries_served", "plans_cached", "plan_cache_hits",
                "plan_cache_misses", "plan_cache_hit_rate",
                "total_modeled_ns", "total_energy_nj", "parity_checks",
                "replays", "failures", "stragglers", "chip_rescales"):
        assert s_on[key] == s_off[key], key
    # disabled telemetry records nothing
    assert off.telemetry.tracer.events == []
    assert off.telemetry.metrics.snapshot() == {}


def test_reliability_counters_flow_to_registry():
    rng = np.random.default_rng(5)
    svc = QueryService(
        n_banks=4, telemetry=Telemetry(trace=False),
        reliability=ReliabilityConfig(mode="ecc",
                                      model=TRAErrorModel(p_flip=0.0)))
    for n in "ab":
        svc.register_bits(n, rng.integers(0, 2, 200).astype(bool),
                          group="t0")
    svc.query_batch([Query("a & b", POPCOUNT)])
    m = svc.telemetry.metrics
    # fault-free ecc runs 2 replicas, no tie-breaks, no corrected bits
    assert m.counter("reliability_replicas_total").value == 2
    assert m.counter("ecc_tiebreaks_total").value == 0
    assert m.counter("tra_corrected_bits_total").value == 0
    assert m.counter("parity_checks_total").value == 1
    s = svc.stats()
    assert s["reliability_replicas"] == 2
    assert s["parity_checks"] == svc.scheduler.parity_checks == 1


def test_serve_stream_trace_and_counters_consistent(tmp_path, traced_run):
    tel = Telemetry()
    svc = build_service(SPEC, n_banks=4, telemetry=tel)
    stream = query_stream(SPEC, svc)
    batches = [stream[:12], stream[12:]]
    values, rep = svc.serve_stream(batches, str(tmp_path / "ckpt"),
                                   ckpt_every=1)
    assert len(values) == len(stream)
    m = tel.metrics
    assert m.counter("queries_total").value == len(stream)
    assert m.counter("batches_total").value == len(batches)
    assert m.counter("checkpoints_total").value >= 1
    assert svc.stats()["queries_served"] == len(stream)
    payload = svc.export_chrome_trace(tmp_path / "trace.json")
    loaded = json.loads(payload.read_text())
    validate_chrome_trace(loaded)
    names = [e["name"] for e in loaded["traceEvents"] if e["ph"] == "B"]
    assert names.count("batch") == len(batches)
    assert names.count("query") == len(stream)
    checkpoints = [e for e in loaded["traceEvents"]
                   if e["ph"] == "i" and e["name"] == "checkpoint"]
    assert checkpoints
