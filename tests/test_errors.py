"""TRA error model + mitigated execution (core.errors, service reliability).

The reliability contract: rate-0 injection is bit-identical to the clean
interpreter oracle on every backend; a fixed PRNG key draws the same fault
pattern on the scan VM and the Pallas megakernel; majority vote corrects
single-replica faults; the service's vote/ecc modes stay bit-identical to
an unmitigated service while charging measurable overhead. Randomized
cross-checking lives in test_property_errors.py.
"""
import numpy as np
import pytest
import jax

from repro.core import compiler, engine, errors, lowering
from repro.core.arith_compiler import ripple_add_program
from repro.core.errors import (ReliabilityConfig, TRAErrorModel, error_planes,
                               execute_ecc, execute_injected, execute_voted,
                               single_fault_planes)
from repro.service import Catalog, CatalogError, Query, QueryService

W = 8


def _data(rows, seed=0, words=W):
    rng = np.random.default_rng(seed)
    return {r: rng.integers(0, 1 << 32, words, dtype=np.uint32)
            for r in rows}


PROGRAMS = {
    "and": (compiler.and_program("D0", "D1", "D2"), ("D0", "D1"), ["D2"]),
    "xor": (compiler.xor_program("D0", "D1", "D2"), ("D0", "D1"), ["D2"]),
    "maj3": (compiler.maj3_program("D0", "D1", "D2", "D3"),
             ("D0", "D1", "D2"), ["D3"]),
    "not": (compiler.not_program("D0", "D1"), ("D0",), ["D1"]),
}


# ---------------------------------------------------------------------------
# the model itself
# ---------------------------------------------------------------------------


def test_model_validates_p_flip_and_pattern_scale():
    with pytest.raises(ValueError):
        TRAErrorModel(p_flip=1.5)
    with pytest.raises(ValueError):
        TRAErrorModel(p_flip=-0.1)
    with pytest.raises(ValueError):
        TRAErrorModel(pattern_scale=(1.0, 1.0))


def test_flip_probs_zero_on_non_tra_commands():
    lp = lowering.lower(PROGRAMS["xor"][0])
    model = TRAErrorModel(p_flip=1e-2)
    probs = model.flip_probs(lp.table)
    assert probs.shape == (lp.n_cmds, errors.N_PATTERNS)
    tra = (np.asarray(lp.table)[:, 0] & lowering.KIND_TRA) != 0
    assert (probs[~tra] == 0.0).all()
    assert (probs[tra] > 0.0).all()


def test_flip_probs_pattern_scaling_and_temperature():
    lp = lowering.lower(PROGRAMS["maj3"][0])
    tra = (np.asarray(lp.table)[:, 0] & lowering.KIND_TRA) != 0
    cold = TRAErrorModel(p_flip=1e-3).flip_probs(lp.table)[tra]
    # mixed patterns (1/2 charged) fail more than unanimous (0/3)
    assert (cold[:, 1] > cold[:, 0]).all()
    assert (cold[:, 2] > cold[:, 3]).all()
    hot = TRAErrorModel(p_flip=1e-3,
                        temperature_c=errors.NOMINAL_C + 20
                        ).flip_probs(lp.table)[tra]
    assert (hot > cold).all()


def test_row_factors_deterministic_and_shared_by_row_triple():
    lp = lowering.lower(PROGRAMS["maj3"][0])
    model = TRAErrorModel()
    f1, f2 = model.row_factors(lp.table), model.row_factors(lp.table)
    np.testing.assert_array_equal(f1, f2)
    src = np.asarray(lp.table)[:, 1:4]
    for i in range(len(src)):
        for j in range(i):
            if (src[i] == src[j]).all():
                assert f1[i] == f1[j]


def test_error_planes_rate0_exact_zeros_and_shapes():
    lp = lowering.lower(PROGRAMS["xor"][0])
    planes = error_planes(lp.table, jax.random.PRNGKey(0), (3,), W,
                          TRAErrorModel(p_flip=0.0))
    assert planes.shape == (lp.n_cmds, 4, 3, W)
    assert not np.asarray(planes).any()


def test_error_planes_seeded_and_reproducible():
    lp = lowering.lower(PROGRAMS["maj3"][0])
    model = TRAErrorModel(p_flip=0.05)
    a = error_planes(lp.table, jax.random.PRNGKey(1), (), W, model)
    b = error_planes(lp.table, jax.random.PRNGKey(1), (), W, model)
    c = error_planes(lp.table, jax.random.PRNGKey(2), (), W, model)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.asarray(a).any()
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    # non-TRA command planes are exactly zero whatever the key draws
    tra = (np.asarray(lp.table)[:, 0] & lowering.KIND_TRA) != 0
    assert not np.asarray(a)[~tra].any()


# ---------------------------------------------------------------------------
# rate-0 bit-identity: injection machinery must be invisible when silent
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_rate0_injection_matches_interpreter(name, backend):
    program, inputs, outputs = PROGRAMS[name]
    data = _data(inputs, seed=hash(name) % 1000)
    ref = engine.execute(program, data, outputs=outputs, lowered=False)
    lp = lowering.lower(program)
    got = execute_injected(lp, data, outputs=outputs, backend=backend,
                           model=TRAErrorModel(p_flip=0.0))
    for o in outputs:
        np.testing.assert_array_equal(np.asarray(ref[o]), np.asarray(got[o]),
                                      err_msg=o)


def test_rate0_injection_arith_program_batched():
    res = ripple_add_program(4)
    rows = [f"X{j}" for j in range(4)] + [f"Y{j}" for j in range(4)]
    data = {r: np.stack([v, ~v])
            for r, v in _data(rows, seed=4).items()}
    ref = engine.execute(res.program, data, outputs=res.outputs,
                         lowered=False)
    lp = lowering.lower(res.program)
    for backend in ("scan", "pallas"):
        got = execute_injected(lp, data, outputs=list(res.outputs),
                               backend=backend,
                               model=TRAErrorModel(p_flip=0.0))
        for o in res.outputs:
            np.testing.assert_array_equal(np.asarray(ref[o]),
                                          np.asarray(got[o]), err_msg=o)


# ---------------------------------------------------------------------------
# cross-backend fault determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["maj3", "xor"])
def test_fixed_key_same_faults_scan_vs_megakernel(name):
    program, inputs, outputs = PROGRAMS[name]
    data = _data(inputs, seed=11)
    lp = lowering.lower(program)
    model = TRAErrorModel(p_flip=0.03)
    key = jax.random.PRNGKey(42)
    a = execute_injected(lp, data, outputs=outputs, backend="scan",
                         model=model, key=key)
    b = execute_injected(lp, data, outputs=outputs, backend="pallas",
                         model=model, key=key)
    clean = engine.execute(program, data, outputs=outputs, lowered=False)
    corrupted = False
    for o in outputs:
        np.testing.assert_array_equal(np.asarray(a[o]), np.asarray(b[o]),
                                      err_msg=o)
        corrupted |= not np.array_equal(np.asarray(a[o]),
                                        np.asarray(clean[o]))
    assert corrupted  # at 3% per bit the faults must actually land


# ---------------------------------------------------------------------------
# mitigation
# ---------------------------------------------------------------------------


def test_single_fault_planes_only_tra_commands_flip():
    lp = lowering.lower(PROGRAMS["xor"][0])
    table = np.asarray(lp.table)
    non_tra = int(np.flatnonzero((table[:, 0] & lowering.KIND_TRA) == 0)[0])
    planes = single_fault_planes(lp.table, (), W, non_tra, 0, 0)
    assert not np.asarray(planes).any()
    tra = int(np.flatnonzero((table[:, 0] & lowering.KIND_TRA) != 0)[0])
    planes = np.asarray(single_fault_planes(lp.table, (), W, tra, 2, 5)).copy()
    assert planes[tra, :, 2].tolist() == [32] * 4
    planes[tra, :, 2] = 0
    assert not planes.any()


def test_vote_corrects_single_replica_fault():
    program, inputs, outputs = PROGRAMS["maj3"]
    data = _data(inputs, seed=3)
    lp = lowering.lower(program)
    clean = engine.execute(program, data, outputs=outputs, lowered=False)
    tra = int(np.flatnonzero(
        (np.asarray(lp.table)[:, 0] & lowering.KIND_TRA) != 0)[0])
    fault = single_fault_planes(lp.table, (), W, tra, 1, 7)
    faulty = lowering.execute_lowered(lp, data, outputs=outputs,
                                      errors=fault)
    assert not np.array_equal(np.asarray(faulty[outputs[0]]),
                              np.asarray(clean[outputs[0]]))
    voted = errors.vote_outputs(
        [faulty, clean, clean], outputs)
    np.testing.assert_array_equal(np.asarray(voted[outputs[0]]),
                                  np.asarray(clean[outputs[0]]))


def test_execute_voted_rate0_identity_and_validation():
    program, inputs, outputs = PROGRAMS["xor"]
    data = _data(inputs, seed=5)
    lp = lowering.lower(program)
    ref = engine.execute(program, data, outputs=outputs, lowered=False)
    out = execute_voted(lp, data, outputs, model=TRAErrorModel(p_flip=0.0))
    np.testing.assert_array_equal(np.asarray(out["D2"]),
                                  np.asarray(ref["D2"]))
    for k in (1, 2, 4):
        with pytest.raises(ValueError):
            execute_voted(lp, data, outputs, k=k)


def test_execute_ecc_fast_path_and_tie_break():
    program, inputs, outputs = PROGRAMS["maj3"]
    data = _data(inputs, seed=6)
    lp = lowering.lower(program)
    ref = engine.execute(program, data, outputs=outputs, lowered=False)
    out, n = execute_ecc(lp, data, outputs, model=TRAErrorModel(p_flip=0.0))
    assert n == 2   # fault-free replicas agree: no third run
    np.testing.assert_array_equal(np.asarray(out["D3"]),
                                  np.asarray(ref["D3"]))
    out, n = execute_ecc(lp, data, outputs,
                         model=TRAErrorModel(p_flip=0.2),
                         key=jax.random.PRNGKey(9))
    assert n == 3   # heavy faults: replicas disagree, tie-break runs


def test_reliability_config_validation():
    for mode in errors.RELIABILITY_MODES:
        ReliabilityConfig(mode=mode)
    with pytest.raises(ValueError):
        ReliabilityConfig(mode="retry")
    with pytest.raises(ValueError):
        ReliabilityConfig(k=2)


# ---------------------------------------------------------------------------
# catalog parity planes (the ECC-at-rest half)
# ---------------------------------------------------------------------------


def _catalog(seed=0):
    rng = np.random.default_rng(seed)
    cat = Catalog()
    for i, name in enumerate(["u", "v", "w"]):
        cat.register_bits(name, rng.integers(0, 2, 100).astype(bool),
                          group="g0" if i < 2 else None)
    return cat


def test_catalog_parity_maintained_incrementally():
    cat = _catalog()
    expect = np.asarray(cat.get("u").words) ^ np.asarray(cat.get("v").words)
    np.testing.assert_array_equal(np.asarray(cat.parity_plane("g0")), expect)
    np.testing.assert_array_equal(np.asarray(cat.parity_plane(None)),
                                  np.asarray(cat.get("w").words))
    assert cat.verify_parity()
    with pytest.raises(CatalogError):
        cat.parity_plane("nope")


def test_catalog_parity_detects_corruption():
    cat = _catalog()
    entry = cat.get("v")
    entry.words = entry.words ^ np.uint32(1 << 9)   # flip one stored bit
    assert not cat.verify_parity()


# ---------------------------------------------------------------------------
# service reliability modes
# ---------------------------------------------------------------------------

QUERIES = ["a & b", "a | c & ~d", "(a ^ b) | (c & d)"]


def _service(**kw):
    rng = np.random.default_rng(7)
    svc = QueryService(n_banks=4, **kw)
    for n in "abcd":
        svc.register_bits(n, rng.integers(0, 2, 300).astype(bool),
                          group="t0")
    return svc


@pytest.fixture(scope="module")
def baseline():
    svc = _service()
    return svc, [svc.query(q).value for q in QUERIES]


@pytest.mark.parametrize("mode", ["vote", "ecc"])
def test_service_mitigated_modes_bit_identical_at_rate0(mode, baseline):
    _, ref = baseline
    svc = _service(reliability=ReliabilityConfig(
        mode=mode, model=TRAErrorModel(p_flip=0.0)))
    assert [svc.query(q).value for q in QUERIES] == ref
    if mode == "ecc":
        assert svc.scheduler.parity_checks == len(QUERIES)
        assert svc.stats()["parity_checks"] == len(QUERIES)


def test_service_vote_corrects_low_rate_faults(baseline):
    _, ref = baseline
    svc = _service(reliability=ReliabilityConfig(
        mode="vote", model=TRAErrorModel(p_flip=1e-4), seed=7))
    assert [svc.query(q).value for q in QUERIES] == ref


def test_service_vote_charges_latency_and_energy_overhead(baseline):
    base, _ = baseline
    svc = _service(reliability=ReliabilityConfig(
        mode="vote", model=TRAErrorModel(p_flip=0.0)))
    for q in QUERIES:
        clean, voted = base.query(q), svc.query(q)
        assert voted.latency_ns > clean.latency_ns
        assert voted.energy_nj == pytest.approx(3 * clean.energy_nj)


def test_service_ecc_detects_corrupted_catalog():
    svc = _service(reliability=ReliabilityConfig(
        mode="ecc", model=TRAErrorModel(p_flip=0.0)))
    entry = svc.catalog.get("b")
    entry.words = entry.words ^ np.uint32(1)
    with pytest.raises(RuntimeError, match="parity"):
        svc.query("a & b")


def test_reliability_mode_rejected_with_cluster():
    from repro.service import Scheduler

    with pytest.raises(ValueError, match="chip granularity"):
        Scheduler(catalog=Catalog(), cluster=object(),
                  reliability=ReliabilityConfig(mode="vote"))
