"""Per-architecture smoke tests (reduced configs, one train step on CPU) and
model-level correctness: decode-vs-prefill consistency, SSD-vs-naive-scan
oracle, MoE routing invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.models import build
from repro.models.ssm import ssd_chunked
from repro.serve.kvcache import extend_cache

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, train=True):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if train:
        b["labels"] = jnp.roll(b["tokens"], -1, axis=1)
        b["mask"] = jnp.ones((B, S), jnp.float32)
    if cfg.frontend == "audio":
        b["frames"] = jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision":
        b["patches"] = jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: one forward/loss + grad step, finite."""
    cfg = reduced(get_config(arch))
    bundle = build(cfg)
    params = bundle.init(KEY)
    batch = _batch(cfg)

    def loss_fn(p):
        return bundle.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_shapes(arch):
    cfg = reduced(get_config(arch))
    bundle = build(cfg)
    params = bundle.init(KEY)
    batch = _batch(cfg, train=False)
    logits, cache = jax.jit(bundle.prefill)(params, batch)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert len(jax.tree.leaves(cache)) > 0


@pytest.mark.parametrize("arch", ["qwen3_8b", "mamba2_1p3b", "zamba2_2p7b",
                                  "seamless_m4t_medium"])
def test_decode_matches_prefill(arch):
    """prefill(S) + decode_step(token_S) == prefill(S+1) last logits —
    validates KV caches, SSM state recurrence, and conv caches."""
    cfg = reduced(get_config(arch))
    bundle = build(cfg)
    params = bundle.init(KEY)
    S = 24
    batch = _batch(cfg, S=S + 1, train=False)
    ref, _ = jax.jit(bundle.prefill)(params, batch)
    short = dict(batch, tokens=batch["tokens"][:, :S])
    _, cache = jax.jit(bundle.prefill)(params, short)
    cache = extend_cache(cache, 8)
    got, _ = jax.jit(bundle.decode_step)(
        params, batch["tokens"][:, S], cache, jnp.int32(S))
    ref = np.asarray(ref, np.float32)
    got = np.asarray(got, np.float32)
    err = np.abs(ref - got).max() / (np.abs(ref).max() + 1e-9)
    assert err < 0.05, err


def test_moe_decode_matches_prefill_no_drops():
    """With capacity high enough that nothing drops, MoE decode must agree
    with prefill exactly (same routing)."""
    cfg = dataclasses.replace(reduced(get_config("kimi_k2_1t_a32b")),
                              capacity_factor=16.0)
    bundle = build(cfg)
    params = bundle.init(KEY)
    S = 16
    batch = _batch(cfg, S=S + 1, train=False)
    ref, _ = jax.jit(bundle.prefill)(params, batch)
    _, cache = jax.jit(bundle.prefill)(
        params, dict(batch, tokens=batch["tokens"][:, :S]))
    cache = extend_cache(cache, 8)
    got, _ = jax.jit(bundle.decode_step)(
        params, batch["tokens"][:, S], cache, jnp.int32(S))
    err = (np.abs(np.asarray(ref - got, np.float32)).max()
           / (np.abs(np.asarray(ref, np.float32)).max() + 1e-9))
    assert err < 0.05, err


def test_ssd_chunked_matches_naive_recurrence():
    """Chunked SSD == step-by-step linear recurrence (the decode rule)."""
    B, S, H, P, N = 2, 48, 3, 4, 8
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.3
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))

    y_chunk, final = ssd_chunked(x, dt, a_log, Bm, Cm, chunk=16)

    A = -np.exp(np.asarray(a_log))
    xs, dts = np.asarray(x), np.asarray(dt)
    Bs, Cs = np.asarray(Bm), np.asarray(Cm)
    s = np.zeros((B, H, P, N))
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        decay = np.exp(dts[:, t] * A)                      # (B, H)
        dbx = np.einsum("bh,bn,bhp->bhpn", dts[:, t], Bs[:, t], xs[:, t])
        s = s * decay[..., None, None] + dbx
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cs[:, t], s)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32), ys,
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(final), s, rtol=2e-2, atol=2e-2)


def test_ssd_handles_ragged_tail():
    B, S, H, P, N = 1, 21, 2, 4, 4   # 21 % 16 != 0
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (B, S, H, P))
    dt = jnp.ones((B, S, H)) * 0.1
    y, final = ssd_chunked(x, dt, jnp.zeros((H,)),
                           jnp.ones((B, S, N)), jnp.ones((B, S, N)),
                           chunk=16)
    assert y.shape == (B, S, H, P)
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_moe_aux_loss_balanced_router():
    """A uniform router should give aux loss ~1 (E * sum(1/E * 1/E * E))."""
    from repro.models.moe import moe_ffn, moe_init
    cfg = reduced(get_config("kimi_k2_1t_a32b"))
    p, _ = moe_init(KEY, cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))   # uniform routing
    x = jax.random.normal(KEY, (2, 32, cfg.d_model), jnp.bfloat16)
    y, aux = jax.jit(lambda p, x: moe_ffn(p, x, cfg))(p, x)
    assert y.shape == x.shape
    assert 0.9 < float(aux) < 1.1


def test_chunked_attention_matches_naive():
    from repro.models.layers import chunked_attention
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    out = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    # naive reference
    G = H // KV
    qg = np.asarray(q).reshape(B, S, KV, G, hd)
    s = np.einsum("bikgd,bjkd->bkgij", qg, np.asarray(k)) / np.sqrt(hd)
    mask = np.tril(np.ones((S, S), bool))
    s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("bkgij,bjkd->bikgd", p, np.asarray(v)).reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(out, np.float32), o,
                               rtol=2e-3, atol=2e-3)
