"""Application studies: functional correctness + cost-model claims (§8)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import bitmap_index, bitset, bitweaving


# -- §8.1 bitmap indices ----------------------------------------------------


def test_bitmap_query_functional():
    key = jax.random.PRNGKey(0)
    db = bitmap_index.UserDatabase.synthetic(key, m_users=500, n_weeks=4,
                                             p_active=0.5)
    n_every, male_counts, ops = bitmap_index.weekly_active_query(db)
    # numpy oracle
    from repro.core.bitplane import unpack_bits

    daily = np.asarray(unpack_bits(db.daily, 500))
    male = np.asarray(unpack_bits(db.male, 500))
    weekly = daily.any(axis=1)            # (weeks, users)
    exp_every = weekly.all(axis=0).sum()
    exp_male = (weekly & male).sum(axis=1)
    assert int(n_every) == int(exp_every)
    np.testing.assert_array_equal(np.asarray(male_counts), exp_male)
    assert ops == {"or": 24, "and": 7, "bitcount": 5}


def test_bitmap_speedup_matches_paper():
    """Paper: 6.0X average over the query parameter range."""
    sps = [bitmap_index.speedup(m, n)
           for m in (8 << 20, 16 << 20, 32 << 20) for n in range(2, 9)]
    assert 5.0 <= float(np.mean(sps)) <= 7.0
    assert all(s > 1 for s in sps)


def test_bitmap_query_time_scales_with_mn():
    """Paper: execution time grows with m*n."""
    t1 = bitmap_index.query_time_ns(8 << 20, 2, use_buddy=True)
    t2 = bitmap_index.query_time_ns(16 << 20, 2, use_buddy=True)
    t3 = bitmap_index.query_time_ns(16 << 20, 6, use_buddy=True)
    assert t1 < t2 < t3


# -- §8.2 BitWeaving --------------------------------------------------------


def test_bitweaving_query_functional():
    vals = np.random.default_rng(3).integers(0, 2**12, 5000,
                                             dtype=np.uint64).astype(np.uint32)
    cnt, bv = bitweaving.scan_query(jnp.asarray(vals), 12, 500, 2500)
    assert int(cnt) == int(((vals >= 500) & (vals <= 2500)).sum())


def test_bitweaving_speedup_range_matches_paper():
    """Paper: 1.8X-11.8X, 7.0X average; speedup grows with b."""
    grid = bitweaving.speedup_grid()
    v = list(grid.values())
    assert 5.5 <= float(np.mean(v)) <= 8.5
    assert min(v) > 1.3 and max(v) < 14.0
    # monotone-ish in b at fixed r (paper: larger b -> more Buddy fraction)
    r = 1 << 25
    bs = [grid[(b, r)] for b in (4, 8, 16, 32)]
    assert all(y > x for x, y in zip(bs, bs[1:]))


def test_bitweaving_cache_jump():
    """Paper: speedup jumps when the baseline working set leaves the cache."""
    sp_small = bitweaving.speedup(1 << 19, 16)   # 1 MB planes: cached
    sp_large = bitweaving.speedup(1 << 25, 16)   # 64 MB: DRAM
    assert sp_large > sp_small
    # and Buddy still wins in-cache (paper: up to 4.1X cache-resident)
    assert 1.5 < sp_small < 6.0


def test_buddy_ops_per_plane_exact():
    # c=0b101, 3 bits: bits (1,0,1) -> 2+1+2 = 5 per constant
    assert bitweaving.buddy_ops_per_plane(0b101, 0b101, 3) == 10
    assert bitweaving.buddy_ops_per_plane(0, 0, 4) == 8       # all zero bits
    assert bitweaving.buddy_ops_per_plane(0xF, 0xF, 4) == 16  # all one bits


# -- §8.3 set ops -----------------------------------------------------------


def test_setops_crossover_matches_paper():
    """Paper Fig. 12: RB-tree wins only for tiny sets (16 of 2^19); Buddy
    wins >= 3X from 64 elements; Buddy beats SIMD bitset everywhere."""
    grid = bitset.figure12_grid()
    assert grid[16].buddy_vs_rbtree < 1.0
    assert grid[64].buddy_vs_rbtree >= 3.0
    big = [c.buddy_vs_rbtree for m, c in grid.items() if m >= 64]
    assert float(np.mean(big)) >= 3.0
    assert all(c.buddy_vs_bitset > 1.0 for c in grid.values())


def test_setops_functional_union_intersection():
    from repro.ops import BitSet

    rng = np.random.default_rng(1)
    domain = 1 << 19  # the paper's domain
    a_np = set(rng.integers(0, domain, 1000).tolist())
    b_np = set(rng.integers(0, domain, 1000).tolist())
    a = BitSet.from_elements(jnp.asarray(sorted(a_np)), domain)
    b = BitSet.from_elements(jnp.asarray(sorted(b_np)), domain)
    assert int(a.union(b).cardinality()) == len(a_np | b_np)
    assert int(a.intersection(b).cardinality()) == len(a_np & b_np)
    assert int(a.difference(b).cardinality()) == len(a_np - b_np)
