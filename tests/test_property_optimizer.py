"""Property tests for the cost-based optimizer (ISSUE satellite):
random Expr DAGs x catalog layouts must stay bit-identical to the
unoptimized oracle, never cost more AAPs than the plain pipeline, and the
cost model must be monotone in the command counts it prices."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitplane import unpack_bits
from repro.core.compiler import Expr, compile_expr_fused
from repro.service import (MATERIALIZE, CostParams, Query, QueryService,
                           cost_program, run_queries_unbatched)

LEAVES = ("a", "b", "c", "d")


def _rand_expr(rng, depth=3):
    """A random boolean DAG over LEAVES: and/or/xor/not/maj3."""
    if depth <= 0 or rng.random() < 0.25:
        return Expr.of(str(rng.choice(LEAVES)))
    op = rng.choice(["and", "or", "xor", "not", "maj3"],
                    p=[0.3, 0.3, 0.2, 0.1, 0.1])
    if op == "not":
        return ~_rand_expr(rng, depth - 1)
    if op == "maj3":
        return Expr("maj3", tuple(_rand_expr(rng, depth - 1)
                                  for _ in range(3)))
    a, b = _rand_expr(rng, depth - 1), _rand_expr(rng, depth - 1)
    return Expr(op, (a, b))


def _ref(e, env):
    """Plain numpy bool evaluation of an Expr DAG."""
    if e.op == "row":
        return env[e.row]
    vals = [_ref(a, env) for a in e.args]
    if e.op == "and":
        return vals[0] & vals[1]
    if e.op == "or":
        return vals[0] | vals[1]
    if e.op == "xor":
        return vals[0] ^ vals[1]
    if e.op == "not":
        return ~vals[0]
    if e.op == "maj3":
        a, b, c = vals
        return (a & b) | (b & c) | (a & c)
    raise AssertionError(e.op)


def _service(rng, n_bits, n_banks):
    svc = QueryService(n_banks=n_banks)
    env = {}
    for name in LEAVES:
        env[name] = rng.random(n_bits) < 0.5
        svc.register_bits(name, env[name])
    return svc, env


# layouts: sub-word, multi-word, and word-straddling domains x bank counts
LAYOUTS = [(96, 2), (200, 8), (513, 4)]


@pytest.mark.parametrize("n_bits,n_banks", LAYOUTS)
def test_random_dags_bit_identical_and_never_more_aaps(n_bits, n_banks):
    rng = np.random.default_rng(1000 + n_bits + n_banks)
    svc, env = _service(rng, n_bits, n_banks)
    exprs = [_rand_expr(rng) for _ in range(8)]
    queries = [Query(e, MATERIALIZE) for e in exprs]
    rep = svc.query_batch(queries)
    ref = run_queries_unbatched(svc.catalog, queries)
    for e, r, oracle in zip(exprs, rep.results, ref.results):
        # optimized batch == unoptimized sequential interpreter oracle
        np.testing.assert_array_equal(np.asarray(r.value),
                                      np.asarray(oracle.value))
        # and both == plain numpy semantics
        np.testing.assert_array_equal(
            np.asarray(unpack_bits(jnp.asarray(r.value), n_bits)),
            _ref(e, env))
    # never-more-AAPs, per plan and for the whole batch
    for e in exprs:
        bp = svc.planner.plan(e)
        assert bp.plan.n_aaps <= bp.plan.n_aaps_unopt
    assert rep.total_aaps <= rep.baseline_aaps


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_overlapping_batches_share_and_stay_identical(seed):
    """High-overlap batches: a common random sub-DAG embedded in every
    query. CSE may or may not fire (it must win the cost-off), but the
    results are always bit-identical and never cost more."""
    rng = np.random.default_rng(2000 + seed)
    svc, env = _service(rng, 200, 8)
    base = _rand_expr(rng, depth=2)
    exprs = []
    for _ in range(6):
        other = _rand_expr(rng, depth=2)
        op = rng.choice(["and", "or", "xor"])
        exprs.append(Expr(str(op), (base, other)))
    queries = [Query(e, MATERIALIZE) for e in exprs]
    rep = svc.query_batch(queries)
    ref = run_queries_unbatched(svc.catalog, queries)
    for e, r, oracle in zip(exprs, rep.results, ref.results):
        np.testing.assert_array_equal(np.asarray(r.value),
                                      np.asarray(oracle.value))
        np.testing.assert_array_equal(
            np.asarray(unpack_bits(jnp.asarray(r.value), 200)),
            _ref(e, env))
    assert rep.total_aaps <= rep.baseline_aaps


def test_optimized_vs_unoptimized_service_identical():
    """The same random stream through optimize=True and optimize=False
    services returns identical values (popcount mode exercises readout)."""
    rng = np.random.default_rng(3)
    exprs = [_rand_expr(rng) for _ in range(10)]
    rng = np.random.default_rng(3)          # same data both sides
    opt, _ = _service(rng, 200, 8)
    rng = np.random.default_rng(3)
    plain, _ = _service(rng, 200, 8)
    plain_svc = QueryService(n_banks=8, optimize=False)
    for name in LEAVES:
        plain_svc.register_bits(
            name, np.asarray(unpack_bits(
                jnp.asarray(plain.catalog.get(name).words), 200)))
    rep_opt = opt.query_batch([Query(e) for e in exprs])
    rep_plain = plain_svc.query_batch([Query(e) for e in exprs])
    assert ([r.value for r in rep_opt.results]
            == [r.value for r in rep_plain.results])
    assert rep_opt.total_aaps <= rep_plain.total_aaps


def test_cost_model_monotone_in_command_counts():
    """Componentwise monotonicity: a program with >= AAPs and >= APs never
    prices below a smaller one, under every layout parameterization."""
    rng = np.random.default_rng(4)
    progs = [compile_expr_fused(_rand_expr(rng), "OUT").program
             for _ in range(12)]
    params = [CostParams(), CostParams(n_blocks=4),
              CostParams(n_banks=16, n_chips=4)]
    for ps in params:
        costs = [cost_program(p, 2, 1, ps) for p in progs]
        for p1, c1 in zip(progs, costs):
            for p2, c2 in zip(progs, costs):
                if p1.n_aap <= p2.n_aap and p1.n_ap <= p2.n_ap:
                    assert c1.latency_ns <= c2.latency_ns
                    assert c1.total_ns <= c2.total_ns
                    assert c1.amortized_ns <= c2.amortized_ns
    # block count scales the serial totals monotonically
    prog = progs[0]
    totals = [cost_program(prog, 2, 1,
                           CostParams(n_blocks=b)).total_ns
              for b in (1, 2, 4, 8)]
    assert totals == sorted(totals) and totals[0] < totals[-1]
    # more parallel slots never increase the amortized share
    amort = [cost_program(prog, 2, 1,
                          CostParams(n_banks=nb)).amortized_ns
             for nb in (1, 2, 8, 64)]
    assert amort == sorted(amort, reverse=True)
