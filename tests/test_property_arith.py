"""Property tests for the bit-serial arithmetic layer vs NumPy.

Widths 1-32, unsigned and two's-complement signed (including overflow
wraparound), across the jnp oracle, the Pallas kernel path, the AAP
microprogram engine path, and the bank-parallel (n_banks > 1) path.

Runs under hypothesis when available; otherwise the seeded-random fallback
(`_hypothesis_fallback`) keeps the invariants exercised.
"""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import arith_compiler, engine
from repro.kernels import ref
from repro.ops import arith as oar
from repro.ops.predicate import VerticalColumn
from repro.ops.transpose import from_vertical

N = 64  # values per drawn column

width_st = st.integers(min_value=1, max_value=32)
seed_st = st.integers(min_value=0, max_value=2**16)
banks_st = st.sampled_from([2, 4, 8])


def _draw_cols(n_bits, seed):
    rng = np.random.default_rng(seed)
    hi = 1 << n_bits
    av = rng.integers(0, hi, N, dtype=np.uint64).astype(np.uint32)
    bv = rng.integers(0, hi, N, dtype=np.uint64).astype(np.uint32)
    a = VerticalColumn.encode(jnp.asarray(av), n_bits)
    b = VerticalColumn.encode(jnp.asarray(bv), n_bits)
    return av, bv, a, b


def _decode(col):
    return np.asarray(from_vertical(col.planes, col.n_bits,
                                    use_kernel=False))[:N].astype(np.uint64)


def _wrap(x, n_bits):
    return x % (1 << n_bits)


@settings(max_examples=20, deadline=None)
@given(width_st, seed_st)
def test_add_sub_unsigned_wraparound(n_bits, seed):
    """Fast path == NumPy mod 2**n for every width, overflow included."""
    av, bv, a, b = _draw_cols(n_bits, seed)
    a64, b64 = av.astype(np.uint64), bv.astype(np.uint64)
    np.testing.assert_array_equal(
        _decode(oar.add_columns(a, b, use_kernel=False)),
        _wrap(a64 + b64, n_bits))
    np.testing.assert_array_equal(
        _decode(oar.sub_columns(a, b, use_kernel=False)),
        _wrap(a64 - b64 + (1 << n_bits), n_bits))


@settings(max_examples=10, deadline=None)
@given(width_st, seed_st)
def test_add_sub_signed_twos_complement(n_bits, seed):
    """The same wrap-around planes are exact two's-complement signed
    arithmetic: decode with the sign bit and compare against Python ints
    wrapped into [-2^(n-1), 2^(n-1))."""
    av, bv, a, b = _draw_cols(n_bits, seed)
    half = 1 << (n_bits - 1)
    full = 1 << n_bits

    def signed(u):
        u = u.astype(np.int64)
        return np.where(u >= half, u - full, u)

    def wrap_signed(x):
        return ((x + half) % full) - half

    got = signed(_decode(oar.add_columns(a, b, use_kernel=False)))
    np.testing.assert_array_equal(got, wrap_signed(signed(av) + signed(bv)))
    got = signed(_decode(oar.sub_columns(a, b, use_kernel=False)))
    np.testing.assert_array_equal(got, wrap_signed(signed(av) - signed(bv)))


@settings(max_examples=10, deadline=None)
@given(width_st, seed_st)
def test_compare_and_sum_match_numpy(n_bits, seed):
    av, bv, a, b = _draw_cols(n_bits, seed)
    np.testing.assert_array_equal(
        np.asarray(oar.lt_columns(a, b, use_kernel=False).to_bits()),
        av < bv)
    k = int(av[0]) if av[0] > 0 else 1
    if 0 < k < (1 << n_bits):
        np.testing.assert_array_equal(
            np.asarray(oar.lt_const(a, k, use_kernel=False).to_bits()),
            av < k)
    assert oar.sum_column(a) == int(av.astype(np.uint64).sum())


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([1, 2, 7, 12, 32]), seed_st, banks_st)
def test_engine_and_banked_paths_bit_identical(n_bits, seed, banks):
    """AAP microprogram on the simulated machine == fast path, at 1 bank
    and word-sharded across n_banks > 1."""
    av, bv, a, b = _draw_cols(n_bits, seed)
    exp_add = _decode(oar.add_columns(a, b, use_kernel=False))
    exp_sub = _decode(oar.sub_columns(a, b, use_kernel=False))
    for n_banks in (1, banks):
        np.testing.assert_array_equal(
            _decode(oar.add_columns_dram(a, b, n_banks=n_banks)), exp_add)
        np.testing.assert_array_equal(
            _decode(oar.sub_columns_dram(a, b, n_banks=n_banks)), exp_sub)
    np.testing.assert_array_equal(
        np.asarray(oar.lt_columns_dram(a, b, n_banks=banks).to_bits()),
        av < bv)
    assert oar.sum_column_dram(a, n_banks=banks) == \
        int(av.astype(np.uint64).sum())


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([3, 8, 16]), seed_st)
def test_kernel_path_matches_ref(n_bits, seed):
    """The Pallas ripple kernels agree with the jnp oracle."""
    av, bv, a, b = _draw_cols(n_bits, seed)
    for sub in (False, True):
        np.testing.assert_array_equal(
            np.asarray(oar._add(a, b, sub, use_kernel=True).planes),
            np.asarray(ref.bitserial_add(a.planes, b.planes, sub=sub)))
    np.testing.assert_array_equal(
        np.asarray(oar.lt_columns(a, b, use_kernel=True).words),
        np.asarray(ref.bitserial_lt(a.planes, b.planes))
        & np.asarray(oar._mask(a)))


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=16), seed_st)
def test_microprogram_never_disturbs_operands(n_bits, seed):
    """The adder restores its operand planes (AAP sensing is destructive
    only to raised rows; operands must survive for later queries)."""
    av, bv, a, b = _draw_cols(n_bits, seed)
    res = arith_compiler.ripple_add_program(n_bits)
    data = {f"X{j}": a.planes[j] for j in range(n_bits)}
    data.update({f"Y{j}": b.planes[j] for j in range(n_bits)})
    after = engine.execute(res.program, data)
    for j in range(n_bits):
        np.testing.assert_array_equal(np.asarray(after[f"X{j}"]),
                                      np.asarray(a.planes[j]))
        np.testing.assert_array_equal(np.asarray(after[f"Y{j}"]),
                                      np.asarray(b.planes[j]))
