"""Chaos suite: injected failures against the serving stack.

Failures are injected through `dist.fault_tolerance.FaultTolerance`'s
chaos hook (raise = a chip dying mid-dispatch, sleep = a straggler) and
through `serve_stream`'s per-step injector; every scenario must recover to
results bit-identical to a never-failed run, with the recovery visible on
the policy timeline.

Single-process scenarios run in tier-1. Multi-chip chip-kill scenarios are
``@pytest.mark.chaos`` and need forced host devices — the CI multi-device
job runs ``pytest -m chaos`` under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on a single-device
host the subprocess test at the bottom keeps chip-kill coverage in tier-1.
"""
import os
import subprocess
import sys
import tempfile
import textwrap
import time

import jax
import numpy as np
import pytest

from repro.dist.fault_tolerance import (ChipFailure, FaultTolerance,
                                        SimulatedFailure, StragglerMonitor)
from repro.service import Query, QueryService, results_bit_identical

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_DEV = len(jax.devices())

multichip = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >=2 devices (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=8 before jax imports); "
           "the CI multi-device job runs these in-process")

QUERIES = [Query("a & b"), Query("a | c & ~d"),
           Query("(a ^ b) | (c & d)"), Query("~a & d", mode="materialize")]


def _service(n_chips=None, **kw):
    rng = np.random.default_rng(2)
    svc = QueryService(n_banks=8, n_chips=n_chips,
                       max_chips=8 if n_chips else None, **kw)
    for n in "abcd":
        svc.register_bits(n, rng.integers(0, 2, 700).astype(bool),
                          group="t0")
    return svc


# ---------------------------------------------------------------------------
# single-process chaos (tier-1)
# ---------------------------------------------------------------------------


def test_failed_group_replayed_bit_identical():
    clean = _service().query_batch(QUERIES)
    ft = FaultTolerance(max_replays=2)
    armed = {"live": True}

    def inject(g):
        if g == 1 and armed["live"]:
            armed["live"] = False
            raise SimulatedFailure("transient kernel fault")

    ft.failure_injector = inject
    svc = _service(fault_tolerance=ft)
    rep = svc.query_batch(QUERIES)
    assert results_bit_identical(clean.results, rep.results)
    assert ft.failures == 1 and ft.replays == 1
    assert "failure@group1:SimulatedFailure" in ft.timeline
    assert "replay@group1" in ft.timeline


def test_replays_exhausted_reraises():
    ft = FaultTolerance(max_replays=1)

    def inject(g):
        raise SimulatedFailure("permanent fault")

    ft.failure_injector = inject
    svc = _service(fault_tolerance=ft)
    with pytest.raises(SimulatedFailure):
        svc.query_batch(QUERIES[:1])
    assert ft.failures == 2             # initial attempt + 1 replay
    assert ft.replays == 1


def test_straggling_group_flagged_on_timeline():
    ft = FaultTolerance(monitor=StragglerMonitor(alpha=1.0, threshold=3.0,
                                                 warmup=2))

    def inject(g):
        if g == 5:
            time.sleep(0.5)             # a chip gone slow, not dead

    ft.failure_injector = inject
    svc = _service(fault_tolerance=ft)
    for _ in range(6):                  # groups 0..5; 0 absorbs jit compile
        svc.query_batch(QUERIES[:1])
    assert 5 in ft.stragglers
    assert "straggler@group5" in ft.timeline
    assert ft.failures == 0             # slow is not dead: no replay


def test_serve_stream_failure_recovers_and_resumes():
    base = _service()
    batches = [[Query("a & b"), Query("c | d")], [Query("a ^ b")],
               [Query("~a & d")], [Query("a & b & c")]]
    expect = [base.query(q.query).value for b in batches for q in b]
    with tempfile.TemporaryDirectory() as d:
        ck_dir = os.path.join(d, "ck")
        armed = {"live": True}

        def inject(step):
            if step == 2 and armed["live"]:
                armed["live"] = False
                raise SimulatedFailure("mid-stream crash")

        vals, rep = _service().serve_stream(batches, ck_dir, ckpt_every=1,
                                            failure_injector=inject)
        assert list(vals) == expect
        assert rep.failures == 1 and rep.restores == 1
        assert "restore@2" in rep.timeline
        # a FRESH service resumes from the final checkpoint: nothing reruns
        vals2, rep2 = _service().serve_stream(batches, ck_dir)
        assert list(vals2) == expect
        assert rep2.steps_run == 0
        assert rep2.timeline[0] == f"resume@{len(batches)}"


def test_serve_stream_rejects_materialize():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="materialize"):
            _service().serve_stream([[Query("a & b", mode="materialize")]],
                                    os.path.join(d, "ck"))


# ---------------------------------------------------------------------------
# multi-chip chip-kill (CI multi-device job: pytest -m chaos)
# ---------------------------------------------------------------------------


@pytest.mark.chaos
@multichip
def test_chip_kill_rescales_and_recovers_bit_identical():
    clean = _service(n_chips=4).query_batch(QUERIES)
    ft = FaultTolerance(max_replays=2)
    armed = {"live": True}

    def inject(g):
        if g == 2 and armed["live"]:
            armed["live"] = False
            raise ChipFailure(3)

    ft.failure_injector = inject
    svc = _service(n_chips=4, fault_tolerance=ft)
    rep = svc.query_batch(QUERIES)
    assert results_bit_identical(clean.results, rep.results)
    # 4 chips over a 64-slot grid: 3 doesn't divide, recovery lands on 2
    assert svc.n_chips == 2
    assert "failure@group2:ChipFailure" in ft.timeline
    assert "rescale@4->2" in ft.timeline
    assert "replay@group2" in ft.timeline
    # the shrunken cluster keeps serving correctly
    rep2 = svc.query_batch(QUERIES)
    assert results_bit_identical(clean.results, rep2.results)


@pytest.mark.chaos
@multichip
def test_chip_kill_mid_stream_preserves_every_result():
    base = _service()
    batches = [[Query("a & b"), Query("c | d")], [Query("a ^ b")],
               [Query("(a ^ b) | (c & d)")]]
    expect = [base.query(q.query).value for b in batches for q in b]
    ft = FaultTolerance(max_replays=2)
    armed = {"live": True}

    def inject(g):
        if g == 1 and armed["live"]:
            armed["live"] = False
            raise ChipFailure(1)

    ft.failure_injector = inject
    svc = _service(n_chips=2, fault_tolerance=ft)
    with tempfile.TemporaryDirectory() as d:
        vals, _ = svc.serve_stream(batches, os.path.join(d, "ck"))
    assert list(vals) == expect
    assert svc.n_chips == 1
    assert "rescale@2->1" in ft.timeline


# ---------------------------------------------------------------------------
# subprocess: chip-kill acceptance independent of this host's device count
# ---------------------------------------------------------------------------


def test_chip_kill_recovery_subprocess():
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {REPO!r} + "/src")
        import numpy as np
        from repro.dist.fault_tolerance import ChipFailure, FaultTolerance
        from repro.service import (Query, QueryService,
                                   results_bit_identical)

        rng = np.random.default_rng(2)
        bits = {{n: rng.integers(0, 2, 700).astype(bool) for n in "abcd"}}
        def build(**kw):
            svc = QueryService(n_banks=8, n_chips=4, max_chips=8, **kw)
            for n, v in bits.items():
                svc.register_bits(n, v, group="t0")
            return svc
        qs = [Query("a & b"), Query("a | c & ~d"),
              Query("~a & d", mode="materialize")]
        clean = build().query_batch(qs)
        ft = FaultTolerance(max_replays=2)
        armed = {{"live": True}}
        def inject(g):
            if g == 1 and armed["live"]:
                armed["live"] = False
                raise ChipFailure(2)
        ft.failure_injector = inject
        svc = build(fault_tolerance=ft)
        rep = svc.query_batch(qs)
        assert results_bit_identical(clean.results, rep.results)
        assert svc.n_chips == 2 and "rescale@4->2" in ft.timeline
        print("CHAOS_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert "CHAOS_OK" in r.stdout, r.stderr[-2000:]
