"""Benchmark harness: section-name validation and the perf-regression gate.

`benchmarks.run` used to ignore unknown section names silently (a typo'd
``python -m benchmarks.run fig9_thruoghput`` printed only the CSV header
and exited 0); it must now exit non-zero listing the valid names.
`benchmarks.perf_gate` is the CI comparison that replaced the
existence-only BENCH_*.json check.
"""
import json
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks import perf_gate  # noqa: E402
from benchmarks import run as benchrun  # noqa: E402


# ---------------------------------------------------------------------------
# benchmarks.run section validation
# ---------------------------------------------------------------------------


def test_unknown_section_exits_nonzero(capsys):
    with pytest.raises(SystemExit) as exc:
        benchrun.main(["fig9_thruoghput"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "fig9_thruoghput" in err
    assert "fig9_throughput" in err        # valid names are listed
    assert "cluster_scaling" in err


def test_mixed_known_unknown_rejected_before_running(capsys):
    with pytest.raises(SystemExit) as exc:
        benchrun.main(["perf_summary", "nope"])
    assert exc.value.code == 2
    out = capsys.readouterr().out
    assert "name,us_per_call" not in out   # nothing ran


def test_section_modules_exist():
    for section in benchrun.SECTIONS:
        assert (REPO / "benchmarks" / f"{section}.py").exists(), section


# ---------------------------------------------------------------------------
# perf gate
# ---------------------------------------------------------------------------


def _write(directory, bench, rows, smoke=False):
    p = directory / f"BENCH_{bench}.json"
    p.write_text(json.dumps({"bench": bench, "rows": rows, "smoke": smoke}))
    return p


def test_gate_passes_within_band(tmp_path):
    base = tmp_path / "base"
    cur = tmp_path / "cur"
    base.mkdir(), cur.mkdir()
    _write(base, "x", [{"name": "x/a", "bytes": 64, "modeled_ns": 100.0,
                        "speedup": 4.0, "wall_steady_us": 10.0}])
    _write(cur, "x", [{"name": "x/a", "bytes": 64, "modeled_ns": 110.0,
                       "speedup": 3.8, "wall_steady_us": 12.0}])
    fails, warns, compared, skipped = perf_gate.run_gate(base, cur, ["x"])
    assert not fails and not warns
    assert compared == 3 and skipped == 0


def test_gate_fails_on_2x_wall_regression(tmp_path):
    base, cur = tmp_path / "base", tmp_path / "cur"
    base.mkdir(), cur.mkdir()
    _write(base, "x", [{"name": "x/a", "bytes": 64, "wall_steady_us": 10.0}])
    _write(cur, "x", [{"name": "x/a", "bytes": 64, "wall_steady_us": 25.0}])
    fails, warns, _, _ = perf_gate.run_gate(base, cur, ["x"])
    assert len(fails) == 1 and "wall_steady_us" in fails[0]


def test_gate_warns_between_1p3x_and_2x(tmp_path):
    base, cur = tmp_path / "base", tmp_path / "cur"
    base.mkdir(), cur.mkdir()
    _write(base, "x", [{"name": "x/a", "bytes": 64, "speedup": 4.0}])
    _write(cur, "x", [{"name": "x/a", "bytes": 64, "speedup": 2.5}])
    fails, warns, _, _ = perf_gate.run_gate(base, cur, ["x"])
    assert not fails and len(warns) == 1 and "speedup" in warns[0]


def test_gate_skips_size_mismatched_rows(tmp_path):
    """Smoke runs shrink operands; cross-size wall comparisons are noise."""
    base, cur = tmp_path / "base", tmp_path / "cur"
    base.mkdir(), cur.mkdir()
    _write(base, "x", [{"name": "x/a", "bytes": 1 << 20,
                        "wall_steady_us": 10.0}])
    _write(cur, "x", [{"name": "x/a", "bytes": 1 << 10,
                       "wall_steady_us": 500.0}])
    fails, warns, compared, skipped = perf_gate.run_gate(base, cur, ["x"])
    assert not fails and not warns
    assert compared == 0 and skipped == 1


def test_gate_fails_on_missing_row_and_missing_file(tmp_path):
    base, cur = tmp_path / "base", tmp_path / "cur"
    base.mkdir(), cur.mkdir()
    _write(base, "x", [{"name": "x/a", "bytes": 64, "modeled_ns": 1.0},
                       {"name": "x/b", "bytes": 64, "modeled_ns": 1.0}])
    _write(cur, "x", [{"name": "x/a", "bytes": 64, "modeled_ns": 1.0}])
    fails, _, _, _ = perf_gate.run_gate(base, cur, ["x", "y"])
    assert any("x/b" in f for f in fails)          # coverage regression
    assert any("BENCH_y.json" in f for f in fails)  # required file missing


def test_gate_tolerates_dropped_rows_across_modes(tmp_path):
    """A smoke run may drop cases a full baseline has (e.g. vm_dispatch
    keeps only the gate programs) — that is not a coverage regression."""
    base, cur = tmp_path / "base", tmp_path / "cur"
    base.mkdir(), cur.mkdir()
    _write(base, "x", [{"name": "x/a", "bytes": 64, "modeled_ns": 1.0},
                       {"name": "x/b", "bytes": 64, "modeled_ns": 1.0}])
    _write(cur, "x", [{"name": "x/a", "bytes": 64, "modeled_ns": 1.0}],
           smoke=True)
    fails, warns, compared, skipped = perf_gate.run_gate(base, cur, ["x"])
    assert not fails and not warns
    assert compared == 1 and skipped == 1


def test_gate_on_committed_baselines_vs_themselves():
    """The committed root baselines must gate cleanly against themselves
    (this is exactly what CI sees when a PR changes no perf behavior)."""
    fails, warns, compared, _ = perf_gate.run_gate(
        REPO, REPO, perf_gate.REQUIRED)
    assert not fails, fails
    assert not warns, warns
    assert compared > 0


def test_optimizer_baseline_clears_aap_reduction_floor():
    """Acceptance: the committed BENCH_optimizer.json shows >= 1.3x
    modeled-AAP reduction on the high-overlap batch, and the optimizer
    never emitted more AAPs than the plain pipeline on any row."""
    rows = perf_gate.load_rows(REPO / "BENCH_optimizer.json")
    overlap = [r for name, r in rows.items() if "overlap" in name]
    assert overlap, "missing high-overlap rows"
    assert all(r["aap_speedup"] >= 1.3 for r in overlap), overlap
    assert all(r["total_aaps"] <= r["baseline_aaps"]
               for r in rows.values()), rows


def test_cluster_scaling_baseline_shows_modeled_scaling():
    """Acceptance: BENCH_cluster_scaling.json at the repo root carries the
    modeled cross-chip scaling rows the CI gate compares."""
    rows = perf_gate.load_rows(REPO / "BENCH_cluster_scaling.json")
    for op in ("and", "xor"):
        speedups = [rows[f"cluster_scaling/modeled_{op}_c{c}"]["speedup"]
                    for c in (1, 2, 4, 8)]
        assert speedups[0] == 1.0
        assert all(b > a for a, b in zip(speedups, speedups[1:])), speedups
        assert speedups[-1] >= 4.0
