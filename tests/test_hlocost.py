"""Trip-count-aware HLO cost walker: validated against XLA's own
HloCostAnalysis on unrolled modules (where XLA is trustworthy), and against
the unrolled module for scanned ones (where XLA under-counts)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlocost import analyze_text, shape_info


def test_shape_info():
    assert shape_info("f32[4,8]{1,0}") == (32, 128)
    assert shape_info("bf16[10]") == (10, 20)
    assert shape_info("(s32[], f32[2,2]{1,0})") == (1 + 4, 4 + 16)
    assert shape_info("pred[]") == (1, 1)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_matches_xla_on_unrolled_matmuls():
    def unrolled(x, ws):
        for i in range(10):
            x = jax.nn.relu(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    c = _compile(unrolled, x, ws)
    xla = c.cost_analysis()
    if isinstance(xla, list):   # older jax returns [dict]
        xla = xla[0]
    mine = analyze_text(c.as_text())
    # dots dominate; within 2% of XLA
    assert abs(mine.flops - xla["flops"]) / xla["flops"] < 0.02


def test_scan_trip_count_multiplied():
    def scanned(x, ws):
        def body(h, w):
            return jax.nn.relu(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    def unrolled(x, ws):
        for i in range(10):
            x = jax.nn.relu(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    cs = _compile(scanned, x, ws)
    cu = _compile(unrolled, x, ws)
    ms = analyze_text(cs.as_text())
    mu = analyze_text(cu.as_text())
    # scanned == unrolled within 5% (XLA itself reports 10x less on scanned)
    assert abs(ms.flops - mu.flops) / mu.flops < 0.05
    xla_scanned = cs.cost_analysis()
    if isinstance(xla_scanned, list):   # older jax returns [dict]
        xla_scanned = xla_scanned[0]
    xla_scanned = xla_scanned["flops"]
    assert ms.flops > 5 * xla_scanned   # proves XLA undercounts scans


def test_nested_scan_trip_counts():
    def nested(x, ws):
        def outer(h, w):
            def inner(hh, _):
                return jax.nn.relu(hh @ w), None
            h2, _ = jax.lax.scan(inner, h, None, length=4)
            return h2, None
        h, _ = jax.lax.scan(outer, x, ws)
        return h

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 128, 128), jnp.float32)
    c = _compile(nested, x, ws)
    mine = analyze_text(c.as_text())
    expect = 2 * 128 ** 3 * 3 * 4     # 12 matmuls
    assert abs(mine.flops - expect) / expect < 0.1


def test_collective_bytes_counted():
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # single-device: no collectives expected
    def f(x):
        return x @ x

    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    mine = analyze_text(c.as_text())
    assert mine.collective_bytes == 0


def test_dot_flops_exact():
    def f(a, b):
        return jnp.einsum("ik,kj->ij", a, b)

    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    c = _compile(f, a, b)
    mine = analyze_text(c.as_text())
    expect = 2 * 64 * 16 * 32
    assert abs(mine.flops - expect) / expect < 0.05
