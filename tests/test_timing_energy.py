"""Timing/energy models must reproduce the paper's §5.3/§7 headline numbers."""
import pytest

from repro.core import compiler, energy, timing


def test_aap_latencies():
    t = timing.DDR3_1600
    assert t.aap_ns == pytest.approx(49.0)
    assert t.ap_ns == pytest.approx(45.0)
    naive = timing.DramTiming(split_decoder=False)
    assert naive.aap_ns == pytest.approx(80.0)


def test_throughput_ratios_match_paper():
    """§7: Buddy-1-bank is 3.8-9.1x Skylake and 2.7-6.4x GTX745;
    abstract: 10.9-25.6x (4 banks vs best baseline)."""
    table = timing.throughput_table()
    r_sky = [row["buddy_1bank"] / row["skylake"] for row in table.values()]
    r_gtx = [row["buddy_1bank"] / row["gtx745"] for row in table.values()]
    r4_gtx = [row["buddy_4bank"] / row["gtx745"] for row in table.values()]
    assert 3.5 <= min(r_sky) and max(r_sky) <= 9.5, r_sky
    assert 2.5 <= min(r_gtx) and max(r_gtx) <= 6.8, r_gtx
    assert 10.4 <= min(r4_gtx) and max(r4_gtx) <= 26.5, r4_gtx


def test_buddy_scales_linearly_with_banks():
    table = timing.throughput_table(banks_list=(1, 2, 4, 8))
    for row in table.values():
        assert row["buddy_2bank"] == pytest.approx(2 * row["buddy_1bank"])
        assert row["buddy_8bank"] == pytest.approx(8 * row["buddy_1bank"])


def test_tfaw_throttles_many_banks():
    prog = compiler.op_program("and", ["D0", "D1"], "D2")
    free = timing.buddy_throughput_gbps(prog, banks=8, respect_tfaw=False)
    thr = timing.buddy_throughput_gbps(prog, banks=8, respect_tfaw=True)
    assert thr < free
    # 1 bank is never tFAW limited
    assert timing.buddy_throughput_gbps(prog, 1, respect_tfaw=True) == \
        pytest.approx(timing.buddy_throughput_gbps(prog, 1))


PAPER_TABLE3 = {  # nJ/KB
    "not": (93.7, 1.6), "and": (137.9, 3.2), "or": (137.9, 3.2),
    "nand": (137.9, 4.0), "nor": (137.9, 4.0),
    "xor": (137.9, 5.5), "xnor": (137.9, 5.5),
}


@pytest.mark.parametrize("op", sorted(PAPER_TABLE3))
def test_energy_matches_table3(op):
    ddr3_paper, buddy_paper = PAPER_TABLE3[op]
    assert energy.ddr3_energy_nj_per_kb(op) == pytest.approx(ddr3_paper, rel=0.10)
    assert energy.buddy_energy_nj_per_kb(op) == pytest.approx(buddy_paper, rel=0.10)


def test_energy_reduction_range():
    """Abstract: 25.1x - 59.5x reduction."""
    t = energy.energy_table()
    reds = [row["reduction"] for row in t.values()]
    assert min(reds) > 22 and max(reds) < 62


def test_capacity_cost_is_one_percent():
    from repro.core.addressing import SubarrayGeometry

    g = SubarrayGeometry()
    assert g.capacity_loss == pytest.approx(0.01, abs=0.002)  # §5.4


def test_rowclone_psm_dispatch():
    """§6.2.2: ops needing 3 PSM copies run on the CPU instead."""
    import numpy as np

    from repro.core.isa import BuddyDevice

    dev = BuddyDevice(row_bits=1024)
    rng = np.random.default_rng(0)
    rows = {n: rng.integers(0, 2**32, 32, dtype=np.uint32) for n in "abcd"}
    # same affinity group: all in one subarray -> buddy path
    dev.store("a", rows["a"], group="g0")
    dev.store("b", rows["b"], group="g0")
    r = dev.bop("and", "out", ["a", "b"], group="g0")
    assert r.path == "buddy" and r.n_psm == 0
    np.testing.assert_array_equal(np.asarray(r.value), rows["a"] & rows["b"])
    # scattered operands: 2 PSM copies -> still buddy but slower
    dev2 = BuddyDevice(row_bits=1024)
    dev2.store("a", rows["a"], group="g0")
    dev2.store("b", rows["b"], group="g1")
    dev2.store("out2", rows["c"], group="g2")
    r2 = dev2.bop("and", "out2", ["a", "b"])
    assert r2.n_psm == 2 and r2.path == "buddy"
    assert r2.latency_ns > r.latency_ns
    np.testing.assert_array_equal(np.asarray(r2.value), rows["a"] & rows["b"])
