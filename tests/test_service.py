"""Query service: catalog placement, planner/plan cache, batching scheduler,
service facade, and the apps-as-service-clients paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitplane import unpack_bits
from repro.core.compiler import Expr, expr_key
from repro.service import (MATERIALIZE, POPCOUNT, Catalog, CatalogError,
                           Planner, Query, QueryParseError, QueryService,
                           WorkloadSpec, build_service, canonicalize,
                           parse_query, query_stream, run_queries_unbatched)

RNG = np.random.default_rng(7)


def _bits(n=200, p=0.5):
    return RNG.random(n) < p


def _svc_ab(n=200):
    svc = QueryService(n_banks=4)
    a, b, c = _bits(n), _bits(n), _bits(n)
    svc.register_bits("a", a)
    svc.register_bits("b", b)
    svc.register_bits("c", c)
    return svc, a, b, c


# -- catalog ----------------------------------------------------------------


def test_catalog_rejects_reserved_and_duplicate_names():
    cat = Catalog()
    for bad in ("T0", "DCC1", "B12", "C0", "TMP3", "IN0", "OUT", "1x"):
        with pytest.raises(CatalogError):
            cat.register(bad, np.zeros(4, np.uint32))
    cat.register("ok", np.zeros(4, np.uint32))
    with pytest.raises(CatalogError):
        cat.register("ok", np.zeros(4, np.uint32))


def test_catalog_pins_bit_domain():
    cat = Catalog()
    cat.register_bits("a", _bits(100))
    with pytest.raises(CatalogError):
        cat.register_bits("b", _bits(101))


def test_catalog_affinity_group_colocates():
    cat = Catalog()
    h1 = cat.register_bits("x", _bits(64), group="g").handle
    h2 = cat.register_bits("y", _bits(64), group="g").handle
    cat.register_bits("z", _bits(64))   # no group: placed independently
    assert (h1.bank, h1.subarray) == (h2.bank, h2.subarray)
    assert h1.row != h2.row
    # grouped ops need zero PSM copies; ungrouped generally cost one
    assert cat.psm_copies(["x"], "y") == 0
    assert cat.psm_copies(["x", "y"], "z") == 1


# -- parser -----------------------------------------------------------------


def test_parse_precedence_and_parens():
    # ~ binds tighter than &, & tighter than ^, ^ tighter than |
    e = parse_query("a | b ^ c & ~d")
    assert expr_key(e) == expr_key(
        Expr.of("a") | (Expr.of("b") ^ (Expr.of("c") & ~Expr.of("d"))))
    e2 = parse_query("(a | b) & maj(a, b, c)")
    assert expr_key(e2) == expr_key(
        (Expr.of("a") | Expr.of("b"))
        & Expr("maj3", (Expr.of("a"), Expr.of("b"), Expr.of("c"))))


def test_parse_errors():
    for bad in ("a &", "& a", "(a | b", "a $ b", "", "maj(a, b)"):
        with pytest.raises(QueryParseError):
            parse_query(bad)


# -- plan cache (satellite: counter-verified) --------------------------------


def test_same_query_twice_compiles_once():
    svc, a, b, _ = _svc_ab()
    svc.query("a & b")
    assert svc.planner.compile_count == 1
    assert svc.planner.cache.misses == 1
    svc.query("a & b")
    assert svc.planner.compile_count == 1   # hit skipped recompilation
    assert svc.planner.cache.hits == 1
    assert len(svc.planner.cache) == 1


def test_structurally_equal_exprs_share_cache_entry():
    """Differently-constructed but structurally-equal queries hit one
    entry via expr_key of the canonical DAG."""
    planner = Planner()
    variants = [
        "a & b",
        " a   &(b)",
        parse_query("a & b"),
        Expr.of("a") & Expr.of("b"),
        Expr("and", (Expr("row", row="a"), Expr("row", row="b"))),
    ]
    plans = [planner.plan(v) for v in variants]
    assert planner.compile_count == 1
    assert planner.cache.hits == len(variants) - 1
    assert len({p.plan.key for p in plans}) == 1


def test_canonicalization_shares_plans_across_rows():
    """Same shape over different catalog vectors -> one compiled program."""
    planner = Planner()
    p1 = planner.plan("a & b")
    p2 = planner.plan("c & d")
    assert p1.plan is p2.plan
    assert p1.bindings == ["a", "b"]
    assert p2.bindings == ["c", "d"]
    assert planner.compile_count == 1
    # repeated leaf maps to one canonical input
    canon, bindings = canonicalize(parse_query("x & (x | y)"))
    assert bindings == ["x", "y"]
    assert expr_key(canon) == expr_key(
        Expr.of("IN0") & (Expr.of("IN0") | Expr.of("IN1")))


# -- scheduler ---------------------------------------------------------------


def test_popcount_and_materialize_match_numpy():
    svc, a, b, c = _svc_ab()
    r = svc.query("(a | b) & ~c")
    expect = (a | b) & ~c
    assert r.value == int(expect.sum())
    m = svc.query("(a | b) & ~c", mode=MATERIALIZE)
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(jnp.asarray(m.value), 200)), expect)


def test_mixed_mode_batch_shares_plan_group():
    """popcount + materialize queries of one shape run as one group and
    both modes return correct values."""
    svc, a, b, c = _svc_ab()
    rep = svc.query_batch([
        Query("a & b", POPCOUNT),
        Query("a & c", MATERIALIZE),
        Query("b & c", POPCOUNT),
    ])
    assert rep.n_plan_groups == 1
    assert rep.results[0].value == int((a & b).sum())
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(jnp.asarray(rep.results[1].value), 200)),
        a & c)
    assert rep.results[2].value == int((b & c).sum())


def test_batched_equals_sequential_unbatched():
    spec = WorkloadSpec(n_tenants=2, n_weeks=2, domain_bits=512,
                        n_queries=32, seed=3)
    svc = build_service(spec, n_banks=8)
    queries = query_stream(spec, svc)
    rep = svc.query_batch(queries)
    ref = run_queries_unbatched(svc.catalog, queries)
    assert [r.value for r in rep.results] == [r.value for r in ref.results]
    # batching actually grouped: fewer plan groups than queries
    assert rep.n_plan_groups < len(queries)


def test_bank_scaling_speedup():
    spec = WorkloadSpec(n_tenants=2, n_weeks=3, domain_bits=512,
                        n_queries=64, seed=5)
    # the raw substrate claim: unoptimized, bank parallelism scales >= 3x
    svc8u = build_service(spec, n_banks=8, optimize=False)
    rep8u = svc8u.query_batch(query_stream(spec, svc8u))
    svc1u = build_service(spec, n_banks=1, optimize=False)
    rep1u = svc1u.query_batch(query_stream(spec, svc1u))
    assert [r.value for r in rep8u.results] \
        == [r.value for r in rep1u.results]
    assert rep1u.makespan_ns / rep8u.makespan_ns >= 3.0
    # the optimizer strips redundant (parallelizable) work, so its bank
    # scaling is shallower — but every deployment point is strictly faster
    # than its unoptimized counterpart, still bit-identical, still > 2x
    svc8 = build_service(spec, n_banks=8)
    rep8 = svc8.query_batch(query_stream(spec, svc8))
    svc1 = build_service(spec, n_banks=1)
    rep1 = svc1.query_batch(query_stream(spec, svc1))
    assert [r.value for r in rep8.results] == [r.value for r in rep8u.results]
    assert [r.value for r in rep8.results] == [r.value for r in rep1.results]
    assert rep8.makespan_ns <= rep8u.makespan_ns
    assert rep1.makespan_ns <= rep1u.makespan_ns
    assert rep1.makespan_ns / rep8.makespan_ns >= 2.0
    # hit rate on the repeated stream clears the serving bar
    assert svc8.stats()["plan_cache_hit_rate"] > 0.5


def test_latency_accounting_sane():
    svc, *_ = _svc_ab()
    rep = svc.query_batch([Query("a & b"), Query("a | c"), Query("b ^ c")])
    lats = [r.latency_ns for r in rep.results]
    assert all(l > 0 for l in lats)
    assert max(lats) <= rep.makespan_ns
    assert rep.latency_percentile_ns(50) <= rep.latency_percentile_ns(99)
    assert rep.qps > 0
    assert all(r.energy_nj > 0 for r in rep.results)
    banks = {r.bank for r in rep.results}
    assert len(banks) == 3  # least-loaded assignment spread the batch


# -- service facade ----------------------------------------------------------


def test_materialize_roundtrip():
    svc, a, b, c = _svc_ab()
    svc.materialize("ab", "a & b")
    r = svc.query("ab | c")
    assert r.value == int(((a & b) | c).sum())


def test_range_scan_parity_recorded_behavior():
    """Pins the behavior the removed `range_scan_fast` shortcut used to
    record: `range_scan(..., MATERIALIZE).words` is the packed predicate
    bitmap, bit-for-bit equal to the direct numpy evaluation."""
    svc = QueryService(n_banks=4)
    vals = RNG.integers(0, 256, 224, dtype=np.uint32)
    svc.register_column("col", jnp.asarray(vals), 8)
    lo, hi = 40, 180
    r = svc.query(svc.range_scan_query("col", lo, hi), mode=MATERIALIZE)
    expect = (vals >= lo) & (vals <= hi)
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(jnp.asarray(r.value), 224)), expect)
    np.testing.assert_array_equal(
        np.asarray(svc.range_scan("col", lo, hi, mode=MATERIALIZE).words),
        np.asarray(r.value))
    # popcount mode agrees
    assert svc.range_scan("col", lo, hi).value == int(expect.sum())
    assert not hasattr(svc, "range_scan_fast")


def test_stats_shape():
    svc, *_ = _svc_ab()
    svc.query("a & b")
    s = svc.stats()
    for k in ("queries_served", "plans_cached", "plan_cache_hits",
              "plan_cache_misses", "plan_cache_hit_rate", "compile_count",
              "total_modeled_ns", "total_energy_nj"):
        assert k in s


# -- apps as service clients --------------------------------------------------


def test_bitmap_index_service_client_bit_identical():
    from repro.apps import bitmap_index

    db = bitmap_index.UserDatabase.synthetic(
        jax.random.PRNGKey(2), m_users=300, n_weeks=3, p_active=0.4)
    n1, m1, _ = bitmap_index.weekly_active_query(db)
    n2, m2, stats = bitmap_index.weekly_active_query_service(db)
    assert int(n1) == n2
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    # the per-week male filters share one canonical plan
    assert stats["plan_cache_hits"] >= db.daily.shape[0] - 1


@pytest.mark.parametrize("op", ["union", "intersection", "difference"])
def test_bitset_service_client_bit_identical(op):
    from repro.apps.bitset import setop_via_service

    lists = [RNG.choice(256, size=30, replace=False) for _ in range(4)]
    result, qr, ref = setop_via_service(lists, 256, op=op)
    np.testing.assert_array_equal(np.asarray(result.bits.words),
                                  np.asarray(ref.bits.words))
    assert qr.n_aaps > 0
