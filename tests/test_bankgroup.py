"""Bank-parallel execution: banked results must be bit-identical to
single-bank execution for every program, and the pipelined controller
schedule must actually get faster with more banks."""
import numpy as np
import pytest

from repro.core import bankgroup, compiler, engine
from repro.core.bankgroup import (BankGroup, execute_banked,
                                  pipeline_latency_ns, shard_words,
                                  unshard_words)
from repro.core.compiler import Expr, compile_expr_fused

RNG = np.random.default_rng(11)
W = 96  # not divisible by every bank count on purpose


def rows(n):
    return {f"D{i}": RNG.integers(0, 2**32, W, dtype=np.uint32)
            for i in range(n)}


def test_shard_roundtrip():
    x = RNG.integers(0, 2**32, (W,), dtype=np.uint32)
    for banks in (1, 2, 3, 5, 8):
        s = shard_words(x, banks)
        assert s.shape[0] == banks
        np.testing.assert_array_equal(np.asarray(unshard_words(s, W)), x)


@pytest.mark.parametrize("banks", [1, 2, 4, 7])
@pytest.mark.parametrize("op", ["and", "or", "xor", "xnor", "nand", "andnot"])
def test_banked_matches_single_bank(op, banks):
    data = rows(2)
    prog = compiler.op_program(op, ["D0", "D1"], "D2")
    ref = engine.execute(prog, data, outputs=["D2"])["D2"]
    out = execute_banked(prog, data, banks, outputs=["D2"])["D2"]
    assert out.shape == (W,)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_banked_fused_expression():
    data = rows(3)
    a, b, c = (Expr.of(f"D{i}") for i in range(3))
    res = compile_expr_fused((a & b) | (b & c) | (c & a), "OUT")
    ref = engine.execute(res.program, data, outputs=["OUT"])["OUT"]
    out = execute_banked(res.program, data, 4, outputs=["OUT"])["OUT"]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_engine_execute_n_banks_param():
    data = rows(2)
    prog = compiler.op_program("xor", ["D0", "D1"], "D2")
    ref = engine.execute(prog, data, outputs=["D2"])["D2"]
    out = engine.execute(prog, data, outputs=["D2"], n_banks=3)["D2"]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_bankgroup_vmap_state_isolation():
    """Each bank computes on ITS slice only — no cross-bank mixing."""
    banks, per = 4, 8
    a = RNG.integers(0, 2**32, (banks, per), dtype=np.uint32)
    b = RNG.integers(0, 2**32, (banks, per), dtype=np.uint32)
    grp = BankGroup.create(banks, per, {"D0": a, "D1": b})
    prog = compiler.op_program("and", ["D0", "D1"], "D2")
    out = grp.run(prog)
    np.testing.assert_array_equal(np.asarray(out.read("D2")), a & b)
    # sources preserved per bank
    np.testing.assert_array_equal(np.asarray(out.read("D0")), a)


def test_bankgroup_rejects_unsharded_rows():
    with pytest.raises(ValueError):
        BankGroup.create(4, 8, {"D0": np.zeros((2, 8), np.uint32)})


def test_ops_banked_dispatch_matches():
    from repro.ops import bitwise as obw

    a = RNG.integers(0, 2**32, (1 << 12,), dtype=np.uint32)
    b = RNG.integers(0, 2**32, (1 << 12,), dtype=np.uint32)
    for fn, oracle in [(obw.bitwise_xor, a ^ b), (obw.bitwise_and, a & b),
                       (obw.andnot, a & ~b)]:
        out = fn(a, b, banks=4)
        np.testing.assert_array_equal(np.asarray(out), oracle)


def test_setops_banked_merges():
    from repro.ops.setops import BitSet

    dom = 1 << 10
    s1 = BitSet.from_elements(RNG.integers(0, dom, 100), dom)
    s2 = BitSet.from_elements(RNG.integers(0, dom, 100), dom)
    s3 = BitSet.from_elements(RNG.integers(0, dom, 100), dom)
    for op in ("union", "intersection", "difference"):
        ref = getattr(s1, op)(s2, s3)
        out = getattr(s1, op)(s2, s3, banks=2)
        np.testing.assert_array_equal(np.asarray(out.bits.words),
                                      np.asarray(ref.bits.words))


def test_pipeline_schedule_scales_and_bounds():
    prog = compiler.op_program("xor", ["D0", "D1"], "D2")
    n_blocks = 64
    last = None
    for banks in (1, 2, 4, 8):
        s = pipeline_latency_ns(n_blocks, banks, prog)
        assert s.total_ns <= s.serial_ns + 1e-9
        if last is not None:
            assert s.total_ns <= last  # more banks never slower
        last = s.total_ns
    # single bank with no overlap degenerates to the serial sum
    s1 = pipeline_latency_ns(n_blocks, 1, prog)
    assert s1.total_ns == pytest.approx(s1.serial_ns)
    # unbounded banks: transfer-stream bound + one program tail
    s_inf = pipeline_latency_ns(n_blocks, n_blocks, prog)
    from repro.core.timing import DDR3_1600, program_latency_ns
    expect = n_blocks * DDR3_1600.aap_ns + program_latency_ns(prog)
    assert s_inf.total_ns == pytest.approx(expect)


def test_banked_throughput_faster_than_single():
    prog = compiler.op_program("and", ["D0", "D1"], "D2")
    t1 = bankgroup.banked_throughput_gbps(256, 1, prog)
    t8 = bankgroup.banked_throughput_gbps(256, 8, prog)
    assert t8 > t1
