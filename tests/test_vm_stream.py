"""Streamed megakernel + fused reduction epilogue, end to end.

Covers the streamed-plane rebuild of `kernels.vm` and the `reduce=` path
it threads through the executor stack:

  * multi-grid-block streaming (explicit ``block_cols`` forces >= 4 word
    blocks even on CPU) stays bit-identical to the interpreter oracle
    across every batch-axis layout;
  * the fused popcount/aggregate epilogue equals
    materialize-then-popcount exactly, with and without tail masks and
    injected TRA faults;
  * `run_megakernel` API parity — ``errors`` used to be silently dropped
    (regression);
  * materialize mode returns EXACT rows/words — no sublane-padded
    writeback escapes the kernel (regression);
  * `execute_lowered(reduce=...)`, `execute_banked(reduce=...)`, and the
    scheduler's count-only fused dispatch agree with their materializing
    references;
  * `choose_backend(fused_reduce=True)` lowers the pallas threshold.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bankgroup, compiler, engine, lowering
from repro.core.commands import Program
from repro.core.errors import single_fault_planes
from repro.core.lowering import KIND_TRA
from repro.kernels.vm import run_megakernel
from repro.ops.popcount import popcount_words
from repro.service import (Query, QueryService, build_service, query_stream,
                           run_queries_unbatched, AGGREGATE, POPCOUNT,
                           WorkloadSpec)
from repro.service.optimizer import (_PALLAS_MIN_CMDS, _PALLAS_MIN_CMDS_FUSED,
                                     choose_backend)

RNG = np.random.default_rng(11)

# 520 words at block_cols=128 -> 5 grid blocks, the last one partial
W = 520
BLOCK = 128
BATCHES = [(), (3,), (2, 2)]


def _program():
    """(D0 ^ D1) & D2 -> OUT2, plus OUT1 = D0 & D1 — two outputs."""
    cmds = []
    for prog in (compiler.xor_program("D0", "D1", "A0"),
                 compiler.and_program("A0", "D2", "OUT2"),
                 compiler.and_program("D0", "D1", "OUT1")):
        cmds.extend(prog.commands)
    return Program(cmds, "stream"), ["D0", "D1", "D2"], ["OUT1", "OUT2"]


def _data(ins, batch, words=W, seed=0):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(rng.integers(0, 1 << 32, batch + (words,),
                                        dtype=np.uint32))
            for k in ins}


def _oracle(prog, data, outs):
    ref = engine.execute(prog, data, outputs=outs, lowered=False)
    return jnp.stack([ref[o] for o in outs])


def _tra_cmds(lp):
    return [int(c) for c in np.flatnonzero(
        (np.asarray(lp.table)[:, 0] & KIND_TRA) != 0)]


def _propagating_fault(lp, data, outs, batch=(), word=1, bit=7):
    """A single-TRA fault whose flip actually reaches an output row (not
    every sensed value survives to the end of the program)."""
    clean = lowering.execute_lowered(lp, data, W, outs, backend="scan")
    for cmd in _tra_cmds(lp):
        fault = single_fault_planes(lp.table, batch, W, cmd, word, bit)
        faulty = lowering.execute_lowered(lp, data, W, outs, backend="scan",
                                          errors=fault)
        if any(not np.array_equal(np.asarray(faulty[o]),
                                  np.asarray(clean[o])) for o in outs):
            return fault
    raise AssertionError("no propagating single fault found")


# -- streaming bit-identity ---------------------------------------------------


@pytest.mark.parametrize("batch", BATCHES)
def test_multi_block_materialize_matches_oracle(batch):
    prog, ins, outs = _program()
    lp = lowering.lower(prog)
    data = _data(ins, batch)
    plane = lowering.make_plane(lp, data, W, batch=batch)
    got = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(_oracle(prog, data, outs)))


def test_materialize_returns_exact_rows_and_words():
    """No sublane/lane padding escapes: 3 outputs (not a multiple of 8),
    520 words (not a multiple of 128) come back exactly."""
    prog, ins, _ = _program()
    prog = Program(list(prog.commands)
                   + list(compiler.or_program("OUT1", "OUT2", "OUT3").commands),
                   "stream3")
    outs = ["OUT1", "OUT2", "OUT3"]
    lp = lowering.lower(prog)
    data = _data(ins, ())
    plane = lowering.make_plane(lp, data, W)
    got = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK)
    assert got.shape == (3, W)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(_oracle(prog, data, outs)))


# -- fused reduction epilogue -------------------------------------------------


@pytest.mark.parametrize("batch", BATCHES)
@pytest.mark.parametrize("with_mask", [False, True])
def test_fused_popcount_equals_materialize_then_popcount(batch, with_mask):
    prog, ins, outs = _program()
    lp = lowering.lower(prog)
    data = _data(ins, batch)
    plane = lowering.make_plane(lp, data, W, batch=batch)
    mask = (jnp.asarray(RNG.integers(0, 1 << 32, (W,), dtype=np.uint32))
            if with_mask else None)
    counts = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK,
                            reduce="popcount", mask=mask)
    rows = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK)
    ref = popcount_words(rows if mask is None else rows & mask, axis=-1)
    assert counts.dtype == jnp.int32
    assert counts.shape == (len(outs),) + batch
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref))


@pytest.mark.parametrize("batch", [(), (3,)])
def test_fused_aggregate_weighted_sum(batch):
    prog, ins, outs = _program()
    lp = lowering.lower(prog)
    data = _data(ins, batch)
    plane = lowering.make_plane(lp, data, W, batch=batch)
    agg = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK,
                         reduce="aggregate")
    counts = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK,
                            reduce="popcount")
    want = sum(np.asarray(counts[j], np.float32) * float(1 << j)
               for j in range(len(outs)))
    assert agg.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(agg), want, rtol=1e-6)


def test_per_batch_mask_broadcast():
    prog, ins, outs = _program()
    lp = lowering.lower(prog)
    batch = (3,)
    data = _data(ins, batch)
    plane = lowering.make_plane(lp, data, W, batch=batch)
    mask = jnp.asarray(RNG.integers(0, 1 << 32, batch + (W,),
                                    dtype=np.uint32))
    counts = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK,
                            reduce="popcount", mask=mask)
    rows = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK)
    ref = popcount_words(rows & mask, axis=-1)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref))


def test_reduce_mode_validation():
    prog, ins, outs = _program()
    lp = lowering.lower(prog)
    plane = lowering.make_plane(lp, _data(ins, ()), W)
    with pytest.raises(ValueError, match="reduce"):
        run_megakernel(lp, plane, tuple(outs), reduce="sum")
    with pytest.raises(ValueError, match="mask"):
        run_megakernel(lp, plane, tuple(outs),
                       mask=jnp.zeros((W,), jnp.uint32))
    with pytest.raises(ValueError, match="word axis"):
        run_megakernel(lp, plane, tuple(outs), reduce="popcount",
                       mask=jnp.zeros((W + 1,), jnp.uint32))


# -- error-injection API parity (regression) ---------------------------------


def test_run_megakernel_threads_errors_through():
    """`run_megakernel` used to drop ``errors`` silently — a faulty run
    came back clean. It must now match the scan VM's injected result and
    differ from the clean one."""
    prog, ins, outs = _program()
    lp = lowering.lower(prog)
    data = _data(ins, ())
    plane = lowering.make_plane(lp, data, W)
    fault = _propagating_fault(lp, data, outs)
    faulty = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK,
                            errors=fault)
    clean = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK)
    ref = lowering.execute_lowered(lp, data, W, outs, backend="scan",
                                   errors=fault)
    assert not np.array_equal(np.asarray(faulty), np.asarray(clean))
    np.testing.assert_array_equal(
        np.asarray(faulty), np.stack([np.asarray(ref[o]) for o in outs]))


@pytest.mark.parametrize("batch", [(), (2,)])
def test_fused_popcount_with_injected_fault(batch):
    prog, ins, outs = _program()
    lp = lowering.lower(prog)
    data = _data(ins, batch)
    plane = lowering.make_plane(lp, data, W, batch=batch)
    fault = _propagating_fault(lp, data, outs, batch=batch, word=2, bit=3)
    counts = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK,
                            reduce="popcount", errors=fault)
    rows = run_megakernel(lp, plane, tuple(outs), block_cols=BLOCK,
                          errors=fault)
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.asarray(popcount_words(rows, axis=-1)))


# -- executor-stack threading -------------------------------------------------


@pytest.mark.parametrize("backend", ["scan", "pallas"])
def test_execute_lowered_reduce(backend):
    prog, ins, outs = _program()
    lp = lowering.lower(prog)
    data = _data(ins, (3,))
    mask = jnp.asarray(RNG.integers(0, 1 << 32, (W,), dtype=np.uint32))
    rows = lowering.execute_lowered(lp, data, W, outs, backend=backend)
    got = lowering.execute_lowered(lp, data, W, outs, backend=backend,
                                   reduce="popcount", mask=mask)
    for o in outs:
        np.testing.assert_array_equal(
            np.asarray(got[o]),
            np.asarray(popcount_words(rows[o] & mask, axis=-1)))
    # passthrough rows (inputs requested as outputs) also reduce
    got = lowering.execute_lowered(lp, data, W, outs + ["D0"],
                                   backend=backend, reduce="popcount")
    np.testing.assert_array_equal(
        np.asarray(got["D0"]),
        np.asarray(popcount_words(data["D0"], axis=-1)))
    agg = lowering.execute_lowered(lp, data, W, outs, backend=backend,
                                   reduce="aggregate")
    want = sum(np.asarray(popcount_words(rows[o], axis=-1), np.float32)
               * float(1 << j) for j, o in enumerate(outs))
    np.testing.assert_allclose(np.asarray(agg), want, rtol=1e-6)


def test_execute_lowered_reduce_validation():
    prog, ins, outs = _program()
    lp = lowering.lower(prog)
    data = _data(ins, ())
    with pytest.raises(ValueError, match="reduce"):
        lowering.execute_lowered(lp, data, W, outs, reduce="mean")
    with pytest.raises(ValueError, match="mask"):
        lowering.execute_lowered(lp, data, W, outs,
                                 mask=jnp.zeros((W,), jnp.uint32))


@pytest.mark.parametrize("n_banks", [1, 4])
def test_execute_banked_reduce(n_banks):
    prog, ins, outs = _program()
    # 70 words over 4 banks -> 18-word shards with 2 pad words; the
    # all-ones base mask must zero them out of the counts
    words = 70
    data = {k: v for k, v in _data(ins, (), words=words).items()}
    ref = engine.execute(prog, data, outputs=outs)
    counts = bankgroup.execute_banked(prog, data, n_banks, outputs=outs,
                                      reduce="popcount")
    for o in outs:
        assert int(counts[o]) == int(popcount_words(ref[o], axis=None))
    mask = jnp.asarray(RNG.integers(0, 1 << 32, (words,), dtype=np.uint32))
    counts = bankgroup.execute_banked(prog, data, n_banks, outputs=outs,
                                      reduce="popcount", mask=mask)
    for o in outs:
        assert int(counts[o]) == int(popcount_words(ref[o] & mask,
                                                    axis=None))
    agg = bankgroup.execute_banked(prog, data, n_banks, outputs=outs,
                                   reduce="aggregate")
    want = sum(float(int(popcount_words(ref[o], axis=None))) * (1 << j)
               for j, o in enumerate(outs))
    np.testing.assert_allclose(float(agg), want, rtol=1e-6)
    with pytest.raises(ValueError, match="lowered"):
        bankgroup.execute_banked(prog, data, n_banks, outputs=outs,
                                 lowered=False, reduce="popcount")


def test_banked_reduce_ignores_pad_words_driven_to_one():
    """A program that drives a row to all-ones must not count the zero-pad
    words `shard_words` appends to uneven shards."""
    prog = compiler.one_program("D0")
    words = 7                      # 4 banks -> 2-word shards, 1 pad word
    data = {"D0": jnp.zeros((words,), jnp.uint32)}
    counts = bankgroup.execute_banked(prog, data, 4, outputs=["D0"],
                                      reduce="popcount")
    assert int(counts["D0"]) == words * 32


# -- scheduler fused dispatch -------------------------------------------------


def test_scheduler_count_only_groups_use_fused_reduce(monkeypatch):
    spec = WorkloadSpec(n_tenants=2, n_weeks=2, domain_bits=512,
                        n_queries=24, seed=3)
    svc = build_service(spec, n_banks=8)
    queries = [q for q in query_stream(spec, svc) if q.mode == POPCOUNT]
    assert len(queries) >= 8
    seen = []
    orig = lowering.execute_lowered

    def spy(*args, **kwargs):
        seen.append(kwargs.get("reduce"))
        return orig(*args, **kwargs)

    ref = run_queries_unbatched(svc.catalog, queries)
    import repro.service.scheduler as sched
    monkeypatch.setattr(sched.lowering, "execute_lowered", spy)
    rep = svc.query_batch(queries)
    assert [r.value for r in rep.results] == [r.value for r in ref.results]
    # count-only groups went through the fused epilogue (CSE shared-plane
    # production legitimately materializes, and plans small enough for
    # the interpreter stay eager — but at least the large groups fuse)
    assert "popcount" in seen


def test_scheduler_aggregate_mode_fused_matches_reference():
    svc = QueryService(n_banks=4)
    rng = np.random.default_rng(5)
    bits = {k: rng.random(300) < 0.5 for k in "abcd"}
    for k, v in bits.items():
        svc.register_bits(k, v)
    q = "(a & b) | (c & ~d)"
    want = int(((bits["a"] & bits["b"])
                | (bits["c"] & ~bits["d"])).sum())
    rep = svc.query_batch([Query(q, POPCOUNT), Query(q, AGGREGATE)])
    assert rep.results[0].value == want
    assert rep.results[1].value == want  # single plane: weight 2**0


# -- backend selection --------------------------------------------------------


def test_choose_backend_fused_threshold():
    def prog_with(n_cmds):
        cmds = []
        while len(cmds) < n_cmds:
            cmds.extend(compiler.and_program("D0", "D1", "D2").commands)
        return Program(cmds[:n_cmds], f"n{n_cmds}")

    mid = prog_with((_PALLAS_MIN_CMDS + _PALLAS_MIN_CMDS_FUSED) // 2)
    assert choose_backend(mid, "tpu") == "scan"
    assert choose_backend(mid, "tpu", fused_reduce=True) == "pallas"
    big = prog_with(_PALLAS_MIN_CMDS)
    assert choose_backend(big, "tpu", fused_reduce=True) == "pallas"
    tiny = Program(list(compiler.and_program("D0", "D1", "D2").commands)[:2],
                   "tiny")
    assert choose_backend(tiny, "tpu", fused_reduce=True) == "interp"
    assert choose_backend(mid, "cpu", fused_reduce=True) == "scan"
