"""Documentation stays true: every module/script referenced by the docs
exists, and every Python code block in the docs actually runs (so imports
resolve and examples don't rot as the tree moves)."""
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md",
             REPO / "docs" / "architecture.md",
             REPO / "docs" / "paper_mapping.md"]

_PATH_RE = re.compile(
    r"`((?:src|benchmarks|tests|examples|docs)/[\w./]+\.(?:py|md))`")
_PYBLOCK_RE = re.compile(r"```python\n(.*?)```", re.S)
_MODULE_RE = re.compile(r"\b(repro(?:\.\w+)+)\b")


def test_docs_exist():
    for f in DOC_FILES:
        assert f.exists(), f"missing doc: {f}"


def test_referenced_paths_exist():
    missing = []
    for f in DOC_FILES:
        for ref in set(_PATH_RE.findall(f.read_text())):
            if not (REPO / ref).exists():
                missing.append(f"{f.name}: {ref}")
    assert not missing, f"docs reference nonexistent files: {missing}"


def test_paper_mapping_covers_every_benchmark():
    """Each benchmark script must appear in the reproduction index."""
    text = (REPO / "docs" / "paper_mapping.md").read_text()
    scripts = sorted(p.name for p in (REPO / "benchmarks").glob("fig*.py"))
    scripts += sorted(p.name for p in (REPO / "benchmarks").glob("table*.py"))
    missing = [s for s in scripts if s not in text]
    assert not missing, f"paper_mapping.md misses benchmarks: {missing}"


def test_doc_module_references_import():
    """Dotted repro.* module names in the docs must be importable."""
    import importlib

    bad = []
    for f in DOC_FILES:
        for mod in set(_MODULE_RE.findall(f.read_text())):
            root = ".".join(mod.split(".")[:3])  # repro.pkg.module at most
            try:
                importlib.import_module(root)
            except ImportError:
                try:  # maybe the tail is an attribute, not a module
                    importlib.import_module(".".join(root.split(".")[:2]))
                except ImportError:
                    bad.append(f"{f.name}: {mod}")
    assert not bad, f"docs reference unimportable modules: {bad}"


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_doc_python_blocks_run(doc):
    """Every ```python block in the docs executes cleanly."""
    blocks = _PYBLOCK_RE.findall(doc.read_text())
    for i, block in enumerate(blocks):
        ns: dict = {}
        try:
            exec(compile(block, f"{doc.name}:block{i}", "exec"), ns)
        except Exception as e:  # pragma: no cover - failure message
            pytest.fail(f"{doc.name} python block {i} failed: {e!r}")


def test_readme_quickstart_and_tier1_commands():
    text = (REPO / "README.md").read_text()
    assert "examples/quickstart.py" in text
    assert (REPO / "examples" / "quickstart.py").exists()
    assert "PYTHONPATH=src python -m pytest -x -q" in text
