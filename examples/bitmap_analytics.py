"""Paper §8.1 end-to-end: bitmap-index analytics (the Fig. 10 workload).

Runs the real query — "how many unique users were active every week of the
past n weeks, and how many male users were active each week?" — functionally
on the packed bitwise ops layer, and reports the modeled Buddy vs baseline
end-to-end times (the Fig. 10 reproduction lives in benchmarks/fig10_bitmap).

Run:  PYTHONPATH=src python examples/bitmap_analytics.py [--users 1000000]
"""
import argparse
import time

import jax

from repro.apps.bitmap_index import (UserDatabase, query_time_ns, speedup,
                                     weekly_active_query)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=1_000_000)
    ap.add_argument("--weeks", type=int, default=4)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    print(f"building synthetic user db: {args.users} users, "
          f"{args.weeks} weeks of daily activity bitmaps...")
    db = UserDatabase.synthetic(key, args.users, args.weeks)

    t0 = time.time()
    every_week, male_weekly, ops = weekly_active_query(db)
    t = time.time() - t0
    print(f"\nquery answered in {t:.2f}s (functional, packed-plane ops):")
    print(f"  users active every week: {int(every_week)}")
    print(f"  male users active per week: "
          f"{[int(x) for x in male_weekly]}")
    print(f"  bitwise op counts: {ops}")

    t_base = query_time_ns(args.users, args.weeks, use_buddy=False)
    t_buddy = query_time_ns(args.users, args.weeks, use_buddy=True)
    print("\nmodeled end-to-end time (paper cost model):")
    print(f"  baseline (SIMD CPU): {t_base/1e6:.2f} ms")
    print(f"  Buddy (in-DRAM):     {t_buddy/1e6:.2f} ms")
    print(f"  speedup: {speedup(args.users, args.weeks):.1f}x "
          f"(paper reports 6.0x avg across m, n)")


if __name__ == "__main__":
    main()
