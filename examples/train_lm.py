"""End-to-end training driver: a ~10M-param qwen3-family model for a few
hundred steps on synthetic data, with checkpointing and failure recovery —
the full production path (config -> model -> optimizer -> resilient runner)
at laptop scale. On a TPU slice, drop --reduced and the identical driver
trains the full assigned configs under the production mesh.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--signum]
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3_0p6b")
    ap.add_argument("--signum", action="store_true",
                    help="majority-vote 1-bit signSGD (the Buddy collective)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "8", "--seq", "128",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"]
    if args.signum:
        argv += ["--opt", "signum", "--lr", "1e-3"]
    return train_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
