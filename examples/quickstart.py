"""Quickstart: the Buddy-RAM bulk-bitwise substrate in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

# ---- 1. Bulk bitwise ops (the paper's core primitive) ----------------------
from repro.ops.bitwise import bitwise_and, bitwise_or, majority3
from repro.core.bitplane import pack_bits, unpack_bits

key = jax.random.PRNGKey(0)
n = 1 << 20                     # 1M-bit vectors
a = jax.random.bernoulli(key, 0.5, (n,))
b = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (n,))
c = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (n,))
pa, pb, pc = pack_bits(a), pack_bits(b), pack_bits(c)   # 32x packed uint32

x = bitwise_and(pa, pb)
y = bitwise_or(pa, pb)
m = majority3(pa, pb, pc)       # = triple-row activation (TRA)
assert np.array_equal(np.asarray(unpack_bits(m, n)),
                      np.asarray((a & b) | (b & c) | (c & a)))
print(f"1M-bit AND/OR/MAJ3 on packed planes: OK "
      f"({pa.nbytes} bytes per operand vs {a.nbytes} unpacked)")

# ---- 2. The in-DRAM execution model (AAP programs, Fig. 8) -----------------
from repro.core.compiler import and_program
from repro.core.timing import DDR3_1600, program_latency_ns

prog = and_program("D0", "D1", "D2")
print(f"\nBuddy 'Dk = Di and Dj' as an AAP program "
      f"({len(prog.commands)} commands):")
for c in prog.commands:
    print("   ", c)
lat = program_latency_ns(prog, DDR3_1600)
print(f"latency (split row decoder): {lat:.0f} ns for an 8KB row — vs "
      f"~{3 * 8192 / 12.8:.0f} ns to even move 3 rows over a DDR3-1600 "
      f"channel")

# ---- 2b. The fusing compiler + multi-bank engine ---------------------------
from repro.core.compiler import Expr, compile_expr, compile_expr_fused
from repro.core import engine as eng

ea, eb, ec = Expr.of("D0"), Expr.of("D1"), Expr.of("D2")
maj_expr = (ea & eb) | (eb & ec) | (ec & ea)
unfused = compile_expr(maj_expr, "OUT")
fused = compile_expr_fused(maj_expr, "OUT")
print(f"\nfusing compiler: majority-of-3 DAG lowers to "
      f"{len(fused.program.commands)} commands fused vs "
      f"{len(unfused.program.commands)} unfused (one native TRA)")

rows_data = {f"D{i}": np.random.default_rng(i).integers(
    0, 2**32, 4096, dtype=np.uint32) for i in range(3)}
out_1 = eng.execute(fused.program, rows_data, outputs=["OUT"])["OUT"]
out_8 = eng.execute(fused.program, rows_data, outputs=["OUT"], n_banks=8)["OUT"]
assert np.array_equal(np.asarray(out_1), np.asarray(out_8))
print("multi-bank engine: 8-bank vmap execution == single-bank, bit-exact")

# ---- 3. Buddy as a data-curation stage (bitmap-index pipeline) -------------
from repro.data.bitmap_filter import CorpusCatalog, build_filter

cat = CorpusCatalog.synthetic(key, n_docs=100_000)
bitmap, n_ok = build_filter(
    cat, require=("lang_en", "quality_hi", "dedup_canonical"),
    exclude=("toxic",), ranges={"n_tokens": (256, 4095)})
print(f"\ncorpus filter: {n_ok}/{cat.n_docs} documents eligible "
      f"(evaluated as bulk bitwise ops over packed bitmaps)")

# ---- 3b. The query service: submit()/QueryHandle over a catalog ------------
from repro.service import Query, QueryService, ServiceConfig, SloConfig

svc = QueryService(ServiceConfig(n_banks=8, slo=SloConfig(p99_ns=5e6)))
rng = np.random.default_rng(7)
for name in ("mon", "tue", "wed"):
    svc.register_bits(name, rng.random(1 << 12) < 0.4, group="days")

h = svc.submit("mon & tue", tenant="analytics")     # -> QueryHandle
assert h.done()
print(f"\nservice: |mon & tue| = {h.result().scalar} "
      f"(async handle, resolved eagerly without a serving loop)")

# the same handles flow through the continuous-serving runtime
from repro.service import Arrival

loop = svc.serve_loop(depth=2)
trace = [Arrival(t_ns=i * 20_000.0,
                 query=Query("mon & tue | wed", tenant="analytics"))
         for i in range(8)]
rep = loop.run_trace(trace)
print(f"serving loop: {len(rep.served)} served in {len(rep.ticks)} ticks, "
      f"{rep.sustained_qps:.0f} modeled qps, "
      f"p99 sojourn {rep.sojourn_percentile_ns(99) / 1e3:.1f} us")

# ---- 4. Majority-vote 1-bit gradient compression (TRA as a collective) -----
from repro.optim.signum import pack_tree, unpack_tree

g = {"w": jax.random.normal(key, (1000,))}
packed, meta = pack_tree(g)
signs = unpack_tree(packed, meta)
print(f"\nsign-compressed gradient: {g['w'].nbytes} B -> {packed.nbytes} B "
      f"(32x), majority-vote aggregated across data-parallel workers")
print("\nquickstart OK")
