"""Serving example: batched prefill + KV-cache decode for any assigned
architecture (reduced config on CPU; identical path serves the full configs
on a TPU slice — decode_32k / long_500k are the dry-run-validated shapes).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch mamba2_1p3b
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0p6b")
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()
    return serve_main(["--arch", args.arch, "--batch", "4",
                       "--prompt-len", "64", "--max-new", str(args.max_new)])


if __name__ == "__main__":
    raise SystemExit(main())
