"""Paper §8.2 end-to-end: BitWeaving-V database column scans.

`select count(*) from T where c1 <= val <= c2` evaluated entirely with bulk
bitwise operations over the vertical bit-plane layout, via the fused Pallas
scan kernel; the Fig. 11 sweep lives in benchmarks/fig11_bitweaving.

Run:  PYTHONPATH=src python examples/bitweaving_scan.py [--rows 4000000]
"""
import argparse
import time

import jax
import numpy as np

from repro.apps.bitweaving import speedup as scan_speedup
from repro.ops.predicate import VerticalColumn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4_000_000)
    ap.add_argument("--bits", type=int, default=12)
    ap.add_argument("--lo", type=int, default=100)
    ap.add_argument("--hi", type=int, default=900)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    vals = jax.random.randint(key, (args.rows,), 0, 1 << args.bits)
    print(f"encoding {args.rows} x {args.bits}-bit column into "
          f"BitWeaving-V planes...")
    col = VerticalColumn.encode(vals, args.bits)

    t0 = time.time()
    hits = col.scan(args.lo, args.hi)
    n = int(hits.popcount())
    t = time.time() - t0
    ref = int(np.sum((np.asarray(vals) >= args.lo)
                     & (np.asarray(vals) <= args.hi)))
    assert n == ref, (n, ref)
    print(f"count(*) where {args.lo} <= val <= {args.hi}: {n} "
          f"(verified vs numpy) in {t:.3f}s")
    print(f"\nmodeled Buddy speedup over SIMD BitWeaving for this scan: "
          f"{scan_speedup(args.rows, args.bits):.1f}x "
          f"(paper reports 1.8-11.8x, 7.0x avg)")


if __name__ == "__main__":
    main()
