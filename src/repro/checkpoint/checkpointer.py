"""Fault-tolerant checkpointing.

* Atomic: writes land in `step_XXXXXXXX.tmp-<nonce>/` and are renamed into
  place only after the manifest is fsync'd — a crash mid-save can never
  corrupt the latest valid checkpoint.
* Async: `save()` snapshots device arrays to host (blocking only for the
  device->host copy) and hands serialization to a background thread.
* Elastic restore: `load_checkpoint(..., shardings=...)` re-lays out every
  leaf for a *different* mesh than the one that saved it (leaves are stored
  unsharded; resharding is a device_put with the new NamedSharding).
* bf16-safe: leaves are serialized as raw bytes + dtype tag (ml_dtypes
  round-trips bfloat16 through numpy).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save ----------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        """Snapshot to host, then serialize (async unless async_save=False)."""
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: Dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        leaves = _flatten_with_paths(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for i, (name, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            fn = f"leaf_{i:05d}.bin"
            with open(os.path.join(tmp, fn), "wb") as f:
                f.write(arr.tobytes())
            manifest["leaves"].append(
                {"name": name, "file": fn, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---- restore ---------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and ".tmp" not in d and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None
                ) -> Tuple[int, Any, Dict]:
        """Restore into the structure of `like`. `shardings` (optional tree
        of NamedSharding mirroring `like`) re-lays-out for the current mesh
        (elastic restart).

        With ``step=None``, a checkpoint that turns out damaged on read (a
        crash can truncate or delete leaf files even after the manifest
        landed — e.g. a torn filesystem, or an operator partially cleaning
        the directory) is skipped and the next-older intact step is used;
        an explicitly requested ``step`` still raises on damage.
        """
        if step is not None:
            return self._restore_step(step, like, shardings)
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        last_err: Optional[Exception] = None
        for s in reversed(steps):
            try:
                return self._restore_step(s, like, shardings)
            except (OSError, ValueError, KeyError) as e:
                last_err = e    # damaged: fall back to the next-older step
        raise FileNotFoundError(
            f"no intact checkpoint in {self.dir}: {last_err}")

    def _restore_step(self, step: int, like: Any,
                      shardings: Optional[Any]) -> Tuple[int, Any, Dict]:
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        arrs = []
        for entry in manifest["leaves"]:
            with open(os.path.join(path, entry["file"]), "rb") as f:
                buf = f.read()
            arr = np.frombuffer(buf, dtype=np.dtype(entry["dtype"])
                                ).reshape(entry["shape"])
            arrs.append(arr)
        treedef = jax.tree.structure(like)
        tree = jax.tree.unflatten(treedef, arrs)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return step, tree, manifest.get("extra", {})


def load_checkpoint(directory: str, like: Any, step: Optional[int] = None,
                    shardings: Optional[Any] = None):
    return Checkpointer(directory).restore(like, step, shardings)
