from repro.checkpoint.checkpointer import Checkpointer, load_checkpoint
