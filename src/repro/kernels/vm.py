"""Pallas megakernel VM: one kernel launch executes a whole AAP program.

The lowered-program analog of the paper's §7 controller: instead of one
`pallas_call` per operator (`kernels.bitwise` / `kernels.arith`), the whole
subarray plane tensor is loaded into VMEM **once**, a `fori_loop` sequencer
walks the static ``(n_cmds, 5)`` opcode table (scalar-prefetched, so the
command stream is resident before the body runs — the TPU version of the
dumb sequencer in SIMDRAM's µProgram engine), and only the requested output
rows are written back to HBM. Data never leaves the "subarray" (VMEM) for
the duration of the program — the TPU translation of "operands never cross
the channel".

Grid = word blocks (bitwise programs are word-local), so arbitrarily wide
rows stream through a fixed VMEM footprint: one ``(n_rows, block_cols)``
plane block plus the table. At the default 2048-word block a 128-row plane
is 1 MiB — far under the ~16 MiB/core VMEM.

Semantics are exactly `core.lowering._vm_step` (same encoding, same write
order) and bit-identical to `core.engine.Subarray.run`
(tests/test_property_lowering.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lowering import FIXED_ROWS, LoweredProgram
from repro.kernels.common import (LANE, SUBLANE, pad_to, pick_block, round_up,
                                  use_interpret)

_N_FIXED = len(FIXED_ROWS)


def _vm_kernel(n_cmds: int, out_idx: tuple, with_err: bool = False):
    def kern(tbl_ref, plane_ref, *refs):
        if with_err:
            err_ref, out_ref, scratch = refs
        else:
            err_ref = None
            out_ref, scratch = refs
        # load the whole plane block into VMEM once; it stays resident for
        # every command of the program
        scratch[...] = plane_ref[...]
        full = jnp.uint32(0xFFFFFFFF)
        zero = jnp.uint32(0)
        bits = jax.lax.broadcasted_iota(jnp.int32, (_N_FIXED, 1), 0)

        def body(i, carry):
            kind = tbl_ref[i, 0]

            def src(col, polbit):
                row = scratch[pl.ds(tbl_ref[i, col], 1), :]
                mask = jnp.where((kind >> polbit) & 1, full, zero)
                return row ^ mask

            s0, s1, s2 = src(1, 2), src(2, 3), src(3, 4)
            v = (s0 & s1) | (s1 & s2) | (s2 & s0)   # (1, bw) sensed value
            if with_err:
                # TRA fault injection at compute time: command i's four
                # pattern-class XOR masks live at rows 4i..4i+3 of the
                # flattened error block; exactly one class matches per bit
                # (same selection as `core.lowering._vm_exec`)
                e0 = err_ref[pl.ds(4 * i, 1), :]
                e1 = err_ref[pl.ds(4 * i + 1, 1), :]
                e2 = err_ref[pl.ds(4 * i + 2, 1), :]
                e3 = err_ref[pl.ds(4 * i + 3, 1), :]
                ones3 = s0 & s1 & s2
                lit = s0 | s1 | s2
                flip = ((e0 & ~lit) | (e1 & (lit & ~v))
                        | (e2 & (v & ~ones3)) | (e3 & ones3))
                v = v ^ flip

            aux = tbl_ref[i, 4]
            pos_sel = (((aux >> bits) & 1) == 1)
            neg_sel = ((((aux >> 8) >> bits) & 1) == 1)
            head = scratch[0:_N_FIXED, :]
            head = jnp.where(pos_sel, v, head)
            head = jnp.where(neg_sel, ~v, head)
            scratch[0:_N_FIXED, :] = head
            scratch[pl.ds(aux >> 16, 1), :] = v     # D/C destination or sink
            return carry

        jax.lax.fori_loop(0, n_cmds, body, 0)
        for k, ridx in enumerate(out_idx):          # static gather: only the
            out_ref[k, :] = scratch[ridx, :]        # output rows leave VMEM

    return kern


@functools.partial(jax.jit, static_argnames=("out_idx", "block_cols"))
def _vm_call(table: jax.Array, plane: jax.Array, errors=None, *,
             out_idx: tuple, block_cols: int) -> jax.Array:
    n_rows, w = plane.shape
    n_cmds = table.shape[0]
    rp = round_up(n_rows, SUBLANE)
    bw = pick_block(w, block_cols, LANE)
    wp = round_up(w, bw)
    plane_p = pad_to(plane, (rp, wp))
    n_out = len(out_idx)
    op = round_up(max(n_out, 1), SUBLANE)
    with_err = errors is not None
    in_specs = [pl.BlockSpec((rp, bw), lambda j, tbl: (0, j))]
    operands = [table, plane_p]
    if with_err:
        # flattened (n_cmds * 4, words) XOR-mask block, row-padded to the
        # sublane tile; rides VMEM next to the plane for the whole program
        ep = round_up(errors.shape[0], SUBLANE)
        operands.append(pad_to(jnp.asarray(errors, jnp.uint32), (ep, wp)))
        in_specs.append(pl.BlockSpec((ep, bw), lambda j, tbl: (0, j)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(wp // bw,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((op, bw), lambda j, tbl: (0, j)),
        scratch_shapes=[pltpu.VMEM((rp, bw), jnp.uint32)],
    )
    out = pl.pallas_call(
        _vm_kernel(n_cmds, out_idx, with_err),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((op, wp), jnp.uint32),
        interpret=use_interpret(),
    )(*operands)
    return out[:n_out, :w]


def vm_megakernel(table: np.ndarray, plane: jax.Array, out_idx: tuple,
                  block_cols: int = 2048, errors=None) -> jax.Array:
    """Run a lowered opcode table over a plane tensor in one kernel launch.

    ``plane`` is ``(n_rows, words)`` uint32, optionally with inner batch
    axes (``(n_rows, *batch, words)``) — the bank/query axes of
    `core.bankgroup` / the service scheduler, or the chip-local
    ``(1, local_banks, ...)`` block a `core.cluster.ChipCluster` shard
    executes under `shard_map`. All batch axes collapse into ONE vmapped
    kernel axis (a single flat launch grid per shard, instead of one
    nested vmap level per axis), then reshape back; returns the
    ``(len(out_idx), *batch, words)`` output rows only.

    ``errors`` (optional) is the ``(n_cmds, 4, *batch, words)`` TRA
    fault-mask tensor of `core.errors.error_planes`; per vmap slice it is
    flattened to a ``(n_cmds * 4, words)`` block resident in VMEM beside
    the plane, so injection happens inside the sequencer loop at TRA
    compute time — bit-identical to the scan VM's injection for the same
    masks (tests/test_errors.py).
    """
    plane = jnp.asarray(plane, jnp.uint32)
    table = jnp.asarray(table, jnp.int32)
    out_idx = tuple(int(i) for i in out_idx)
    if use_interpret():
        # off-TPU there is no VMEM budget and interpret-mode grid steps are
        # the cost driver: one block per call
        block_cols = max(block_cols, plane.shape[-1])
    call = functools.partial(_vm_call, out_idx=out_idx,
                             block_cols=block_cols)
    n_cmds, words = table.shape[0], plane.shape[-1]
    if errors is not None:
        errors = jnp.broadcast_to(
            jnp.asarray(errors, jnp.uint32),
            (n_cmds, 4) + plane.shape[1:-1] + (words,))
    if plane.ndim == 2:
        if errors is None:
            return call(table, plane)
        return call(table, plane, errors.reshape(n_cmds * 4, words))
    batch = plane.shape[1:-1]
    flat = jnp.moveaxis(plane, 0, -2).reshape((-1,) + (plane.shape[0],
                                                       plane.shape[-1]))
    if errors is None:
        out = jax.vmap(lambda p: call(table, p))(flat)
    else:
        eflat = jnp.moveaxis(errors, (0, 1), (-3, -2)).reshape(
            (-1, n_cmds * 4, words))
        out = jax.vmap(lambda p, e: call(table, p, e))(flat, eflat)
    out = out.reshape(batch + out.shape[-2:])
    return jnp.moveaxis(out, -2, 0)


def run_megakernel(lp: LoweredProgram, plane: jax.Array,
                   outputs: tuple, block_cols: int = 2048) -> jax.Array:
    """Named-row convenience over `vm_megakernel`."""
    out_idx = tuple(lp.row_index(o) for o in outputs)
    return vm_megakernel(lp.table, plane, out_idx, block_cols)
