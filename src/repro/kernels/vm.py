"""Streamed-plane Pallas megakernel VM: one launch executes a whole program.

The lowered-program analog of the paper's §7 controller: instead of one
`pallas_call` per operator (`kernels.bitwise` / `kernels.arith`), the whole
subarray plane tensor streams through VMEM block by block, a `fori_loop`
sequencer walks the static ``(n_cmds, 5)`` opcode table (scalar-prefetched,
so the command stream is resident before the body runs — the TPU version of
the dumb sequencer in SIMDRAM's µProgram engine), and only the requested
output rows — or just their popcounts — ever leave the chip.

Launch shape: the grid is ``(flat_batch, word_blocks)``. Every bank/query
batch axis folds into the leading grid axis (ONE launch covers the whole
stacked dispatch — no per-slice `jax.vmap` over flattened planes), and the
word axis tiles into ``block_cols``-wide blocks, so arbitrarily wide rows
stream through a fixed ``(n_rows, block_cols)`` VMEM footprint. Pallas
pipelines the grid with double-buffered HBM→VMEM block copies: while the
sequencer chews block j, block j+1's async copy is in flight — the
copy/compute overlap that puts the kernel on the HBM bandwidth roofline
(measured by ``benchmarks/vm_stream.py`` against `repro.hw.HBM_BW`).

Fused reduction epilogue (``reduce=``): bitwise programs are word-local, so
count-only queries (the scheduler's popcount / aggregate result modes)
never need the output planes in HBM at all. With ``reduce="popcount"`` the
kernel popcounts each output row's block in VMEM (SWAR, Hacker's Delight
5-2) and accumulates per-plane int32 counts across the word-block grid axis
in a VMEM-resident output block — per (batch, plane) only ONE int32 crosses
to HBM, regardless of operand width. ``reduce="aggregate"`` additionally
weights the counts ``sum_j 2**j * popcount(OUT_j)`` outside the kernel
(Python-int safe via float64 is NOT used — see `vm_megakernel`). An
optional per-word ``mask`` (the catalog tail mask) ANDs into every counted
block; padding lanes beyond the true word count are masked inside the
kernel, so programs that drive pad words to 1 (NOT et al.) never miscount.

Semantics are exactly `core.lowering._vm_step` (same encoding, same write
order) and bit-identical to `core.engine.Subarray.run`
(tests/test_property_lowering.py, tests/test_vm_stream.py) — including TRA
fault injection via ``errors`` and the fused epilogue vs
materialize-then-popcount.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lowering import FIXED_ROWS, LoweredProgram
from repro.kernels.common import (LANE, SUBLANE, pad_to, pick_block, round_up,
                                  use_interpret)

_N_FIXED = len(FIXED_ROWS)

#: word-block width on real accelerators. At 2048 words a 128-row plane
#: block is 1 MiB of VMEM — small enough that the pipeline's double
#: buffering (2x in-flight blocks) stays far under the ~16 MiB/core budget.
DEFAULT_BLOCK_COLS = 2048

REDUCE_MODES = (None, "popcount", "aggregate")

# jax renamed TPUCompilerParams -> CompilerParams; tolerate both (and very
# old versions with neither — then no dimension semantics are passed).
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _popcount_block(w: jax.Array) -> jax.Array:
    """SWAR popcount of a uint32 block (Hacker's Delight 5-2), elementwise.

    Inlined rather than imported from `repro.ops.popcount` to keep this
    kernel module free of an ops-package import cycle.
    """
    w = w - ((w >> 1) & jnp.uint32(0x55555555))
    w = (w & jnp.uint32(0x33333333)) + ((w >> 2) & jnp.uint32(0x33333333))
    w = (w + (w >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (w * jnp.uint32(0x01010101)) >> 24


def _vm_kernel(n_cmds: int, out_idx: tuple, with_err: bool, with_mask: bool,
               reduce_counts: bool, n_words: int, block_w: int):
    def kern(tbl_ref, plane_ref, *refs):
        refs = list(refs)
        err_ref = refs.pop(0) if with_err else None
        mask_ref = refs.pop(0) if with_mask else None
        out_ref, scratch = refs
        # stream this (batch, word-block) plane tile into VMEM; it stays
        # resident for every command of the program while the pipeline
        # prefetches the next grid block behind it
        scratch[...] = plane_ref[...]
        full = jnp.uint32(0xFFFFFFFF)
        zero = jnp.uint32(0)
        bits = jax.lax.broadcasted_iota(jnp.int32, (_N_FIXED, 1), 0)

        def body(i, carry):
            kind = tbl_ref[i, 0]

            def src(col, polbit):
                row = scratch[pl.ds(tbl_ref[i, col], 1), :]
                mask = jnp.where((kind >> polbit) & 1, full, zero)
                return row ^ mask

            s0, s1, s2 = src(1, 2), src(2, 3), src(3, 4)
            v = (s0 & s1) | (s1 & s2) | (s2 & s0)   # (1, bw) sensed value
            if with_err:
                # TRA fault injection at compute time: command i's four
                # pattern-class XOR masks live at rows 4i..4i+3 of the
                # flattened error block; exactly one class matches per bit
                # (same selection as `core.lowering._vm_exec`)
                e0 = err_ref[pl.ds(4 * i, 1), :]
                e1 = err_ref[pl.ds(4 * i + 1, 1), :]
                e2 = err_ref[pl.ds(4 * i + 2, 1), :]
                e3 = err_ref[pl.ds(4 * i + 3, 1), :]
                ones3 = s0 & s1 & s2
                lit = s0 | s1 | s2
                flip = ((e0 & ~lit) | (e1 & (lit & ~v))
                        | (e2 & (v & ~ones3)) | (e3 & ones3))
                v = v ^ flip

            aux = tbl_ref[i, 4]
            pos_sel = (((aux >> bits) & 1) == 1)
            neg_sel = ((((aux >> 8) >> bits) & 1) == 1)
            head = scratch[0:_N_FIXED, :]
            head = jnp.where(pos_sel, v, head)
            head = jnp.where(neg_sel, ~v, head)
            scratch[0:_N_FIXED, :] = head
            scratch[pl.ds(aux >> 16, 1), :] = v     # D/C destination or sink
            return carry

        jax.lax.fori_loop(0, n_cmds, body, 0)

        if not reduce_counts:
            for k, ridx in enumerate(out_idx):      # static gather: only the
                out_ref[k, :] = scratch[ridx, :]    # output rows leave VMEM
            return

        # fused reduction epilogue: popcount the output rows of THIS word
        # block and accumulate into the VMEM-resident (n_out, 1) count
        # block — the out index map is constant in j, so the block never
        # round-trips to HBM between grid steps. Lanes past the true word
        # count are zeroed (programs like NOT drive pad words to ones), as
        # are lanes the caller's word mask drops.
        j = pl.program_id(1)
        col = jax.lax.broadcasted_iota(jnp.int32, (1, block_w), 1) \
            + j * block_w
        vmask = jnp.where(col < n_words, full, zero)
        if with_mask:
            vmask = vmask & mask_ref[...]
        rows = jnp.concatenate([scratch[r:r + 1, :] for r in out_idx])
        counts = jnp.sum(_popcount_block(rows & vmask).astype(jnp.int32),
                         axis=1, keepdims=True)    # (n_out, 1)

        @pl.when(j == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        out_ref[...] += counts

    return kern


@functools.partial(jax.jit,
                   static_argnames=("out_idx", "block_cols", "reduce"))
def _vm_call(table: jax.Array, plane: jax.Array, errors=None, mask=None, *,
             out_idx: tuple, block_cols: int,
             reduce: Optional[str] = None) -> jax.Array:
    """One grid-folded pallas_call over a flat (B, n_rows, words) plane."""
    B, n_rows, w = plane.shape
    n_cmds = table.shape[0]
    rp = round_up(n_rows, SUBLANE)
    bw = pick_block(w, block_cols, LANE)
    wp = round_up(w, bw)
    plane_p = pad_to(plane, (B, rp, wp))
    n_out = len(out_idx)
    with_err = errors is not None
    with_mask = mask is not None
    in_specs = [pl.BlockSpec((None, rp, bw), lambda b, j, tbl: (b, 0, j))]
    operands = [table, plane_p]
    if with_err:
        # flattened (B, n_cmds * 4, words) XOR-mask tensor, row-padded to
        # the sublane tile; each block streams through VMEM alongside the
        # plane block it faults
        ep = round_up(errors.shape[-2], SUBLANE)
        operands.append(pad_to(jnp.asarray(errors, jnp.uint32), (B, ep, wp)))
        in_specs.append(
            pl.BlockSpec((None, ep, bw), lambda b, j, tbl: (b, 0, j)))
    if with_mask:
        # (1, words) shared mask or (B, words) per-batch mask
        mb = mask.shape[0]
        operands.append(pad_to(jnp.asarray(mask, jnp.uint32), (mb, wp)))
        if mb == 1:
            in_specs.append(pl.BlockSpec((1, bw), lambda b, j, tbl: (0, j)))
        else:
            in_specs.append(pl.BlockSpec((1, bw), lambda b, j, tbl: (b, j)))
    if reduce is None:
        # exact output rows/words: Pallas masks the partial trailing block,
        # so no padded HBM writeback escapes the dispatch
        out_shape = jax.ShapeDtypeStruct((B, n_out, w), jnp.uint32)
        out_spec = pl.BlockSpec((None, n_out, bw), lambda b, j, tbl: (b, 0, j))
        dim_sem = ("parallel", "parallel")
    else:
        out_shape = jax.ShapeDtypeStruct((B, n_out, 1), jnp.int32)
        out_spec = pl.BlockSpec((None, n_out, 1), lambda b, j, tbl: (b, 0, 0))
        # the count block accumulates across the word-block axis, so j must
        # iterate in order; batches stay independent
        dim_sem = ("parallel", "arbitrary")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, wp // bw),
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[pltpu.VMEM((rp, bw), jnp.uint32)],
    )
    kwargs = {}
    if _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=dim_sem)
    out = pl.pallas_call(
        _vm_kernel(n_cmds, out_idx, with_err, with_mask, reduce is not None,
                   w, bw),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=use_interpret(),
        **kwargs,
    )(*operands)
    return out[..., 0] if reduce is not None else out


def vm_megakernel(table: np.ndarray, plane: jax.Array, out_idx: tuple,
                  block_cols: Optional[int] = None, errors=None,
                  reduce: Optional[str] = None, mask=None) -> jax.Array:
    """Run a lowered opcode table over a plane tensor in one kernel launch.

    ``plane`` is ``(n_rows, words)`` uint32, optionally with inner batch
    axes (``(n_rows, *batch, words)``) — the bank/query axes of
    `core.bankgroup` / the service scheduler, or the chip-local
    ``(1, local_banks, ...)`` block a `core.cluster.ChipCluster` shard
    executes under `shard_map`. All batch axes fold into the LEADING GRID
    AXIS of a single launch (no per-slice `jax.vmap`), the word axis tiles
    into ``block_cols``-wide grid blocks, and Pallas double-buffers the
    HBM→VMEM block stream across grid steps.

    ``block_cols=None`` picks `DEFAULT_BLOCK_COLS` on accelerators and one
    whole-width block in interpret mode (off-TPU there is no VMEM budget
    and interpret-mode grid steps are the cost driver). An explicit value
    is honored everywhere — tests and benchmarks use it to exercise
    multi-block streaming on CPU.

    ``errors`` (optional) is the ``(n_cmds, 4, *batch, words)`` TRA
    fault-mask tensor of `core.errors.error_planes`; per batch slice it is
    flattened to a ``(n_cmds * 4, words)`` block streamed beside the
    plane, so injection happens inside the sequencer loop at TRA compute
    time — bit-identical to the scan VM's injection for the same masks.

    ``reduce`` selects the fused reduction epilogue:
      * ``None`` — return the ``(len(out_idx), *batch, words)`` output
        rows (exact rows and words; nothing padded reaches HBM).
      * ``"popcount"`` — return ``(len(out_idx), *batch)`` int32 per-plane
        popcounts, accumulated in VMEM inside the kernel; output planes
        never materialize to HBM.
      * ``"aggregate"`` — return the ``batch``-shaped float32 weighted sum
        ``sum_j 2**j * popcount(OUT_j)`` (`_weight_counts`); the per-plane
        counts still accumulate in VMEM — only the tiny weighting runs
        outside the kernel. Exact-big-integer consumers (the scheduler's
        aggregate result mode) take ``reduce="popcount"`` counts and
        weight host-side with Python ints instead.

    ``mask`` (reduce modes only) is a per-word uint32 mask ANDed into every
    counted block — shape ``(words,)``, or any shape broadcastable to
    ``batch + (words,)`` (e.g. the per-bank catalog tail-mask shards of
    the cluster layer).
    """
    if reduce not in REDUCE_MODES:
        raise ValueError(f"unknown reduce mode {reduce!r}; "
                         f"expected one of {REDUCE_MODES}")
    if mask is not None and reduce is None:
        raise ValueError("mask= is only meaningful with a reduce mode")
    plane = jnp.asarray(plane, jnp.uint32)
    table = jnp.asarray(table, jnp.int32)
    out_idx = tuple(int(i) for i in out_idx)
    n_cmds, words = table.shape[0], plane.shape[-1]
    n_rows = plane.shape[0]
    batch = plane.shape[1:-1]
    if block_cols is None:
        block_cols = words if use_interpret() else DEFAULT_BLOCK_COLS
    if not out_idx:
        if reduce is None:
            return jnp.zeros((0,) + batch + (words,), jnp.uint32)
        counts = jnp.zeros((0,) + batch, jnp.int32)
        return counts if reduce == "popcount" else _weight_counts(counts)

    flat = jnp.moveaxis(plane, 0, -2).reshape((-1, n_rows, words))
    eflat = None
    if errors is not None:
        errors = jnp.broadcast_to(
            jnp.asarray(errors, jnp.uint32),
            (n_cmds, 4) + batch + (words,))
        eflat = jnp.moveaxis(errors, (0, 1), (-3, -2)).reshape(
            (-1, n_cmds * 4, words))
    mflat = None
    if mask is not None:
        m = jnp.asarray(mask, jnp.uint32)
        if m.shape[-1] != words:
            raise ValueError(
                f"mask word axis {m.shape[-1]} != plane words {words}")
        if all(d == 1 for d in m.shape[:-1]):
            mflat = m.reshape((1, words))           # shared across batches
        else:
            mflat = jnp.broadcast_to(m, batch + (words,)).reshape(
                (-1, words))                        # per-batch mask
    out = _vm_call(table, flat, eflat, mflat, out_idx=out_idx,
                   block_cols=int(block_cols),
                   reduce=None if reduce is None else "popcount")
    if reduce is None:
        out = out.reshape(batch + out.shape[-2:])
        return jnp.moveaxis(out, -2, 0)
    counts = jnp.moveaxis(out.reshape(batch + (len(out_idx),)), -1, 0)
    if reduce == "popcount":
        return counts                               # (n_out,) + batch int32
    return _weight_counts(counts)


def _weight_counts(counts: jax.Array) -> jax.Array:
    """``sum_j 2**j * counts[j]`` without x64: float64 is unavailable under
    jax's default int32 lattice, so the weighted sum is returned as float32
    — exact for small planes, and documented as approximate beyond 2**24.
    Exact-integer consumers (`service.scheduler`) take ``reduce="popcount"``
    counts and weight host-side with Python ints instead."""
    n_out = counts.shape[0]
    weights = jnp.asarray([float(1 << j) for j in range(n_out)],
                          jnp.float32).reshape((n_out,) + (1,)
                                               * (counts.ndim - 1))
    return jnp.sum(counts.astype(jnp.float32) * weights, axis=0)


def run_megakernel(lp: LoweredProgram, plane: jax.Array,
                   outputs: tuple, block_cols: Optional[int] = None,
                   errors=None, reduce: Optional[str] = None,
                   mask=None) -> jax.Array:
    """Named-row convenience over `vm_megakernel`.

    Full API parity with `vm_megakernel` — in particular ``errors`` is
    threaded through (it used to be silently dropped; regression-tested by
    tests/test_vm_stream.py).
    """
    out_idx = tuple(lp.row_index(o) for o in outputs)
    return vm_megakernel(lp.table, plane, out_idx, block_cols=block_cols,
                         errors=errors, reduce=reduce, mask=mask)
