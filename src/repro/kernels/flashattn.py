"""Flash attention for TPU (pl.pallas_call + BlockSpec VMEM tiling).

Why it exists here: the dry-run roofline showed every train/prefill cell
memory-bound, dominated by the (S, S) score/prob traffic that XLA must
materialize between the QK^T and PV matmuls (two dots cannot fuse). This
kernel keeps the (block_q, block_k) tiles and the online-softmax state in
VMEM; HBM traffic per attention is exactly q+k+v+o.

Grid layout: (batch, q_heads, nq, nk) with the kv dimension "arbitrary"
(sequential) — the running max/denominator/accumulator live in VMEM scratch
across the nk steps (the standard TPU flash schedule). GQA is folded via the
k/v index_map (kv_head = q_head // group). Causal cells skip fully-masked
blocks with pl.when, so the causal waste is runtime-skipped, not just masked.

VMEM budget per core at the default (block_q=512, block_k=512, hd=128):
  q/k/v tiles: 3 x 512x128x2B = 384 KB; s/p: 512x512x4B = 1 MB
  acc + m + l scratch: 512x128x4 + 2x512x4 = 260 KB           << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import use_interpret

# jax renamed TPUCompilerParams -> CompilerParams; support both without
# mutating the shared pltpu module
def _no_compiler_params(*_a, **_k):
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported by flashattn")


_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams",
                                  _no_compiler_params))

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int,
                  kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip blocks strictly above the diagonal (runtime skip).
    run = (qi + 1) * bq - 1 >= ki * bk if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, 0]                                   # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < kv_len
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            valid = valid & (qpos >= kpos)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _out():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _flash_kernel_lse(q_ref, k_ref, v_ref, o_ref, lse_ref,
                      m_scr, l_scr, acc_scr, **kw):
    """Forward that also emits logsumexp rows (for the custom backward)."""
    _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, **kw)
    ki = pl.program_id(3)

    @pl.when(ki == kw["nk"] - 1)
    def _emit_lse():
        lse_ref[0, 0] = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, block_q: int = 512,
                           block_k: int = 512) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd), H % KV == 0.
    Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    Sq_orig, Sk_orig = Sq, Sk
    if Sq % bq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, (-Sq) % bq), (0, 0)))
        Sq = q.shape[2]
    if Sk % bk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, (-Sk) % bk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, (-Sk) % bk), (0, 0)))
        Sk = k.shape[2]
    nq, nk = Sq // bq, Sk // bk
    kern = functools.partial(
        _flash_kernel, scale=1.0 / np.sqrt(hd), causal=causal,
        bq=bq, bk=bk, nk=nk, kv_len=Sk_orig)
    out = pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=use_interpret(),
    )(q, k, v)
    return out[:, :, :Sq_orig]


# --------------------------------------------------------------------------
# backward kernels (flash bwd: recompute p from q, k and the saved lse)
# --------------------------------------------------------------------------

def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_scr, *, scale, causal, bq, bk, nk,
                         kv_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    run = (qi + 1) * bq - 1 >= ki * bk if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < kv_len
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            valid = valid & (qpos >= kpos)
        p = jnp.where(valid, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_scr[...] = dq_scr[...] + jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _out():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal,
                          bq, bk, nq, kv_len):
    ki = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    run = (qi + 1) * bq - 1 >= ki * bk if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < kv_len
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            valid = valid & (qpos >= kpos)
        p = jnp.where(valid, jnp.exp(s - lse[:, None]), 0.0)
        dv_scr[...] = dv_scr[...] + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_scr[...] = dk_scr[...] + jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _out():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention_fwd_kernel(q, k, v, causal=True, block_q=512,
                               block_k=512):
    """Like flash_attention_kernel but also returns lse (B, H, Sq)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    Sq_orig, Sk_orig = Sq, Sk
    if Sq % bq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, (-Sq) % bq), (0, 0)))
        Sq = q.shape[2]
    if Sk % bk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, (-Sk) % bk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, (-Sk) % bk), (0, 0)))
        Sk = k.shape[2]
    nq, nk = Sq // bq, Sk // bk
    kern = functools.partial(
        _flash_kernel_lse, scale=1.0 / np.sqrt(hd), causal=causal,
        bq=bq, bk=bk, nk=nk, kv_len=Sk_orig)
    o, lse = pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=use_interpret(),
    )(q, k, v)
    return o[:, :, :Sq_orig], lse[:, :, :Sq_orig]


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention_bwd_kernel(q, k, v, o, lse, do, causal=True,
                               block_q=512, block_k=512):
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    Sq_orig, Sk_orig = Sq, Sk
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)
    if Sq % bq:
        pq = (-Sq) % bq
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        do = jnp.pad(do, ((0, 0), (0, 0), (0, pq), (0, 0)))
        # padded lse rows = +inf -> p = exp(-inf) = 0: no phantom gradients
        lse = jnp.pad(lse, ((0, 0), (0, 0), (0, pq)),
                      constant_values=jnp.inf)
        delta = jnp.pad(delta, ((0, 0), (0, 0), (0, pq)))
        Sq = q.shape[2]
    if Sk % bk:
        pk = (-Sk) % bk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
        Sk = k.shape[2]
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / np.sqrt(hd)
    common = dict(scale=scale, causal=causal, bq=bq, bk=bk, kv_len=Sk_orig)

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, nk=nk, **common),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=use_interpret(),
    )(q, k, v, do, lse, delta)

    # per-q-head dk/dv, then reduce over the GQA group
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, nq=nq, **common),
        grid=(B, H, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, ki, qi: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, ki, qi, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, ki, qi, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, ki, qi: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, ki, qi: (b, h, qi)),
            pl.BlockSpec((1, 1, bq), lambda b, h, ki, qi: (b, h, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki, qi: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki, qi: (b, h, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sk, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sk, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=use_interpret(),
    )(q, k, v, do, lse, delta)
    dk = dk_h.reshape(B, KV, G, Sk, hd).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(B, KV, G, Sk, hd).sum(axis=2).astype(v.dtype)
    return dq[:, :, :Sq_orig], dk[:, :, :Sk_orig], dv[:, :, :Sk_orig]


# --------------------------------------------------------------------------
# differentiable public API
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_hm(q, k, v, causal, block_q, block_k):
    return flash_attention_kernel(q, k, v, causal=causal,
                                  block_q=block_q, block_k=block_k)


def _flash_hm_fwd(q, k, v, causal, block_q, block_k):
    o, lse = flash_attention_fwd_kernel(q, k, v, causal=causal,
                                        block_q=block_q, block_k=block_k)
    return o, (q, k, v, o, lse)


def _flash_hm_bwd(causal, block_q, block_k, res, do):
    q, k, v, o, lse = res
    return flash_attention_bwd_kernel(q, k, v, o, lse, do, causal=causal,
                                      block_q=block_q, block_k=block_k)


_flash_hm.defvjp(_flash_hm_fwd, _flash_hm_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512) -> jax.Array:
    """Differentiable flash attention. Layout-adapting wrapper:
    q (B, S, H, hd), k/v (B, S, KV, hd) — the model-side layout —
    transposed to head-major for blocking.

    Under an axis_rules mesh context the kernel runs inside a shard_map
    (batch -> dp axes, heads -> model): a pallas_call is opaque to GSPMD, so
    without manual partitioning every chip would execute the FULL grid
    (observed: 2800x flops blowup on the dry run). KV heads are expanded to
    the q-head count first so the head sharding needs no cross-shard GQA
    indexing — the extra k/v HBM reads (G x) are orders of magnitude smaller
    than the score traffic this kernel eliminates."""
    from repro.dist.sharding import current_mesh, resolve_spec
    mesh = current_mesh()
    qt = q.transpose(0, 2, 1, 3)        # (B, H, S, hd)
    B, H, Sq, hd = qt.shape
    KV = k.shape[2]
    G = H // KV
    if mesh is None:
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        out = _flash_hm(qt, kt, vt, causal, block_q, block_k)
        return out.transpose(0, 2, 1, 3)

    kt = k.transpose(0, 2, 1, 3)        # (B, KV, S, hd)
    vt = v.transpose(0, 2, 1, 3)
    spec = resolve_spec((B, H, Sq, hd), ("batch", "heads", None, None), mesh)
    # k/v: same batch sharding, heads replicated across model (KV < model
    # size); each shard slices out just the kv heads its q heads map to.
    kv_spec = jax.sharding.PartitionSpec(spec[0], None, None, None)
    h_axes = spec[1]
    h_shards = 1
    if h_axes is not None:
        names = h_axes if isinstance(h_axes, tuple) else (h_axes,)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for a in names:
            h_shards *= sizes[a]
    H_loc = H // h_shards

    def _local(a, b, c):
        if h_shards > 1:
            # local q heads are a contiguous [idx*H_loc, ...) range; their
            # kv group is contiguous too when H_loc divides G or G divides
            # H_loc (always true for powers-of-two GQA configs).
            idx = jax.lax.axis_index(h_axes if isinstance(h_axes, str)
                                     else list(h_axes))
            kv_start = (idx * H_loc) // G
            kv_count = max(1, H_loc // G)
            b = jax.lax.dynamic_slice_in_dim(b, kv_start, kv_count, axis=1)
            c = jax.lax.dynamic_slice_in_dim(c, kv_start, kv_count, axis=1)
        # custom_vjp takes nondiff args positionally (no kwargs allowed)
        return _flash_hm(a, b, c, causal, block_q, block_k)

    f = jax.shard_map(
        _local, mesh=mesh, in_specs=(spec, kv_spec, kv_spec),
        out_specs=spec, axis_names=set(mesh.axis_names), check_vma=False)
    return f(qt, kt, vt).transpose(0, 2, 1, 3)
