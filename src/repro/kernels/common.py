"""Shared helpers for the Pallas kernels (tiling, padding, interpret mode)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


@functools.lru_cache(None)
def use_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode off-TPU (CPU CI/tests)."""
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pad_to(x: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    pads = [(0, t - s) for s, t in zip(x.shape, shape)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def pick_block(dim: int, preferred: int, align: int) -> int:
    """Largest aligned block <= preferred covering dim without huge padding."""
    if dim <= align:
        return align
    return min(round_up(dim, align), preferred)


# TPU native tile for 32-bit types is (8, 128); blocks are multiples of it.
SUBLANE = 8
LANE = 128
