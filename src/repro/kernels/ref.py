"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts exact equality (bitwise ops) / allclose (float paths) against
these functions. They are also the small-input fallback dispatch path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# fused bitwise ops
# ---------------------------------------------------------------------------

BITWISE_OPS = {
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
    "nand": lambda a, b: ~(a & b),
    "nor": lambda a, b: ~(a | b),
    "xnor": lambda a, b: ~(a ^ b),
    "andnot": lambda a, b: a & ~b,
    "not": lambda a: ~a,
    "maj3": lambda a, b, c: (a & b) | (b & c) | (c & a),
}


def bitwise(op: str, *args: jax.Array) -> jax.Array:
    args = tuple(jnp.asarray(a, jnp.uint32) for a in args)
    return BITWISE_OPS[op](*args)


# ---------------------------------------------------------------------------
# majority over k bit-planes (generalized TRA)
# ---------------------------------------------------------------------------


def majority_k(planes: jax.Array, threshold: int | None = None) -> jax.Array:
    """planes: (k, ...) uint32. Majority (count > k/2), or count >= threshold.

    Oracle implementation: unpack each bit position and count — O(32k) work,
    exact by construction.
    """
    k = planes.shape[0]
    if threshold is None:
        threshold = k // 2 + 1
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (planes[..., None] >> shifts) & jnp.uint32(1)   # (k, ..., 32)
    counts = bits.astype(jnp.int32).sum(axis=0)            # (..., 32)
    maj = (counts >= threshold).astype(jnp.uint32)
    return (maj << shifts).sum(axis=-1).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# popcount
# ---------------------------------------------------------------------------


def popcount(words: jax.Array) -> jax.Array:
    from repro.ops.popcount import popcount_words

    return popcount_words(words)


# ---------------------------------------------------------------------------
# BitWeaving-V bit transpose: values -> vertical bit planes
# ---------------------------------------------------------------------------


def bit_transpose(values: jax.Array, n_bits: int) -> jax.Array:
    """values: (n,) uint32 integers (< 2**n_bits), n % 32 == 0.

    Returns planes: (n_bits, n//32) uint32 — plane j, word g, bit i equals
    bit j of values[32*g + i] (LSB-first packing; plane 0 = LSB).
    """
    n = values.shape[0]
    assert n % 32 == 0
    v = values.astype(jnp.uint32)
    planes = []
    shifts = jnp.arange(32, dtype=jnp.uint32)
    for j in range(n_bits):
        bits = (v >> jnp.uint32(j)) & jnp.uint32(1)
        w = (bits.reshape(-1, 32) << shifts).sum(-1).astype(jnp.uint32)
        planes.append(w)
    return jnp.stack(planes)


def bit_untranspose(planes: jax.Array, n_bits: int) -> jax.Array:
    """Inverse of bit_transpose -> (n,) uint32 values."""
    b, g = planes.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (planes[:, :, None] >> shifts) & jnp.uint32(1)   # (b, g, 32)
    bits = bits.reshape(b, g * 32)
    vals = jnp.zeros((g * 32,), jnp.uint32)
    for j in range(n_bits):
        vals = vals | (bits[j] << jnp.uint32(j))
    return vals


# ---------------------------------------------------------------------------
# BitWeaving-V predicate scan: c1 <= v <= c2 over vertical planes
# ---------------------------------------------------------------------------


def _cmp_planes(planes: jax.Array, c: int, n_bits: int
                ) -> Tuple[jax.Array, jax.Array]:
    """Bit-serial compare of every packed column value against constant c.

    Returns (lt, eq) packed words. Scans MSB -> LSB (BitWeaving §4).
    """
    g = planes.shape[1]
    ones = jnp.full((g,), 0xFFFFFFFF, jnp.uint32)
    zeros = jnp.zeros((g,), jnp.uint32)
    lt, eq = zeros, ones
    for j in range(n_bits - 1, -1, -1):
        cj = ones if ((c >> j) & 1) else zeros
        lt = lt | (eq & ~planes[j] & cj)
        eq = eq & ~(planes[j] ^ cj)
    return lt, eq


def bitweaving_scan(planes: jax.Array, c1: int, c2: int, n_bits: int) -> jax.Array:
    """Result bitvector of predicate c1 <= v <= c2 (paper §8.2 query)."""
    lt1, eq1 = _cmp_planes(planes, c1, n_bits)
    lt2, eq2 = _cmp_planes(planes, c2, n_bits)
    ge_c1 = ~lt1
    le_c2 = lt2 | eq2
    return ge_c1 & le_c2


# ---------------------------------------------------------------------------
# bit-serial ripple-carry arithmetic over vertical planes (SIMDRAM-style)
# ---------------------------------------------------------------------------


def bitserial_add(a_planes: jax.Array, b_planes: jax.Array,
                  sub: bool = False) -> jax.Array:
    """(n_bits, ...) x2 uint32 planes -> (n_bits, ...) sum planes.

    Ripple-carry full adders per bit position; SUB is a + ~b + 1. The
    carry/borrow out of the MSB is dropped (wrap modulo 2**n_bits), so the
    result is exact for unsigned and two's-complement signed operands alike.
    """
    a = jnp.asarray(a_planes, jnp.uint32)
    b = jnp.asarray(b_planes, jnp.uint32)
    n_bits = a.shape[0]
    c = (jnp.full_like(a[0], 0xFFFFFFFF) if sub else jnp.zeros_like(a[0]))
    outs = []
    for j in range(n_bits):
        bj = ~b[j] if sub else b[j]
        outs.append(a[j] ^ bj ^ c)
        c = (a[j] & bj) | (bj & c) | (c & a[j])
    return jnp.stack(outs)


def bitserial_lt(a_planes: jax.Array, b_planes: jax.Array) -> jax.Array:
    """(n_bits, ...) x2 uint32 planes -> (...) packed `a < b` (unsigned)."""
    a = jnp.asarray(a_planes, jnp.uint32)
    b = jnp.asarray(b_planes, jnp.uint32)
    n_bits = a.shape[0]
    lt = jnp.zeros_like(a[0])
    eq = jnp.full_like(a[0], 0xFFFFFFFF)
    for j in range(n_bits - 1, -1, -1):
        lt = lt | (eq & ~a[j] & b[j])
        eq = eq & ~(a[j] ^ b[j])
    return lt


# ---------------------------------------------------------------------------
# sign pack / unpack (1-bit gradient compression)
# ---------------------------------------------------------------------------


def pack_signs(x: jax.Array) -> jax.Array:
    """x: (..., 32*w) float -> (..., w) uint32; bit = IEEE sign bit
    (jnp.signbit: true for -0.0, matching the kernel's bitcast path)."""
    n = x.shape[-1]
    assert n % 32 == 0
    bits = jnp.signbit(x).astype(jnp.uint32)
    bits = bits.reshape(x.shape[:-1] + (n // 32, 32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (bits << shifts).sum(-1).astype(jnp.uint32)


def unpack_signs(words: jax.Array, dtype=jnp.float32) -> jax.Array:
    """(..., w) uint32 -> (..., 32*w) in {-1, +1} (bit=1 -> -1)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,))
    return (1.0 - 2.0 * bits.astype(dtype)).astype(dtype)
