"""Popcount reduction kernel: SWAR per word, per-block partial sums.

Buddy keeps `bitcount` on the CPU (paper §8.1); on TPU we keep it resident:
each grid cell reduces an (8, bw) uint32 block to one int32 partial with the
Hacker's-Delight SWAR sequence on the VPU, and the partials are summed by XLA.
Bytes moved: N words in, N/(br*bw) partials out — pure memory-bound streaming.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (LANE, SUBLANE, pad_to, pick_block, round_up,
                                  use_interpret)


def _popcount_swar(w):
    w = w - ((w >> 1) & jnp.uint32(0x55555555))
    w = (w & jnp.uint32(0x33333333)) + ((w >> 2) & jnp.uint32(0x33333333))
    w = (w + (w >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (w * jnp.uint32(0x01010101)) >> 24


def _kern(x_ref, o_ref):
    o_ref[0, 0] = _popcount_swar(x_ref[...]).astype(jnp.int32).sum()


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "block_cols"))
def popcount_kernel(words: jax.Array, block_rows: int = SUBLANE,
                    block_cols: int = 2048) -> jax.Array:
    """words: (rows, words) uint32 -> scalar int64 total popcount."""
    r, w = words.shape
    br = pick_block(r, block_rows, SUBLANE)
    bw = pick_block(w, block_cols, LANE)
    rp, wp = round_up(r, br), round_up(w, bw)
    x = pad_to(jnp.asarray(words, jnp.uint32), (rp, wp))
    partials = pl.pallas_call(
        _kern,
        grid=(rp // br, wp // bw),
        in_specs=[pl.BlockSpec((br, bw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp // br, wp // bw), jnp.int32),
        interpret=use_interpret(),
    )(x)
    # int32 is exact up to 2^31 set bits (= 256 MiB of all-ones input).
    return partials.sum(dtype=jnp.int32)
