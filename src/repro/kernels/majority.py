"""Majority-of-k over packed bit-planes — generalized triple-row activation.

TRA computes MAJ3 in analog; lifting the paper's primitive to k operands
(needed for majority-vote gradient aggregation across k data-parallel
workers) uses a carry-save adder network: each bit position accumulates a
ceil(log2(k+1))-bit counter held as bit-planes in VREGs, then a bit-serial
>= threshold comparison produces the packed majority word. Total work is
O(k log k) VPU bit-ops per word — no unpacking, no integer widening; the
operand planes stream through VMEM exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (LANE, SUBLANE, pad_to, pick_block, round_up,
                                  use_interpret)


def _csa_add_bit(counter, bit):
    """Ripple-add a 1-bit plane into an LSB-first list of counter planes."""
    carry = bit
    out = []
    for s in counter:
        out.append(s ^ carry)
        carry = s & carry
    return out, carry


def _ge_const(counter, threshold: int):
    """Packed (counter >= threshold), counter is LSB-first plane list."""
    ones = jnp.full_like(counter[0], 0xFFFFFFFF)
    zeros = jnp.zeros_like(counter[0])
    ge = zeros
    eq = ones
    for j in range(len(counter) - 1, -1, -1):
        tj = ones if ((threshold >> j) & 1) else zeros
        ge = ge | (eq & counter[j] & ~tj)
        eq = eq & ~(counter[j] ^ tj)
    return ge | eq


def _majority_kernel(k: int, threshold: int):
    import math

    n_planes = max(1, math.ceil(math.log2(k + 1)))

    def kern(x_ref, o_ref):
        counter = [jnp.zeros_like(x_ref[0]) for _ in range(n_planes)]
        for i in range(k):  # static unroll: k is a compile-time constant
            counter, _ = _csa_add_bit(counter, x_ref[i])
        o_ref[...] = _ge_const(counter, threshold)

    return kern


@functools.partial(jax.jit, static_argnums=(1,),
                   static_argnames=("threshold", "block_rows", "block_cols"))
def majority_kernel(planes: jax.Array, threshold: int | None = None,
                    block_rows: int = SUBLANE, block_cols: int = 2048
                    ) -> jax.Array:
    """planes: (k, rows, words) uint32 -> (rows, words) packed majority."""
    k, r, w = planes.shape
    if threshold is None:
        threshold = k // 2 + 1
    br = pick_block(r, block_rows, SUBLANE)
    bw = pick_block(w, block_cols, LANE)
    rp, wp = round_up(r, br), round_up(w, bw)
    x = pad_to(jnp.asarray(planes, jnp.uint32), (k, rp, wp))
    out = pl.pallas_call(
        _majority_kernel(k, threshold),
        grid=(rp // br, wp // bw),
        in_specs=[pl.BlockSpec((k, br, bw), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((br, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, wp), jnp.uint32),
        interpret=use_interpret(),
    )(x)
    return out[:r, :w]
