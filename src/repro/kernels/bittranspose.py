"""32x32 bit-matrix transpose kernel: horizontal values -> BitWeaving-V planes.

BitWeaving-V (paper §8.2) stores bit j of every column value contiguously.
Converting a (n,) uint32 column into 32 vertical planes is a bit transpose of
each 32-value group. The kernel runs the 5-stage masked-swap butterfly
(Hacker's Delight 7-3, vectorized across groups): log2(32) passes of
shift/xor/mask on the VPU, VMEM-resident, instead of 1024 bit-extract ops.

Convention (LSB-first, verified identity): out[w, g] bit i == in[g*32+i] bit w.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANE, pad_to, pick_block, round_up, use_interpret


def _swap_mask(j: int) -> jnp.uint32:
    """Mask selecting the HIGH j bits of each 2j-bit group."""
    pat = ((1 << j) - 1) << j
    m = 0
    for s in range(0, 32, 2 * j):
        m |= pat << s
    return jnp.uint32(m & 0xFFFFFFFF)


def transpose32_blocks(a: jax.Array) -> jax.Array:
    """(g, 32) uint32 -> (g, 32); B[g, w] bit i == A[g, i] bit w.

    Shared by the kernel body and the jnp fast path of ref.bit_transpose.
    """
    g = a.shape[0]
    for j in (16, 8, 4, 2, 1):
        m = _swap_mask(j)
        x = a.reshape(g, 32 // (2 * j), 2, j)
        a0, a1 = x[:, :, 0, :], x[:, :, 1, :]
        t = (a0 ^ (a1 << jnp.uint32(j))) & m
        a0 = a0 ^ t
        a1 = a1 ^ (t >> jnp.uint32(j))
        a = jnp.stack([a0, a1], axis=2).reshape(g, 32)
    return a


def _kern(x_ref, o_ref):
    # x block: (bg, 32) groups; output block: (32, bg) planes
    o_ref[...] = transpose32_blocks(x_ref[...]).T


@functools.partial(jax.jit, static_argnames=("block_groups",))
def bit_transpose_kernel(values: jax.Array, block_groups: int = 512) -> jax.Array:
    """values: (n,) uint32, n % 32 == 0 -> planes (32, n // 32)."""
    n = values.shape[0]
    assert n % 32 == 0
    g = n // 32
    bg = pick_block(g, block_groups, LANE)
    gp = round_up(g, bg)
    x = pad_to(jnp.asarray(values, jnp.uint32).reshape(g, 32), (gp, 32))
    out = pl.pallas_call(
        _kern,
        grid=(gp // bg,),
        in_specs=[pl.BlockSpec((bg, 32), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((32, bg), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((32, gp), jnp.uint32),
        interpret=use_interpret(),
    )(x)
    return out[:, :g]


@functools.partial(jax.jit, static_argnames=("block_groups",))
def bit_untranspose_kernel(planes: jax.Array, block_groups: int = 512
                           ) -> jax.Array:
    """planes: (32, g) -> values (g*32,): the transpose is an involution
    modulo the axis swap, so reuse the same butterfly."""
    _, g = planes.shape
    bg = pick_block(g, block_groups, LANE)
    gp = round_up(g, bg)
    x = pad_to(jnp.asarray(planes, jnp.uint32), (32, gp))

    def kern(x_ref, o_ref):
        o_ref[...] = transpose32_blocks(x_ref[...].T)

    out = pl.pallas_call(
        kern,
        grid=(gp // bg,),
        in_specs=[pl.BlockSpec((32, bg), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bg, 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, 32), jnp.uint32),
        interpret=use_interpret(),
    )(x)
    return out[:g].reshape(g * 32)
