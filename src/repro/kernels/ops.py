"""Public jit'd wrappers over the Pallas kernels (with ref fallbacks)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.arith import bitserial_add_kernel, bitserial_lt_kernel
from repro.kernels.bitwise import banked_bitwise_kernel, bitwise_kernel
from repro.kernels.bittranspose import (bit_transpose_kernel,
                                        bit_untranspose_kernel)
from repro.kernels.bitweaving import bitweaving_scan_kernel
from repro.kernels.flashattn import flash_attention  # noqa: F401
from repro.kernels.majority import majority_kernel
from repro.kernels.popcount import popcount_kernel
from repro.kernels.signpack import pack_signs_kernel, unpack_signs_kernel
from repro.kernels.vm import run_megakernel, vm_megakernel  # noqa: F401


def bitwise(op: str, *args: jax.Array, **kw) -> jax.Array:
    """Fused bitwise op on 2-D (rows, words) uint32 arrays."""
    args = tuple(jnp.asarray(a, jnp.uint32) for a in args)
    if args[0].ndim == 1:
        out = bitwise_kernel(op, *(a[None, :] for a in args), **kw)
        return out[0]
    return bitwise_kernel(op, *args, **kw)


def bitwise_banked(op: str, *args: jax.Array, n_banks: int = 1,
                   **kw) -> jax.Array:
    """Bank-parallel bitwise op: operands sharded word-wise over `n_banks`.

    1-D (words,) or 2-D (rows, words) uint32 operands are partitioned with
    `core.bankgroup.shard_words`, evaluated with the bank-gridded kernel
    (grid leading dim = bank), and reassembled. Bit-identical to
    `bitwise(op, *args)` for every op and bank count.
    """
    from repro.core.bankgroup import shard_words, unshard_words
    from repro.kernels.common import (SUBLANE, pad_to, round_up,
                                      use_interpret)

    args = tuple(jnp.asarray(a, jnp.uint32) for a in args)
    orig = args[0].shape
    if args[0].ndim == 1:
        # fold the flat vector into SUBLANE rows (elementwise ops are
        # layout-invariant) so the kernel's row-tile padding costs nothing
        wp = round_up(orig[0], SUBLANE)
        args = tuple(pad_to(a, (wp,)).reshape(SUBLANE, wp // SUBLANE)
                     for a in args)
    sharded = tuple(shard_words(a, n_banks) for a in args)
    if "block_cols" not in kw and use_interpret():
        # off-TPU there is no VMEM budget and interpret-mode grid steps are
        # the cost driver: one block per bank.
        kw["block_cols"] = sharded[0].shape[-1]
    out = banked_bitwise_kernel(op, *sharded, **kw)
    flat = unshard_words(out, args[0].shape[-1])
    return flat.reshape(-1)[:orig[0]] if len(orig) == 1 else flat


def majority(planes: jax.Array, threshold: int | None = None, **kw) -> jax.Array:
    """(k, rows, words) -> (rows, words) packed majority (generalized TRA)."""
    if planes.ndim == 2:
        return majority_kernel(planes[:, None, :], threshold, **kw)[0]
    return majority_kernel(planes, threshold, **kw)


def popcount(words: jax.Array, **kw) -> jax.Array:
    if words.ndim == 1:
        words = words[None, :]
    return popcount_kernel(words, **kw)


def bit_transpose(values: jax.Array, n_bits: int, **kw) -> jax.Array:
    """(n,) uint32 -> (n_bits, n//32) vertical planes (LSB-first order)."""
    return bit_transpose_kernel(values, **kw)[:n_bits]


def bit_untranspose(planes: jax.Array, n_bits: int, **kw) -> jax.Array:
    b, g = planes.shape
    if b < 32:
        planes = jnp.concatenate(
            [planes, jnp.zeros((32 - b, g), jnp.uint32)], axis=0)
    return bit_untranspose_kernel(planes, **kw)


def bitweaving_scan(planes: jax.Array, c1: int, c2: int, n_bits: int, **kw
                    ) -> jax.Array:
    return bitweaving_scan_kernel(planes, c1, c2, n_bits, **kw)


def bitserial_add(a_planes: jax.Array, b_planes: jax.Array,
                  sub: bool = False, **kw) -> jax.Array:
    """(n_bits, words) or (n_bits, rows, words) plane add/sub (mod 2**n)."""
    if a_planes.ndim == 2:
        out = bitserial_add_kernel(a_planes[:, None, :],
                                   b_planes[:, None, :], sub, **kw)
        return out[:, 0]
    return bitserial_add_kernel(a_planes, b_planes, sub, **kw)


def bitserial_lt(a_planes: jax.Array, b_planes: jax.Array, **kw) -> jax.Array:
    """Packed unsigned `a < b` over vertical planes."""
    if a_planes.ndim == 2:
        return bitserial_lt_kernel(a_planes[:, None, :],
                                   b_planes[:, None, :], **kw)[0]
    return bitserial_lt_kernel(a_planes, b_planes, **kw)


def pack_signs(x: jax.Array, **kw) -> jax.Array:
    if x.ndim == 1:
        return pack_signs_kernel(x[None, :], **kw)[0]
    return pack_signs_kernel(x, **kw)


def unpack_signs(words: jax.Array, dtype=jnp.float32, **kw) -> jax.Array:
    if words.ndim == 1:
        return unpack_signs_kernel(words[None, :], dtype, **kw)[0]
    return unpack_signs_kernel(words, dtype, **kw)
