"""Fused BitWeaving-V predicate scan kernel: c1 <= v <= c2 in one pass.

The paper accelerates BitWeaving by executing its bitwise inner loop in DRAM.
On TPU the equivalent win is fusion: the naive formulation evaluates two
bit-serial comparisons (v >= c1, v <= c2), reading all b planes twice and
materializing intermediate lt/eq planes in HBM. This kernel keeps the
comparison state (lt1/eq1/lt2/eq2 packed words) in VREGs and streams each
plane block through VMEM exactly once — bytes moved drop from ~3x planes to
1x planes + 1 output word per 32 values.

Plane layout: (b, g) uint32, plane index 0 = LSB (ref.bit_transpose order);
the scan walks MSB -> LSB as in BitWeaving §4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANE, pad_to, pick_block, round_up, use_interpret


def _scan_kernel(n_bits: int, c1: int, c2: int):
    def kern(p_ref, o_ref):
        ones = jnp.full_like(p_ref[0], 0xFFFFFFFF)
        zeros = jnp.zeros_like(p_ref[0])
        lt1, eq1 = zeros, ones
        lt2, eq2 = zeros, ones
        for j in range(n_bits - 1, -1, -1):  # MSB -> LSB, static unroll
            pj = p_ref[j]
            c1j = ones if ((c1 >> j) & 1) else zeros
            c2j = ones if ((c2 >> j) & 1) else zeros
            lt1 = lt1 | (eq1 & ~pj & c1j)
            eq1 = eq1 & ~(pj ^ c1j)
            lt2 = lt2 | (eq2 & ~pj & c2j)
            eq2 = eq2 & ~(pj ^ c2j)
        # c1 <= v <= c2  ==  ~(v < c1) & ((v < c2) | (v == c2))
        o_ref[...] = ~lt1 & (lt2 | eq2)

    return kern


@functools.partial(jax.jit, static_argnums=(1, 2, 3),
                   static_argnames=("block_cols",))
def bitweaving_scan_kernel(planes: jax.Array, c1: int, c2: int, n_bits: int,
                           block_cols: int = 2048) -> jax.Array:
    """planes: (b, g) uint32 -> (g,) packed result of c1 <= v <= c2."""
    b, g = planes.shape
    assert b >= n_bits
    bw = pick_block(g, block_cols, LANE)
    gp = round_up(g, bw)
    x = pad_to(jnp.asarray(planes, jnp.uint32), (b, gp))
    out = pl.pallas_call(
        _scan_kernel(n_bits, c1, c2),
        grid=(gp // bw,),
        in_specs=[pl.BlockSpec((b, bw), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((gp,), jnp.uint32),
        interpret=use_interpret(),
    )(x)
    return out[:g]
