"""Sign pack/unpack kernels: 32:1 gradient compression for majority-vote
signSGD (the Buddy technique lifted to the data-parallel collective).

pack:   (r, 32*w) float32/bf16 -> (r, w) uint32, bit i = sign bit of lane i.
unpack: (r, w) uint32 -> (r, 32*w) {-1,+1} float.

The pack kernel extracts IEEE sign bits with a bitcast + logical shift (no
compares, no selects) and folds 32 lanes/word with the shift-or tree on the
VPU. This runs as the producer stage right before the all-gather in
`optim/signum.py`, so only packed words cross the ICI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (LANE, SUBLANE, pad_to, pick_block, round_up,
                                  use_interpret)


def _pack_kern(x_ref, o_ref):
    x = x_ref[...]
    # IEEE sign bit -> {0,1}; works for f32 via bitcast, other dtypes via <0.
    if x.dtype == jnp.float32:
        bits = jax.lax.bitcast_convert_type(x, jnp.uint32) >> 31
    else:
        bits = jnp.signbit(x).astype(jnp.uint32)
    r, n = bits.shape
    bits = bits.reshape(r, n // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    o_ref[...] = (bits << shifts).sum(axis=-1).astype(jnp.uint32)


def _unpack_kern(dtype):
    def kern(w_ref, o_ref):
        w = w_ref[...]
        r, nw = w.shape
        shifts = jnp.arange(32, dtype=jnp.uint32)
        bits = (w[:, :, None] >> shifts) & jnp.uint32(1)
        o_ref[...] = (1.0 - 2.0 * bits.astype(jnp.float32)).astype(dtype) \
            .reshape(r, nw * 32)

    return kern


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "block_words"))
def pack_signs_kernel(x: jax.Array, block_rows: int = SUBLANE,
                      block_words: int = 512) -> jax.Array:
    """x: (r, n) float, n % 32 == 0 -> (r, n//32) uint32."""
    r, n = x.shape
    assert n % 32 == 0
    w = n // 32
    br = pick_block(r, block_rows, SUBLANE)
    bw = pick_block(w, block_words, LANE)
    rp, wp = round_up(r, br), round_up(w, bw)
    # pad with +0.0 => sign bit 0 in padding
    xp = pad_to(x, (rp, wp * 32))
    out = pl.pallas_call(
        _pack_kern,
        grid=(rp // br, wp // bw),
        in_specs=[pl.BlockSpec((br, bw * 32), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, wp), jnp.uint32),
        interpret=use_interpret(),
    )(xp)
    return out[:r, :w]


@functools.partial(jax.jit, static_argnums=(1,),
                   static_argnames=("block_rows", "block_words"))
def unpack_signs_kernel(words: jax.Array, dtype=jnp.float32,
                        block_rows: int = SUBLANE, block_words: int = 512
                        ) -> jax.Array:
    """words: (r, w) uint32 -> (r, 32*w) in {-1,+1}."""
    r, w = words.shape
    br = pick_block(r, block_rows, SUBLANE)
    bw = pick_block(w, block_words, LANE)
    rp, wp = round_up(r, br), round_up(w, bw)
    x = pad_to(jnp.asarray(words, jnp.uint32), (rp, wp))
    out = pl.pallas_call(
        _unpack_kern(dtype),
        grid=(rp // br, wp // bw),
        in_specs=[pl.BlockSpec((br, bw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bw * 32), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, wp * 32), dtype),
        interpret=use_interpret(),
    )(x)
    return out[:r, : w * 32]
