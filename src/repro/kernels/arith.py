"""Bit-serial ripple-carry arithmetic over packed bit-planes (Pallas).

The TPU fast path of `ops.arith`, mirroring `kernels/majority.py`: operand
columns arrive as vertical bit-planes (n_bits, rows, words) and the kernel
ripples a full adder across the planes entirely in VPU registers — carry
never touches memory, each operand plane streams through VMEM exactly once,
and the output planes land in one pass. SUB rides the same adder as
a + ~b + 1 (carry-in of all-ones, complemented b). LESS-THAN is the
MSB-first compare chain (lt/eq registers), producing one packed result
plane. Semantics match `kernels/ref.py` oracles and the AAP microprograms
of `core.arith_compiler` bit-for-bit (tests/test_arith.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (LANE, SUBLANE, pad_to, pick_block, round_up,
                                  use_interpret)


def _maj(a, b, c):
    return (a & b) | (b & c) | (c & a)


def _ripple_kernel(n_bits: int, sub: bool):
    def kern(a_ref, b_ref, o_ref):
        # carry-in: 0 for add, 1 for a + ~b + 1 (two's-complement sub)
        c = (jnp.full_like(a_ref[0], 0xFFFFFFFF) if sub
             else jnp.zeros_like(a_ref[0]))
        for j in range(n_bits):  # static unroll: n_bits is compile-time
            aj = a_ref[j]
            bj = ~b_ref[j] if sub else b_ref[j]
            o_ref[j] = aj ^ bj ^ c
            if j < n_bits - 1:
                c = _maj(aj, bj, c)

    return kern


def _lt_kernel(n_bits: int):
    def kern(a_ref, b_ref, o_ref):
        ones = jnp.full_like(a_ref[0], 0xFFFFFFFF)
        lt = jnp.zeros_like(a_ref[0])
        eq = ones
        for j in range(n_bits - 1, -1, -1):  # MSB-first compare chain
            lt = lt | (eq & ~a_ref[j] & b_ref[j])
            eq = eq & ~(a_ref[j] ^ b_ref[j])
        o_ref[...] = lt

    return kern


def _planes_call(kernel, a: jax.Array, b: jax.Array, plane_out: bool,
                 block_rows: int, block_cols: int) -> jax.Array:
    """Shared pallas_call plumbing: pad/tile (n_bits, rows, words) operands."""
    k, r, w = a.shape
    br = pick_block(r, block_rows, SUBLANE)
    bw = pick_block(w, block_cols, LANE)
    rp, wp = round_up(r, br), round_up(w, bw)
    ap = pad_to(jnp.asarray(a, jnp.uint32), (k, rp, wp))
    bp = pad_to(jnp.asarray(b, jnp.uint32), (k, rp, wp))
    out_shape = (k, rp, wp) if plane_out else (rp, wp)
    out_block = ((k, br, bw), lambda i, j: (0, i, j)) if plane_out \
        else ((br, bw), lambda i, j: (i, j))
    out = pl.pallas_call(
        kernel,
        grid=(rp // br, wp // bw),
        in_specs=[pl.BlockSpec((k, br, bw), lambda i, j: (0, i, j)),
                  pl.BlockSpec((k, br, bw), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec(*out_block),
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.uint32),
        interpret=use_interpret(),
    )(ap, bp)
    return out[:, :r, :w] if plane_out else out[:r, :w]


@functools.partial(jax.jit, static_argnames=("sub", "block_rows",
                                             "block_cols"))
def bitserial_add_kernel(a: jax.Array, b: jax.Array, sub: bool = False,
                         block_rows: int = SUBLANE, block_cols: int = 2048
                         ) -> jax.Array:
    """(n_bits, rows, words) x2 -> (n_bits, rows, words) sum/difference
    planes, wrapping modulo 2**n_bits."""
    assert a.shape == b.shape, (a.shape, b.shape)
    return _planes_call(_ripple_kernel(a.shape[0], sub), a, b, True,
                        block_rows, block_cols)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def bitserial_lt_kernel(a: jax.Array, b: jax.Array,
                        block_rows: int = SUBLANE, block_cols: int = 2048
                        ) -> jax.Array:
    """(n_bits, rows, words) x2 -> (rows, words) packed `a < b` (unsigned)."""
    assert a.shape == b.shape, (a.shape, b.shape)
    return _planes_call(_lt_kernel(a.shape[0]), a, b, False,
                        block_rows, block_cols)
