"""Fused bulk bitwise Pallas kernel (the TPU 'subarray').

One pallas_call evaluates a whole bitwise operator (including the composite
ones: nand/nor/xnor/maj3/andnot) in a single pass: each operand row-block is
read from HBM into VMEM exactly once and the result written once. This is the
TPU translation of Buddy's "operands never cross the channel" — the paper's
AAP sequence for e.g. XOR touches DRAM rows 7 times; a cache-based CPU moves
3 bytes per output byte; the fused kernel moves the theoretical minimum.

VMEM budget at the default (8, 2048) uint32 block: 64 KiB per operand, at
most 3 operands + 1 output = 256 KiB -- far under the ~16 MiB/core VMEM, and
the (8, 128k)-aligned tiles keep loads on the native (8,128) int32 tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (LANE, SUBLANE, pad_to, pick_block, round_up,
                                  use_interpret)

# op -> (arity, kernel body on refs)
_BODIES = {
    "and": (2, lambda a, b: a & b),
    "or": (2, lambda a, b: a | b),
    "xor": (2, lambda a, b: a ^ b),
    "nand": (2, lambda a, b: ~(a & b)),
    "nor": (2, lambda a, b: ~(a | b)),
    "xnor": (2, lambda a, b: ~(a ^ b)),
    "andnot": (2, lambda a, b: a & ~b),
    "not": (1, lambda a: ~a),
    "maj3": (3, lambda a, b, c: (a & b) | (b & c) | (c & a)),
}


def _kernel(op: str, n_in: int):
    body = _BODIES[op][1]

    def kern(*refs):
        ins, out = refs[:n_in], refs[n_in]
        out[...] = body(*(r[...] for r in ins))

    return kern


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("block_rows", "block_cols"))
def banked_bitwise_kernel(op: str, *args, block_rows: int = SUBLANE,
                          block_cols: int = 2048) -> jax.Array:
    """Bank-gridded variant: args are (n_banks, rows, words) uint32.

    The leading grid dimension is the bank axis — each grid step touches one
    bank's row-block only, mirroring the hardware's per-bank independence
    (one `BankGroup` dispatch = one kernel launch, no cross-bank traffic).
    """
    arity, _ = _BODIES[op]
    assert len(args) == arity, (op, len(args))
    nb, r, w = args[0].shape
    br = pick_block(r, block_rows, SUBLANE)
    bw = pick_block(w, block_cols, LANE)
    rp, wp = round_up(r, br), round_up(w, bw)
    padded = tuple(pad_to(jnp.asarray(a, jnp.uint32), (nb, rp, wp))
                   for a in args)
    grid = (nb, rp // br, wp // bw)
    spec = pl.BlockSpec((1, br, bw), lambda b, i, j: (b, i, j))
    out = pl.pallas_call(
        _kernel(op, arity),
        grid=grid,
        in_specs=[spec] * arity,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((nb, rp, wp), jnp.uint32),
        interpret=use_interpret(),
    )(*padded)
    return out[:, :r, :w]


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("block_rows", "block_cols"))
def bitwise_kernel(op: str, *args, block_rows: int = SUBLANE,
                   block_cols: int = 2048) -> jax.Array:
    """args: 2-D uint32 arrays (rows, words), identical shapes."""
    arity, _ = _BODIES[op]
    assert len(args) == arity, (op, len(args))
    x = args[0]
    r, w = x.shape
    br = pick_block(r, block_rows, SUBLANE)
    bw = pick_block(w, block_cols, LANE)
    rp, wp = round_up(r, br), round_up(w, bw)
    padded = tuple(pad_to(jnp.asarray(a, jnp.uint32), (rp, wp)) for a in args)
    grid = (rp // br, wp // bw)
    spec = pl.BlockSpec((br, bw), lambda i, j: (i, j))
    out = pl.pallas_call(
        _kernel(op, arity),
        grid=grid,
        in_specs=[spec] * arity,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rp, wp), jnp.uint32),
        interpret=use_interpret(),
    )(*padded)
    return out[:r, :w]
