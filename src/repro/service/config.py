"""Service construction + serving-policy configuration objects.

`ServiceConfig` consolidates the constructor keywords `QueryService` grew
over nine PRs (deployment shape, reliability, fault tolerance, telemetry,
optimizer toggles) into one dataclass, and adds the serving-loop policy
knob (`slo`) introduced with `service.server.ServingLoop`:

    svc = QueryService(ServiceConfig(n_banks=8, n_chips=4,
                                     slo=SloConfig(p99_ns=5e6)))

The old keyword constructor still works — `QueryService(n_banks=8,
reliability=...)` routes every keyword through `ServiceConfig` — but the
deployment-shaping keywords named by the migration note (`reliability`,
`fault_tolerance`, `n_chips`, `backend`) emit a `DeprecationWarning`
pointing here.

`SloConfig` is the admission-control contract of the serving loop: a
modeled p99 sojourn target plus the policy applied when the modeled queue
delay projects past it ("shed" drops the newest lowest-priority work with
a `QueryShedError`, "defer" parks the lowest-priority tenants until the
backlog drains, "none" only observes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.timing import DDR3_1600, DramTiming

SHED = "shed"
DEFER = "defer"
OBSERVE = "none"


@dataclasses.dataclass(frozen=True)
class SloConfig:
    """p99 sojourn target + breach policy for the serving loop."""

    #: modeled arrival -> completion (sojourn) p99 target, nanoseconds
    p99_ns: float = 5e6
    #: breach policy: "shed" (drop newest lowest-priority queries),
    #: "defer" (park lowest-priority tenants until the backlog drains),
    #: or "none" (observe only — gauges move, nothing is dropped)
    policy: str = SHED
    #: admit while projected sojourn <= safety * p99_ns; the headroom
    #: absorbs estimation error in the per-tick service-time EMA
    safety: float = 1.0

    def __post_init__(self):
        if self.policy not in (SHED, DEFER, OBSERVE):
            raise ValueError(f"unknown SLO policy {self.policy!r}")
        if self.p99_ns <= 0:
            raise ValueError("p99_ns must be positive")


@dataclasses.dataclass
class ServiceConfig:
    """Everything `QueryService` needs to construct a deployment.

    Field semantics are unchanged from the old keyword constructor (each
    field's docs live on the attribute of the same name in
    `service.service.QueryService`); `slo` and `backend` are new here —
    `slo` feeds `QueryService.serve_loop()` as the default admission
    policy, `backend` is the scheduler's default lowered-VM dispatch
    backend for plans the optimizer left unpinned.
    """

    n_banks: int = 8
    timing: DramTiming = DDR3_1600
    n_chips: Optional[int] = None
    max_chips: Optional[int] = None
    backend: str = "scan"
    reliability: Optional["ReliabilityConfig"] = None  # noqa: F821
    fault_tolerance: Optional["FaultTolerance"] = None  # noqa: F821
    telemetry: Optional["Telemetry"] = None  # noqa: F821
    optimize: bool = True
    plan_cache_capacity: Optional[int] = 1024
    #: serving-loop admission policy (None = no SLO: observe-only loop)
    slo: Optional[SloConfig] = None


#: keywords whose bare-kwarg spelling is deprecated in favor of
#: ServiceConfig (the rest stay silent: they are stable convenience
#: keywords, not deployment shape)
DEPRECATED_KWARGS = frozenset(
    {"reliability", "fault_tolerance", "n_chips", "backend"})

CONFIG_FIELDS = frozenset(
    f.name for f in dataclasses.fields(ServiceConfig))
