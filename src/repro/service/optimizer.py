"""Cost-based query optimizer: one pricing model from Expr DAG to backend.

The planning pipeline is `parse -> canonicalize -> optimize -> cost ->
bind -> dispatch`; this module owns the `optimize` and `cost` stages plus
the cross-query sharing pass the scheduler applies per batch. It follows
the compiler/allocator story of the 2019 in-DRAM bulk-bitwise execution
engine (arXiv:1905.09822 §4) on top of the Buddy substrate: every
alternative is priced in AAPs x `core.timing` latency x `core.energy`
energy, and the cheapest wins — never-worse by construction, because the
unoptimized candidate always competes.

Three decisions are made here:

  * **predicate reordering** (`reorder_expr`): associative-commutative
    chains (`and`/`or`/`xor`) are flattened, deduplicated (idempotence
    across non-adjacent operands, XOR parity cancellation — cases the
    pairwise fusion rules cannot see) and re-built left-deep in
    (estimated-cost, structural-key) order. The deterministic order also
    makes differently-written queries converge on one canonical shape, so
    they share a single cached plan. The plan cache compiles both the
    original and the reordered DAG and keeps whichever costs fewer AAPs.
  * **backend selection** (`choose_backend`): per plan, recorded on the
    `Plan` — the eager interpreter for degenerate 1-2 command programs
    (a VM launch costs more than the program), the Pallas megakernel for
    long programs on accelerator devices, the scan VM otherwise.
  * **cross-query CSE** (`plan_group_cse`): within one batch, bound
    sub-DAGs that appear in >= 2 queries compile once into ephemeral
    "$cse{k}" planes; consumers reference the plane as an input leaf
    (a RowClone copy on the modeled bus) instead of recomputing it. The
    rewrite is kept only when the exact re-costed AAP total is lower
    than the unshared baseline.

`ExplainReport` is the user-facing surface of all three decisions,
reachable through `QueryService.explain()` and `launch/serve_bitwise.py
--explain`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import energy as energy_model
from repro.core import timing as timing_model
from repro.core.commands import Program
from repro.core.compiler import (CHAIN_OPS, Expr, expr_key, expr_size,
                                 flatten_chain, iter_subexprs, rebuild_chain)

#: leaf-name prefix of batch-ephemeral shared planes. Starts with "$" so it
#: can never collide with a catalog name (`catalog._NAME_RE` requires a
#:  letter/underscore first character).
CSE_PREFIX = "$cse"

#: pre-fusion AAP cost of each raw Expr op — the structural estimate the
#: reordering sort key uses (the authoritative number is always a real
#: compile; this only has to rank operands consistently).
_OP_AAPS = {"not": 2, "and": 4, "or": 4, "maj3": 4, "xor": 7}


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Everything the cost model is parameterized by.

    `n_blocks` is the operand size in 8KB row-blocks (`ceil(domain /
    ROW_BITS)`), `n_banks`/`n_chips` the parallelism the amortized view
    divides by. `device` overrides backend detection ("" = ask jax).
    """

    timing: timing_model.DramTiming = timing_model.DDR3_1600
    energy: energy_model.EnergyModel = energy_model.DEFAULT_ENERGY
    n_banks: int = 8
    n_chips: int = 1
    n_blocks: int = 1
    device: str = ""

    def resolved_device(self) -> str:
        if self.device:
            return self.device
        import jax

        return jax.default_backend()


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Price of one plan execution under a `CostParams`.

    `latency_ns`/`energy_nj` are per row-block program costs, `xfer_ns`
    the serialized operand+result bus transfers per block, `total_ns` /
    `total_energy_nj` the all-blocks single-bank serial view, and
    `amortized_ns` the per-query share when a full batch keeps every
    (chip, bank) busy.
    """

    n_aaps: int
    n_aps: int
    latency_ns: float
    energy_nj: float
    xfer_ns: float
    total_ns: float
    total_energy_nj: float
    amortized_ns: float


def cost_program(program: Program, n_inputs: int, n_outputs: int,
                 params: CostParams = CostParams()) -> PlanCost:
    """Price one compiled program: AAPs x latency x energy x transfers."""
    lat = timing_model.program_latency_ns(program, params.timing)
    en = energy_model.program_energy_nj(program, params.energy)
    xfer = params.timing.aap_ns * (n_inputs + n_outputs)
    blocks = max(1, params.n_blocks)
    total_ns = blocks * (xfer + lat)
    return PlanCost(
        n_aaps=program.n_aap, n_aps=program.n_ap, latency_ns=lat,
        energy_nj=en, xfer_ns=xfer, total_ns=total_ns,
        total_energy_nj=blocks * en,
        amortized_ns=total_ns / max(1, params.n_banks * params.n_chips))


def cost_programs(programs: Sequence[Program],
                  arities: Sequence[Tuple[int, int]],
                  params: CostParams = CostParams()) -> List[PlanCost]:
    """Batched costing: one timing/energy query for a whole plan set."""
    lats = timing_model.programs_latency_ns(programs, params.timing)
    ens = energy_model.programs_energy_nj(programs, params.energy)
    blocks = max(1, params.n_blocks)
    slots = max(1, params.n_banks * params.n_chips)
    out: List[PlanCost] = []
    for prog, (n_in, n_out), lat, en in zip(programs, arities, lats, ens):
        xfer = params.timing.aap_ns * (n_in + n_out)
        total_ns = blocks * (xfer + lat)
        out.append(PlanCost(
            n_aaps=prog.n_aap, n_aps=prog.n_ap, latency_ns=lat,
            energy_nj=en, xfer_ns=xfer, total_ns=total_ns,
            total_energy_nj=blocks * en, amortized_ns=total_ns / slots))
    return out


# ---------------------------------------------------------------------------
# Stage: optimize (predicate / AND-OR-XOR chain reordering)
# ---------------------------------------------------------------------------


def _est_cost(e: Expr, memo: Dict[Tuple, int]) -> int:
    """Structural AAP estimate: distinct interior ops weighted by their
    primitive program cost (DAG sharing counted once, like the compiler)."""
    k = expr_key(e)
    got = memo.get(k)
    if got is not None:
        return got
    cost = sum(_OP_AAPS.get(n.op, 4) for n in iter_subexprs(e)
               if n.op != "row")
    memo[k] = cost
    return cost


def reorder_expr(expr: Expr) -> Expr:
    """Cost-ordered, deduplicated rewrite of every a-c chain in the DAG.

    Bottom-up over the DAG (memoized on structural keys so sharing is
    preserved): each maximal `and`/`or`/`xor` chain is flattened,
    duplicate operands are removed (`a & x & a -> a & x`; XOR keeps the
    parity, `a ^ b ^ a -> b`), and the survivors are re-built left-deep
    sorted by (estimated AAP cost, structural key). Cheap operands first
    and a deterministic total order — so operand-order variants of one
    query converge on a single canonical shape. Semantics are preserved;
    a chain that cancels to nothing (`a ^ a`) is left untouched for the
    compiler's own rules to handle.
    """
    memo: Dict[Tuple, Expr] = {}
    cost_memo: Dict[Tuple, int] = {}

    def go(e: Expr) -> Expr:
        k = expr_key(e)
        got = memo.get(k)
        if got is not None:
            return got
        if e.op == "row":
            memo[k] = e
            return e
        node = Expr(e.op, tuple(go(a) for a in e.args))
        if e.op in CHAIN_OPS:
            ops = flatten_chain(node, e.op)
            if e.op == "xor":
                parity: Dict[Tuple, int] = {}
                first: Dict[Tuple, Expr] = {}
                order: List[Tuple] = []
                for o in ops:
                    ko = expr_key(o)
                    if ko not in parity:
                        parity[ko] = 0
                        first[ko] = o
                        order.append(ko)
                    parity[ko] ^= 1
                uniq = [first[ko] for ko in order if parity[ko]]
            else:
                seen: Dict[Tuple, None] = {}
                uniq = []
                for o in ops:
                    ko = expr_key(o)
                    if ko not in seen:
                        seen[ko] = None
                        uniq.append(o)
            if uniq:
                uniq.sort(key=lambda o: (_est_cost(o, cost_memo),
                                         repr(expr_key(o))))
                node = rebuild_chain(e.op, uniq)
        memo[k] = node
        return node

    return go(expr)


# ---------------------------------------------------------------------------
# Stage: backend selection
# ---------------------------------------------------------------------------

#: below this command count the eager interpreter beats any VM launch
_INTERP_MAX_CMDS = 2
#: at/above this command count the Pallas megakernel amortizes its launch —
#: but only on accelerator devices; off-TPU it runs in interpret mode and
#: would only slow the host down
_PALLAS_MIN_CMDS = 48
#: fused-reduction dispatches amortize sooner: the count epilogue runs in
#: VMEM scratch and skips the output-plane HBM writeback entirely, so the
#: launch overhead is recouped at roughly half the command count
_PALLAS_MIN_CMDS_FUSED = 24


def choose_backend(program: Program, device: str,
                   fused_reduce: bool = False) -> str:
    """Per-plan dispatch backend: "interp" | "scan" | "pallas".

    ``fused_reduce=True`` prices a count-only dispatch (the megakernel's
    ``reduce=`` epilogue): the pallas threshold drops because the fused
    path never writes output planes back to HBM. Tiny programs still go
    to the interpreter — a popcount on the host beats any launch there.
    """
    n_cmds = len(program.commands)
    if n_cmds <= _INTERP_MAX_CMDS:
        return "interp"
    floor = _PALLAS_MIN_CMDS_FUSED if fused_reduce else _PALLAS_MIN_CMDS
    if device in ("tpu", "gpu") and n_cmds >= floor:
        return "pallas"
    return "scan"


# ---------------------------------------------------------------------------
# The optimizer object the plan cache drives
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QueryOptimizer:
    """Bundles the cost model with the per-plan optimization decisions.

    Owned by the `PlanCache` (`service.planner`): `reorder` supplies the
    alternative candidate DAG, `cost` prices the winner, `backend` records
    the dispatch choice on the `Plan`. `enable_cse` gates the scheduler's
    batch-level sharing pass.
    """

    params: CostParams = CostParams()
    enable_reorder: bool = True
    enable_cse: bool = True

    def __post_init__(self):
        self._device = self.params.resolved_device()

    def reorder(self, canon: Expr) -> Expr:
        return reorder_expr(canon) if self.enable_reorder else canon

    def cost(self, program: Program, n_inputs: int,
             n_outputs: int) -> PlanCost:
        return cost_program(program, n_inputs, n_outputs, self.params)

    def backend(self, program: Program, fused_reduce: bool = False) -> str:
        return choose_backend(program, self._device, fused_reduce)


# ---------------------------------------------------------------------------
# Cross-query CSE within one plan-group batch
# ---------------------------------------------------------------------------


def bind_expr(canon: Expr, input_map: Dict[str, str]) -> Expr:
    """Substitute canonical IN-leaves back to actual catalog rows."""
    if canon.op == "row":
        return Expr.of(input_map.get(canon.row, canon.row))
    return Expr(canon.op, tuple(bind_expr(a, input_map) for a in canon.args))


def _rewrite(e: Expr, picked: Dict[Tuple, str]) -> Expr:
    """Top-down replacement of picked sub-DAGs by their plane leaves.

    Outermost match wins — a picked region nested inside another picked
    region survives only inside the outer region's definition.
    """
    name = picked.get(expr_key(e))
    if name is not None:
        return Expr.of(name)
    if e.op == "row":
        return e
    return Expr(e.op, tuple(_rewrite(a, picked) for a in e.args))


def _cse_leaves(e: Expr, acc: Optional[set] = None) -> set:
    """The `$cse` plane names an expression references."""
    if acc is None:
        acc = set()
    if e.op == "row":
        if e.row.startswith(CSE_PREFIX):
            acc.add(e.row)
    else:
        for a in e.args:
            _cse_leaves(a, acc)
    return acc


@dataclasses.dataclass
class CseDef:
    """One shared subexpression: computed once, referenced as a leaf."""

    name: str                 # "$cse{k}" plane leaf
    expr: Expr                # bound body (may reference earlier planes)
    bound: object             # the def's own BoundPlan
    uses: int                 # containers (queries or defs) referencing it


@dataclasses.dataclass
class CseBatch:
    """Outcome of the batch sharing pass (only produced when it wins)."""

    bound: List[object]       # per query: rewritten or original BoundPlan
    defs: List[CseDef]        # topologically ordered (dependencies first)
    baseline_aaps: int        # sum of the unshared per-query plan AAPs
    optimized_aaps: int       # defs once + rewritten consumers


def plan_group_cse(bound: Sequence[object],
                   exprs: Sequence[Optional[Expr]],
                   plan_fn: Callable[[Expr], object],
                   ) -> Optional[CseBatch]:
    """Share sub-DAGs appearing in >= 2 of a batch's bound queries.

    `bound` are the batch's original BoundPlans, `exprs` the bound boolean
    DAGs over actual catalog rows (None = ineligible query: arithmetic,
    multi-output), `plan_fn` plans an Expr through the normal pipeline.

    Candidates are counted with per-query set semantics, picked outermost
    -first (largest saving), then iterated to a fixpoint dropping any pick
    that ends up referenced by fewer than two containers. The rewrite is
    abandoned wholesale unless the exact re-costed AAP total (defs once +
    rewritten consumers) is strictly below the unshared baseline — the
    optimizer never emits more AAPs than the current pipeline.
    """
    count: Dict[Tuple, int] = {}
    node_of: Dict[Tuple, Expr] = {}
    n_eligible = 0
    for e in exprs:
        if e is None:
            continue
        n_eligible += 1
        for n in iter_subexprs(e):
            if n.op == "row":
                continue
            k = expr_key(n)
            count[k] = count.get(k, 0) + 1
            node_of.setdefault(k, n)
    if n_eligible < 2:
        return None
    cands = [k for k, c in count.items() if c >= 2]
    if not cands:
        return None
    # outermost-first pick order; names assigned once, deterministically
    cands.sort(key=lambda k: (-expr_size(node_of[k]), repr(k)))
    picked: Dict[Tuple, str] = {k: f"{CSE_PREFIX}{i}"
                                for i, k in enumerate(cands)}

    uses: Dict[str, int] = {}
    rewritten: List[Optional[Expr]] = []
    bodies: Dict[Tuple, Expr] = {}
    while True:
        rewritten = [(_rewrite(e, picked) if e is not None else None)
                     for e in exprs]
        bodies = {}
        for k in picked:
            node = node_of[k]
            bodies[k] = (Expr(node.op,
                              tuple(_rewrite(a, picked) for a in node.args))
                         if node.op != "row" else node)
        uses = {name: 0 for name in picked.values()}
        for e in rewritten:
            if e is None:
                continue
            for name in _cse_leaves(e):
                if name in uses:
                    uses[name] += 1
        for k, body in bodies.items():
            for name in _cse_leaves(body):
                if name in uses:
                    uses[name] += 1
        drop = [k for k, name in picked.items() if uses[name] < 2]
        if not drop:
            break
        for k in drop:
            del picked[k]
        if not picked:
            return None

    # topological order: a def lands after every plane it references
    by_name = {picked[k]: k for k in picked}
    order: List[Tuple] = []
    state: Dict[Tuple, int] = {}

    def visit(k: Tuple):
        if state.get(k) == 2:
            return
        assert state.get(k) != 1, "cyclic $cse dependency"
        state[k] = 1
        for name in sorted(_cse_leaves(bodies[k])):
            if name in by_name:
                visit(by_name[name])
        state[k] = 2
        order.append(k)

    for k in sorted(picked, key=lambda k: picked[k]):
        visit(k)

    defs = [CseDef(name=picked[k], expr=bodies[k],
                   bound=plan_fn(bodies[k]), uses=uses[picked[k]])
            for k in order]
    new_bound: List[object] = []
    for orig, e, r in zip(bound, exprs, rewritten):
        if e is None or r is None or expr_key(r) == expr_key(e):
            new_bound.append(orig)
        else:
            new_bound.append(plan_fn(r))

    baseline = sum(bp.plan.n_aaps for bp in bound)
    optimized = (sum(d.bound.plan.n_aaps for d in defs)
                 + sum(bp.plan.n_aaps for bp in new_bound))
    if optimized >= baseline:
        return None
    return CseBatch(bound=new_bound, defs=defs,
                    baseline_aaps=baseline, optimized_aaps=optimized)


# ---------------------------------------------------------------------------
# explain(): the human-readable decision record
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PlanExplain:
    """One query's planning outcome inside an `ExplainReport`."""

    index: int
    query: str
    backend: str
    cache_hit: bool
    n_aaps: int
    n_aaps_unopt: int
    latency_ns: float
    energy_nj: float
    xfer_ns: float
    n_inputs: int
    shared: Tuple[str, ...] = ()    # $cse planes this query consumes
    rewritten: bool = False


@dataclasses.dataclass
class CseExplain:
    """One shared plane inside an `ExplainReport`."""

    name: str
    n_aaps: int
    uses: int


@dataclasses.dataclass
class ExplainReport:
    """Per-plan cost breakdown + backend choice + sharing report."""

    plans: List[PlanExplain]
    cse: List[CseExplain]
    n_plan_groups: int
    total_aaps: int
    baseline_aaps: int
    makespan_ns: float
    n_banks: int = 8
    n_chips: int = 1

    @property
    def aap_reduction(self) -> float:
        """How many times fewer AAPs than the unoptimized pipeline."""
        if self.total_aaps <= 0:
            return 1.0
        return self.baseline_aaps / self.total_aaps

    def __str__(self) -> str:
        head = (f"{'q':>4} {'backend':<8}{'hit':<5}{'aaps':>6} "
                f"{'(unopt)':>8} {'latency':>10} {'energy':>9}  shared")
        lines = ["-- explain " + "-" * max(8, len(head) - 11), head]
        for p in self.plans:
            q = p.query if len(p.query) <= 34 else p.query[:31] + "..."
            lines.append(
                f"{p.index:>4} {p.backend:<8}"
                f"{('yes' if p.cache_hit else 'no'):<5}"
                f"{p.n_aaps:>6} {p.n_aaps_unopt:>8} "
                f"{p.latency_ns:>8.0f}ns {p.energy_nj:>7.1f}nj  "
                f"{','.join(p.shared) or '-':<10} {q}")
        for d in self.cse:
            lines.append(f"   shared plane {d.name}: {d.n_aaps} AAPs, "
                         f"{d.uses} uses (computed once)")
        lines.append(
            f"   {len(self.plans)} queries -> {self.n_plan_groups} plan "
            f"groups on {self.n_chips} chip(s) x {self.n_banks} banks")
        lines.append(
            f"   total {self.total_aaps} AAPs vs {self.baseline_aaps} "
            f"unoptimized ({self.aap_reduction:.2f}x fewer); modeled "
            f"makespan {self.makespan_ns / 1e3:.1f} us")
        return "\n".join(lines)
