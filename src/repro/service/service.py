"""`QueryService` — the user-facing facade of the bulk-bitwise query engine.

Wires catalog -> planner/plan-cache -> batching scheduler into one object:

    svc = QueryService(n_banks=8)
    svc.register_bits("mon", monday_bits, group="tenant0")
    svc.register_bits("tue", tuesday_bits, group="tenant0")
    n = svc.query("mon & tue").value          # popcount aggregate
    svc.materialize("both", "mon & tue")      # derived vector, re-queryable

Columns (BitWeaving-V layout) ride the same machinery: `register_column`
places each vertical bit plane as a catalog vector, and `range_scan` lowers
`lo <= v <= hi` to the fusable predicate DAG of `ops.predicate` so the scan
executes as one minimized AAP program through the scheduler. The TPU fast
path for the same predicate (`range_scan_fast`) dispatches the fused
between-scan kernel via `ops.predicate.between_scan`; both paths return
bit-identical result vectors (tests/test_service.py).

Registered columns also unlock the bit-serial arithmetic grammar
(`core.arith_compiler` lowered through the planner/scheduler):

    svc.register_column("age", ages, 7)
    svc.query("age < 30 & male")            # comparison predicate
    svc.query("sum(age)").value             # SUM aggregation
    svc.query("spend + refund")             # element-wise add (aggregate)
    svc.materialize_column("total", "spend + refund")   # derived column
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import Expr
from repro.core.timing import DDR3_1600, DramTiming
from repro.ops.predicate import VerticalColumn, between_scan, range_scan_expr
from repro.service.catalog import Catalog, CatalogEntry
from repro.service.planner import Planner
from repro.service.scheduler import (MATERIALIZE, POPCOUNT, BatchReport,
                                     Query, QueryResult, Scheduler)


@dataclasses.dataclass
class QueryService:
    """Catalog + planner + scheduler behind one serving interface.

    ``n_chips=None`` (default) is the single-process deployment: one
    device, bank-axis batching only. ``n_chips=C`` is the distributed
    deployment mode: a `core.cluster.ChipCluster` over C mesh devices,
    catalog vectors word-sharded across chips (placement recorded per
    vector, affinity groups chip-local), every plan-group dispatched as
    one `shard_map` VM launch, popcounts tree-psum'd. `rescale(C')`
    re-plans the layout through `dist.elastic.plan_rescale` and re-places
    the catalog without losing a single registered vector.
    """

    n_banks: int = 8
    timing: DramTiming = DDR3_1600
    #: distributed deployment: number of mesh chips (None = single-process)
    n_chips: Optional[int] = None
    #: placement granularity — vectors shard over max_chips*n_banks slots,
    #: fixed across rescales; defaults to the smallest multiple of n_chips
    #: >= 8 (see `core.cluster.ChipCluster.create`)
    max_chips: Optional[int] = None

    def __post_init__(self):
        self.catalog = Catalog()
        self.planner = Planner()
        self.cluster = None
        if self.n_chips is not None:
            from repro.core.cluster import ChipCluster

            self.cluster = ChipCluster.create(
                self.n_chips, n_banks=self.n_banks,
                max_chips=self.max_chips)
            self.max_chips = self.cluster.max_chips
            self.catalog.attach_cluster(self.cluster)
        self.scheduler = Scheduler(catalog=self.catalog, planner=self.planner,
                                   n_banks=self.n_banks, timing=self.timing,
                                   cluster=self.cluster)
        self._columns: Dict[str, VerticalColumn] = {}

    # -- catalog management --------------------------------------------------

    def register(self, name: str, value, n_bits: Optional[int] = None,
                 group: Optional[str] = None) -> CatalogEntry:
        return self.catalog.register(name, value, n_bits, group)

    def register_bits(self, name: str, bits,
                      group: Optional[str] = None) -> CatalogEntry:
        return self.catalog.register_bits(name, bits, group)

    def register_column(self, name: str, values: jax.Array, n_bits: int,
                        group: Optional[str] = None) -> VerticalColumn:
        """Store an integer column: one catalog vector per vertical plane.

        Plane j of column `name` becomes catalog row `{name}.b{j}`; the
        column's logical length must equal the catalog bit domain so plane
        vectors and bitmap vectors are freely combinable in one query.
        Registration also records the column's width, which is what lets
        the planner expand `sum(name)` / `name + other` / `name < K`.
        """
        col = VerticalColumn.encode(values, n_bits)
        if self.catalog.n_bits is not None \
                and col.n_values != self.catalog.n_bits:
            raise ValueError(
                f"column {name!r}: {col.n_values} values != catalog domain "
                f"{self.catalog.n_bits}")
        self.catalog.register_column(name, col.planes, col.n_values, n_bits,
                                     group=group)
        self._columns[name] = col
        return col

    def materialize_column(self, name: str, query: Union[str, Expr],
                           group: Optional[str] = None) -> VerticalColumn:
        """Run an arithmetic query (`a + b`, `a - b`), register the result
        planes as a new column, re-queryable like any registered column."""
        r = self.query(query, mode=MATERIALIZE)
        planes = jnp.asarray(np.asarray(r.value), jnp.uint32)
        if planes.ndim != 2:
            raise ValueError(
                f"{query!r} did not produce a plane stack; "
                "materialize_column needs an arithmetic query")
        assert self.catalog.n_bits is not None
        col = VerticalColumn(planes, int(planes.shape[0]),
                             self.catalog.n_bits)
        self.catalog.register_column(name, planes, self.catalog.n_bits,
                                     col.n_bits, group=group)
        self._columns[name] = col
        return col

    # -- query interface -----------------------------------------------------

    def query(self, query: Union[str, Expr], mode: str = POPCOUNT,
              tenant: Optional[str] = None) -> QueryResult:
        """Serve one query (a batch of one)."""
        return self.query_batch([Query(query, mode, tenant)]).results[0]

    def query_batch(self, queries: Sequence[Query]) -> BatchReport:
        """Serve a batch of concurrent queries through the scheduler."""
        return self.scheduler.submit(queries)

    def materialize(self, name: str, query: Union[str, Expr],
                    group: Optional[str] = None) -> CatalogEntry:
        """Run `query`, register its result vector under `name`."""
        r = self.query(query, mode=MATERIALIZE)
        return self.catalog.register(name, r.value, self.catalog.n_bits,
                                     group=group)

    # -- range scans ---------------------------------------------------------

    def range_scan_query(self, column: str, lo: int, hi: int) -> Expr:
        """The predicate lo <= column <= hi as a fusable Expr DAG."""
        col = self._columns[column]
        return range_scan_expr(col.n_bits, lo, hi,
                               plane_prefix=f"{column}.b")

    def range_scan(self, column: str, lo: int, hi: int,
                   mode: str = POPCOUNT,
                   tenant: Optional[str] = None) -> QueryResult:
        """Serve lo <= column <= hi through the in-DRAM scheduler path."""
        return self.query(self.range_scan_query(column, lo, hi), mode, tenant)

    def range_scan_fast(self, column: str, lo: int, hi: int) -> np.ndarray:
        """The same predicate on the fused TPU between-scan kernel path."""
        col = self._columns[column]
        bv = between_scan(col.planes, lo, hi, col.n_bits)
        return np.asarray(bv & np.asarray(self.catalog.mask()))

    # -- elastic deployment --------------------------------------------------

    def rescale(self, n_chips: int):
        """Elastically change the chip count of a distributed deployment.

        The placement granularity (``max_chips * n_banks`` word-slots) is
        the preserved "global batch" of `dist.elastic.plan_rescale`: each
        chip always drives `n_banks` physical banks per sweep
        (``per_shard_batch``), and the slot grid is re-divided so the new
        chips cover it in ``plan.grad_accum`` sequential sweeps. Raises
        `ValueError` (from `plan_rescale`) when the layout cannot be
        preserved exactly — e.g. 3 chips over an 8-chip-granular
        placement. On success the catalog is re-placed onto the new mesh:
        every registered vector keeps its bits (slot contents are
        invariant, only slot->chip assignment moves) and every derived
        column / affinity group survives. Returns the `RescalePlan`.
        """
        if self.cluster is None:
            raise ValueError(
                "rescale() needs a distributed service; construct with "
                "QueryService(n_chips=...)")
        from repro.core.cluster import ChipCluster
        from repro.dist.elastic import plan_rescale

        old = self.cluster
        plan = plan_rescale(global_batch=old.slots,
                            old_mesh_shards=old.n_chips,
                            new_mesh_shards=n_chips,
                            old_accum=old.sweeps)
        assert plan.per_shard_batch == self.n_banks
        self.cluster = ChipCluster.create(
            n_chips, n_banks=self.n_banks, max_chips=old.max_chips)
        assert self.cluster.sweeps == plan.grad_accum
        self.n_chips = n_chips
        self.catalog.attach_cluster(self.cluster)
        self.scheduler.cluster = self.cluster
        return plan

    # -- observability -------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        cache = self.planner.cache
        return {
            "queries_served": self.scheduler.queries_served,
            "plans_cached": len(cache),
            "plan_cache_hits": cache.hits,
            "plan_cache_misses": cache.misses,
            "plan_cache_hit_rate": cache.hit_rate,
            "compile_count": self.planner.compile_count,
            "total_modeled_ns": self.scheduler.total_modeled_ns,
            "total_energy_nj": self.scheduler.total_energy_nj,
            "n_chips": self.n_chips or 1,
            "chip_sweeps": self.cluster.sweeps if self.cluster else 0,
        }
