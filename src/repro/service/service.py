"""`QueryService` — the user-facing facade of the bulk-bitwise query engine.

Wires catalog -> planner/plan-cache -> batching scheduler into one object:

    svc = QueryService(ServiceConfig(n_banks=8))
    svc.register_bits("mon", monday_bits, group="tenant0")
    svc.register_bits("tue", tuesday_bits, group="tenant0")
    n = svc.query("mon & tue").value          # popcount aggregate
    svc.materialize("both", "mon & tue")      # derived vector, re-queryable

The serving surface is the async handle model:

    h = svc.submit("mon & tue", tenant="t0")  # -> QueryHandle
    h.done(); h.result().scalar

`query()`, `query_batch()` and `range_scan()` are thin synchronous
wrappers over `submit()` — a batch defers its handles and `flush()`
serves them as one scheduler dispatch. Without an attached serving loop
`submit()` executes eagerly (a batch of one); with a running
`ServingLoop` (`svc.serve_loop().start()`) it enqueues into the
continuous-serving runtime (`service.server`), which packs in-flight
queries into scheduler ticks under SLO admission control.

Construction keywords live in `ServiceConfig` (`service.config`). The
old bare-keyword constructor still works — `QueryService(n_banks=8)` —
but the deployment-shaping keywords (`reliability`, `fault_tolerance`,
`n_chips`, `backend`) emit a `DeprecationWarning` pointing at the
config dataclass.

Columns (BitWeaving-V layout) ride the same machinery: `register_column`
places each vertical bit plane as a catalog vector, and `range_scan` lowers
`lo <= v <= hi` to the fusable predicate DAG of `ops.predicate` so the scan
executes as one minimized AAP program through the cost-based planning
pipeline (`parse -> canonicalize -> optimize -> cost -> bind -> dispatch`,
`service.optimizer`). `explain()` reports every planning decision for a
batch: per-plan cost breakdown, chosen backend, and the
shared-subexpression report of the cross-query CSE pass.

Registered columns also unlock the bit-serial arithmetic grammar
(`core.arith_compiler` lowered through the planner/scheduler):

    svc.register_column("age", ages, 7)
    svc.query("age < 30 & male")            # comparison predicate
    svc.query("sum(age)").value             # SUM aggregation
    svc.query("spend + refund")             # element-wise add (aggregate)
    svc.materialize_column("total", "spend + refund")   # derived column
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import Expr
from repro.ops.predicate import VerticalColumn, range_scan_expr
from repro.service.catalog import Catalog, CatalogEntry
from repro.service.config import (CONFIG_FIELDS, DEPRECATED_KWARGS,
                                  ServiceConfig)
from repro.service.optimizer import (CostParams, ExplainReport,
                                     QueryOptimizer)
from repro.service.planner import PlanCache, Planner
from repro.service.scheduler import (MATERIALIZE, POPCOUNT, BatchReport,
                                     Query, QueryResult, Scheduler)
from repro.service.server import QueryHandle, ServingLoop


class QueryService:
    """Catalog + planner + scheduler behind one serving interface.

    Construct with a `ServiceConfig` (preferred) or the legacy keyword
    form; keywords override config fields. ``n_chips=None`` (default)
    is the single-process deployment: one device, bank-axis batching
    only. ``n_chips=C`` is the distributed deployment mode: a
    `core.cluster.ChipCluster` over C mesh devices, catalog vectors
    word-sharded across chips (placement recorded per vector, affinity
    groups chip-local), every plan-group dispatched as one `shard_map`
    VM launch, popcounts tree-psum'd. `rescale(C')` re-plans the layout
    through `dist.elastic.plan_rescale` and re-places the catalog
    without losing a single registered vector.

    Attribute docs (reliability / fault_tolerance / telemetry /
    optimize / plan_cache_capacity semantics) live on `ServiceConfig`.
    """

    def __init__(self, config: Optional[ServiceConfig] = None, **kwargs):
        if config is None:
            config = ServiceConfig()
        if kwargs:
            unknown = sorted(set(kwargs) - CONFIG_FIELDS)
            if unknown:
                raise TypeError(
                    f"QueryService: unknown keyword(s) {unknown}; valid "
                    f"fields: {sorted(CONFIG_FIELDS)}")
            deprecated = sorted(set(kwargs) & DEPRECATED_KWARGS)
            if deprecated:
                warnings.warn(
                    f"QueryService({', '.join(deprecated)}=...) keywords "
                    "are deprecated; pass "
                    f"ServiceConfig({', '.join(deprecated)}=...) instead",
                    DeprecationWarning, stacklevel=2)
            config = dataclasses.replace(config, **kwargs)
        self.config = config
        self.n_banks = config.n_banks
        self.timing = config.timing
        self.n_chips = config.n_chips
        self.max_chips = config.max_chips
        self.reliability = config.reliability
        self.fault_tolerance = config.fault_tolerance
        self.telemetry = config.telemetry
        self.optimize = config.optimize
        self.plan_cache_capacity = config.plan_cache_capacity
        if self.telemetry is None:
            from repro.obs.telemetry import Telemetry

            self.telemetry = Telemetry(trace=False)
        self.catalog = Catalog()
        optimizer = None
        if self.optimize:
            optimizer = QueryOptimizer(params=CostParams(
                timing=self.timing, n_banks=self.n_banks,
                n_chips=self.n_chips or 1))
        self.optimizer = optimizer
        self.planner = Planner(cache=PlanCache(
            timing=self.timing, optimizer=optimizer,
            capacity=self.plan_cache_capacity))
        self.cluster = None
        if self.n_chips is not None:
            from repro.core.cluster import ChipCluster

            self.cluster = ChipCluster.create(
                self.n_chips, n_banks=self.n_banks,
                max_chips=self.max_chips)
            self.max_chips = self.cluster.max_chips
            self.catalog.attach_cluster(self.cluster)
        if (self.fault_tolerance is not None
                and self.fault_tolerance.on_chip_failure is None):
            self.fault_tolerance.on_chip_failure = self._recover_chip_failure
        self.scheduler = Scheduler(catalog=self.catalog, planner=self.planner,
                                   n_banks=self.n_banks, timing=self.timing,
                                   backend=config.backend,
                                   cluster=self.cluster,
                                   reliability=self.reliability,
                                   fault_tolerance=self.fault_tolerance,
                                   telemetry=self.telemetry)
        self._columns: Dict[str, VerticalColumn] = {}
        #: serializes direct dispatch against a live serving loop
        self._dispatch_lock = threading.RLock()
        self._loop: Optional[ServingLoop] = None
        self._pending: List[tuple] = []     # deferred (Query, QueryHandle)

    # -- catalog management --------------------------------------------------

    def register(self, name: str, value, n_bits: Optional[int] = None,
                 group: Optional[str] = None) -> CatalogEntry:
        return self.catalog.register(name, value, n_bits, group)

    def register_bits(self, name: str, bits,
                      group: Optional[str] = None) -> CatalogEntry:
        return self.catalog.register_bits(name, bits, group)

    def register_column(self, name: str, values: jax.Array, n_bits: int,
                        group: Optional[str] = None) -> VerticalColumn:
        """Store an integer column: one catalog vector per vertical plane.

        Plane j of column `name` becomes catalog row `{name}.b{j}`; the
        column's logical length must equal the catalog bit domain so plane
        vectors and bitmap vectors are freely combinable in one query.
        Registration also records the column's width, which is what lets
        the planner expand `sum(name)` / `name + other` / `name < K`.
        """
        col = VerticalColumn.encode(values, n_bits)
        if self.catalog.n_bits is not None \
                and col.n_values != self.catalog.n_bits:
            raise ValueError(
                f"column {name!r}: {col.n_values} values != catalog domain "
                f"{self.catalog.n_bits}")
        self.catalog.register_column(name, col.planes, col.n_values, n_bits,
                                     group=group)
        self._columns[name] = col
        return col

    def materialize_column(self, name: str, query: Union[str, Expr],
                           group: Optional[str] = None) -> VerticalColumn:
        """Run an arithmetic query (`a + b`, `a - b`), register the result
        planes as a new column, re-queryable like any registered column."""
        r = self.query(query, mode=MATERIALIZE)
        planes = jnp.asarray(np.asarray(r.value), jnp.uint32)
        if planes.ndim != 2:
            raise ValueError(
                f"{query!r} did not produce a plane stack; "
                "materialize_column needs an arithmetic query")
        assert self.catalog.n_bits is not None
        col = VerticalColumn(planes, int(planes.shape[0]),
                             self.catalog.n_bits)
        self.catalog.register_column(name, planes, self.catalog.n_bits,
                                     col.n_bits, group=group)
        self._columns[name] = col
        return col

    # -- query interface (async handle model) --------------------------------

    def submit(self, query: Union[str, Expr, Query], *,
               mode: str = POPCOUNT, tenant: Optional[str] = None,
               priority: int = 0, deadline_ns: Optional[float] = None,
               defer: bool = False) -> QueryHandle:
        """Submit one query; returns a `QueryHandle`.

        Routing: with a running `ServingLoop` attached (`serve_loop()` +
        `start()`), the query enqueues into the continuous-serving
        runtime and the handle resolves when its tick completes (or
        raises `QueryShedError` if admission control dropped it). With
        ``defer=True`` the handle parks until the next `flush()` serves
        every deferred query as ONE scheduler batch (what
        `query_batch()` does). Otherwise the query executes eagerly as
        a batch of one and the handle returns already resolved.
        """
        q = query if isinstance(query, Query) else Query(query, mode, tenant)
        if self._loop is not None and self._loop.accepting and not defer:
            return self._loop.submit(q, priority=priority,
                                     deadline_ns=deadline_ns)
        handle = QueryHandle(q, priority=priority, deadline_ns=deadline_ns)
        if defer:
            self._pending.append((q, handle))
            return handle
        self._run_batch([(q, handle)])
        return handle

    def flush(self) -> BatchReport:
        """Serve every deferred `submit(..., defer=True)` as one batch."""
        pending, self._pending = self._pending, []
        return self._run_batch(pending)

    def _run_batch(self, pending: Sequence[tuple]) -> BatchReport:
        """Direct (loop-less) dispatch path; resolves the handles."""
        queries = [q for q, _ in pending]
        with self._dispatch_lock:
            try:
                report = self.scheduler.submit(queries)
            except BaseException as e:
                for _, handle in pending:
                    handle._fail(e)
                raise
        for (_, handle), result in zip(pending, report.results):
            handle._resolve(result)
        return report

    def query(self, query: Union[str, Expr], mode: str = POPCOUNT,
              tenant: Optional[str] = None) -> QueryResult:
        """Serve one query synchronously (`submit()` + `result()`)."""
        return self.submit(query, mode=mode, tenant=tenant).result()

    def query_batch(self, queries: Sequence[Query]) -> BatchReport:
        """Serve a batch of concurrent queries through the scheduler.

        A thin wrapper over the handle model: every query defers, one
        `flush()` serves them as a single plan-grouped dispatch.
        """
        for q in queries:
            self.submit(q, defer=True)
        return self.flush()

    # -- continuous serving --------------------------------------------------

    def serve_loop(self, **kwargs) -> ServingLoop:
        """Build (and attach) the continuous-serving runtime.

        Returns a `service.server.ServingLoop` bound to this service's
        scheduler; its SLO defaults to ``config.slo``. Use
        ``run_trace(arrivals)`` for deterministic open-loop replay or
        ``start()``/``submit()``/``stop()`` for live serving (while the
        loop accepts, `submit()` on this service routes into it).
        """
        loop = ServingLoop(self, **kwargs)
        self._loop = loop
        return loop

    def materialize(self, name: str, query: Union[str, Expr],
                    group: Optional[str] = None) -> CatalogEntry:
        """Run `query`, register its result vector under `name`."""
        r = self.query(query, mode=MATERIALIZE)
        return self.catalog.register(name, r.value, self.catalog.n_bits,
                                     group=group)

    # -- range scans ---------------------------------------------------------

    def range_scan_query(self, column: str, lo: int, hi: int) -> Expr:
        """The predicate lo <= column <= hi as a fusable Expr DAG."""
        col = self._columns[column]
        return range_scan_expr(col.n_bits, lo, hi,
                               plane_prefix=f"{column}.b")

    def range_scan(self, column: str, lo: int, hi: int,
                   mode: str = POPCOUNT,
                   tenant: Optional[str] = None) -> QueryResult:
        """Serve lo <= column <= hi through the general optimizer path.

        The predicate DAG goes through the same cost-driven pipeline as
        every other query: the compile-off picks the minimal fused
        between-scan program (what the removed `range_scan_fast` branch
        hard-coded) and the optimizer's backend choice dispatches long
        scans to the megakernel on accelerator devices. (The deprecated
        `range_scan_fast` alias was removed; `range_scan(...,
        mode=MATERIALIZE).words` is the bit-identical replacement —
        tests/test_service.py pins the recorded behavior.)
        """
        return self.query(self.range_scan_query(column, lo, hi), mode, tenant)

    def explain(self, queries: Sequence[Union[Query, str]]) -> ExplainReport:
        """Plan a batch without executing it; report every decision.

        Returns the optimizer's `ExplainReport`: per-plan cost breakdown
        (AAPs vs the unoptimized pipeline, modeled latency/energy/
        transfers), the chosen backend per plan, the shared-subexpression
        planes the batch would compute once, and the modeled makespan.
        `print(svc.explain([...]))` renders the human-readable table.
        """
        return self.scheduler.explain(queries)

    # -- elastic deployment --------------------------------------------------

    def rescale(self, n_chips: int):
        """Elastically change the chip count of a distributed deployment.

        The placement granularity (``max_chips * n_banks`` word-slots) is
        the preserved "global batch" of `dist.elastic.plan_rescale`: each
        chip always drives `n_banks` physical banks per sweep
        (``per_shard_batch``), and the slot grid is re-divided so the new
        chips cover it in ``plan.grad_accum`` sequential sweeps. Raises
        `ValueError` (from `plan_rescale`) when the layout cannot be
        preserved exactly — e.g. 3 chips over an 8-chip-granular
        placement. On success the catalog is re-placed onto the new mesh:
        every registered vector keeps its bits (slot contents are
        invariant, only slot->chip assignment moves) and every derived
        column / affinity group survives. Returns the `RescalePlan`.
        """
        if self.cluster is None:
            raise ValueError(
                "rescale() needs a distributed service; construct with "
                "ServiceConfig(n_chips=...)")
        from repro.core.cluster import ChipCluster
        from repro.dist.elastic import plan_rescale

        old = self.cluster
        plan = plan_rescale(global_batch=old.slots,
                            old_mesh_shards=old.n_chips,
                            new_mesh_shards=n_chips,
                            old_accum=old.sweeps)
        assert plan.per_shard_batch == self.n_banks
        self.cluster = ChipCluster.create(
            n_chips, n_banks=self.n_banks, max_chips=old.max_chips)
        assert self.cluster.sweeps == plan.grad_accum
        self.n_chips = n_chips
        self.catalog.attach_cluster(self.cluster)
        self.scheduler.cluster = self.cluster
        return plan

    # -- fault tolerance -----------------------------------------------------

    def _recover_chip_failure(self, exc: BaseException) -> None:
        """Default `FaultTolerance.on_chip_failure` hook: rescale down.

        A `dist.fault_tolerance.ChipFailure` on a distributed deployment
        means one chip of the mesh is gone; recovery elastically re-plans
        the placement onto the largest valid smaller chip count (the slot
        grid constrains which counts divide evenly — `rescale` raises
        `ValueError` for the rest) and re-places every catalog vector, so
        the replayed plan-group lands on the surviving mesh with nothing
        lost. Non-chip failures (a transient kernel fault) need no
        topology change; the scheduler's replay alone recovers them.
        """
        from repro.dist.fault_tolerance import ChipFailure

        if not isinstance(exc, ChipFailure) or self.cluster is None:
            return
        old = self.cluster.n_chips
        for c in range(old - 1, 0, -1):
            try:
                self.rescale(c)
            except ValueError:
                continue    # slot grid not divisible by c chips
            if self.fault_tolerance is not None:
                self.fault_tolerance.timeline.append(f"rescale@{old}->{c}")
            tel = self.telemetry
            if tel.metering:
                tel.metrics.counter("chip_rescales_total").inc()
            if tel.tracing:
                tel.tracer.instant("chip_rescale", old=old, new=c)
            return
        raise RuntimeError(
            f"chip failure on a {old}-chip mesh with no valid smaller "
            "layout") from exc

    def serve_stream(self, batches: Sequence[Sequence[Query]],
                     checkpoint_dir: str, ckpt_every: int = 2,
                     failure_injector=None, max_restores: int = 16):
        """Serve a stream of query batches with checkpointed recovery.

        Each batch is one step of a `dist.fault_tolerance.ResilientRunner`:
        scalar results land in a flat values array inside the runner state,
        which is checkpointed every ``ckpt_every`` batches
        (`checkpoint.Checkpointer`, atomic + async). A failure mid-stream
        replays from the last checkpoint; a *fresh* service pointed at the
        same directory resumes where the previous job stopped and skips
        the already-served prefix. Returns ``(values, RunReport)`` with
        ``values[i]`` the scalar of the i-th query in stream order.

        Scalar modes only — a materialized word vector has no slot in the
        fixed-structure checkpoint state.
        """
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.dist.fault_tolerance import ResilientRunner

        batches = [list(b) for b in batches]
        for b in batches:
            for q in b:
                if q.mode == MATERIALIZE:
                    raise ValueError(
                        "serve_stream checkpoints scalar results; "
                        "materialize queries don't fit the stream state")
        sizes = [len(b) for b in batches]
        offsets = np.concatenate([[0], np.cumsum(sizes)])
        n_total = int(offsets[-1])

        def step_fn(state, step, batch):
            report = self.query_batch(batch)
            # restore round-trips through jnp.asarray, so re-host + re-cast
            # instead of mutating (state may be a device array)
            values = np.asarray(state["values"]).astype(np.int64).copy()
            lo = int(offsets[step])
            values[lo:lo + len(batch)] = [int(r.value)
                                          for r in report.results]
            return {"done": np.int64(step + 1), "values": values}, {}

        runner = ResilientRunner(
            step_fn, lambda step: batches[step],
            Checkpointer(checkpoint_dir), ckpt_every=ckpt_every,
            max_restores=max_restores, telemetry=self.telemetry)
        init = {"done": np.int64(0),
                "values": np.zeros(n_total, np.int64)}
        state, report = runner.run(init, len(batches),
                                   failure_injector=failure_injector)
        return np.asarray(state["values"]).astype(np.int64), report

    # -- observability -------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """One unified stat surface, backed by the metrics registry.

        With metering on (the default), the counter-backed keys read
        through `telemetry.metrics` — the same registry the Prometheus
        snapshot and per-tenant counters export — and gain latency
        percentiles plus the reliability / fault-tolerance totals. The
        legacy keys (`queries_served`, `plan_cache_*`, ...) are aliases of
        the registry series; with metering off they fall back to the
        always-maintained legacy attributes, so the dict shape is stable
        either way.
        """
        cache = self.planner.cache
        tel = self.telemetry
        ft = self.fault_tolerance
        if tel.metering:
            m = tel.metrics
            s: Dict[str, float] = {
                "queries_served": int(m.counter("queries_total").value),
                "plans_cached": len(cache),
                "plan_cache_hits": int(
                    m.counter("plan_cache_hits_total").value),
                "plan_cache_misses": int(
                    m.counter("plan_cache_misses_total").value),
                "plan_cache_hit_rate": cache.hit_rate,
                "plan_cache_evictions": int(
                    m.counter("plan_cache_evictions_total").value),
                "cse_planes": int(m.counter("cse_planes_total").value),
                "compile_count": self.planner.compile_count,
                "total_modeled_ns": m.counter("modeled_ns_total").value,
                "total_energy_nj": m.counter(
                    "modeled_energy_nj_total").value,
                "n_chips": self.n_chips or 1,
                "chip_sweeps": self.cluster.sweeps if self.cluster else 0,
                "parity_checks": int(
                    m.counter("parity_checks_total").value),
                "batches": int(m.counter("batches_total").value),
                "modeled_latency_p50_ns": m.histogram(
                    "modeled_latency_ns").percentile(50),
                "modeled_latency_p99_ns": m.histogram(
                    "modeled_latency_ns").percentile(99),
                "reliability_replicas": int(
                    m.counter("reliability_replicas_total").value),
                "ecc_tiebreaks": int(
                    m.counter("ecc_tiebreaks_total").value),
                "tra_corrected_bits": int(
                    m.counter("tra_corrected_bits_total").value),
                "chip_rescales": int(
                    m.counter("chip_rescales_total").value),
                "serve_queue_depth": m.gauge("serve_queue_depth").value,
                "serve_shed": int(m.counter("serve_shed_total").value),
                "serve_ticks": int(m.counter("serve_ticks_total").value),
            }
        else:
            s = {
                "queries_served": self.scheduler.queries_served,
                "plans_cached": len(cache),
                "plan_cache_hits": cache.hits,
                "plan_cache_misses": cache.misses,
                "plan_cache_hit_rate": cache.hit_rate,
                "plan_cache_evictions": cache.evictions,
                "cse_planes": self.scheduler.cse_planes_built,
                "compile_count": self.planner.compile_count,
                "total_modeled_ns": self.scheduler.total_modeled_ns,
                "total_energy_nj": self.scheduler.total_energy_nj,
                "n_chips": self.n_chips or 1,
                "chip_sweeps": self.cluster.sweeps if self.cluster else 0,
                "parity_checks": self.scheduler.parity_checks,
                "chip_rescales": (sum(
                    1 for t in ft.timeline if t.startswith("rescale@"))
                    if ft else 0),
            }
        # fault-tolerance state folds in from the policy object (legacy
        # source of truth); the registry's ft_* counters mirror it
        s["replays"] = ft.replays if ft else 0
        s["failures"] = ft.failures if ft else 0
        s["stragglers"] = len(ft.stragglers) if ft else 0
        s["straggler_ema_s"] = (ft.monitor.ema or 0.0) if ft else 0.0
        return s

    def export_chrome_trace(self, path=None):
        """Export the batch span trees + modeled timelines recorded so far
        as Chrome trace-event JSON (needs `telemetry` with tracing on);
        validated against the trace schema, written to `path` if given."""
        return self.telemetry.export_chrome_trace(path)

    def prometheus(self) -> str:
        """The metrics registry as Prometheus text exposition format."""
        return self.telemetry.prometheus()
