"""Continuous-serving runtime: the long-lived `ServingLoop`.

`QueryService` (service.py) is a one-shot facade: every `query_batch`
serializes host-side planning against device execution at the batch
boundary. This module promotes serving to a persistent loop (ROADMAP
item 1, in the style of vLLM's TPU worker) built from four pieces:

  * **request queue** — open-loop arrivals land in per-tenant FIFO
    queues (`submit()` in live mode, an `Arrival` trace in deterministic
    replay). Per-tenant order is preserved end-to-end: the property
    suite asserts no query is lost, duplicated, or reordered within a
    tenant.
  * **tick packing** — each scheduler tick selects up to ``capacity``
    queries with deficit-round-robin per-tenant fairness and hands them
    to the scheduler, which groups them by canonical plan shape into
    stacked dispatches over the fixed ``(max_chips, local_banks,
    queries)`` slot grid (`capacity = slots * depth`: every (chip, bank)
    slot holds ``depth`` in-flight queries per tick).
  * **double-buffered dispatch** — the host-side parse/plan/bind of tick
    N+1 (`Scheduler.plan_queries`) overlaps with device execution of
    tick N (a one-slot worker thread running
    ``Scheduler.submit(preplanned=...)``). Tick N+1's formation time is
    projected from an EMA service-time estimate, exactly the information
    a real server has while a tick is still in flight — so the replay is
    deterministic regardless of thread scheduling. Tracing serializes
    the pipeline (span stacks are single-threaded by design).
  * **admission control / backpressure** — with an `SloConfig`, each
    tick projects every queued query's sojourn (waited-so-far + queue
    position x EMA per-query service time). Policy "shed" drops the
    newest lowest-priority queries until the projection fits the p99
    target (`QueryShedError` on the handle); "defer" parks the
    lowest-priority class while higher-priority work drains (never
    reordering within a tenant — a deferred head parks its whole
    queue). Expired per-query deadlines shed regardless of policy.

Everything is instrumented through the PR 7 telemetry layer: queue-depth
gauge, shed/deferred counters, per-tick occupancy histogram, tick spans
plus queue-depth counter samples in the Chrome trace.

Two clocks, as everywhere in this repo: `run_trace` replays an arrival
trace in *modeled* nanoseconds (DDR3 AAP timing — deterministic,
CI-gateable p99s), while wall-clock throughput of the pipelined loop vs
the serialized closed loop is measured separately
(`benchmarks/serve_loop.py`). Live mode (`start`/`submit`/`stop`) runs
the same machinery against the wall clock.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import math
import threading
import time
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

from repro.service.config import DEFER, OBSERVE, SHED, SloConfig
from repro.service.scheduler import POPCOUNT, Query, QueryResult

# handle lifecycle states
PENDING = "pending"
DONE = "done"
SHED_STATUS = "shed"
FAILED = "failed"

SERVED = "served"


class QueryShedError(RuntimeError):
    """The admission controller dropped this query before execution."""

    def __init__(self, message: str, reason: Optional[str] = None):
        super().__init__(message)
        self.reason = reason


class QueryHandle:
    """Async result handle returned by ``submit()``.

    ``result()`` blocks until the query is served (returning its
    `QueryResult`), raises `QueryShedError` if admission control dropped
    it, or re-raises the serving failure. ``done()`` is the non-blocking
    probe. Handles resolve exactly once.
    """

    def __init__(self, query: Query, priority: int = 0,
                 deadline_ns: Optional[float] = None):
        self.query = query
        self.priority = priority
        self.deadline_ns = deadline_ns
        self.status = PENDING
        self._event = threading.Event()
        self._result: Optional[QueryResult] = None
        self._error: Optional[BaseException] = None

    @property
    def tenant(self) -> Optional[str]:
        return self.query.tenant

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> QueryResult:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"query not served within {timeout}s (status={self.status})")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    # -- resolution (serving side) ------------------------------------------

    def _resolve(self, result: QueryResult) -> None:
        self._result = result
        self.status = DONE
        self._event.set()

    def _shed(self, reason: str) -> None:
        self._error = QueryShedError(f"query shed ({reason})", reason)
        self.status = SHED_STATUS
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self.status = FAILED
        self._event.set()


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop request: a query arriving at modeled time ``t_ns``."""

    t_ns: float
    query: Query
    priority: int = 0
    deadline_ns: Optional[float] = None


@dataclasses.dataclass
class ServeRecord:
    """Per-query outcome of a serving run, in arrival order."""

    index: int
    tenant: Optional[str]
    priority: int
    arrival_ns: float
    status: str                       # "served" | "shed"
    shed_reason: Optional[str] = None
    tick: int = -1
    dispatch_ns: float = 0.0
    complete_ns: float = 0.0
    result: Optional[QueryResult] = None

    @property
    def sojourn_ns(self) -> float:
        """Modeled arrival -> completion latency (served records)."""
        return self.complete_ns - self.arrival_ns


@dataclasses.dataclass
class TickStats:
    """One scheduler tick: packing + timing accounting."""

    tick: int
    form_ns: float                    # formation time (modeled)
    start_ns: float                   # device dispatch start (modeled)
    makespan_ns: float
    n_queries: int
    n_groups: int                     # distinct plan shapes packed
    occupancy: float                  # n_queries / capacity
    queue_depth: int                  # left queued after formation
    plan_wall_us: float = 0.0
    exec_wall_us: float = 0.0


@dataclasses.dataclass
class ServeReport:
    """Aggregate outcome of one serving run (trace replay or live)."""

    records: List[ServeRecord]
    ticks: List[TickStats]
    capacity: int
    wall_s: float
    slo: Optional[SloConfig] = None
    deferred_total: int = 0
    pipelined: bool = False

    @property
    def served(self) -> List[ServeRecord]:
        return [r for r in self.records if r.status == SERVED]

    @property
    def shed(self) -> List[ServeRecord]:
        return [r for r in self.records if r.status == SHED_STATUS]

    @property
    def duration_ns(self) -> float:
        """Modeled first-arrival -> last-completion span."""
        served = self.served
        if not served:
            return 0.0
        first = min(r.arrival_ns for r in self.records)
        return max(r.complete_ns for r in served) - first

    @property
    def sustained_qps(self) -> float:
        """Modeled served-query throughput over the whole run."""
        d = self.duration_ns
        return len(self.served) / (d * 1e-9) if d > 0 else 0.0

    @property
    def wall_qps(self) -> float:
        """Host wall-clock served-query throughput (pipeline metric)."""
        return len(self.served) / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def shed_frac(self) -> float:
        return len(self.shed) / len(self.records) if self.records else 0.0

    @property
    def occupancy_mean(self) -> float:
        if not self.ticks:
            return 0.0
        return sum(t.occupancy for t in self.ticks) / len(self.ticks)

    def sojourn_percentile_ns(self, pct: float) -> float:
        """Nearest-rank percentile of served sojourns (as BatchReport)."""
        lats = sorted(r.sojourn_ns for r in self.served)
        if not lats:
            return 0.0
        i = min(len(lats) - 1, int(math.ceil(pct / 100.0 * len(lats))) - 1)
        return lats[max(i, 0)]

    def results(self) -> List[Optional[QueryResult]]:
        """Per-arrival results in arrival order (None where shed)."""
        return [r.result for r in self.records]


@dataclasses.dataclass
class _Item:
    """A queued query inside the loop."""

    index: int
    seq: int                          # admission order tiebreak
    arrival_ns: float
    query: Query
    priority: int
    deadline_ns: Optional[float]
    handle: Optional[QueryHandle] = None
    tick: int = -1

    @property
    def tenant_key(self) -> str:
        return self.query.tenant or ""


class _Done:
    """Already-resolved stand-in for a Future (serial mode)."""

    def __init__(self, value):
        self._value = value

    def result(self):
        return self._value


@dataclasses.dataclass
class _Inflight:
    future: object                    # Future[(BatchReport, exec_wall_us)]
    batch: List[_Item]
    start_ns: float                   # exact: device was free at launch
    form_ns: float
    est_free_ns: float                # projected completion (EMA)
    plan_wall_us: float
    tick: int


class ServingLoop:
    """Long-lived slot-packing serving loop over a `QueryService`.

    Deterministic replay: ``run_trace(arrivals)`` steps modeled time
    through an open-loop arrival trace and returns a `ServeReport`.
    Live serving: ``start()`` spawns the loop thread, ``submit()``
    returns a `QueryHandle`, ``stop()`` drains and reports.
    """

    def __init__(self, service, *, depth: int = 4,
                 capacity: Optional[int] = None,
                 slo: Optional[SloConfig] = None,
                 drr_quantum: int = 4,
                 pipeline: bool = True,
                 max_queue: Optional[int] = None,
                 est_alpha: float = 0.25,
                 on_tick=None):
        self.service = service
        self.scheduler = service.scheduler
        self.telemetry = service.telemetry
        cluster = service.cluster
        #: (chip, bank) positions of the placement slot grid — the PR 5
        #: granularity (max_chips * n_banks) when clustered, else the
        #: bank group
        self.slots = cluster.slots if cluster is not None else service.n_banks
        self.depth = depth
        self.capacity = capacity if capacity is not None \
            else self.slots * depth
        if self.capacity <= 0:
            raise ValueError("capacity must be positive")
        self.slo = slo if slo is not None else service.config.slo
        self.drr_quantum = max(1, drr_quantum)
        self.pipeline = pipeline
        self.max_queue = max_queue
        self.est_alpha = est_alpha
        #: optional callback(TickStats) fired as each tick finalizes —
        #: the launcher's live dashboard hook
        self.on_tick = on_tick
        self.accepting = False
        #: serializes device dispatch against the service's direct path
        self.dispatch_lock = service._dispatch_lock
        self._thread: Optional[threading.Thread] = None
        self._cv = threading.Condition()
        self._live_buffer: List[Tuple[float, _Item]] = []
        self._stopping = False
        self._live_error: Optional[BaseException] = None
        self._reset_state()
        if self.telemetry.metering:
            m = self.telemetry.metrics
            self._g_depth = m.gauge("serve_queue_depth")
            self._c_admitted = m.counter("serve_admitted_total")
            self._c_shed = m.counter("serve_shed_total")
            self._c_deferred = m.counter("serve_deferred_total")
            self._c_ticks = m.counter("serve_ticks_total")
            self._h_occupancy = m.histogram("serve_tick_occupancy")
            self._h_sojourn = m.histogram("serve_sojourn_ns")

    # -- shared state --------------------------------------------------------

    def _reset_state(self) -> None:
        self._queues: "OrderedDict[str, Deque[_Item]]" = OrderedDict()
        self._deficit: Dict[str, float] = {}
        self._rr_start = 0
        self._n_queued = 0
        self._seq = 0
        self._tick_seq = 0
        self._device_free = 0.0
        self._est_query_ns: Optional[float] = None
        self._records: List[ServeRecord] = []
        self._ticks: List[TickStats] = []
        self._deferred_total = 0

    @property
    def queue_depth(self) -> int:
        return self._n_queued

    def _admit(self, item: _Item) -> None:
        if (self.max_queue is not None
                and self._n_queued >= self.max_queue):
            self._shed_item(item, "backpressure", item.arrival_ns)
            return
        q = self._queues.get(item.tenant_key)
        if q is None:
            q = self._queues[item.tenant_key] = deque()
            self._deficit.setdefault(item.tenant_key, 0.0)
        q.append(item)
        self._n_queued += 1
        if self.telemetry.metering:
            self._c_admitted.inc()

    def _queued_snapshot(self) -> List[_Item]:
        """All queued items in global arrival order (service-order
        approximation for sojourn projection)."""
        items = [it for q in self._queues.values() for it in q]
        items.sort(key=lambda it: (it.arrival_ns, it.seq))
        return items

    def _oldest_arrival(self) -> float:
        return min(q[0].arrival_ns for q in self._queues.values() if q)

    def _remove(self, item: _Item) -> None:
        self._queues[item.tenant_key].remove(item)
        self._n_queued -= 1

    def _shed_item(self, item: _Item, reason: str, now_ns: float) -> None:
        self._records.append(ServeRecord(
            index=item.index, tenant=item.query.tenant,
            priority=item.priority, arrival_ns=item.arrival_ns,
            status=SHED_STATUS, shed_reason=reason, complete_ns=now_ns))
        if item.handle is not None:
            item.handle._shed(reason)
        tel = self.telemetry
        if tel.metering:
            self._c_shed.inc()
        if tel.tracing:
            tel.tracer.instant("serve_shed", index=item.index,
                               reason=reason, tenant=item.query.tenant)

    # -- admission control ---------------------------------------------------

    def _projection_target(self) -> Optional[float]:
        if (self.slo is None or self.slo.policy == OBSERVE
                or self._est_query_ns is None):
            return None
        return self.slo.p99_ns * self.slo.safety

    def _projected_sojourns(self, now_ns: float) -> List[Tuple[float, _Item]]:
        """(projected sojourn, item) per queued query: time already
        waited plus queue position x EMA per-query service time — the
        modeled queue delay the SLO policy acts on."""
        est = self._est_query_ns or 0.0
        return [((now_ns - it.arrival_ns) + (p + 1) * est, it)
                for p, it in enumerate(self._queued_snapshot())]

    def _shed_deadlines(self, now_ns: float) -> None:
        expired = [it for q in self._queues.values() for it in q
                   if it.deadline_ns is not None
                   and now_ns - it.arrival_ns > it.deadline_ns]
        for it in expired:
            self._remove(it)
            self._shed_item(it, "deadline", now_ns)

    def _slo_shed(self, now_ns: float) -> None:
        """Drop newest lowest-priority queries until every projected
        sojourn fits the target."""
        target = self._projection_target()
        if target is None:
            return
        while True:
            over = [it for s, it in self._projected_sojourns(now_ns)
                    if s > target]
            if not over:
                return
            victim = min(over, key=lambda it: (it.priority,
                                               -it.arrival_ns, -it.seq))
            self._remove(victim)
            self._shed_item(victim, "slo", now_ns)

    def _defer_floor(self, now_ns: float) -> Optional[int]:
        """Priority class parked this tick (defer policy, on breach)."""
        target = self._projection_target()
        if target is None:
            return None
        if not any(s > target for s, _ in self._projected_sojourns(now_ns)):
            return None
        prios = {it.priority for q in self._queues.values() for it in q}
        if len(prios) < 2:
            return None     # nothing lower-priority to defer to
        return min(prios)

    # -- tick formation (DRR) ------------------------------------------------

    def _form_tick(self, now_ns: float, can_defer: bool) -> List[_Item]:
        """Select up to ``capacity`` queries, deficit-round-robin fair.

        Each round visits the active tenants in rotating order, credits
        each visited tenant ``drr_quantum`` units, and drains its FIFO
        head while credit and room remain — a hog tenant gets the same
        per-round credit as everyone else, so its backlog cannot starve
        light tenants. A tenant whose head is deferred is skipped whole
        (taking a later query would reorder within the tenant).
        """
        self._shed_deadlines(now_ns)
        if self.slo is not None and self.slo.policy == SHED:
            self._slo_shed(now_ns)
        floor = None
        if can_defer and self.slo is not None and self.slo.policy == DEFER:
            floor = self._defer_floor(now_ns)
            if floor is not None:
                parked = sum(1 for q in self._queues.values()
                             for it in q if it.priority <= floor)
                self._deferred_total += parked
                if self.telemetry.metering:
                    self._c_deferred.inc(parked)
        selected: List[_Item] = []
        room = self.capacity
        order = [t for t in self._queues if self._queues[t]]
        if not order:
            return selected
        self._rr_start %= len(order)
        order = order[self._rr_start:] + order[:self._rr_start]
        self._rr_start += 1
        while room > 0:
            progressed = False
            for t in order:
                q = self._queues[t]
                if not q:
                    self._deficit[t] = 0.0
                    continue
                self._deficit[t] = min(self._deficit[t] + self.drr_quantum,
                                       float(self.capacity))
                while q and self._deficit[t] >= 1.0 and room > 0:
                    head = q[0]
                    if floor is not None and head.priority <= floor:
                        break       # deferred head parks the tenant queue
                    q.popleft()
                    self._n_queued -= 1
                    self._deficit[t] -= 1.0
                    selected.append(head)
                    room -= 1
                    progressed = True
                if not q:
                    self._deficit[t] = 0.0
            if not progressed:
                break
        if self.telemetry.metering:
            self._g_depth.set(self._n_queued)
        return selected

    # -- dispatch ------------------------------------------------------------

    def _execute(self, queries: List[Query], bound) -> object:
        """Device stage: one preplanned scheduler dispatch.

        CSE stays off in the loop — the sharing pass compiles ephemeral
        plans through the planner cache the pipelined host stage is
        using from the other thread; cross-tick plan-shape packing is
        the loop's sharing mechanism instead.
        """
        with self.dispatch_lock:
            return self.scheduler.submit(queries, preplanned=bound,
                                         allow_cse=False)

    def _launch(self, batch: List[_Item], bound, form_ns: float,
                plan_us: float, pool) -> _Inflight:
        start = max(self._device_free, form_ns)
        tick = self._tick_seq
        self._tick_seq += 1
        for it in batch:
            it.tick = tick
        queries = [it.query for it in batch]

        def run():
            w0 = time.perf_counter()
            rep = self._execute(queries, bound)
            return rep, (time.perf_counter() - w0) * 1e6

        fut = pool.submit(run) if pool is not None else _Done(run())
        est = self._est_query_ns or 0.0
        return _Inflight(fut, batch, start, form_ns,
                         start + est * len(batch), plan_us, tick)

    def _finalize(self, fl: _Inflight) -> None:
        rep, exec_us = fl.future.result()
        self._device_free = fl.start_ns + rep.makespan_ns
        per_q = rep.makespan_ns / max(1, len(fl.batch))
        if self._est_query_ns is None:
            self._est_query_ns = per_q
        else:
            a = self.est_alpha
            self._est_query_ns = a * per_q + (1 - a) * self._est_query_ns
        occupancy = len(fl.batch) / self.capacity
        stats = TickStats(
            tick=fl.tick, form_ns=fl.form_ns, start_ns=fl.start_ns,
            makespan_ns=rep.makespan_ns, n_queries=len(fl.batch),
            n_groups=rep.n_plan_groups, occupancy=occupancy,
            queue_depth=self._n_queued, plan_wall_us=fl.plan_wall_us,
            exec_wall_us=exec_us)
        self._ticks.append(stats)
        if self.on_tick is not None:
            self.on_tick(stats)
        tel = self.telemetry
        for it, r in zip(fl.batch, rep.results):
            complete = fl.start_ns + r.latency_ns
            self._records.append(ServeRecord(
                index=it.index, tenant=it.query.tenant,
                priority=it.priority, arrival_ns=it.arrival_ns,
                status=SERVED, tick=fl.tick, dispatch_ns=fl.start_ns,
                complete_ns=complete, result=r))
            if it.handle is not None:
                it.handle._resolve(r)
            if tel.metering:
                self._h_sojourn.observe(complete - it.arrival_ns)
        if tel.metering:
            self._c_ticks.inc()
            self._h_occupancy.observe(occupancy)
            self._g_depth.set(self._n_queued)
        if tel.tracing:
            tr = tel.tracer
            tr.model_event("tick", fl.start_ns, rep.makespan_ns,
                           "serve/ticks", tick=fl.tick,
                           n_queries=len(fl.batch),
                           n_groups=rep.n_plan_groups,
                           occupancy=occupancy)
            tr.counter_event("serve_queue_depth", fl.start_ns,
                             "serve/queue", depth=self._n_queued)

    # -- deterministic trace replay ------------------------------------------

    def run_trace(self, arrivals: Sequence[Arrival],
                  pipeline: Optional[bool] = None) -> ServeReport:
        """Replay an open-loop arrival trace in modeled time.

        ``pipeline=True`` (default: the loop's setting) overlaps host
        planning of tick N+1 with device execution of tick N on a
        one-slot worker; formation of the overlapped tick projects the
        in-flight completion from the service-time EMA, so the replay
        is deterministic either way. Tracing forces serial mode (span
        stacks are single-threaded).
        """
        use_pipe = self.pipeline if pipeline is None else pipeline
        if self.telemetry.tracing:
            use_pipe = False
        self._reset_state()
        items = [
            _Item(index=i, seq=i, arrival_ns=a.t_ns, query=a.query,
                  priority=a.priority, deadline_ns=a.deadline_ns)
            for i, a in enumerate(
                sorted(arrivals, key=lambda a: a.t_ns))
        ]
        self._seq = len(items)
        pending: Deque[_Item] = deque(items)
        pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                if use_pipe else None)
        wall0 = time.perf_counter()
        prev: Optional[_Inflight] = None
        min_now = 0.0
        tr = self.telemetry.tracer
        tracing = self.telemetry.tracing
        try:
            while pending or self._n_queued or prev is not None:
                est_free = (prev.est_free_ns if prev is not None
                            else self._device_free)
                cands = []
                if self._n_queued:
                    cands.append(self._oldest_arrival())
                if pending:
                    cands.append(pending[0].arrival_ns)
                batch: List[_Item] = []
                bound = None
                now = plan_us = 0.0
                if cands:
                    now = max(est_free, min(cands), min_now)
                    while pending and pending[0].arrival_ns <= now:
                        self._admit(pending.popleft())
                    can_defer = bool(pending) or prev is not None
                    batch = self._form_tick(now, can_defer)
                    if batch:
                        if tracing:
                            tr.begin("tick", tick=self._tick_seq,
                                     n_queries=len(batch))
                            tr.begin("tick_plan")
                        w0 = time.perf_counter()
                        # host stage of the double buffer: overlapped
                        # with `prev` still executing on the worker
                        bound = self.scheduler.plan_queries(
                            [it.query for it in batch])
                        plan_us = (time.perf_counter() - w0) * 1e6
                        if tracing:
                            tr.end()    # tick_plan
                if prev is not None:
                    self._finalize(prev)
                    prev = None
                if batch:
                    min_now = 0.0
                    prev = self._launch(batch, bound, now, plan_us, pool)
                    if pool is None:
                        self._finalize(prev)
                        prev = None
                    if tracing:
                        tr.end()        # tick
                elif cands and pending:
                    # nothing eligible at `now`: the next attempt must
                    # see new work, or it would spin on the same state
                    min_now = pending[0].arrival_ns
        finally:
            if pool is not None:
                pool.shutdown(wait=True)
        wall_s = time.perf_counter() - wall0
        self._records.sort(key=lambda r: r.index)
        return ServeReport(
            records=self._records, ticks=self._ticks,
            capacity=self.capacity, wall_s=wall_s, slo=self.slo,
            deferred_total=self._deferred_total, pipelined=use_pipe)

    # -- live serving --------------------------------------------------------

    def _wall_ns(self) -> float:
        return (time.perf_counter() - self._wall0) * 1e9

    def start(self) -> "ServingLoop":
        """Spawn the live serving thread; `submit()` now enqueues."""
        if self._thread is not None:
            raise RuntimeError("serving loop already started")
        self._reset_state()
        self._stopping = False
        self._live_error = None
        self._wall0 = time.perf_counter()
        self.accepting = True
        self._thread = threading.Thread(target=self._live_run,
                                        name="serving-loop", daemon=True)
        self._thread.start()
        return self

    def submit(self, query: Union[Query, str, object], *,
               mode: str = POPCOUNT, tenant: Optional[str] = None,
               priority: int = 0,
               deadline_ns: Optional[float] = None) -> QueryHandle:
        """Enqueue one query on the live loop; returns its handle."""
        q = query if isinstance(query, Query) else Query(query, mode, tenant)
        handle = QueryHandle(q, priority=priority, deadline_ns=deadline_ns)
        with self._cv:
            if not self.accepting:
                raise RuntimeError(
                    "serving loop is not accepting (call start())")
            self._live_buffer.append((self._wall_ns(), handle))
            self._cv.notify()
        return handle

    def _live_run(self) -> None:
        try:
            while True:
                with self._cv:
                    if (not self._live_buffer and not self._stopping
                            and self._n_queued == 0):
                        self._cv.wait(0.02)
                    buf, self._live_buffer = self._live_buffer, []
                    stopping = self._stopping
                for t_ns, handle in buf:
                    self._admit(_Item(
                        index=self._seq, seq=self._seq, arrival_ns=t_ns,
                        query=handle.query, priority=handle.priority,
                        deadline_ns=handle.deadline_ns, handle=handle))
                    self._seq += 1
                if self._n_queued == 0:
                    if stopping:
                        return
                    continue
                now = self._wall_ns()
                # live clock: the same formation/admission machinery
                # runs on wall nanoseconds (the EMA and projections stay
                # unit-consistent because ticks are finalized on wall
                # time below)
                batch = self._form_tick(now, can_defer=not stopping)
                if not batch:
                    continue
                bound = self.scheduler.plan_queries(
                    [it.query for it in batch])
                fl = self._launch(batch, bound, now, 0.0, None)
                # overwrite modeled bookkeeping with wall: device is
                # free when the dispatch actually returned
                rep, exec_us = fl.future.result()
                end_ns = self._wall_ns()
                fl.start_ns = now
                wall_makespan = max(end_ns - now, 1.0)
                rep = dataclasses.replace(rep, makespan_ns=wall_makespan)
                for r in rep.results:
                    r.latency_ns = wall_makespan
                fl.future = _Done((rep, exec_us))
                self._finalize(fl)
        except BaseException as e:  # noqa: BLE001 - fail pending handles
            self._live_error = e
            for q in self._queues.values():
                for it in q:
                    if it.handle is not None:
                        it.handle._fail(e)
            with self._cv:
                for _, handle in self._live_buffer:
                    handle._fail(e)
                self._live_buffer = []

    def stop(self, drain: bool = True) -> ServeReport:
        """Stop the live loop (draining the queue first by default)."""
        if self._thread is None:
            raise RuntimeError("serving loop was not started")
        with self._cv:
            self.accepting = False
            self._stopping = True
            if not drain:
                for q in self._queues.values():
                    while q:
                        it = q.popleft()
                        self._n_queued -= 1
                        self._shed_item(it, "shutdown", self._wall_ns())
            self._cv.notify()
        self._thread.join()
        self._thread = None
        if self._live_error is not None:
            raise self._live_error
        self._records.sort(key=lambda r: r.index)
        return ServeReport(
            records=self._records, ticks=self._ticks,
            capacity=self.capacity,
            wall_s=time.perf_counter() - self._wall0, slo=self.slo,
            deferred_total=self._deferred_total, pipelined=False)
