"""Query planner: parse -> canonicalize -> optimize -> cost -> bind.

The planner turns a query string over catalog names (`"(mon | tue) & male"`)
into a `core.compiler.Expr` DAG, *canonicalizes* the leaf names to
positional inputs `IN0..INk`, and runs the canonical DAG through the
cost-based optimizer (`service.optimizer`): the plan cache compiles both
the original and the cost-reordered candidate with `compile_expr_fused`
and keeps whichever needs fewer AAPs — so the optimized pipeline can never
emit more AAPs than the unoptimized one. Plans are memoized in a bounded
LRU `PlanCache` keyed by the structural `expr_key` of the *winning*
canonical DAG (a route table maps as-written keys to it), so

  * the same query twice compiles once (hit counter-verified by tests),
  * structurally identical queries over *different* catalog vectors share
    one plan — e.g. every tenant's 7-way weekly OR-tree is one cached
    program, which is also what lets the scheduler batch them into one
    bank-group dispatch (the controller broadcasts a single AAP sequence;
    each bank holds a different tenant's rows), and
  * operand-order variants (`c & (a|b)` vs `(b|a) & c`) converge on one
    reordered shape and share that single compiled plan.

A `Plan` carries the compiled program plus its derived costs: AAP count,
per-row-block modeled latency (`core.timing`) and energy (`core.energy`),
the full `PlanCost` breakdown, and the backend the optimizer chose for
dispatch (`interp` / `scan` / `pallas`).

Beyond boolean queries, the grammar covers the bit-serial arithmetic layer
(`core.arith_compiler`) over registered integer columns:

  * `col < 17` / `colA < colB` — comparison predicates, expanded into
    boolean DAGs over the columns' bit planes (usable anywhere a bitvector
    name is: `age < 30 & male`);
  * `colA + colB` / `colA - colB` — element-wise wrap-around add/sub,
    compiled to the maj3+xor ripple microprogram with multi-plane outputs;
  * `sum(col)` / `sum(colA + colB)` / `sum(colA - colB)` — SUM aggregation
    (the scheduler's `aggregate` result mode).

Expanding these needs the column-name -> bit-width map, which the catalog
owns (`Catalog.columns`); pass it as `columns=`. Arithmetic plans ride the
same `PlanCache`, keyed on (op, width), so every tenant's `sum(col)` over
an 8-bit column is ONE cached microprogram.
"""
from __future__ import annotations

import dataclasses
import re
from collections import OrderedDict
from typing import (Container, Dict, List, Mapping, Optional, Tuple,
                    Union)

from repro.core import arith_compiler
from repro.core import energy as energy_model
from repro.core import lowering
from repro.core import timing as timing_model
from repro.core.commands import Program
from repro.core.compiler import (CompileResult, Expr, compile_expr_fused,
                                 expr_key)
from repro.service.catalog import plane_name
from repro.service.optimizer import PlanCost, QueryOptimizer

DST = "OUT"
_IN_PREFIX = "IN"


class QueryParseError(ValueError):
    pass


# ---------------------------------------------------------------------------
# Parser: `<` > `~` > `&` > `^` > `|`, parens, maj(a,b,c); names may contain
# word chars plus . / : - (tenant-scoped names like "t3/wed"). Integer
# literals appear only as the right-hand side of `<`.
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(r"\s*([A-Za-z_][\w./:-]*|\d+|[()&|^~,<])")


def _tokenize(text: str) -> List[str]:
    tokens, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            if text[pos:].strip():
                raise QueryParseError(
                    f"bad character {text[pos:].strip()[0]!r} in query "
                    f"{text!r}")
            break
        tokens.append(m.group(1))
        pos = m.end()
    return tokens


def _expand_lt(lhs: Expr, rhs: str, columns: Optional[Mapping[str, int]],
               text: str) -> Expr:
    """Expand `col < K` / `colA < colB` into a plane-level boolean DAG."""
    if lhs.op != "row":
        raise QueryParseError(
            f"left side of '<' must be a column name in {text!r}")
    if not columns or lhs.row not in columns:
        raise QueryParseError(
            f"{lhs.row!r} is not a registered integer column in {text!r}")
    n_bits = columns[lhs.row]
    if rhs.isdigit():
        k = int(rhs)
        if k <= 0 or k >= (1 << n_bits):
            raise QueryParseError(
                f"{lhs.row} < {k} is constant for a {n_bits}-bit column "
                f"in {text!r}")
        e = arith_compiler.lt_const_expr(n_bits, k, prefix=f"{lhs.row}.b")
        assert e is not None
        return e
    if rhs not in columns:
        raise QueryParseError(
            f"{rhs!r} is not a registered integer column in {text!r}")
    if columns[rhs] != n_bits:
        raise QueryParseError(
            f"width mismatch in {text!r}: {lhs.row} is {n_bits}-bit, "
            f"{rhs} is {columns[rhs]}-bit")
    return arith_compiler.lt_columns_expr(n_bits, f"{lhs.row}.b",
                                          f"{rhs}.b")


def parse_query(text: str,
                columns: Optional[Mapping[str, int]] = None) -> Expr:
    """Parse a query string over catalog names into an Expr DAG.

    `columns` (column name -> bit width, `Catalog.columns`) enables the
    comparison forms `col < K` and `colA < colB`, which expand to boolean
    DAGs over the columns' bit planes.
    """
    tokens = _tokenize(text)
    idx = 0

    def peek() -> Optional[str]:
        return tokens[idx] if idx < len(tokens) else None

    def take(expected: Optional[str] = None) -> str:
        nonlocal idx
        if idx >= len(tokens):
            raise QueryParseError(f"unexpected end of query {text!r}")
        tok = tokens[idx]
        if expected is not None and tok != expected:
            raise QueryParseError(
                f"expected {expected!r} but got {tok!r} in {text!r}")
        idx += 1
        return tok

    def atom() -> Expr:
        tok = take()
        if tok == "(":
            e = or_level()
            take(")")
            return e
        if tok == "~":
            return ~atom()
        if tok == "maj" and peek() == "(":
            take("(")
            a = or_level()
            take(",")
            b = or_level()
            take(",")
            c = or_level()
            take(")")
            return Expr("maj3", (a, b, c))
        if re.match(r"^[A-Za-z_]", tok):
            return Expr.of(tok)
        raise QueryParseError(f"unexpected token {tok!r} in {text!r}")

    def cmp_atom() -> Expr:
        e = atom()
        if peek() == "<":
            take()
            return _expand_lt(e, take(), columns, text)
        return e

    def and_level() -> Expr:
        e = cmp_atom()
        while peek() == "&":
            take()
            e = e & cmp_atom()
        return e

    def xor_level() -> Expr:
        e = and_level()
        while peek() == "^":
            take()
            e = e ^ and_level()
        return e

    def or_level() -> Expr:
        e = xor_level()
        while peek() == "|":
            take()
            e = e | xor_level()
        return e

    e = or_level()
    if idx != len(tokens):
        raise QueryParseError(f"trailing tokens {tokens[idx:]} in {text!r}")
    return e


# ---------------------------------------------------------------------------
# Arithmetic query forms: sum(col), col + col, col - col
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArithQuery:
    """A parsed arithmetic query over registered integer columns.

    op: 'read' (a bare column inside sum()), 'add', or 'sub'.
    cols: the 1 or 2 column names involved.
    aggregate: True for sum(...) — the result is the scalar
        sum_j 2**j * popcount(result plane j); False for a bare
        `a + b`, whose materialized value is the result plane stack.
    """

    op: str
    cols: Tuple[str, ...]
    aggregate: bool


_NAME = r"[A-Za-z_][\w./:-]*"
# `-` is a legal name character ("weekly-total" is ONE catalog name). A
# whitespace-preceded `-` always subtracts (`a - b`); a tight `a-b`
# tokenizes as one hyphenated name and is disambiguated by longest-match
# against the catalog (`_hyphen_sub`): a fully registered name stays a
# boolean leaf, otherwise a split whose sides are both registered integer
# columns reads as subtraction. `+` is never a name char.
_OP = r"(?P<op>\+|(?<=\s)-)"
_SUM_RE = re.compile(
    rf"^\s*sum\s*\(\s*(?P<a>{_NAME})\s*(?:{_OP}\s*(?P<b>{_NAME})\s*)?\)\s*$")
_ADDSUB_RE = re.compile(
    rf"^\s*(?P<a>{_NAME})\s*{_OP}\s*(?P<b>{_NAME})\s*$")
_BARE_NAME_RE = re.compile(rf"^{_NAME}$")


def _hyphen_sub(name: str, columns: Optional[Mapping[str, int]],
                names: Optional[Container[str]]) -> Optional[ArithQuery]:
    """Longest-match disambiguation of a tight hyphenated name.

    A fully registered bitvector (`names`, usually the catalog) or column
    name always wins — `weekly-total` stays ONE leaf even if `weekly` and
    `total` happen to be columns. Otherwise try each `-` split point,
    longest left operand first, and read `colA-colB` as subtraction when
    both sides are registered integer columns.
    """
    if names is not None and name in names:
        return None
    if not columns or name in columns or "-" not in name:
        return None
    cuts = [i for i, ch in enumerate(name) if ch == "-"]
    for i in reversed(cuts):
        a, b = name[:i], name[i + 1:]
        if a in columns and b in columns:
            if columns[a] != columns[b]:
                raise QueryParseError(
                    f"width mismatch in {name!r}: {columns[a]} vs "
                    f"{columns[b]}")
            return ArithQuery("sub", (a, b), False)
    return None


def parse_any(text: str, columns: Optional[Mapping[str, int]] = None,
              names: Optional[Container[str]] = None
              ) -> Union[Expr, ArithQuery]:
    """Parse either a boolean query or an arithmetic form.

    `sum(...)` is always arithmetic. A bare `a + b` / `a - b` is
    arithmetic only when both names are registered columns — names may
    legally contain `-`, so `weekly-total` (one hyphenated catalog name,
    checked against `names`) stays a boolean leaf; a tight `colA-colB`
    that is NOT itself registered but splits into two registered columns
    reads as subtraction (`_hyphen_sub` longest-match).
    """
    m = _SUM_RE.match(text)
    if m:
        a, op, b = m.group("a"), m.group("op"), m.group("b")
        cols = columns or {}
        if op is not None:
            if a not in cols or b not in cols:
                raise QueryParseError(
                    f"sum() needs registered integer columns in {text!r}")
            if cols[a] != cols[b]:
                raise QueryParseError(
                    f"width mismatch in {text!r}: {cols[a]} vs {cols[b]}")
            return ArithQuery("add" if op == "+" else "sub", (a, b), True)
        if a in cols:
            return ArithQuery("read", (a,), True)
        hy = _hyphen_sub(a, cols, names)
        if hy is not None:
            return ArithQuery(hy.op, hy.cols, True)
        raise QueryParseError(
            f"sum() needs registered integer columns in {text!r}")
    m = _ADDSUB_RE.match(text)
    if m and columns:
        a, op, b = m.group("a"), m.group("op"), m.group("b")
        if a in columns and b in columns:
            if columns[a] != columns[b]:
                raise QueryParseError(
                    f"width mismatch in {text!r}: {columns[a]} vs "
                    f"{columns[b]}")
            return ArithQuery("add" if op == "+" else "sub", (a, b), False)
    bare = text.strip()
    if "-" in bare and _BARE_NAME_RE.match(bare):
        hy = _hyphen_sub(bare, columns, names)
        if hy is not None:
            return hy
    return parse_query(text, columns)


# ---------------------------------------------------------------------------
# Canonicalization: leaf rows -> IN0..INk in first-visit order
# ---------------------------------------------------------------------------


def canonicalize(expr: Expr) -> Tuple[Expr, List[str]]:
    """Rename leaves to positional IN-names; returns (canonical, bindings).

    `bindings[i]` is the catalog row that canonical input `IN{i}` stands
    for. Repeated leaves map to the same input, so structure is preserved
    and the compiler's CSE still sees shared subexpressions.
    """
    order: Dict[str, int] = {}

    def go(e: Expr) -> Expr:
        if e.op == "row":
            if e.row not in order:
                order[e.row] = len(order)
            return Expr.of(f"{_IN_PREFIX}{order[e.row]}")
        return Expr(e.op, tuple(go(a) for a in e.args))

    canon = go(expr)
    return canon, list(order)


def _canon_leaves(e: Expr, acc: Optional[set] = None) -> set:
    """Distinct leaf row names of a (canonical) expression DAG."""
    if acc is None:
        acc = set()
    if e.op == "row":
        acc.add(e.row)
    else:
        for a in e.args:
            _canon_leaves(a, acc)
    return acc


@dataclasses.dataclass(frozen=True)
class Plan:
    """A compiled, costed query plan over canonical inputs IN0..INk.

    Boolean plans write the single row DST; arithmetic plans write one row
    per result bit plane (`outputs`, LSB-first). Whether a query's served
    value is the plane stack or the weighted popcount scalar is the
    scheduler's per-query result mode, not a plan property — `sum(a + b)`
    and a bare `a + b` share one cached plan.

    `lowered` is the plan's register-machine form (`core.lowering`): row
    names resolved to plane indices plus the static opcode table. Caching
    it here means the scheduler dispatches a plan-group straight into the
    scan VM / Pallas megakernel with zero per-batch lowering work, and
    every plan lowered to the same (n_cmds, n_rows) shape shares one jitted
    executable.

    The optimizer records its decisions here: `backend` is the per-plan
    dispatch choice ("interp"/"scan"/"pallas"; None = scheduler default),
    `cost` the full `PlanCost` breakdown, `n_aaps_unopt` what the
    unoptimized pipeline would have spent (always >= `n_aaps` — the
    original candidate competes in every compile-off), and `canon` the
    winning canonical DAG (what the scheduler's cross-query CSE pass
    rebinds; None for arithmetic plans, which it never rewrites).
    """

    key: Tuple                      # expr_key of the canonical DAG
    program: Program                # writes `outputs`, reads IN0..INk
    n_inputs: int
    n_temp_rows: int
    latency_ns_per_block: float     # one 8KB-row-block execution
    energy_nj_per_block: float
    outputs: Tuple[str, ...] = (DST,)
    lowered: Optional[lowering.LoweredProgram] = None
    backend: Optional[str] = None
    cost: Optional[PlanCost] = None
    n_aaps_unopt: Optional[int] = None
    canon: Optional[Expr] = None

    @property
    def n_aaps(self) -> int:
        return self.program.n_aap


@dataclasses.dataclass
class PlanCache:
    """Bounded LRU expr_key -> Plan memo, with the optimize/cost stages.

    Two tables: `_plans` maps the *winning* canonical key to its compiled
    `Plan` (bounded at `capacity`, LRU-evicted, `evictions`-counted), and
    `_route` maps as-written canonical keys to (winner key, binding
    permutation) so operand-order variants land on one shared plan without
    recompiling. On a route miss the cache reorders the DAG through the
    attached `QueryOptimizer`, compiles BOTH candidates, and keeps the one
    with fewer AAPs — `compiles` counts these compile events (a structural
    hit on the reordered key is a miss that compiles nothing).

    The legacy integer counters (`hits`/`misses`) are always maintained;
    when a `repro.obs.MetricsRegistry` is attached (`attach_metrics`, wired
    by the scheduler from `QueryService(telemetry=...)`) every hit/miss/
    eviction also lands on the registry's `plan_cache_{hits,misses,
    evictions}_total` counters — the single stat surface
    `QueryService.stats()` reads.
    """

    timing: timing_model.DramTiming = timing_model.DDR3_1600
    energy: energy_model.EnergyModel = energy_model.DEFAULT_ENERGY
    optimizer: Optional[QueryOptimizer] = None
    capacity: Optional[int] = 1024

    def __post_init__(self):
        self._plans: "OrderedDict[Tuple, Plan]" = OrderedDict()
        # as-written key -> (winner key, perm); new_bindings[i] =
        # old_bindings[perm[i]]. Bounded at 4x capacity; stale entries
        # (winner evicted) are dropped lazily on lookup.
        self._route: "OrderedDict[Tuple, Tuple[Tuple, Tuple[int, ...]]]" \
            = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.compiles = 0
        self.evictions = 0
        from repro.obs.metrics import _NULL_INSTRUMENT

        self._m_hits = _NULL_INSTRUMENT
        self._m_misses = _NULL_INSTRUMENT
        self._m_evictions = _NULL_INSTRUMENT

    def attach_metrics(self, registry) -> None:
        """Mirror hit/miss/eviction counts onto `registry` from now on."""
        self._m_hits = registry.counter("plan_cache_hits_total")
        self._m_misses = registry.counter("plan_cache_misses_total")
        self._m_evictions = registry.counter("plan_cache_evictions_total")

    def __len__(self) -> int:
        return len(self._plans)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def _insert(self, key: Tuple, plan: Plan) -> None:
        self._plans[key] = plan
        self._plans.move_to_end(key)
        if self.capacity is not None:
            while len(self._plans) > self.capacity:
                self._plans.popitem(last=False)
                self.evictions += 1
                self._m_evictions.inc()

    def _set_route(self, key0: Tuple, wkey: Tuple,
                   perm: Tuple[int, ...]) -> None:
        self._route[key0] = (wkey, perm)
        self._route.move_to_end(key0)
        if self.capacity is not None:
            while len(self._route) > 4 * self.capacity:
                self._route.popitem(last=False)

    def _finish(self, canon: Expr, res: CompileResult, key: Tuple,
                n_aaps_unopt: int) -> Plan:
        # n_inputs counts the *bound* canonical leaves, not the rows the
        # compiled program happens to activate: algebraic simplification can
        # eliminate a leaf entirely (`IN0 | (IN0 & IN1)` compiles to a copy
        # of IN0), and scanning the command stream for the IN prefix would
        # then disagree with the planner's bindings and break the
        # scheduler's input placement. The canonical DAG always carries
        # every leaf, so its leaf count == len(bindings) by construction
        # (asserted in BoundPlan).
        n_inputs = len(_canon_leaves(canon))
        program = res.program
        opt = self.optimizer
        plan = Plan(
            key=key,
            program=program,
            n_inputs=n_inputs,
            n_temp_rows=res.n_temp_rows,
            latency_ns_per_block=timing_model.program_latency_ns(
                program, self.timing),
            energy_nj_per_block=energy_model.program_energy_nj(
                program, self.energy),
            lowered=lowering.lower(program),
            backend=opt.backend(program) if opt is not None else None,
            cost=(opt.cost(program, n_inputs, 1)
                  if opt is not None else None),
            n_aaps_unopt=n_aaps_unopt,
            canon=canon,
        )
        self._insert(key, plan)
        return plan

    def lookup(self, canon: Expr) -> Tuple[Plan, bool, Tuple[int, ...]]:
        """Return (plan, was_hit, perm); optimizes + compiles on miss.

        `perm` maps the caller's first-visit bindings onto the winning
        plan's canonical inputs: bind IN{i} to `bindings[perm[i]]`. The
        reordered candidate can also *drop* leaves (XOR parity, chain
        idempotence), in which case len(perm) < len(bindings).
        """
        key0 = expr_key(canon)
        route = self._route.get(key0)
        if route is not None:
            wkey, perm = route
            plan = self._plans.get(wkey)
            if plan is not None:
                self._plans.move_to_end(wkey)
                self._route.move_to_end(key0)
                self.hits += 1
                self._m_hits.inc()
                return plan, True, perm
            del self._route[key0]       # stale: winner was evicted
        self.misses += 1
        self._m_misses.inc()
        ident = tuple(range(len(_canon_leaves(canon))))
        canon2, perm = canon, ident
        opt = self.optimizer
        if opt is not None:
            re2 = opt.reorder(canon)
            if expr_key(re2) != key0:
                canon2, names2 = canonicalize(re2)
                perm = tuple(int(n[len(_IN_PREFIX):]) for n in names2)
        key2 = expr_key(canon2)
        if key2 != key0:
            plan = self._plans.get(key2)
            if plan is not None:
                # structural hit: the reordered shape is already compiled
                # (an operand-order variant got here first) — a miss that
                # costs no compile.
                self._plans.move_to_end(key2)
                self._set_route(key0, key2, perm)
                return plan, False, perm
        # Compile-off: the as-written candidate always competes, so the
        # optimized pipeline can never emit more AAPs than the plain one.
        self.compiles += 1
        res1: CompileResult = compile_expr_fused(canon, DST)
        wkey, wcanon, wres, wperm = key0, canon, res1, ident
        if key2 != key0:
            res2 = compile_expr_fused(canon2, DST)
            if res2.program.n_aap <= res1.program.n_aap:
                # ties go to the reordered shape: it is the convergent key
                # that operand-order variants of this query also reach
                wkey, wcanon, wres, wperm = key2, canon2, res2, perm
        plan = self._finish(wcanon, wres, wkey,
                            n_aaps_unopt=res1.program.n_aap)
        self._set_route(key0, wkey, wperm)
        return plan, False, wperm

    def lookup_arith(self, op: str, n_bits: int) -> Tuple[Plan, bool]:
        """Memoized arithmetic microprogram plan, keyed on (op, width).

        The canonical shape binds the first operand's planes to
        IN0..IN{n-1} and (for add/sub) the second's to IN{n}..IN{2n-1};
        outputs are OUT0..OUT{n-1} LSB-first. Every tenant's `sum(col)`
        over an equal-width column — and sum-wrapped vs bare forms of the
        same op — hit the same entry.
        """
        key = ("arith", op, n_bits)
        plan = self._plans.get(key)
        if plan is not None:
            self._plans.move_to_end(key)
            self.hits += 1
            self._m_hits.inc()
            return plan, True
        self.misses += 1
        self._m_misses.inc()
        self.compiles += 1
        if op == "read":
            res = arith_compiler.plane_readout_program(
                n_bits, _IN_PREFIX, DST)
            program = res.program
            n_inputs = n_bits
        elif op in ("add", "sub"):
            res = arith_compiler.ripple_add_program(
                n_bits, "XA", "XB", DST, sub=(op == "sub"))
            rename = {f"XA{j}": f"{_IN_PREFIX}{j}" for j in range(n_bits)}
            rename.update({f"XB{j}": f"{_IN_PREFIX}{n_bits + j}"
                           for j in range(n_bits)})
            program = arith_compiler.rename_rows(res.program, rename)
            n_inputs = 2 * n_bits
        else:
            raise ValueError(f"unknown arithmetic op {op!r}")
        opt = self.optimizer
        plan = Plan(
            key=key,
            program=program,
            n_inputs=n_inputs,
            n_temp_rows=res.n_temp_rows,
            latency_ns_per_block=timing_model.program_latency_ns(
                program, self.timing),
            energy_nj_per_block=energy_model.program_energy_nj(
                program, self.energy),
            outputs=tuple(res.outputs),
            lowered=lowering.lower(program),
            backend=opt.backend(program) if opt is not None else None,
            cost=(opt.cost(program, n_inputs, len(res.outputs))
                  if opt is not None else None),
            n_aaps_unopt=program.n_aap,
        )
        self._insert(key, plan)
        return plan, False


@dataclasses.dataclass
class BoundPlan:
    """A cached plan bound to one query's actual catalog rows."""

    plan: Plan
    bindings: List[str]             # bindings[i] backs IN{i}
    cache_hit: bool

    def __post_init__(self):
        # Eliminated leaves stay bound (the scheduler still places their
        # rows), so the plan's input arity and the bindings must agree.
        assert self.plan.n_inputs == len(self.bindings), (
            f"plan expects {self.plan.n_inputs} inputs but query bound "
            f"{len(self.bindings)} rows")

    def input_map(self) -> Dict[str, str]:
        return {f"{_IN_PREFIX}{i}": row
                for i, row in enumerate(self.bindings)}


@dataclasses.dataclass
class Planner:
    """Parse + canonicalize + compile-with-memo front half of the service.

    `telemetry` (a `repro.obs.Telemetry`, wired by the scheduler) makes
    `plan` emit the parse -> plan_cache -> bind span chain of each query's
    trace; the default `NULL_TELEMETRY` path does no tracing work.
    """

    cache: PlanCache = dataclasses.field(default_factory=PlanCache)
    telemetry: object = None

    def __post_init__(self):
        if self.telemetry is None:
            from repro.obs.telemetry import NULL_TELEMETRY

            self.telemetry = NULL_TELEMETRY

    @property
    def compile_count(self) -> int:
        """Compile events actually performed (<= cache misses: a miss
        that structurally hits the reordered key compiles nothing)."""
        return self.cache.compiles

    def plan(self, query: Union[str, Expr, ArithQuery],
             columns: Optional[Mapping[str, int]] = None,
             names: Optional[Container[str]] = None) -> BoundPlan:
        tel = self.telemetry
        if not tel.tracing:
            return self._plan(query, columns, names)
        tr = tel.tracer
        with tr.span("plan"):
            return self._plan(query, columns, names, tr)

    def _plan(self, query: Union[str, Expr, ArithQuery],
              columns: Optional[Mapping[str, int]],
              names: Optional[Container[str]] = None,
              tr=None) -> BoundPlan:
        if tr is not None:
            tr.begin("parse")
        if isinstance(query, str):
            parsed: Union[Expr, ArithQuery] = parse_any(query, columns,
                                                        names)
        else:
            parsed = query
        if tr is not None:
            tr.end()
            tr.begin("plan_cache")
        if isinstance(parsed, ArithQuery):
            bp = self._plan_arith(parsed, columns or {})
            if tr is not None:
                tr.end()
                tr.instant("cache_hit" if bp.cache_hit else "cache_miss")
            return bp
        canon, bindings = canonicalize(parsed)
        plan, hit, perm = self.cache.lookup(canon)
        # the winning plan's canonical input i binds the as-written
        # query's perm[i]-th first-visit leaf (identity when the original
        # candidate won; a reordering/leaf-dropping map otherwise)
        bindings = [bindings[p] for p in perm]
        if tr is not None:
            tr.end()
            tr.instant("cache_hit" if hit else "cache_miss")
            tr.begin("bind", n_inputs=plan.n_inputs)
        bp = BoundPlan(plan=plan, bindings=bindings, cache_hit=hit)
        if tr is not None:
            tr.end()
        return bp

    def _plan_arith(self, aq: ArithQuery,
                    columns: Mapping[str, int]) -> BoundPlan:
        widths = []
        for c in aq.cols:
            if c not in columns:
                raise QueryParseError(
                    f"unknown integer column {c!r} in arithmetic query")
            widths.append(columns[c])
        if len(set(widths)) != 1:
            raise QueryParseError(
                f"width mismatch in arithmetic query over {aq.cols}")
        n_bits = widths[0]
        bindings = [plane_name(c, j) for c in aq.cols for j in range(n_bits)]
        plan, hit = self.cache.lookup_arith(aq.op, n_bits)
        return BoundPlan(plan=plan, bindings=bindings, cache_hit=hit)
