"""Query planner: text -> Expr DAG -> fused AAP program, memoized.

The planner turns a query string over catalog names (`"(mon | tue) & male"`)
into a `core.compiler.Expr` DAG, *canonicalizes* the leaf names to
positional inputs `IN0..INk`, and compiles the canonical DAG once with
`compile_expr_fused`. Plans are memoized in a `PlanCache` keyed by the
structural `expr_key` of the canonical DAG, so

  * the same query twice compiles once (hit counter-verified by tests), and
  * structurally identical queries over *different* catalog vectors share
    one plan — e.g. every tenant's 7-way weekly OR-tree is one cached
    program, which is also what lets the scheduler batch them into one
    bank-group dispatch (the controller broadcasts a single AAP sequence;
    each bank holds a different tenant's rows).

A `Plan` carries the compiled program plus its derived costs: AAP count,
per-row-block modeled latency (`core.timing`) and energy (`core.energy`).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple, Union

from repro.core import energy as energy_model
from repro.core import timing as timing_model
from repro.core.commands import Program
from repro.core.compiler import (CompileResult, Expr, compile_expr_fused,
                                 expr_key)

DST = "OUT"
_IN_PREFIX = "IN"


class QueryParseError(ValueError):
    pass


# ---------------------------------------------------------------------------
# Parser: `~` > `&` > `^` > `|`, parens, maj(a,b,c); names may contain
# word chars plus . / : - (tenant-scoped names like "t3/wed").
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(r"\s*([A-Za-z_][\w./:-]*|[()&|^~,])")


def _tokenize(text: str) -> List[str]:
    tokens, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            if text[pos:].strip():
                raise QueryParseError(
                    f"bad character {text[pos:].strip()[0]!r} in query "
                    f"{text!r}")
            break
        tokens.append(m.group(1))
        pos = m.end()
    return tokens


def parse_query(text: str) -> Expr:
    """Parse a query string over catalog names into an Expr DAG."""
    tokens = _tokenize(text)
    idx = 0

    def peek() -> Optional[str]:
        return tokens[idx] if idx < len(tokens) else None

    def take(expected: Optional[str] = None) -> str:
        nonlocal idx
        if idx >= len(tokens):
            raise QueryParseError(f"unexpected end of query {text!r}")
        tok = tokens[idx]
        if expected is not None and tok != expected:
            raise QueryParseError(
                f"expected {expected!r} but got {tok!r} in {text!r}")
        idx += 1
        return tok

    def atom() -> Expr:
        tok = take()
        if tok == "(":
            e = or_level()
            take(")")
            return e
        if tok == "~":
            return ~atom()
        if tok == "maj" and peek() == "(":
            take("(")
            a = or_level()
            take(",")
            b = or_level()
            take(",")
            c = or_level()
            take(")")
            return Expr("maj3", (a, b, c))
        if re.match(r"^[A-Za-z_]", tok):
            return Expr.of(tok)
        raise QueryParseError(f"unexpected token {tok!r} in {text!r}")

    def and_level() -> Expr:
        e = atom()
        while peek() == "&":
            take()
            e = e & atom()
        return e

    def xor_level() -> Expr:
        e = and_level()
        while peek() == "^":
            take()
            e = e ^ and_level()
        return e

    def or_level() -> Expr:
        e = xor_level()
        while peek() == "|":
            take()
            e = e | xor_level()
        return e

    e = or_level()
    if idx != len(tokens):
        raise QueryParseError(f"trailing tokens {tokens[idx:]} in {text!r}")
    return e


# ---------------------------------------------------------------------------
# Canonicalization: leaf rows -> IN0..INk in first-visit order
# ---------------------------------------------------------------------------


def canonicalize(expr: Expr) -> Tuple[Expr, List[str]]:
    """Rename leaves to positional IN-names; returns (canonical, bindings).

    `bindings[i]` is the catalog row that canonical input `IN{i}` stands
    for. Repeated leaves map to the same input, so structure is preserved
    and the compiler's CSE still sees shared subexpressions.
    """
    order: Dict[str, int] = {}

    def go(e: Expr) -> Expr:
        if e.op == "row":
            if e.row not in order:
                order[e.row] = len(order)
            return Expr.of(f"{_IN_PREFIX}{order[e.row]}")
        return Expr(e.op, tuple(go(a) for a in e.args))

    canon = go(expr)
    return canon, list(order)


@dataclasses.dataclass(frozen=True)
class Plan:
    """A compiled, costed query plan over canonical inputs IN0..INk."""

    key: Tuple                      # expr_key of the canonical DAG
    program: Program                # writes DST, reads IN0..INk
    n_inputs: int
    n_temp_rows: int
    latency_ns_per_block: float     # one 8KB-row-block execution
    energy_nj_per_block: float

    @property
    def n_aaps(self) -> int:
        return self.program.n_aap


@dataclasses.dataclass
class PlanCache:
    """expr_key -> Plan memo with hit/miss counters."""

    timing: timing_model.DramTiming = timing_model.DDR3_1600
    energy: energy_model.EnergyModel = energy_model.DEFAULT_ENERGY

    def __post_init__(self):
        self._plans: Dict[Tuple, Plan] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._plans)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def lookup(self, canon: Expr) -> Tuple[Plan, bool]:
        """Return (plan, was_hit); compiles and inserts on miss."""
        key = expr_key(canon)
        plan = self._plans.get(key)
        if plan is not None:
            self.hits += 1
            return plan, True
        self.misses += 1
        result: CompileResult = compile_expr_fused(canon, DST)
        n_inputs = len({a for a in result.program.activates()
                        if a.startswith(_IN_PREFIX)})
        plan = Plan(
            key=key,
            program=result.program,
            n_inputs=n_inputs,
            n_temp_rows=result.n_temp_rows,
            latency_ns_per_block=timing_model.program_latency_ns(
                result.program, self.timing),
            energy_nj_per_block=energy_model.program_energy_nj(
                result.program, self.energy),
        )
        self._plans[key] = plan
        return plan, False


@dataclasses.dataclass
class BoundPlan:
    """A cached plan bound to one query's actual catalog rows."""

    plan: Plan
    bindings: List[str]             # bindings[i] backs IN{i}
    cache_hit: bool

    def input_map(self) -> Dict[str, str]:
        return {f"{_IN_PREFIX}{i}": row
                for i, row in enumerate(self.bindings)}


@dataclasses.dataclass
class Planner:
    """Parse + canonicalize + compile-with-memo front half of the service."""

    cache: PlanCache = dataclasses.field(default_factory=PlanCache)

    @property
    def compile_count(self) -> int:
        """Compilations actually performed (== cache misses)."""
        return self.cache.misses

    def plan(self, query: Union[str, Expr]) -> BoundPlan:
        expr = parse_query(query) if isinstance(query, str) else query
        canon, bindings = canonicalize(expr)
        plan, hit = self.cache.lookup(canon)
        return BoundPlan(plan=plan, bindings=bindings, cache_hit=hit)
