"""Named-bitvector catalog with DRAM row placement.

The query service operates over *named* bitvectors ("the Tuesday activity
bitmap of tenant 3", "the gender attribute bitmap"). The catalog is the
binding between those names and (a) the packed uint32 words that hold the
bits and (b) where those bits live in the modeled DRAM — each registered
vector is placed into subarray rows through `core.allocator.DramAllocator`
(paper §6.2.4 OS support), so co-registered vectors of one tenant land in
one subarray and stay all-FPM reachable while capacity lasts.

Catalog names become the D-group row names of compiled query programs, so
they must stay clear of the reserved B/C-group addresses and the compiler's
temp/canonical-input namespaces — `register` validates that.

In distributed mode (`attach_cluster`) the catalog additionally records a
`ChipPlacement` per vector: its words are sharded over the chip mesh of a
`core.cluster.ChipCluster` and the sharded device copy is cached on the
entry. Affinity groups stay chip-local — group members share one shard
layout, so corresponding word-slots co-reside and queries over a group
never move operand bits between chips. An elastic rescale re-attaches a
new cluster and re-places every entry (slot contents are invariant; only
the slot->chip assignment changes).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp

from repro.core.allocator import DramAllocator, RowHandle
from repro.core.bitplane import BitVector, n_words, pack_bits, tail_mask

# Reserved row-name patterns: B/C-group addresses, designated/DCC rows, the
# compiler's temp rows, and the planner's canonical input/output names.
_RESERVED_RE = re.compile(
    r"^(B\d+|C[01]|T[0-3]|DCC[01]|TMP\d*|IN\d+|OUT)$")
_NAME_RE = re.compile(r"^[A-Za-z_][\w./:-]*$")


class CatalogError(KeyError):
    pass


def plane_name(column: str, j: int) -> str:
    """Catalog row name of bit-plane j of a registered integer column.

    The one naming convention shared by the service (`register_column`),
    the planner (arithmetic query expansion), and range-scan lowering.
    """
    return f"{column}.b{j}"


@dataclasses.dataclass(frozen=True)
class ChipPlacement:
    """Where one bitvector's word-shards live on the chip mesh.

    In distributed mode every vector is word-partitioned over
    ``n_chips * local_banks`` slots (`core.cluster.ChipCluster`); slot s
    lives on chip ``s // local_banks``. Vectors of one affinity `group`
    share this layout, so slot s of *every* group member is resident on
    the same chip — queries over a group combine operands chip-locally
    and nothing but reduction scalars crosses the chip boundary.
    """

    n_chips: int
    local_banks: int          # slot rows resident per chip
    local_words: int          # packed words per slot (after padding)
    group: Optional[str] = None

    @property
    def slots(self) -> int:
        return self.n_chips * self.local_banks

    def chip_of_slot(self, slot: int) -> int:
        return slot // self.local_banks


@dataclasses.dataclass
class CatalogEntry:
    """One registered bitvector: packed words + modeled DRAM placement."""

    name: str
    words: jax.Array          # (n_words,) uint32, LSB-first packed
    n_bits: int
    handle: RowHandle         # (bank, subarray, row) placement
    group: Optional[str] = None
    #: distributed mode only: the (chip, bank, word) sharded device copy
    #: and its layout record (None until a cluster is attached)
    shards: Optional[jax.Array] = None
    placement: Optional[ChipPlacement] = None

    @property
    def n_row_blocks(self) -> int:
        """How many 8KB DRAM rows the vector spans (>= 1)."""
        return self.handle.n_rows


@dataclasses.dataclass
class Catalog:
    """Registry of named bitvectors, placed via the DRAM allocator.

    All vectors in one catalog share a bit domain (`n_bits`) — queries
    combine arbitrary subsets of them, so mixed widths would be a silent
    correctness bug; the first registration pins the width.
    """

    allocator: DramAllocator = dataclasses.field(default_factory=DramAllocator)

    def __post_init__(self):
        self._entries: Dict[str, CatalogEntry] = {}
        self.n_bits: Optional[int] = None
        # integer columns: name -> bit width; planes live as ordinary
        # entries under plane_name(name, j). The planner reads this map to
        # expand arithmetic query forms (sum/+/-/<) into plane programs.
        self.columns: Dict[str, int] = {}
        # distributed mode: the ChipCluster every entry is placed onto
        # (None = single-process catalog, the pre-cluster behavior)
        self._cluster = None
        self._mask_shards: Optional[jax.Array] = None
        # ECC: running XOR parity plane per affinity group (None key =
        # ungrouped), maintained incrementally at registration time —
        # `verify_parity` recomputes from scratch and cross-checks, the
        # integrity probe of the service's "ecc" reliability mode
        self._parity: Dict[Optional[str], jax.Array] = {}

    # -- registration -------------------------------------------------------

    def register(self, name: str, value, n_bits: Optional[int] = None,
                 group: Optional[str] = None) -> CatalogEntry:
        """Register packed uint32 words (or a BitVector) under `name`.

        `group` is the allocator affinity group: vectors registered in one
        group co-locate in one subarray while rows last (all-FPM staging).
        """
        if not _NAME_RE.match(name) or _RESERVED_RE.match(name):
            raise CatalogError(f"invalid or reserved catalog name {name!r}")
        if name in self._entries:
            raise CatalogError(f"catalog name {name!r} already registered")
        if isinstance(value, BitVector):
            words, n_bits = value.words, value.n_bits
        else:
            words = jnp.asarray(value, jnp.uint32)
            if n_bits is None:
                n_bits = int(words.shape[-1]) * 32
        if words.ndim != 1 or words.shape[0] != n_words(n_bits):
            raise CatalogError(
                f"{name!r}: expected ({n_words(n_bits)},) packed words for "
                f"{n_bits} bits, got shape {tuple(words.shape)}")
        if self.n_bits is None:
            self.n_bits = n_bits
        elif n_bits != self.n_bits:
            raise CatalogError(
                f"{name!r}: domain {n_bits} != catalog domain {self.n_bits}")
        handle = self.allocator.alloc(name, n_bits, group=group)
        entry = CatalogEntry(name, words, n_bits, handle, group=group)
        self._entries[name] = entry
        prev = self._parity.get(group)
        cur = jnp.asarray(words, jnp.uint32)
        self._parity[group] = cur if prev is None else prev ^ cur
        if self._cluster is not None:
            self._place(entry)
        return entry

    def register_bits(self, name: str, bits, group: Optional[str] = None
                      ) -> CatalogEntry:
        """Register from a bool/0-1 bit array (packs it first)."""
        bits = jnp.asarray(bits)
        return self.register(name, pack_bits(bits), bits.shape[-1], group)

    def register_column(self, name: str, planes, n_values: int, n_bits: int,
                        group: Optional[str] = None) -> None:
        """Register an integer column: one entry per vertical bit plane.

        `planes` is the (n_bits, n_words) LSB-first plane stack of a
        `VerticalColumn`; plane j lands under `plane_name(name, j)` and the
        column's width is recorded in `self.columns` so arithmetic queries
        (`sum(name)`, `name + other`, `name < K`) can be expanded.
        """
        if name in self.columns:
            raise CatalogError(f"column {name!r} already registered")
        for j in range(n_bits):
            self.register(plane_name(name, j), planes[j], n_values,
                          group=group)
        self.columns[name] = n_bits

    # -- lookup -------------------------------------------------------------

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, name: str) -> CatalogEntry:
        try:
            return self._entries[name]
        except KeyError:
            raise CatalogError(f"unknown catalog name {name!r}") from None

    def names(self) -> List[str]:
        return list(self._entries)

    def row_state(self, names: Iterable[str]) -> Dict[str, jax.Array]:
        """Engine-ready {row name -> words} for a subset of entries."""
        return {n: self.get(n).words for n in names}

    def mask(self) -> jax.Array:
        """Tail mask zeroing the padding bits of the last packed word."""
        assert self.n_bits is not None, "empty catalog has no domain"
        return jnp.asarray(tail_mask(self.n_bits))

    # -- ECC parity planes ----------------------------------------------------

    def parity_plane(self, group: Optional[str] = None) -> jax.Array:
        """The maintained XOR parity of one affinity group's vectors.

        Word-level XOR over the *unsharded* packed words, so the plane is
        invariant across elastic rescales (only slot->chip assignment
        moves, never the words) — what lets the chaos suite assert catalog
        integrity after a chip-kill recovery.
        """
        if group not in self._parity:
            raise CatalogError(f"no vectors registered in group {group!r}")
        return self._parity[group]

    def verify_parity(self) -> bool:
        """Recompute every group's XOR parity and cross-check the
        maintained planes — False means some registered vector's words
        were corrupted (or parity maintenance has a bug)."""
        fresh: Dict[Optional[str], jax.Array] = {}
        for entry in self._entries.values():
            w = jnp.asarray(entry.words, jnp.uint32)
            prev = fresh.get(entry.group)
            fresh[entry.group] = w if prev is None else prev ^ w
        if set(fresh) != set(self._parity):
            return False
        return all(bool(jnp.array_equal(self._parity[g], fresh[g]))
                   for g in fresh)

    # -- chip placement (distributed mode) ------------------------------------

    def _place(self, entry: CatalogEntry) -> None:
        cluster = self._cluster
        entry.shards = cluster.shard_words(entry.words)
        entry.placement = ChipPlacement(
            n_chips=cluster.n_chips, local_banks=cluster.local_banks,
            local_words=int(entry.shards.shape[-1]), group=entry.group)

    def attach_cluster(self, cluster) -> None:
        """Place every registered vector onto a `core.cluster.ChipCluster`.

        Called at service start and again after an elastic `rescale` —
        re-placement re-shards every entry onto the new mesh. The slot
        grid (`cluster.slots`) is invariant across rescales of one
        placement lineage, so the bits held by each slot never move
        between slots; only the slot->chip assignment changes.
        """
        self._cluster = cluster
        self._mask_shards = None
        for entry in self._entries.values():
            self._place(entry)

    @property
    def cluster(self):
        return self._cluster

    def shards(self, name: str) -> jax.Array:
        """The (n_chips, local_banks, local_words) sharded copy of a row."""
        entry = self.get(name)
        if entry.shards is None:
            if self._cluster is None:
                raise CatalogError(
                    f"{name!r} has no chip placement: no cluster attached")
            self._place(entry)
        return entry.shards

    def placement(self, name: str) -> Optional[ChipPlacement]:
        return self.get(name).placement

    def mask_shards(self) -> jax.Array:
        """`mask()` pushed through the cluster's word-shard layout."""
        assert self._cluster is not None, "no cluster attached"
        if self._mask_shards is None:
            self._mask_shards = self._cluster.shard_words(self.mask())
        return self._mask_shards

    # -- placement queries ----------------------------------------------------

    def psm_copies(self, srcs: Iterable[str], dst_group_rep: str) -> int:
        """Operand movements needing PSM for an op over `srcs` (§6.2.2)."""
        return self.allocator.psm_copies_for_op(list(srcs), dst_group_rep)
