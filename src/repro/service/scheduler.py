"""Batching scheduler: concurrent queries -> bank-parallel execution.

The scheduling insight mirrors the hardware: the memory controller can only
broadcast ONE AAP sequence at a time, but every bank applies it to its own
rows concurrently (paper §5.4/§7, `core.bankgroup`). So the scheduler groups
a batch's queries by their *canonical plan* — queries with the same program
shape (every tenant's weekly OR-tree, every range scan of the same width)
become one stacked dispatch where the "bank axis" is the query axis — and
executes each group through the plan's cached `core.lowering.LoweredProgram`
in a single VM dispatch: one constant-size executable per plan shape, one
kernel launch per plan-group. The dispatch backend is per plan — the
cost-based optimizer records "interp"/"scan"/"pallas" on each `Plan`
(`service.optimizer.choose_backend`), with `backend=` as the fallback
default for plans that carry no choice.

Before grouping, the batch runs the optimizer's cross-query sharing pass
(`_apply_cse`): bound sub-DAGs appearing in >= 2 queries compile once into
ephemeral `$cse{k}` planes, dispatched first, and consumers reference the
plane as an input leaf — a RowClone-style copy on the modeled bus instead
of recomputation. The pass keeps the rewrite only when it strictly lowers
the batch's total AAPs, so `BatchReport.total_aaps <= baseline_aaps`
always holds, and the modeled timeline charges shared work exactly once.

Three result modes per query (paper §8 workloads + the arithmetic layer):
  * `popcount`  — COUNT(*) of the predicate bitvector (the bitcount stays
    CPU-side in the paper; here it is one reduction over the masked result
    words).
  * `materialize` — the packed result itself: one word vector for boolean
    plans, the (n_bits, words) result-plane stack for arithmetic plans
    (feeds follow-up queries; the service registers derived vectors and
    derived columns from it).
  * `aggregate` — the scalar sum_j 2**j * popcount(output plane j): SUM()
    over an arithmetic plan's result planes. On a boolean plan this
    degenerates to popcount (one plane, weight 1). Non-materialize modes
    on an arithmetic plan all yield this scalar; `materialize` always
    returns the planes (that is what `materialize_column` builds on).

Latency is modeled, not measured: per 8KB row-block, placing a query's
operands in its bank costs serialized inter-bank transfers on the shared
internal bus (one AAP-time per operand row + one for result readout,
`core.timing`), while per-bank AAP compute (`Plan.latency_ns_per_block`)
overlaps across banks — the same copy/compute pipeline as
`core.bankgroup.pipeline_latency_ns`, lifted to query granularity. Energy
comes from `core.energy` command counts.

Distributed mode (``cluster=ChipCluster(...)``, `core.cluster`): the same
plan-grouping applies, but each group executes as ONE `shard_map` VM launch
over the catalog's chip-sharded vectors — plane tensor
``(n_rows, n_chips, local_banks, n_queries, local_words)``, chip axis on
the device mesh — and popcount/aggregate results reduce with a chip-axis
tree psum, so only count scalars ever cross a chip boundary. The timeline
model gains per-chip buses (transfers serialize per chip, chips are
parallel) plus a ceil(log2 chips)-hop reduction term.

`run_queries_unbatched` is the independent reference path (fresh compile per
query over its natural row names, one engine run per query, 1-bank serial
schedule); the batched scheduler must match it bit-for-bit (asserted by
tests/test_service.py and benchmarks/serve_qps.py) — in distributed mode
too, for every chip count (tests/test_cluster.py).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import arith_compiler, engine, lowering
from repro.core.bitplane import ROW_BITS
from repro.core.compiler import Expr, compile_expr_fused
from repro.core.timing import DDR3_1600, DramTiming
from repro.obs.telemetry import set_telemetry
from repro.ops.popcount import popcount_words
from repro.service.catalog import Catalog, plane_name
from repro.service.optimizer import (CSE_PREFIX, CseBatch, CseExplain,
                                     ExplainReport, PlanExplain, bind_expr,
                                     plan_group_cse)
from repro.service.planner import (DST, ArithQuery, BoundPlan, Plan, Planner,
                                   parse_any)

POPCOUNT = "popcount"
MATERIALIZE = "materialize"
AGGREGATE = "aggregate"


@dataclasses.dataclass
class Query:
    """One client request over catalog names."""

    query: Union[str, Expr, ArithQuery]
    mode: str = POPCOUNT
    tenant: Optional[str] = None

    def __post_init__(self):
        if self.mode not in (POPCOUNT, MATERIALIZE, AGGREGATE):
            raise ValueError(f"unknown result mode {self.mode!r}")


@dataclasses.dataclass
class QueryResult:
    """Outcome of one query: value + modeled cost accounting.

    One canonical shape across the three result modes. `scalar` is always
    populated — the weighted popcount sum_j 2**j * popcount(plane j),
    which for boolean plans is exactly the predicate popcount — because
    the grouped dispatch computes it for every group member anyway.
    `planes` is the canonical packed view of a materialized result: a
    ``(n_output_planes, n_words)`` uint32 array even for boolean plans
    (which used to return a bare word vector, one of three historical
    value shapes). `value` keeps the historical per-mode shape for
    existing callers: popcount/aggregate int, boolean-materialize 1-D
    words, arithmetic-materialize 2-D plane stack.
    """

    index: int                    # position in the submitted batch
    mode: str
    value: Union[int, np.ndarray]  # legacy per-mode shape (see above)
    latency_ns: float             # modeled batch-epoch -> completion
    bank: int
    cache_hit: bool
    n_aaps: int
    energy_nj: float
    tenant: Optional[str] = None
    chip: int = 0                 # distributed mode: serving chip
    #: weighted-popcount scalar, populated for EVERY mode
    scalar: Optional[int] = None

    @property
    def planes(self) -> np.ndarray:
        """Canonical ``(n_output_planes, n_words)`` packed result."""
        if self.mode != MATERIALIZE:
            raise ValueError(
                f"planes: {self.mode!r} query carries only the scalar; "
                "run with mode=MATERIALIZE for packed planes")
        v = np.asarray(self.value)
        return v[None] if v.ndim == 1 else v

    @property
    def words(self) -> np.ndarray:
        """Single-plane (boolean) materialized result as flat words."""
        p = self.planes
        if p.shape[0] != 1:
            raise ValueError(
                f"words: result has {p.shape[0]} planes (arithmetic "
                "query); use .planes")
        return p[0]


@dataclasses.dataclass
class BatchReport:
    """Aggregate view of one scheduler batch.

    `n_cse_planes` counts the batch's shared subexpression planes
    (computed once, consumed by >= 2 queries); `total_aaps` is the
    all-blocks modeled AAP spend including those defs, `baseline_aaps`
    what the unoptimized pipeline (no reordering, no sharing) would have
    spent — `total_aaps <= baseline_aaps` is an optimizer invariant.
    """

    results: List[QueryResult]
    makespan_ns: float
    n_banks: int
    n_plan_groups: int
    n_chips: int = 1
    n_cse_planes: int = 0
    total_aaps: int = 0
    baseline_aaps: int = 0

    @property
    def qps(self) -> float:
        if self.makespan_ns == 0.0:
            return 0.0
        return len(self.results) / (self.makespan_ns * 1e-9)

    def latency_percentile_ns(self, pct: float) -> float:
        lats = sorted(r.latency_ns for r in self.results)
        if not lats:
            return 0.0
        i = min(len(lats) - 1, int(math.ceil(pct / 100.0 * len(lats))) - 1)
        return lats[max(i, 0)]


@dataclasses.dataclass
class Scheduler:
    """Batches queries over the bank group with a modeled timeline."""

    catalog: Catalog
    planner: Planner = dataclasses.field(default_factory=Planner)
    n_banks: int = 8
    timing: DramTiming = DDR3_1600
    #: lowered-VM backend for plan-group dispatch: "scan" (lax.scan VM) or
    #: "pallas" (megakernel, whole plane resident in VMEM per dispatch)
    backend: str = "scan"
    #: distributed mode: a `core.cluster.ChipCluster` — plan-groups become
    #: ONE sharded shard_map launch over (chips x banks x queries) and
    #: popcounts aggregate with a chip-axis tree psum. None = the
    #: single-process path (one device, bank axis only).
    cluster: Optional["ChipCluster"] = None  # noqa: F821 (forward ref)
    #: TRA reliability mode (`core.errors.ReliabilityConfig`): "vote" runs
    #: every lowered plan-group k times with independent seeded fault draws
    #: and bitwise-votes the output planes; "ecc" dual-runs with a vote
    #: tie-break plus a catalog parity check per batch. Injection targets
    #: the single-process VM path; distributed deployments handle faults
    #: at chip granularity through `fault_tolerance` instead.
    reliability: Optional["ReliabilityConfig"] = None  # noqa: F821
    #: chip/straggler fault policy (`dist.fault_tolerance.FaultTolerance`):
    #: plan-group dispatches are timed, replayed on failure (after the
    #: recovery hook — QueryService installs an elastic rescale-down), and
    #: flagged when they straggle past the EMA threshold.
    fault_tolerance: Optional["FaultTolerance"] = None  # noqa: F821
    #: observability sink (`repro.obs.Telemetry`): span tree + modeled
    #: timeline per batch when tracing, registry counters/histograms when
    #: metering. None = `NULL_TELEMETRY` (both off, zero-allocation path).
    telemetry: Optional["Telemetry"] = None  # noqa: F821

    def __post_init__(self):
        self.queries_served = 0
        self.total_modeled_ns = 0.0
        self.total_energy_nj = 0.0
        self.parity_checks = 0
        self.cse_planes_built = 0
        self._group_seq = 0      # deterministic per-dispatch PRNG chain
        if self.telemetry is None:
            from repro.obs.telemetry import NULL_TELEMETRY

            self.telemetry = NULL_TELEMETRY
        # one stat surface: the planner's spans and the plan cache's
        # hit/miss counters land on the same sink as the scheduler's
        self.planner.telemetry = self.telemetry
        if self.telemetry.metering:
            m = self.telemetry.metrics
            self.planner.cache.attach_metrics(m)
            self._m_queries = m.counter("queries_total")
            self._m_batches = m.counter("batches_total")
            self._m_groups = m.counter("plan_groups_total")
            self._m_aaps = m.counter("aaps_total")
            self._m_energy = m.counter("modeled_energy_nj_total")
            self._m_modeled_ns = m.counter("modeled_ns_total")
            self._m_parity = m.counter("parity_checks_total")
            self._m_cse = m.counter("cse_planes_total")
            self._m_lat = m.histogram("modeled_latency_ns")
            self._m_wall = m.histogram("batch_wall_us")
        if (self.reliability is not None
                and self.reliability.mode != "none"
                and self.cluster is not None):
            raise ValueError(
                "reliability injection modes run on the single-process VM "
                "path; distributed deployments recover at chip granularity "
                "(fault_tolerance=...), not per-TRA")

    # -- plumbing -----------------------------------------------------------

    @property
    def _n_blocks(self) -> int:
        """Row-blocks every operand spans (catalog domain / 8KB row)."""
        assert self.catalog.n_bits is not None
        return max(1, math.ceil(self.catalog.n_bits / ROW_BITS))

    def _xfer_ns(self, plan: Plan) -> float:
        # place each operand row in the bank + read each result row back
        # out, all serialized on the shared internal bus (inter-bank
        # RowClone); arithmetic plans move one row per operand/result plane
        return self.timing.aap_ns * (plan.n_inputs + len(plan.outputs))

    def _operand_words(self, name: str,
                       cse_planes: Optional[Dict[str, jax.Array]]):
        """A bound operand's packed words: catalog row or shared plane."""
        if cse_planes is not None and name.startswith(CSE_PREFIX):
            return cse_planes[name]
        return self.catalog.get(name).words

    # -- functional execution ------------------------------------------------

    def _run_group(self, members: List[Tuple[int, BoundPlan]],
                   need_words: bool,
                   cse_planes: Optional[Dict[str, jax.Array]] = None
                   ) -> Tuple[Optional[np.ndarray], List[int], int]:
        """One stacked VM dispatch for all queries sharing a plan.

        Stacks each canonical input IN{i} across the group's queries into a
        leading query axis — exactly the bank-axis layout of
        `core.bankgroup.BankGroup` (one broadcast program, per-bank data) —
        and executes the plan's cached `LoweredProgram` through the scan VM
        or Pallas megakernel: the whole group is ONE kernel launch over a
        ``(n_rows, n_queries, n_words)`` plane tensor, no per-query
        tracing. Returns (masked result words (len(members), n_outputs,
        n_words) or None when no member materializes, per-query scalars,
        replicas run) — the scalar is sum_j 2**j * popcount(output plane
        j), which for single-output boolean plans is exactly the popcount.
        The reduction happens once per group, on device, so for scalar-only
        groups just len(members) ints cross to the host. Replicas is 1 on
        the clean path, k under vote, 2 or 3 under ecc — the multiplier the
        modeled timeline charges for mitigation.
        """
        if self.cluster is not None:
            words, scalars = self._run_group_sharded(members, need_words)
            return words, scalars, 1
        input_rows = [bp.input_map() for _, bp in members]
        data = {
            name: jnp.stack([self._operand_words(rows[name], cse_planes)
                             for rows in input_rows])
            for name in input_rows[0]
        }
        plan = members[0][1].plan
        # per-plan backend choice recorded by the optimizer wins over the
        # scheduler default (mitigated dispatch stays on the VM, where
        # fault injection lives)
        backend = plan.backend or self.backend
        rel = self.reliability
        replicas = 1
        rel_clean = rel is None or rel.mode == "none"
        if (not need_words and rel_clean and backend != "interp"
                and plan.lowered is not None):
            # count-only group: fused-reduction dispatch. The VM popcounts
            # each tail-masked output plane inside the kernel (VMEM scratch
            # on pallas — the planes never reach HBM) and only
            # (n_outputs, n_queries) int32 counts cross to the host, where
            # exact Python ints apply the 2**j aggregate weights.
            opt = getattr(self.planner.cache, "optimizer", None)
            if opt is not None:
                backend = opt.backend(plan.program, fused_reduce=True)
            counts = lowering.execute_lowered(
                plan.lowered, data, outputs=list(plan.outputs),
                backend=backend, reduce="popcount",
                mask=self.catalog.mask())
            cnp = np.asarray(jnp.stack([counts[o] for o in plan.outputs]))
            scalars = [sum(int(cnp[j, s]) << j
                           for j in range(len(plan.outputs)))
                       for s in range(len(members))]
            return None, scalars, 1
        if (rel is not None and rel.mode != "none"
                and plan.lowered is not None):
            out, replicas = self._run_reliable(plan, data)
        elif backend == "interp":
            # degenerate 1-2 command programs: eager micro-op interpreter,
            # a VM launch would cost more than the program
            out = engine.execute(plan.program, data,
                                 outputs=list(plan.outputs), lowered=False)
        elif plan.lowered is not None:
            out = lowering.execute_lowered(
                plan.lowered, data, outputs=list(plan.outputs),
                backend=backend)
        else:   # plans built outside the cache fall back to the engine
            out = engine.execute(plan.program, data,
                                 outputs=list(plan.outputs),
                                 backend=self.backend)
        mask = self.catalog.mask()
        # (n_outputs, len(members), n_words), output planes LSB-first
        masked = jnp.stack([out[o] & mask for o in plan.outputs])
        counts = np.asarray(popcount_words(masked, axis=-1))
        scalars = [sum(int(counts[j, s]) << j
                       for j in range(len(plan.outputs)))
                   for s in range(len(members))]
        words = (np.asarray(jnp.moveaxis(masked, 0, 1))
                 if need_words else None)
        return words, scalars, replicas

    def _run_reliable(self, plan: Plan, data: Dict[str, jax.Array]
                      ) -> Tuple[Dict[str, jax.Array], int]:
        """Mitigated dispatch: vote or ecc over the lowered program.

        Each plan-group consumes one link of a deterministic PRNG chain
        rooted at the config seed, so a served batch reproduces the same
        fault pattern run-to-run (and the replay of a failed group draws
        fresh faults, as a re-executed TRA would).
        """
        from repro.core import errors as errmod

        rel = self.reliability
        key = jax.random.fold_in(jax.random.PRNGKey(rel.seed),
                                 self._group_seq)
        self._group_seq += 1
        model = rel.model or errmod.TRAErrorModel(p_flip=0.0)
        tel = self.telemetry
        stats = {} if tel.metering else None
        if rel.mode == "vote":
            out = errmod.execute_voted(
                plan.lowered, data, list(plan.outputs),
                backend=self.backend, model=model, key=key, k=rel.k,
                stats_out=stats)
            replicas = rel.k
        else:
            out, replicas = errmod.execute_ecc(
                plan.lowered, data, list(plan.outputs),
                backend=self.backend, model=model, key=key,
                stats_out=stats)
        if stats is not None:
            m = tel.metrics
            m.counter("reliability_replicas_total").inc(stats["replicas"])
            m.counter("ecc_tiebreaks_total").inc(stats["tiebreaks"])
            m.counter("tra_corrected_bits_total").inc(
                stats["corrected_bits"])
            if tel.tracing and stats["corrected_bits"]:
                tel.tracer.instant("tra_correction",
                                   corrected_bits=stats["corrected_bits"],
                                   replicas=stats["replicas"])
        return out, replicas

    def _run_group_resilient(self, members: List[Tuple[int, BoundPlan]],
                             need_words: bool,
                             cse_planes: Optional[Dict[str, jax.Array]] = None
                             ) -> Tuple[Optional[np.ndarray], List[int], int]:
        """`_run_group` under the fault policy: timed, replayed, flagged.

        The chaos injector runs inside the guarded+timed window, so a
        raising injector is indistinguishable from a chip dying
        mid-dispatch and a sleeping one from a straggling chip. On failure
        the recovery hook runs first (elastic rescale-down when a
        QueryService owns this scheduler — `self.cluster` is re-read on
        replay, so the group re-lands on the surviving mesh), then the
        whole group is re-dispatched; results are whatever the successful
        attempt produced, which the chaos suite asserts bit-identical to a
        never-failed run.
        """
        ft = self.fault_tolerance
        tel = self.telemetry
        g = ft.groups_dispatched
        ft.groups_dispatched += 1
        for attempt in range(ft.max_replays + 1):
            t0 = time.perf_counter()
            try:
                if ft.failure_injector is not None:
                    ft.failure_injector(g)
                out = self._run_group(members, need_words, cse_planes)
            except Exception as e:  # noqa: BLE001 - any failure is replayable
                ft.failures += 1
                ft.timeline.append(f"failure@group{g}:{type(e).__name__}")
                if tel.metering:
                    tel.metrics.counter("ft_failures_total").inc()
                if tel.tracing:
                    tel.tracer.instant("ft_failure", group=g,
                                       error=type(e).__name__)
                if attempt >= ft.max_replays:
                    raise
                if ft.on_chip_failure is not None:
                    ft.on_chip_failure(e)
                ft.replays += 1
                ft.timeline.append(f"replay@group{g}")
                if tel.metering:
                    tel.metrics.counter("ft_replays_total").inc()
                if tel.tracing:
                    tel.tracer.instant("ft_replay", group=g)
                continue
            if ft.monitor.observe(g, time.perf_counter() - t0):
                ft.stragglers.append(g)
                ft.timeline.append(f"straggler@group{g}")
                if tel.metering:
                    tel.metrics.counter("ft_stragglers_total").inc()
                if tel.tracing:
                    tel.tracer.instant("ft_straggler", group=g)
            if tel.metering and ft.monitor.ema is not None:
                tel.metrics.gauge("straggler_ema_s").set(ft.monitor.ema)
            return out
        raise AssertionError("unreachable: loop exits via return or raise")

    def _run_group_sharded(self, members: List[Tuple[int, BoundPlan]],
                           need_words: bool
                           ) -> Tuple[Optional[np.ndarray], List[int]]:
        """Distributed twin of `_run_group`: one shard_map VM launch.

        Each canonical input stacks the group's queries along an inner
        axis of the catalog's chip-sharded copies, so the plane tensor is
        ``(n_rows, n_chips, local_banks, n_queries, local_words)`` with
        the chip axis laid onto the device mesh. Popcounts reduce with
        the chip-axis tree psum (`ChipCluster.popcounts`) — for
        scalar-only groups nothing but the count matrix leaves the
        shards; materialize gathers the output rows once per group.
        """
        cluster = self.cluster
        input_rows = [bp.input_map() for _, bp in members]
        data = {
            name: jnp.stack([self.catalog.shards(rows[name])
                             for rows in input_rows], axis=2)
            for name in input_rows[0]
        }
        plan = members[0][1].plan
        # shard_map dispatch needs a lowered VM: honor the optimizer's
        # backend only when it is one ("interp" falls back to the default)
        backend = (plan.backend
                   if plan.backend in ("scan", "pallas") else self.backend)
        lp = plan.lowered
        if lp is None:      # plans built outside the cache lower here
            lp = lowering.lower(plan.program)
        if not need_words:
            # scalar-only group: one shard_map launch, only the count
            # matrix crosses the chip boundary
            counts = cluster.popcounts(lp, data, plan.outputs,
                                       self.catalog.mask_shards(),
                                       backend=backend)
            return None, [sum(int(counts[j, s]) << j
                              for j in range(len(plan.outputs)))
                          for s in range(len(members))]
        # materialize group: the output rows must be gathered anyway, so
        # run ONCE and derive the counts from the gathered masked planes
        # (exactly as the single-process twin does)
        out = cluster.run_lowered(lp, data, plan.outputs,
                                  backend=backend)
        n_words = self.catalog.get(
            next(iter(input_rows[0].values()))).words.shape[0]
        mask = self.catalog.mask()
        # (n_outputs, len(members), n_words) -> query-major, as in the
        # single-process path
        masked = jnp.stack(
            [cluster.unshard_words(out[o], int(n_words)) & mask
             for o in plan.outputs])
        counts = np.asarray(popcount_words(masked, axis=-1))
        scalars = [sum(int(counts[j, s]) << j
                       for j in range(len(plan.outputs)))
                   for s in range(len(members))]
        return np.asarray(jnp.moveaxis(masked, 0, 1)), scalars

    # -- the scheduler proper ------------------------------------------------

    def plan_queries(self, queries: Sequence[Query]) -> List[BoundPlan]:
        """Host-side parse/plan/bind of a batch, no dispatch.

        The serving loop's double-buffered tick pipeline runs this for
        tick N+1 while tick N executes on device, then hands the bound
        plans back through ``submit(queries, preplanned=...)`` so the
        dispatch path skips planning entirely.
        """
        return [self.planner.plan(q.query, columns=self.catalog.columns,
                                  names=self.catalog)
                for q in queries]

    def submit(self, queries: Sequence[Query],
               preplanned: Optional[List[BoundPlan]] = None,
               allow_cse: bool = True) -> BatchReport:
        """Plan, group, execute, and cost one batch of concurrent queries.

        ``preplanned`` (from `plan_queries`) skips the planning stage —
        the serving loop plans tick N+1 on the host while tick N runs on
        device. ``allow_cse=False`` additionally skips the batch-level
        sharing pass: the CSE rewrite compiles ephemeral plans through
        the shared planner cache, which the pipelined loop is using from
        the other thread.
        """
        if not queries:
            return BatchReport([], 0.0, self.n_banks, 0)
        tel = self.telemetry
        if not (tel.tracing or tel.metering):
            return self._submit(queries, tel, preplanned, allow_cse)
        wall0 = time.perf_counter()
        if tel.tracing:
            tr = tel.tracer
            # core layers (engine / bankgroup / cluster) have no handle on
            # this scheduler; publish the sink for the dispatch window so
            # their spans nest under this batch
            prev = set_telemetry(tel)
            tr.begin("batch", n_queries=len(queries))
            try:
                report = self._submit(queries, tel, preplanned, allow_cse)
            finally:
                tr.end()
                set_telemetry(prev)
        else:
            report = self._submit(queries, tel, preplanned, allow_cse)
        if tel.metering:
            self._m_batches.inc()
            self._m_groups.inc(report.n_plan_groups)
            self._m_modeled_ns.inc(report.makespan_ns)
            self._m_wall.observe((time.perf_counter() - wall0) * 1e6)
        return report

    def _submit(self, queries: Sequence[Query],
                tel: "Telemetry",  # noqa: F821
                preplanned: Optional[List[BoundPlan]] = None,
                allow_cse: bool = True) -> BatchReport:
        tracing = tel.tracing
        tr = tel.tracer
        if self.reliability is not None and self.reliability.mode == "ecc":
            # ecc mode opens every batch with a catalog integrity probe:
            # the maintained per-group XOR parity must match a fresh
            # recomputation, or some operand vector was corrupted at rest
            self.parity_checks += 1
            if tel.metering:
                self._m_parity.inc()
            if not self.catalog.verify_parity():
                raise RuntimeError(
                    "catalog parity check failed: a registered vector's "
                    "words no longer match the maintained XOR parity plane")

        # 1. plan every query through the cache (hits skip recompilation),
        #    then run the batch-level sharing pass (cross-query CSE)
        orig_bound: List[BoundPlan] = []
        if preplanned is not None:
            orig_bound = list(preplanned)
        elif tracing:
            for i, q in enumerate(queries):
                with tr.span("query", index=i, mode=q.mode):
                    orig_bound.append(self.planner.plan(
                        q.query, columns=self.catalog.columns,
                        names=self.catalog))
        else:
            orig_bound = [self.planner.plan(q.query,
                                            columns=self.catalog.columns,
                                            names=self.catalog)
                          for q in queries]
        if allow_cse:
            bound, cse = self._apply_cse(queries, orig_bound)
        else:
            bound, cse = orig_bound, None

        # 1b. shared-subexpression planes execute first (topo order), ONE
        #     dispatch each; consumers read them as input leaves below
        cse_planes: Dict[str, jax.Array] = {}
        if cse is not None:
            for d in cse.defs:
                if tracing:
                    tr.begin("cse_group", plane=d.name, uses=d.uses,
                             n_aaps=d.bound.plan.n_aaps)
                    tr.begin("cse_dispatch")
                stacked, _, _ = self._run_group([(0, d.bound)], True,
                                                cse_planes)
                cse_planes[d.name] = jnp.asarray(stacked[0][0])
                if tracing:
                    tr.end()    # cse_dispatch
                    tr.end()    # cse_group
            self.cse_planes_built += len(cse.defs)
            if tel.metering:
                self._m_cse.inc(len(cse.defs))

        # 2. group by canonical plan -> one stacked dispatch per group
        groups: Dict[Tuple, List[Tuple[int, BoundPlan]]] = {}
        for idx, bp in enumerate(bound):
            groups.setdefault(bp.plan.key, []).append((idx, bp))
        words_by_idx: Dict[int, np.ndarray] = {}
        count_by_idx: Dict[int, int] = {}
        replicas_by_idx: Dict[int, int] = {}
        dispatch = (self._run_group_resilient
                    if self.fault_tolerance is not None else self._run_group)
        for members in groups.values():
            need_words = any(queries[idx].mode == MATERIALIZE
                             for idx, _ in members)
            if tracing:
                tr.begin("group", members=[idx for idx, _ in members],
                         n_aaps=members[0][1].plan.n_aaps)
                tr.begin("dispatch")
            stacked, scalars, replicas = dispatch(members, need_words,
                                                  cse_planes)
            if tracing:
                tr.end()
                tr.begin("readout")
            plan = members[0][1].plan
            # boolean plans (single DST row) materialize as a flat word
            # vector; arithmetic plans as the (n_outputs, n_words) plane
            # stack — even at width 1, so plane shapes stay stable
            is_boolean = plan.outputs == (DST,)
            for slot, (idx, _) in enumerate(members):
                if stacked is not None:
                    w = stacked[slot]          # (n_outputs, n_words)
                    words_by_idx[idx] = w[0] if is_boolean else w
                count_by_idx[idx] = scalars[slot]
                replicas_by_idx[idx] = replicas
            if tracing:
                tr.end()    # readout
                tr.end()    # group

        # 3. modeled timeline (`_place_batch`): shared planes first, then
        #    queries on least-loaded (chip, bank) slots; a consumer cannot
        #    start before the planes it reads are ready, and shared work
        #    is placed — charged — exactly once.
        n_chips = self.cluster.n_chips if self.cluster is not None else 1
        n_blocks = self._n_blocks
        placements, makespan = self._place_batch(
            bound, cse, replicas_by_idx, tr if tracing else None)
        # defs are real AAPs/energy, but shared: charge them once, to the
        # first consuming query's accounting, so the batch energy total
        # stays the sum of per-result energies
        def_aaps = (sum(d.bound.plan.n_aaps for d in cse.defs)
                    if cse is not None else 0)
        def_energy = (sum(d.bound.plan.energy_nj_per_block
                          for d in cse.defs) * n_blocks
                      if cse is not None else 0.0)
        first_consumer: Optional[int] = None
        if cse is not None:
            for idx, bp in enumerate(bound):
                if any(n.startswith(CSE_PREFIX) for n in bp.bindings):
                    first_consumer = idx
                    break
        results: List[QueryResult] = []
        for idx, (q, bp) in enumerate(zip(queries, bound)):
            c, b, lat = placements[idx]
            replicas = replicas_by_idx.get(idx, 1)
            energy = bp.plan.energy_nj_per_block * n_blocks * replicas
            extra_aaps = 0
            if idx == first_consumer:
                energy += def_energy
                extra_aaps = def_aaps
            value: Union[int, np.ndarray]
            if q.mode == MATERIALIZE:
                value = words_by_idx[idx]
            else:   # popcount / aggregate: the weighted-popcount scalar
                value = count_by_idx[idx]
            results.append(QueryResult(
                index=idx, mode=q.mode, value=value,
                latency_ns=lat, bank=b,
                cache_hit=orig_bound[idx].cache_hit,
                n_aaps=bp.plan.n_aaps,
                energy_nj=energy, tenant=q.tenant, chip=c,
                scalar=count_by_idx[idx]))
            if tracing:
                tr.model_event(f"q{idx}", 0.0, lat, "queries",
                               latency_ns=lat, n_aaps=bp.plan.n_aaps,
                               cache_hit=orig_bound[idx].cache_hit,
                               energy_nj=energy,
                               mode=q.mode, tenant=q.tenant)
            if tel.metering:
                self._m_queries.inc()
                self._m_lat.observe(lat)
                self._m_aaps.inc((bp.plan.n_aaps + extra_aaps)
                                 * n_blocks * replicas)
                self._m_energy.inc(energy)
                if q.tenant is not None:
                    m = tel.metrics
                    m.counter("tenant_queries_total",
                              tenant=q.tenant).inc()
                    m.counter("tenant_aaps_total", tenant=q.tenant).inc(
                        bp.plan.n_aaps * n_blocks * replicas)
                    m.counter("tenant_energy_nj_total",
                              tenant=q.tenant).inc(energy)

        if tracing and n_chips > 1:
            # the chip-axis tree psum: ceil(log2 chips) serialized hops
            # after the last bank completes (recursive doubling,
            # `core.cluster.tree_psum`)
            reduce_ns = math.ceil(math.log2(n_chips)) * self.timing.aap_ns
            base = makespan - reduce_ns
            for h in range(int(math.ceil(math.log2(n_chips)))):
                tr.model_event("psum_hop", base + h * self.timing.aap_ns,
                               self.timing.aap_ns, "reduce", hop=h)
        self.queries_served += len(queries)
        self.total_modeled_ns += makespan
        self.total_energy_nj += sum(r.energy_nj for r in results)
        return BatchReport(
            results, makespan, self.n_banks, len(groups), n_chips=n_chips,
            n_cse_planes=(len(cse.defs) if cse is not None else 0),
            total_aaps=n_blocks * (def_aaps
                                   + sum(bp.plan.n_aaps for bp in bound)),
            baseline_aaps=n_blocks * sum(
                (bp.plan.n_aaps_unopt if bp.plan.n_aaps_unopt is not None
                 else bp.plan.n_aaps) for bp in orig_bound))

    # -- optimize: batch-level sharing + modeled placement -------------------

    def _apply_cse(self, queries: Sequence[Query],
                   orig_bound: List[BoundPlan]
                   ) -> Tuple[List[BoundPlan], Optional[CseBatch]]:
        """The cross-query sharing pass, where this deployment allows it.

        Single-process clean path only: sharded dispatch would have to
        ship planes between chips, mitigated dispatch repeats programs
        whole (a shared plane would be voted once but consumed k times),
        and the fault-tolerance chaos suite counts group dispatches. The
        pass itself guarantees the rewrite is kept only when it strictly
        lowers the batch's total AAPs (`optimizer.plan_group_cse`).
        """
        opt = getattr(self.planner.cache, "optimizer", None)
        if (opt is None or not opt.enable_cse or len(queries) < 2
                or self.cluster is not None
                or self.fault_tolerance is not None
                or (self.reliability is not None
                    and self.reliability.mode != "none")):
            return orig_bound, None
        exprs = [
            (bind_expr(bp.plan.canon, bp.input_map())
             if bp.plan.canon is not None and bp.plan.outputs == (DST,)
             else None)
            for bp in orig_bound
        ]
        cse = plan_group_cse(orig_bound, exprs,
                             lambda e: self.planner._plan(e, None))
        if cse is None:
            return orig_bound, None
        return cse.bound, cse

    def _place_batch(self, bound: Sequence[BoundPlan],
                     cse: Optional[CseBatch],
                     replicas_by_idx: Dict[int, int],
                     tr=None) -> Tuple[List[Tuple[int, int, float]], float]:
        """Modeled timeline placement for one batch (no execution).

        Shared-plane defs place first (dependency-ordered), then every
        query lands on the least-loaded (chip, bank); operand transfers
        serialize on each chip's own internal bus, per-bank AAP compute
        overlaps across banks, chips are fully parallel, and a consumer
        cannot start a block before every shared plane it reads is ready.
        Returns (per-query [(chip, bank, latency_ns)], makespan_ns).
        Multi-chip aggregate readout adds the psum reduction tree
        (ceil(log2 chips) serialized hops); with one chip this
        degenerates to exactly the pre-cluster model.
        """
        n_chips = self.cluster.n_chips if self.cluster is not None else 1
        reduce_ns = (math.ceil(math.log2(n_chips)) * self.timing.aap_ns
                     if n_chips > 1 else 0.0)
        n_blocks = self._n_blocks
        bus_free = [0.0] * n_chips
        bank_free = [[0.0] * self.n_banks for _ in range(n_chips)]
        cse_ready: Dict[str, float] = {}

        def least_loaded() -> Tuple[int, int]:
            return min(((ci, bi) for ci in range(n_chips)
                        for bi in range(self.n_banks)),
                       key=lambda cb: bank_free[cb[0]][cb[1]])

        for d in (cse.defs if cse is not None else ()):
            plan = d.bound.plan
            deps = [n for n in d.bound.bindings if n.startswith(CSE_PREFIX)]
            c, b = least_loaded()
            xfer = self._xfer_ns(plan)
            for _ in range(n_blocks):
                dep = max((cse_ready[p] for p in deps), default=0.0)
                start = max(bus_free[c], bank_free[c][b], dep)
                bus_free[c] = start + xfer
                bank_free[c][b] = bus_free[c] + plan.latency_ns_per_block
                if tr is not None:
                    tr.model_event("cse_xfer", start, xfer, f"chip{c}/bus",
                                   plane=d.name)
                    tr.model_event("cse_compute", bus_free[c],
                                   plan.latency_ns_per_block,
                                   f"chip{c}/bank{b}", plane=d.name)
            cse_ready[d.name] = bank_free[c][b]

        placements: List[Tuple[int, int, float]] = []
        for idx, bp in enumerate(bound):
            deps = [n for n in bp.bindings if n.startswith(CSE_PREFIX)]
            c, b = least_loaded()
            xfer = self._xfer_ns(bp.plan)
            # mitigation overhead is charged where it runs: a k-replica
            # dispatch repeats the in-bank AAP compute k times (operands
            # are already placed, so transfers are NOT repeated) and a
            # voted readout adds one maj-AAP per output plane
            replicas = replicas_by_idx.get(idx, 1)
            vote_ns = (len(bp.plan.outputs) * self.timing.aap_ns
                       if replicas > 1 else 0.0)
            for _ in range(n_blocks):
                dep = max((cse_ready[p] for p in deps), default=0.0)
                start = max(bus_free[c], bank_free[c][b], dep)
                bus_free[c] = start + xfer
                bank_free[c][b] = (bus_free[c]
                                   + bp.plan.latency_ns_per_block * replicas
                                   + vote_ns)
                if tr is not None:
                    tr.model_event("xfer", start, xfer, f"chip{c}/bus",
                                   q=idx)
                    tr.model_event("compute", bus_free[c],
                                   bank_free[c][b] - bus_free[c],
                                   f"chip{c}/bank{b}", q=idx)
            placements.append((c, b, bank_free[c][b] + reduce_ns))
        makespan = max(max(per_chip) for per_chip in bank_free) + reduce_ns
        return placements, makespan

    def explain(self, queries: Sequence[Union[Query, str]]) -> ExplainReport:
        """Plan — but do not execute — a batch; report every decision.

        Runs the full `parse -> canonicalize -> optimize -> cost -> bind`
        pipeline plus the batch sharing pass and the modeled placement,
        and returns the per-plan cost/backend breakdown and the
        shared-subexpression report. Plans land in the cache (a later
        `submit` of the same batch hits), but nothing is dispatched and
        no serving counters move.
        """
        qs = [q if isinstance(q, Query) else Query(q) for q in queries]
        orig_bound = [self.planner.plan(q.query,
                                        columns=self.catalog.columns,
                                        names=self.catalog)
                      for q in qs]
        bound, cse = self._apply_cse(qs, orig_bound)
        placements, makespan = self._place_batch(bound, cse, {})
        n_blocks = self._n_blocks
        plans: List[PlanExplain] = []
        for idx, (q, bp0, bp) in enumerate(zip(qs, orig_bound, bound)):
            plans.append(PlanExplain(
                index=idx, query=str(q.query),
                backend=bp.plan.backend or self.backend,
                cache_hit=bp0.cache_hit,
                n_aaps=bp.plan.n_aaps,
                n_aaps_unopt=(bp0.plan.n_aaps_unopt
                              if bp0.plan.n_aaps_unopt is not None
                              else bp0.plan.n_aaps),
                latency_ns=bp.plan.latency_ns_per_block,
                energy_nj=bp.plan.energy_nj_per_block,
                xfer_ns=self._xfer_ns(bp.plan),
                n_inputs=bp.plan.n_inputs,
                shared=tuple(sorted({n for n in bp.bindings
                                     if n.startswith(CSE_PREFIX)})),
                rewritten=bp is not bp0))
        cse_rows = [CseExplain(name=d.name, n_aaps=d.bound.plan.n_aaps,
                               uses=d.uses)
                    for d in (cse.defs if cse is not None else ())]
        def_aaps = sum(r.n_aaps for r in cse_rows)
        return ExplainReport(
            plans=plans, cse=cse_rows,
            n_plan_groups=len({bp.plan.key for bp in bound}),
            total_aaps=n_blocks * (def_aaps
                                   + sum(bp.plan.n_aaps for bp in bound)),
            baseline_aaps=n_blocks * sum(
                (bp.plan.n_aaps_unopt if bp.plan.n_aaps_unopt is not None
                 else bp.plan.n_aaps) for bp in orig_bound),
            makespan_ns=makespan, n_banks=self.n_banks,
            n_chips=(self.cluster.n_chips
                     if self.cluster is not None else 1))


def results_bit_identical(a: Sequence[QueryResult],
                          b: Sequence[QueryResult]) -> bool:
    """Mode-aware value equality across two result lists.

    Popcount values are ints, materialize values are packed word arrays;
    `np.array_equal` handles both (a bare `==` on arrays would be
    ambiguous under `all()`).
    """
    if len(a) != len(b):
        return False
    return all(np.array_equal(np.asarray(x.value), np.asarray(y.value))
               for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# Reference path: sequential, unbatched, uncached
# ---------------------------------------------------------------------------


def run_queries_unbatched(catalog: Catalog, queries: Sequence[Query],
                          timing: DramTiming = DDR3_1600) -> BatchReport:
    """Execute queries one at a time with fresh per-query compilation.

    This is the service's ground truth: no canonical renaming, no plan
    cache, no stacking, no lowered VM — each query compiles over its
    natural catalog row names (arithmetic forms over the library's natural
    X/Y plane names) and runs through the micro-op interpreter
    (`engine.execute(lowered=False)`) alone on a single bank. The batched
    scheduler's VM dispatch must produce bit-identical values.
    """
    from repro.core.energy import DEFAULT_ENERGY, program_energy_nj
    from repro.core.timing import program_latency_ns

    def expr_leaves(e: Expr, acc: List[str]) -> List[str]:
        if e.op == "row":
            if e.row not in acc:
                acc.append(e.row)
        else:
            for a in e.args:
                expr_leaves(a, acc)
        return acc

    n_blocks = max(1, math.ceil((catalog.n_bits or ROW_BITS) / ROW_BITS))
    mask = catalog.mask()
    clock = 0.0
    results: List[QueryResult] = []
    for idx, q in enumerate(queries):
        parsed = (parse_any(q.query, catalog.columns, catalog)
                  if isinstance(q.query, str) else q.query)
        if isinstance(parsed, ArithQuery):
            n_bits = catalog.columns[parsed.cols[0]]
            if parsed.op == "read":
                res = arith_compiler.plane_readout_program(n_bits, "X", "S")
                data = {f"X{j}": catalog.get(plane_name(parsed.cols[0],
                                                        j)).words
                        for j in range(n_bits)}
            else:
                res = arith_compiler.ripple_add_program(
                    n_bits, "X", "Y", "S", sub=(parsed.op == "sub"))
                data = {f"X{j}": catalog.get(plane_name(parsed.cols[0],
                                                        j)).words
                        for j in range(n_bits)}
                data.update({f"Y{j}": catalog.get(plane_name(parsed.cols[1],
                                                             j)).words
                             for j in range(n_bits)})
            program, outputs = res.program, res.outputs
            # lowered=False: the reference path runs the micro-op
            # interpreter so batched-VM bit-identity is checked against an
            # independent executor, not the VM against itself
            out = engine.execute(program, data, outputs=outputs,
                                 lowered=False)
            planes = np.asarray(
                jnp.stack([out[o] & mask for o in outputs]))
            n_leaves = len(data)
            from repro.ops.arith import weighted_plane_sum

            scalar = int(weighted_plane_sum(jnp.asarray(planes), mask))
            value = planes if q.mode == MATERIALIZE else scalar
        else:
            compiled = compile_expr_fused(parsed, DST)
            program, outputs = compiled.program, [DST]
            leaves = expr_leaves(parsed, [])
            out = engine.execute(program, catalog.row_state(leaves),
                                 outputs=[DST], lowered=False)[DST]
            words = np.asarray(out & mask)
            n_leaves = len(leaves)
            scalar = int(popcount_words(jnp.asarray(words)))
            value = words if q.mode == MATERIALIZE else scalar
        exec_ns = program_latency_ns(program, timing)
        xfer = timing.aap_ns * (n_leaves + len(outputs))
        clock += n_blocks * (xfer + exec_ns)
        results.append(QueryResult(
            index=idx, mode=q.mode, value=value, latency_ns=clock, bank=0,
            cache_hit=False, n_aaps=program.n_aap,
            energy_nj=n_blocks * program_energy_nj(program, DEFAULT_ENERGY),
            tenant=q.tenant, scalar=scalar))
    return BatchReport(results, clock, 1, len(queries))
