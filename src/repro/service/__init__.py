"""Bulk-bitwise query service: catalog, plan cache, batching scheduler.

The serving layer above the paper's in-DRAM machine (ROADMAP north star:
interactive query-shaped traffic over the bank group). Sub-modules:

  catalog    — named bitvectors placed into subarray rows (DramAllocator)
  planner    — query text -> Expr -> fused AAP program, memoized by the
               structural `expr_key` of the canonicalized DAG
  scheduler  — batches concurrent queries, groups them by shared plan into
               stacked bank-group dispatches, models latency/energy
  service    — the `QueryService` facade (register / query / materialize /
               range_scan)
  workload   — synthetic multi-tenant §8 query streams (bitmap analytics,
               BitWeaving scans, set algebra) for benchmarks and serving
"""
from repro.service.catalog import (Catalog, CatalogEntry, CatalogError,
                                   plane_name)
from repro.service.planner import (ArithQuery, BoundPlan, Plan, PlanCache,
                                   Planner, QueryParseError, canonicalize,
                                   parse_any, parse_query)
from repro.service.scheduler import (AGGREGATE, MATERIALIZE, POPCOUNT,
                                     BatchReport, Query, QueryResult,
                                     Scheduler, results_bit_identical,
                                     run_queries_unbatched)
from repro.service.service import QueryService
from repro.service.workload import WorkloadSpec, build_service, query_stream

__all__ = [
    "Catalog", "CatalogEntry", "CatalogError", "plane_name",
    "ArithQuery", "BoundPlan", "Plan", "PlanCache", "Planner",
    "QueryParseError", "canonicalize", "parse_any", "parse_query",
    "AGGREGATE", "MATERIALIZE", "POPCOUNT", "BatchReport", "Query",
    "QueryResult", "Scheduler", "results_bit_identical",
    "run_queries_unbatched",
    "QueryService",
    "WorkloadSpec", "build_service", "query_stream",
]
