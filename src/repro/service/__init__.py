"""Bulk-bitwise query service: catalog, cost-based planner, scheduler.

The serving layer above the paper's in-DRAM machine (ROADMAP north star:
interactive query-shaped traffic over the bank group). Sub-modules:

  catalog    — named bitvectors placed into subarray rows (DramAllocator)
  planner    — the `parse -> canonicalize -> optimize -> cost -> bind`
               front half: query text -> Expr -> fused AAP program,
               memoized in a bounded LRU cache keyed by the structural
               `expr_key` of the winning canonical DAG
  optimizer  — the cost model (AAPs x timing x energy) driving predicate
               reordering, per-plan backend choice, cross-query CSE, and
               the `explain()` report
  scheduler  — batches concurrent queries, runs the batch sharing pass,
               groups by shared plan into stacked bank-group dispatches,
               models latency/energy (shared work charged once)
  service    — the `QueryService` facade (register / submit / query /
               materialize / range_scan / explain), configured by
               `ServiceConfig`
  server     — the continuous-serving runtime: `ServingLoop` packs
               in-flight queries into scheduler ticks (double-buffered
               plan/execute pipelining, DRR tenant fairness, SLO
               admission control per `SloConfig`)
  config     — `ServiceConfig` / `SloConfig` construction + policy knobs
  workload   — synthetic multi-tenant §8 query streams (bitmap analytics,
               BitWeaving scans, set algebra) for benchmarks and serving;
               closed-loop batches plus seeded open-loop Poisson traces
"""
from repro.service.catalog import (Catalog, CatalogEntry, CatalogError,
                                   plane_name)
from repro.service.config import (DEFER, OBSERVE, SHED, ServiceConfig,
                                  SloConfig)
from repro.service.optimizer import (CostParams, CseBatch, CseExplain,
                                     ExplainReport, PlanCost, PlanExplain,
                                     QueryOptimizer, choose_backend,
                                     cost_program, plan_group_cse,
                                     reorder_expr)
from repro.service.planner import (ArithQuery, BoundPlan, Plan, PlanCache,
                                   Planner, QueryParseError, canonicalize,
                                   parse_any, parse_query)
from repro.service.scheduler import (AGGREGATE, MATERIALIZE, POPCOUNT,
                                     BatchReport, Query, QueryResult,
                                     Scheduler, results_bit_identical,
                                     run_queries_unbatched)
from repro.service.server import (Arrival, QueryHandle, QueryShedError,
                                  ServeRecord, ServeReport, ServingLoop,
                                  TickStats)
from repro.service.service import QueryService
from repro.service.workload import (WorkloadSpec, build_service,
                                    poisson_arrivals, query_stream)

__all__ = [
    "Catalog", "CatalogEntry", "CatalogError", "plane_name",
    "DEFER", "OBSERVE", "SHED", "ServiceConfig", "SloConfig",
    "Arrival", "QueryHandle", "QueryShedError", "ServeRecord",
    "ServeReport", "ServingLoop", "TickStats",
    "CostParams", "CseBatch", "CseExplain", "ExplainReport", "PlanCost",
    "PlanExplain", "QueryOptimizer", "choose_backend", "cost_program",
    "plan_group_cse", "reorder_expr",
    "ArithQuery", "BoundPlan", "Plan", "PlanCache", "Planner",
    "QueryParseError", "canonicalize", "parse_any", "parse_query",
    "AGGREGATE", "MATERIALIZE", "POPCOUNT", "BatchReport", "Query",
    "QueryResult", "Scheduler", "results_bit_identical",
    "run_queries_unbatched",
    "QueryService",
    "WorkloadSpec", "build_service", "poisson_arrivals", "query_stream",
]
