"""Synthetic multi-tenant query workload for the bulk-bitwise service.

Models the paper's §8 killer applications as an interactive query stream:

  * bitmap-index analytics (§8.1) — per-tenant daily activity bitmaps plus
    a gender attribute; query templates are the weekly-activity OR-trees,
    the "active every week" AND-of-weeks, and the male-per-week filters.
  * BitWeaving column scans (§8.2) — a per-tenant integer column in
    vertical layout, queried with repeated range predicates.
  * bitvector set operations (§8.3) — per-tenant element sets, queried
    with k-ary intersections and unions.
  * bit-serial arithmetic (SIMDRAM-style, beyond the paper) — per-tenant
    value columns queried with `sum(col)` aggregations, `col < K`
    comparison predicates, and `sum(colA + colB)` ripple-adder sums.

The stream is deliberately repetitive in *shape* (each tenant re-asks the
same templates, and all tenants share template structure), which is exactly
the pattern the planner's canonical plan cache and the scheduler's
plan-grouped batching exploit.

Two consumers share the template bank:

  * `query_stream` — a closed-loop batch of `n_queries` (the serve_qps
    benchmark shape: submit everything at once, measure the batch);
  * `poisson_arrivals` — an open-loop arrival trace for the continuous
    serving runtime (`service.server.ServingLoop.run_trace`): seeded
    per-tenant Poisson processes with skewed rates and a heavy-tailed
    query-size mix, so benchmarks and chaos tests replay the exact same
    offered load.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.apps.bitmap_index import week_or
from repro.service.scheduler import AGGREGATE, POPCOUNT, Query
from repro.service.server import Arrival
from repro.service.service import QueryService


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Knobs of the synthetic stream (defaults sized for CI)."""

    n_tenants: int = 4
    n_weeks: int = 3
    domain_bits: int = 1 << 12      # users / column length / set domain
    col_bits: int = 8               # integer column width for range scans
    n_sets: int = 6                 # element sets per tenant
    n_queries: int = 96
    seed: int = 0
    p_active: float = 0.35


def _week_or(tenant: str, week: int) -> str:
    # shared template: keeps this stream plan-cache-compatible with the
    # apps.bitmap_index service-client path
    return week_or(week, prefix=f"{tenant}/")


def build_service(spec: WorkloadSpec, n_banks: int = 8,
                  telemetry=None, **kwargs) -> QueryService:
    """Populate a service catalog with every tenant's vectors.

    `telemetry` passes through to `QueryService` (a `repro.obs.Telemetry`
    or `NULL_TELEMETRY`; None keeps the service default of metrics-on /
    tracing-off), as do any extra keyword arguments — benchmarks use
    `optimize=False` to build the unoptimized baseline side.
    """
    rng = np.random.default_rng(spec.seed)
    svc = QueryService(n_banks=n_banks, telemetry=telemetry, **kwargs)
    m = spec.domain_bits
    for t in range(spec.n_tenants):
        tenant = f"t{t}"
        for w in range(spec.n_weeks):
            for d in range(7):
                bits = rng.random(m) < spec.p_active
                svc.register_bits(f"{tenant}/w{w}d{d}", bits, group=tenant)
        svc.register_bits(f"{tenant}/male", rng.random(m) < 0.5, group=tenant)
        for s in range(spec.n_sets):
            svc.register_bits(f"{tenant}/s{s}", rng.random(m) < 0.4,
                              group=tenant)
        svc.register_column(f"{tenant}/col",
                            rng.integers(0, 1 << spec.col_bits, m,
                                         dtype=np.uint32),
                            spec.col_bits, group=tenant)
        svc.register_column(f"{tenant}/col2",
                            rng.integers(0, 1 << spec.col_bits, m,
                                         dtype=np.uint32),
                            spec.col_bits, group=tenant)
    return svc


def _make_templates(spec: WorkloadSpec, svc: QueryService, rng):
    """The shared per-tenant query template bank.

    Consumes the first six integer draws of `rng` for the fixed range-scan
    bounds (so the closed-loop stream stays seed-stable), then returns the
    template closures keyed by name. Every template takes a tenant id and
    its own random draws from the same `rng`.
    """
    # a few fixed range predicates per tenant so scans repeat
    bounds: List[Tuple[int, int]] = []
    for _ in range(3):
        lo = int(rng.integers(0, (1 << spec.col_bits) - 1))
        hi = int(rng.integers(lo, 1 << spec.col_bits))
        bounds.append((lo, hi))

    def weekly(t: str, w: int) -> Query:
        return Query(_week_or(t, w), POPCOUNT, tenant=t)

    def every_week(t: str) -> Query:
        text = " & ".join(_week_or(t, w) for w in range(spec.n_weeks))
        return Query(text, POPCOUNT, tenant=t)

    def male_week(t: str, w: int) -> Query:
        return Query(f"{_week_or(t, w)} & {t}/male", POPCOUNT, tenant=t)

    def range_scan(t: str, which: int) -> Query:
        lo, hi = bounds[which]
        return Query(svc.range_scan_query(f"{t}/col", lo, hi),
                     POPCOUNT, tenant=t)

    def intersect(t: str, k: int) -> Query:
        text = " & ".join(f"{t}/s{s}" for s in range(k))
        return Query(text, POPCOUNT, tenant=t)

    def union_diff(t: str) -> Query:
        return Query(f"({t}/s0 | {t}/s1 | {t}/s2) & ~{t}/s3",
                     POPCOUNT, tenant=t)

    def sum_col(t: str) -> Query:
        return Query(f"sum({t}/col)", AGGREGATE, tenant=t)

    def lt_filter(t: str, which: int) -> Query:
        lo, _ = bounds[which]
        k = max(1, lo)  # grammar rejects constant predicates (k == 0)
        return Query(f"{t}/col < {k} & {t}/male", POPCOUNT, tenant=t)

    def sum_add(t: str) -> Query:
        return Query(f"sum({t}/col + {t}/col2)", AGGREGATE, tenant=t)

    def draw(t: str) -> Query:
        kind = int(rng.integers(9))
        if kind == 0:
            return weekly(t, int(rng.integers(spec.n_weeks)))
        elif kind == 1:
            return every_week(t)
        elif kind == 2:
            return male_week(t, int(rng.integers(spec.n_weeks)))
        elif kind == 3:
            return range_scan(t, int(rng.integers(len(bounds))))
        elif kind == 4:
            return intersect(t, int(rng.integers(2, spec.n_sets)))
        elif kind == 5:
            return union_diff(t)
        elif kind == 6:
            return sum_col(t)
        elif kind == 7:
            return lt_filter(t, int(rng.integers(len(bounds))))
        return sum_add(t)

    def draw_light(t: str) -> Query:
        kind = int(rng.integers(4))
        if kind == 0:
            return weekly(t, int(rng.integers(spec.n_weeks)))
        elif kind == 1:
            return male_week(t, int(rng.integers(spec.n_weeks)))
        elif kind == 2:
            return union_diff(t)
        return intersect(t, 2)

    def draw_heavy(t: str) -> Query:
        kind = int(rng.integers(4))
        if kind == 0:
            return every_week(t)
        elif kind == 1:
            return sum_col(t)
        elif kind == 2:
            return sum_add(t)
        return range_scan(t, int(rng.integers(len(bounds))))

    return {"draw": draw, "light": draw_light, "heavy": draw_heavy}


def query_stream(spec: WorkloadSpec, svc: QueryService) -> List[Query]:
    """A mixed, repetitive multi-tenant stream of `n_queries` queries."""
    rng = np.random.default_rng(spec.seed + 1)
    templates = _make_templates(spec, svc, rng)
    queries: List[Query] = []
    while len(queries) < spec.n_queries:
        t = f"t{int(rng.integers(spec.n_tenants))}"
        queries.append(templates["draw"](t))
    return queries


def poisson_arrivals(spec: WorkloadSpec, svc: QueryService, *,
                     rate_qps: float, n_arrivals: int = 64,
                     seed: Optional[int] = None,
                     tenant_weights: Optional[Sequence[float]] = None,
                     heavy_frac: float = 0.2,
                     priorities: Optional[Dict[str, int]] = None,
                     ) -> List[Arrival]:
    """Seeded open-loop arrival trace for the continuous serving runtime.

    Each tenant is an independent Poisson process: the aggregate offered
    rate `rate_qps` (queries per modeled second) splits across tenants by
    `tenant_weights` (default: a 2:1 geometric skew, so tenant 0 is the
    hog and the tail tenants trickle — the shape DRR fairness and
    per-tenant SLO shedding are tested against), `n_arrivals` splits by a
    multinomial draw on the same weights, and inter-arrival gaps are
    exponential. The query mix is heavy-tailed in *size*: probability
    `heavy_frac` draws a heavy template (multi-week AND trees, ripple-add
    SUMs, range scans — many-plane programs), the rest draw light
    single-plane-ish templates. `priorities` maps tenant id -> admission
    priority (higher sheds last); unlisted tenants get 0.

    Deterministic for a given (spec.seed, seed, rate, n): benchmarks and
    chaos tests replay byte-identical offered load.
    """
    rng = np.random.default_rng(spec.seed + 2 if seed is None else seed)
    templates = _make_templates(spec, svc, rng)
    if tenant_weights is None:
        tenant_weights = [2.0 ** -i for i in range(spec.n_tenants)]
    w = np.asarray(tenant_weights, float)
    if len(w) != spec.n_tenants or np.any(w < 0) or w.sum() <= 0:
        raise ValueError(f"bad tenant_weights {tenant_weights!r}")
    w = w / w.sum()
    counts = rng.multinomial(n_arrivals, w)
    priorities = priorities or {}
    arrivals: List[Arrival] = []
    for i, n_t in enumerate(counts):
        if n_t == 0:
            continue
        tenant = f"t{i}"
        rate_per_ns = rate_qps * w[i] / 1e9
        times = np.cumsum(rng.exponential(1.0 / rate_per_ns, size=int(n_t)))
        for t_ns in times:
            heavy = rng.random() < heavy_frac
            q = templates["heavy" if heavy else "light"](tenant)
            arrivals.append(Arrival(t_ns=float(t_ns), query=q,
                                    priority=priorities.get(tenant, 0)))
    arrivals.sort(key=lambda a: a.t_ns)
    return arrivals
