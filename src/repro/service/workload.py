"""Synthetic multi-tenant query workload for the bulk-bitwise service.

Models the paper's §8 killer applications as an interactive query stream:

  * bitmap-index analytics (§8.1) — per-tenant daily activity bitmaps plus
    a gender attribute; query templates are the weekly-activity OR-trees,
    the "active every week" AND-of-weeks, and the male-per-week filters.
  * BitWeaving column scans (§8.2) — a per-tenant integer column in
    vertical layout, queried with repeated range predicates.
  * bitvector set operations (§8.3) — per-tenant element sets, queried
    with k-ary intersections and unions.
  * bit-serial arithmetic (SIMDRAM-style, beyond the paper) — per-tenant
    value columns queried with `sum(col)` aggregations, `col < K`
    comparison predicates, and `sum(colA + colB)` ripple-adder sums.

The stream is deliberately repetitive in *shape* (each tenant re-asks the
same templates, and all tenants share template structure), which is exactly
the pattern the planner's canonical plan cache and the scheduler's
plan-grouped batching exploit.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.apps.bitmap_index import week_or
from repro.service.scheduler import AGGREGATE, POPCOUNT, Query
from repro.service.service import QueryService


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Knobs of the synthetic stream (defaults sized for CI)."""

    n_tenants: int = 4
    n_weeks: int = 3
    domain_bits: int = 1 << 12      # users / column length / set domain
    col_bits: int = 8               # integer column width for range scans
    n_sets: int = 6                 # element sets per tenant
    n_queries: int = 96
    seed: int = 0
    p_active: float = 0.35


def _week_or(tenant: str, week: int) -> str:
    # shared template: keeps this stream plan-cache-compatible with the
    # apps.bitmap_index service-client path
    return week_or(week, prefix=f"{tenant}/")


def build_service(spec: WorkloadSpec, n_banks: int = 8,
                  telemetry=None, **kwargs) -> QueryService:
    """Populate a service catalog with every tenant's vectors.

    `telemetry` passes through to `QueryService` (a `repro.obs.Telemetry`
    or `NULL_TELEMETRY`; None keeps the service default of metrics-on /
    tracing-off), as do any extra keyword arguments — benchmarks use
    `optimize=False` to build the unoptimized baseline side.
    """
    rng = np.random.default_rng(spec.seed)
    svc = QueryService(n_banks=n_banks, telemetry=telemetry, **kwargs)
    m = spec.domain_bits
    for t in range(spec.n_tenants):
        tenant = f"t{t}"
        for w in range(spec.n_weeks):
            for d in range(7):
                bits = rng.random(m) < spec.p_active
                svc.register_bits(f"{tenant}/w{w}d{d}", bits, group=tenant)
        svc.register_bits(f"{tenant}/male", rng.random(m) < 0.5, group=tenant)
        for s in range(spec.n_sets):
            svc.register_bits(f"{tenant}/s{s}", rng.random(m) < 0.4,
                              group=tenant)
        svc.register_column(f"{tenant}/col",
                            rng.integers(0, 1 << spec.col_bits, m,
                                         dtype=np.uint32),
                            spec.col_bits, group=tenant)
        svc.register_column(f"{tenant}/col2",
                            rng.integers(0, 1 << spec.col_bits, m,
                                         dtype=np.uint32),
                            spec.col_bits, group=tenant)
    return svc


def query_stream(spec: WorkloadSpec, svc: QueryService) -> List[Query]:
    """A mixed, repetitive multi-tenant stream of `n_queries` queries."""
    rng = np.random.default_rng(spec.seed + 1)
    # a few fixed range predicates per tenant so scans repeat
    bounds: List[Tuple[int, int]] = []
    for _ in range(3):
        lo = int(rng.integers(0, (1 << spec.col_bits) - 1))
        hi = int(rng.integers(lo, 1 << spec.col_bits))
        bounds.append((lo, hi))

    def weekly(t: str, w: int) -> Query:
        return Query(_week_or(t, w), POPCOUNT, tenant=t)

    def every_week(t: str) -> Query:
        text = " & ".join(_week_or(t, w) for w in range(spec.n_weeks))
        return Query(text, POPCOUNT, tenant=t)

    def male_week(t: str, w: int) -> Query:
        return Query(f"{_week_or(t, w)} & {t}/male", POPCOUNT, tenant=t)

    def range_scan(t: str, which: int) -> Query:
        lo, hi = bounds[which]
        return Query(svc.range_scan_query(f"{t}/col", lo, hi),
                     POPCOUNT, tenant=t)

    def intersect(t: str, k: int) -> Query:
        text = " & ".join(f"{t}/s{s}" for s in range(k))
        return Query(text, POPCOUNT, tenant=t)

    def union_diff(t: str) -> Query:
        return Query(f"({t}/s0 | {t}/s1 | {t}/s2) & ~{t}/s3",
                     POPCOUNT, tenant=t)

    def sum_col(t: str) -> Query:
        return Query(f"sum({t}/col)", AGGREGATE, tenant=t)

    def lt_filter(t: str, which: int) -> Query:
        lo, _ = bounds[which]
        k = max(1, lo)  # grammar rejects constant predicates (k == 0)
        return Query(f"{t}/col < {k} & {t}/male", POPCOUNT, tenant=t)

    def sum_add(t: str) -> Query:
        return Query(f"sum({t}/col + {t}/col2)", AGGREGATE, tenant=t)

    queries: List[Query] = []
    while len(queries) < spec.n_queries:
        t = f"t{int(rng.integers(spec.n_tenants))}"
        kind = int(rng.integers(9))
        if kind == 0:
            queries.append(weekly(t, int(rng.integers(spec.n_weeks))))
        elif kind == 1:
            queries.append(every_week(t))
        elif kind == 2:
            queries.append(male_week(t, int(rng.integers(spec.n_weeks))))
        elif kind == 3:
            queries.append(range_scan(t, int(rng.integers(len(bounds)))))
        elif kind == 4:
            queries.append(intersect(t, int(rng.integers(2, spec.n_sets))))
        elif kind == 5:
            queries.append(union_diff(t))
        elif kind == 6:
            queries.append(sum_col(t))
        elif kind == 7:
            queries.append(lt_filter(t, int(rng.integers(len(bounds)))))
        else:
            queries.append(sum_add(t))
    return queries
