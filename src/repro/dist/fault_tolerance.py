"""Fault-tolerant execution: checkpointed loops with failure recovery, an
EMA-based straggler detector, and the scheduler-facing chaos policy.

`ResilientRunner` wraps a step function with periodic checkpointing and
replay-from-last-checkpoint on (simulated or real) failures; a fresh runner
pointed at the same checkpoint directory resumes where the previous job
stopped — the crash/preemption story for long runs (serving streams use it
through `QueryService.serve_stream`).

`FaultTolerance` is the per-dispatch policy `service.scheduler.Scheduler`
consults around every plan-group launch: failures are replayed (after an
optional chip-failure recovery hook — `QueryService` installs an elastic
rescale-down there), slow groups are flagged by the `StragglerMonitor`, and
everything lands on a timeline the chaos suite asserts against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

from repro.checkpoint.checkpointer import Checkpointer


class SimulatedFailure(RuntimeError):
    """Injected failure (chaos testing); treated exactly like a real one."""


class ChipFailure(SimulatedFailure):
    """A chip died mid-dispatch (chaos-injected or real device loss)."""

    def __init__(self, chip: int, message: str = ""):
        super().__init__(message or f"chip {chip} failed mid-dispatch")
        self.chip = chip


@dataclasses.dataclass
class RunReport:
    """What happened during one `ResilientRunner.run`."""

    steps_run: int = 0      # steps executed by THIS run (incl. replays)
    failures: int = 0
    restores: int = 0
    checkpoints: int = 0
    timeline: List[str] = dataclasses.field(default_factory=list)


class ResilientRunner:
    """Run `step_fn(state, step, data_fn(step))` to `total_steps` with
    checkpoints every `ckpt_every` steps and recovery on failure.

    On failure: restore the last checkpoint (or the initial state if none
    exists yet) and replay from there. On start: resume from the latest
    checkpoint in the directory if present (`timeline[0] == "resume@N"`).
    A final checkpoint is always written at `total_steps` so a subsequent
    job resumes exactly at the end of this one.
    """

    def __init__(self, step_fn: Callable, data_fn: Callable,
                 checkpointer: Checkpointer, ckpt_every: int = 100,
                 max_restores: int = 16, telemetry=None):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.ck = checkpointer
        self.ckpt_every = ckpt_every
        self.max_restores = max_restores
        if telemetry is None:
            from repro.obs.telemetry import NULL_TELEMETRY

            telemetry = NULL_TELEMETRY
        self.telemetry = telemetry

    def _event(self, counter: str, name: str, **args) -> None:
        tel = self.telemetry
        if tel.metering:
            tel.metrics.counter(counter).inc()
        if tel.tracing:
            tel.tracer.instant(name, **args)

    def _restore(self, init_state, rep: RunReport, event: str
                 ) -> Tuple[int, Any]:
        # an async save may still be writing the newest checkpoint: without
        # draining it first, latest_step()/restore() race the background
        # thread and can resume from a stale (or mid-rename) step
        self.ck.wait()
        latest = self.ck.latest_step()
        if latest is None:
            rep.timeline.append(f"{event}@start")
            return 0, init_state
        step, state, _ = self.ck.restore(init_state)
        rep.timeline.append(f"{event}@{step}")
        return step, state

    def run(self, init_state: Any, total_steps: int,
            failure_injector: Optional[Callable[[int], None]] = None
            ) -> Tuple[Any, RunReport]:
        rep = RunReport()
        state = init_state
        step = 0
        self.ck.wait()      # see _restore: never race an async save
        if self.ck.latest_step() is not None:
            step, state = self._restore(init_state, rep, "resume")
            rep.restores += 1
            self._event("stream_resumes_total", "stream_resume", step=step)
        restores_left = self.max_restores
        while step < total_steps:
            try:
                if failure_injector is not None:
                    failure_injector(step)
                batch = self.data_fn(step)
                state, _metrics = self.step_fn(state, step, batch)
                rep.steps_run += 1
                step += 1
                if step % self.ckpt_every == 0 and step < total_steps:
                    self.ck.save(step, state)
                    rep.checkpoints += 1
                    rep.timeline.append(f"ckpt@{step}")
                    self._event("checkpoints_total", "checkpoint",
                                step=step)
            except Exception as e:  # noqa: BLE001 - any failure is recoverable
                rep.failures += 1
                rep.timeline.append(f"failure@{step}:{type(e).__name__}")
                self._event("stream_failures_total", "stream_failure",
                            step=step, error=type(e).__name__)
                restores_left -= 1
                if restores_left < 0:
                    raise
                step, state = self._restore(init_state, rep, "restore")
                rep.restores += 1
                self._event("stream_restores_total", "stream_restore",
                            step=step)
        self.ck.save(total_steps, state)
        rep.checkpoints += 1
        rep.timeline.append(f"ckpt@{total_steps}")
        self._event("checkpoints_total", "checkpoint", step=total_steps)
        self.ck.wait()
        return state, rep


class StragglerMonitor:
    """EMA step-time tracker flagging outlier steps as stragglers.

    `observe(step, seconds)` returns True when the step exceeds
    `threshold` x the EMA. Outliers do NOT update the EMA (one slow step
    must not mask the next), and the first `warmup` observations only seed
    the average.
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 3.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0

    def observe(self, step: int, seconds: float) -> bool:
        self.n += 1
        if self.ema is None:
            self.ema = seconds
            return False
        if self.n > self.warmup and seconds > self.threshold * self.ema:
            return True  # straggler; EMA untouched
        self.ema = self.alpha * seconds + (1 - self.alpha) * self.ema
        return False


@dataclasses.dataclass
class FaultTolerance:
    """Per-plan-group fault policy + live chaos state for the scheduler.

    The scheduler wraps every plan-group dispatch: on an exception the
    group is replayed up to ``max_replays`` times, calling
    ``on_chip_failure`` first (`QueryService` installs an elastic
    rescale-down handler there, so a dead chip's work re-lands on the
    surviving mesh); each successful dispatch is timed through ``monitor``
    and flagged groups are recorded. ``failure_injector(group_idx)`` is
    the chaos hook — it runs *inside* the timed/guarded window, so an
    injector that raises simulates a chip dying mid-dispatch and one that
    sleeps registers as a straggler.

    ``timeline`` collects ``failure@groupN:Exc`` / ``replay@groupN`` /
    ``straggler@groupN`` / ``rescale@C->C'`` events in dispatch order —
    the observable record tests/test_chaos.py asserts against.
    """

    max_replays: int = 2
    monitor: StragglerMonitor = dataclasses.field(
        default_factory=StragglerMonitor)
    #: chaos hook: called with the global plan-group index before dispatch
    failure_injector: Optional[Callable[[int], None]] = None
    #: recovery hook: called with the exception before each replay
    on_chip_failure: Optional[Callable[[BaseException], None]] = None

    def __post_init__(self):
        self.timeline: List[str] = []
        self.stragglers: List[int] = []
        self.failures = 0
        self.replays = 0
        self.groups_dispatched = 0
