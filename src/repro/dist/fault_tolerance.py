"""Fault-tolerant training loop: checkpointed execution with failure
recovery, plus an EMA-based straggler detector.

`ResilientRunner` wraps a step function with periodic checkpointing and
replay-from-last-checkpoint on (simulated or real) failures; a fresh runner
pointed at the same checkpoint directory resumes where the previous job
stopped — the crash/preemption story for long training runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

from repro.checkpoint.checkpointer import Checkpointer


class SimulatedFailure(RuntimeError):
    """Injected failure (chaos testing); treated exactly like a real one."""


@dataclasses.dataclass
class RunReport:
    """What happened during one `ResilientRunner.run`."""

    steps_run: int = 0      # steps executed by THIS run (incl. replays)
    failures: int = 0
    restores: int = 0
    checkpoints: int = 0
    timeline: List[str] = dataclasses.field(default_factory=list)


class ResilientRunner:
    """Run `step_fn(state, step, data_fn(step))` to `total_steps` with
    checkpoints every `ckpt_every` steps and recovery on failure.

    On failure: restore the last checkpoint (or the initial state if none
    exists yet) and replay from there. On start: resume from the latest
    checkpoint in the directory if present (`timeline[0] == "resume@N"`).
    A final checkpoint is always written at `total_steps` so a subsequent
    job resumes exactly at the end of this one.
    """

    def __init__(self, step_fn: Callable, data_fn: Callable,
                 checkpointer: Checkpointer, ckpt_every: int = 100,
                 max_restores: int = 16):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.ck = checkpointer
        self.ckpt_every = ckpt_every
        self.max_restores = max_restores

    def _restore(self, init_state, rep: RunReport, event: str
                 ) -> Tuple[int, Any]:
        latest = self.ck.latest_step()
        if latest is None:
            rep.timeline.append(f"{event}@start")
            return 0, init_state
        step, state, _ = self.ck.restore(init_state)
        rep.timeline.append(f"{event}@{step}")
        return step, state

    def run(self, init_state: Any, total_steps: int,
            failure_injector: Optional[Callable[[int], None]] = None
            ) -> Tuple[Any, RunReport]:
        rep = RunReport()
        state = init_state
        step = 0
        if self.ck.latest_step() is not None:
            step, state = self._restore(init_state, rep, "resume")
            rep.restores += 1
        restores_left = self.max_restores
        while step < total_steps:
            try:
                if failure_injector is not None:
                    failure_injector(step)
                batch = self.data_fn(step)
                state, _metrics = self.step_fn(state, step, batch)
                rep.steps_run += 1
                step += 1
                if step % self.ckpt_every == 0 and step < total_steps:
                    self.ck.save(step, state)
                    rep.checkpoints += 1
                    rep.timeline.append(f"ckpt@{step}")
            except Exception as e:  # noqa: BLE001 - any failure is recoverable
                rep.failures += 1
                rep.timeline.append(f"failure@{step}:{type(e).__name__}")
                restores_left -= 1
                if restores_left < 0:
                    raise
                step, state = self._restore(init_state, rep, "restore")
                rep.restores += 1
        self.ck.save(total_steps, state)
        rep.checkpoints += 1
        rep.timeline.append(f"ckpt@{total_steps}")
        self.ck.wait()
        return state, rep


class StragglerMonitor:
    """EMA step-time tracker flagging outlier steps as stragglers.

    `observe(step, seconds)` returns True when the step exceeds
    `threshold` x the EMA. Outliers do NOT update the EMA (one slow step
    must not mask the next), and the first `warmup` observations only seed
    the average.
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 3.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0

    def observe(self, step: int, seconds: float) -> bool:
        self.n += 1
        if self.ema is None:
            self.ema = seconds
            return False
        if self.n > self.warmup and seconds > self.threshold * self.ema:
            return True  # straggler; EMA untouched
        self.ema = self.alpha * seconds + (1 - self.alpha) * self.ema
        return False
