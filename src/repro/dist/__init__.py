"""Distributed-execution utilities: logical sharding rules, fault-tolerant
training loops, and elastic rescale planning.

This package was referenced throughout the seed (models, kernels, launch,
train) but absent from it; it is reconstructed here against the behavior the
tests and call sites pin down. Everything degrades gracefully on older JAX
(no `shard_map`/`pvary`): sharding constraints become identity outside an
`axis_rules` context and `match_vma` is a no-op when vma typing is absent.
"""
