"""Elastic rescale planning: preserve the global batch when the number of
data-parallel shards changes (node loss / capacity growth).

Checkpoint leaves are stored unsharded (see `repro.checkpoint`), so an
elastic restart only needs a plan for the new schedule: keep the per-shard
microbatch fixed and absorb the shard-count change into gradient
accumulation — optimizer state and LR schedule stay step-identical.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    """New-layout execution plan with the same global batch."""

    global_batch: int
    per_shard_batch: int   # per-shard microbatch (unchanged across rescale)
    grad_accum: int        # accumulation steps on the NEW layout
    new_mesh_shards: int

    @property
    def effective_batch(self) -> int:
        return self.per_shard_batch * self.new_mesh_shards * self.grad_accum


def plan_rescale(global_batch: int, old_mesh_shards: int,
                 new_mesh_shards: int, old_accum: int = 1) -> RescalePlan:
    """Plan for moving `global_batch` from old to new shard count.

    per_shard = global / (old_shards * old_accum) is held fixed;
    grad_accum on the new layout becomes global / (new_shards * per_shard).
    Raises if the global batch cannot be preserved exactly.
    """
    if global_batch % (old_mesh_shards * old_accum):
        raise ValueError(
            f"global_batch {global_batch} not divisible by old layout "
            f"{old_mesh_shards}x{old_accum}")
    per_shard = global_batch // (old_mesh_shards * old_accum)
    if global_batch % (new_mesh_shards * per_shard):
        raise ValueError(
            f"global_batch {global_batch} not preservable on "
            f"{new_mesh_shards} shards with per-shard batch {per_shard}")
    accum = global_batch // (new_mesh_shards * per_shard)
    return RescalePlan(global_batch=global_batch, per_shard_batch=per_shard,
                       grad_accum=accum, new_mesh_shards=new_mesh_shards)
