"""Logical-axis sharding: named rules resolved against a physical mesh.

Model code annotates tensors with *logical* dimension names ("batch",
"heads", "mlp", ...). A rules table maps each logical name to an ordered
tuple of candidate *physical* mesh axes; `resolve_spec` turns (shape,
names, mesh, rules) into a concrete `PartitionSpec` with two safety
properties the tests pin down:

  * divisibility fallback — a dimension that a candidate axis does not
    divide evenly is replicated rather than unevenly sharded (so batch=1
    decode or kv_heads < model-parallelism never produce invalid specs);
  * no axis reuse — one physical axis shards at most one dimension of a
    given tensor (first logical name wins, later ones replicate).

`axis_rules(mesh, rules)` installs a context; `constrain(x, *names)`
applies `with_sharding_constraint` inside it and is the identity outside
(or under `axis_rules(None)`, which disables constraints inside shard_map
manual regions).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Rules = Dict[str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# rule tables (policy variants used by launch/plans.py cells)
# ---------------------------------------------------------------------------

# bulk-bitwise cluster execution (core/cluster.py): the word-shard "chip"
# axis maps onto the physical chip mesh axis; the per-chip "bank" axis
# stays a local batch dimension (banks never leave their chip — a Buddy op
# is contained in one subarray). Single source for the chip-axis mapping;
# DEFAULT_RULES folds it in so `constrain`-style callers resolve it too.
CLUSTER_RULES: Rules = {"chip": ("chip",), "bank": ()}

DEFAULT_RULES: Rules = {
    **CLUSTER_RULES,
    # activations
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": (),
    "embed_act": (),
    # params
    "fsdp": ("data",),
    "embed": (),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "kv_flat": ("model",),
    "head_dim": (),
    "vocab": ("model",),
    "experts": ("model",),
    "state": (),
    "conv": (),
    "conv_w": (),
    "conv_b": (),
    "groups": (),
    "patches": (),
}

# data-parallel-only: params replicated across the dp axes (the model axis
# stays GSPMD-auto); used by the compressed signum/majority train step.
DP_RULES: Rules = {**DEFAULT_RULES, "fsdp": ()}

# sequence parallelism: long-context activations shard their seq dim.
SP_RULES: Rules = {**DEFAULT_RULES, "seq": ("model",)}

# decode-time sequence parallelism: the KV cache shards over model.
DECODE_SP_RULES: Rules = {**DEFAULT_RULES, "kv_seq": ("model",),
                          "kv_flat": ("model",)}


# ---------------------------------------------------------------------------
# context
# ---------------------------------------------------------------------------

_CTX = threading.local()


def _stack() -> List[Tuple[Any, Optional[Rules]]]:
    if not hasattr(_CTX, "stack"):
        _CTX.stack = []
    return _CTX.stack


@contextlib.contextmanager
def axis_rules(mesh=None, rules: Optional[Rules] = None):
    """Install (mesh, rules) for `constrain`/`current_mesh`/`current_rules`.

    `axis_rules(None)` pushes a *disabled* context: constraints inside are
    the identity even if an outer context is active (needed inside
    shard_map manual regions where constraint specs cannot be applied).
    """
    if mesh is not None and rules is None:
        rules = DEFAULT_RULES
    _stack().append((mesh, rules))
    try:
        yield
    finally:
        _stack().pop()


def current_mesh():
    """Mesh of the innermost `axis_rules` context (None if disabled/absent)."""
    s = _stack()
    return s[-1][0] if s else None


def current_rules() -> Optional[Rules]:
    """Rules of the innermost `axis_rules` context (None if disabled/absent)."""
    s = _stack()
    return s[-1][1] if s else None


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------


def _mesh_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_spec(shape: Sequence[int], names: Sequence[Optional[str]],
                 mesh, rules: Optional[Rules] = None) -> P:
    """Resolve logical dim names to a PartitionSpec for `mesh`.

    Per dimension: walk the rule's candidate axes in order, taking each
    axis that (a) exists in the mesh, (b) is not already used by an
    earlier dimension of this tensor, and (c) keeps the dimension evenly
    divisible by the product of taken axis sizes. No taken axes (or name
    None / unknown) -> replicated.
    """
    if rules is None:
        rules = current_rules() or DEFAULT_RULES
    sizes = _mesh_sizes(mesh)
    used: set = set()
    out: List[Any] = []
    for dim, name in zip(shape, names):
        if name is None:
            out.append(None)
            continue
        axes = rules.get(name, ())
        if isinstance(axes, str):
            axes = (axes,)
        taken: List[str] = []
        prod = 1
        for a in axes:
            if a not in sizes or a in used:
                continue
            if dim % (prod * sizes[a]) != 0:
                continue  # this axis doesn't divide; later ones may
            taken.append(a)
            prod *= sizes[a]
        used.update(taken)
        if not taken:
            out.append(None)
        elif len(taken) == 1:
            out.append(taken[0])
        else:
            out.append(tuple(taken))
    return P(*out)


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """`with_sharding_constraint(x, resolve_spec(...))` under an active
    `axis_rules` context; the identity (same object) outside one."""
    mesh, rules = (_stack()[-1] if _stack() else (None, None))
    if mesh is None or rules is None:
        return x
    spec = resolve_spec(x.shape, names, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def strip_axes(rules: Rules, axes: Sequence[str]) -> Rules:
    """Rules with the given physical axes removed from every entry."""
    drop = set(axes)
    return {k: tuple(a for a in v if a not in drop) for k, v in rules.items()}


def _is_spec_leaf(x) -> bool:
    return x is None or (isinstance(x, tuple)
                         and all(e is None or isinstance(e, str) for e in x))


def tree_shardings(shapes: Any, specs: Any, mesh,
                   rules: Optional[Rules] = None) -> Any:
    """NamedSharding tree for `shapes` (leaves with .shape) given a
    matching tree of logical-name tuples (`specs`)."""
    if rules is None:
        rules = current_rules() or DEFAULT_RULES
    flat_shapes, treedef = jax.tree.flatten(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=_is_spec_leaf)
    assert len(flat_shapes) == len(flat_specs), \
        (len(flat_shapes), len(flat_specs))
    out = []
    for leaf, names in zip(flat_shapes, flat_specs):
        if names is None:
            names = (None,) * len(leaf.shape)
        out.append(NamedSharding(
            mesh, resolve_spec(tuple(leaf.shape), names, mesh, rules)))
    return jax.tree.unflatten(treedef, out)


def match_vma(x: Any, ref: jax.Array) -> Any:
    """Make every leaf of `x` vary over (at least) the manual axes `ref`
    varies over — needed to seed scan/loop carries inside shard_map regions
    under vma typing. On JAX without `pvary`/`typeof` this is a no-op."""
    pvary = getattr(jax.lax, "pvary", None)
    typeof = getattr(jax, "typeof", None)
    if pvary is None or typeof is None:
        return x
    ref_vma = getattr(typeof(ref), "vma", frozenset())

    def one(a):
        have = getattr(typeof(a), "vma", frozenset())
        missing = tuple(sorted(ref_vma - have))
        return pvary(a, missing) if missing else a

    return jax.tree.map(one, x)
