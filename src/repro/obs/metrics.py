"""Metrics registry: counters, gauges, histograms + Prometheus text export.

One `MetricsRegistry` replaces the three ad-hoc stat surfaces the serving
stack grew (`PlanCache` hit/miss integers, `Scheduler` running totals,
`FaultTolerance` event counters): every layer increments named instruments
in the registry the `QueryService` owns, and `QueryService.stats()` is a
read-through view of it (old keys kept as aliases).

Instruments are memoized by ``(name, labels)`` so call sites can hold a
reference once and pay a bare attribute add per event:

    m = registry.counter("queries_total", tenant="t0")
    m.inc()

`NULL_METRICS` is the no-op twin: every instrument method does nothing, so
un-telemetered components (a bare `Scheduler`, the default `QueryService`
path when metrics are off) keep their hot loops allocation-free. Callers
that would *build* label kwargs should still guard on
`Telemetry.metering` — constructing the kwargs dict is the allocation.

Histograms retain raw samples (bounded) so percentiles use the *same*
nearest-rank formula as `service.scheduler.BatchReport.latency_percentile_ns`
— the registry's p50/p99 and the batch report's agree exactly
(tests/test_obs.py asserts it).
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple, Union

#: histogram sample-retention cap; counts/sums stay exact beyond it
HISTOGRAM_CAP = 65536

LabelKey = Tuple[str, Tuple[Tuple[str, str], ...]]


class Counter:
    """Monotonically increasing total."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """A value that goes up and down (last write wins)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Sample accumulator with exact count/sum and bounded raw retention.

    `percentile(pct)` uses the nearest-rank rule of
    `BatchReport.latency_percentile_ns` so the registry's latency
    percentiles and the batch report's match bit-for-bit while every
    sample is retained (the first `HISTOGRAM_CAP` observations; count and
    sum stay exact forever).
    """

    __slots__ = ("count", "total", "samples")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if len(self.samples) < HISTOGRAM_CAP:
            self.samples.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, pct: float) -> float:
        lats = sorted(self.samples)
        if not lats:
            return 0.0
        i = min(len(lats) - 1, int(math.ceil(pct / 100.0 * len(lats))) - 1)
        return lats[max(i, 0)]


class _NullInstrument:
    """No-op counter/gauge/histogram standing in for all three."""

    __slots__ = ()
    value = 0.0
    count = 0
    total = 0.0
    mean = 0.0
    samples: List[float] = []

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, pct: float) -> float:
        return 0.0


_NULL_INSTRUMENT = _NullInstrument()


def _key(name: str, labels: Dict[str, str]) -> LabelKey:
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


def _label_str(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class MetricsRegistry:
    """Named, labeled instruments with a flat snapshot and text export."""

    def __init__(self):
        self._counters: Dict[LabelKey, Counter] = {}
        self._gauges: Dict[LabelKey, Gauge] = {}
        self._histograms: Dict[LabelKey, Histogram] = {}

    def counter(self, name: str, **labels: str) -> Counter:
        key = _key(name, labels)
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels: str) -> Gauge:
        key = _key(name, labels)
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, **labels: str) -> Histogram:
        key = _key(name, labels)
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram()
        return h

    # -- export --------------------------------------------------------------

    def snapshot(self) -> Dict[str, Union[int, float]]:
        """Flat ``name{label="v"} -> value`` view (histograms expand to
        ``_count`` / ``_sum`` / ``_p50`` / ``_p99`` pseudo-series)."""
        out: Dict[str, Union[int, float]] = {}
        for (name, labels), c in sorted(self._counters.items()):
            out[f"{name}{_label_str(labels)}"] = c.value
        for (name, labels), g in sorted(self._gauges.items()):
            out[f"{name}{_label_str(labels)}"] = g.value
        for (name, labels), h in sorted(self._histograms.items()):
            ls = _label_str(labels)
            out[f"{name}_count{ls}"] = h.count
            out[f"{name}_sum{ls}"] = h.total
            out[f"{name}_p50{ls}"] = h.percentile(50)
            out[f"{name}_p99{ls}"] = h.percentile(99)
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (type-annotated, one final
        newline; histograms export summary-style count/sum/quantiles)."""
        lines: List[str] = []
        seen_type: set = set()

        def typeline(name: str, kind: str) -> None:
            if name not in seen_type:
                seen_type.add(name)
                lines.append(f"# TYPE {name} {kind}")

        for (name, labels), c in sorted(self._counters.items()):
            typeline(name, "counter")
            lines.append(f"{name}{_label_str(labels)} {c.value:g}")
        for (name, labels), g in sorted(self._gauges.items()):
            typeline(name, "gauge")
            lines.append(f"{name}{_label_str(labels)} {g.value:g}")
        for (name, labels), h in sorted(self._histograms.items()):
            typeline(name, "summary")
            for pct in (50, 99):
                q = dict(labels)
                q["quantile"] = f"0.{pct}"
                lines.append(f"{name}{_label_str(tuple(sorted(q.items())))} "
                             f"{h.percentile(pct):g}")
            lines.append(f"{name}_sum{_label_str(labels)} {h.total:g}")
            lines.append(f"{name}_count{_label_str(labels)} {h.count}")
        return "\n".join(lines) + "\n"


class NullMetrics(MetricsRegistry):
    """No-op registry: every instrument is the shared null singleton."""

    def __init__(self):  # deliberately no instrument dicts
        pass

    def counter(self, name: str, **labels: str):
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels: str):
        return _NULL_INSTRUMENT

    def histogram(self, name: str, **labels: str):
        return _NULL_INSTRUMENT

    def snapshot(self) -> Dict[str, Union[int, float]]:
        return {}

    def to_prometheus(self) -> str:
        return "\n"


NULL_METRICS = NullMetrics()
