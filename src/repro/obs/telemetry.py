"""Telemetry facade: one object bundling a tracer and a metrics registry.

`Telemetry` is what flows through the serving stack — `QueryService`
accepts ``telemetry=`` and hands it to the planner, scheduler, cluster
wrappers and fault-tolerance machinery. Two cheap booleans gate every
instrumentation site:

  * ``tel.tracing`` — span/trace emission is on (a real `Tracer`);
  * ``tel.metering`` — counter/gauge/histogram updates go to a real
    `MetricsRegistry`.

Call sites must test the boolean *before* building kwargs or f-strings,
so the disabled path costs one attribute load + branch and allocates
nothing (the contract `benchmarks/obs_overhead.py` gates).

`NULL_TELEMETRY` is the fully-off singleton used by bare components
(e.g. a `Scheduler` constructed without a service). The default
`QueryService` telemetry is `Telemetry(trace=False)`: metrics on (they
back `stats()` and cost what the old ad-hoc counters cost), tracing off.

Core layers (`core.engine`, `core.bankgroup`, `core.cluster`) have no
handle on the service object, so they consult the module-global set by
`set_telemetry` — `QueryService` installs its telemetry there for the
duration of a dispatch; the default global is `NULL_TELEMETRY`.
"""
from __future__ import annotations

from typing import Optional

from repro.obs.metrics import NULL_METRICS, MetricsRegistry
from repro.obs.trace import (
    NULL_TRACER,
    Tracer,
    validate_chrome_trace,
    write_chrome_trace,
)


class Telemetry:
    """A tracer + metrics registry with fast on/off flags."""

    def __init__(self, trace: bool = True, metrics: bool = True):
        self.tracer = Tracer() if trace else NULL_TRACER
        self.metrics = MetricsRegistry() if metrics else NULL_METRICS
        self.tracing = bool(trace)
        self.metering = bool(metrics)

    def reset_trace(self) -> None:
        self.tracer.reset()

    def export_chrome_trace(self, path=None):
        """The Chrome trace payload; validated + written when `path` given."""
        payload = self.tracer.export()
        if path is not None:
            return write_chrome_trace(payload, path)
        validate_chrome_trace(payload)
        return payload

    def prometheus(self) -> str:
        return self.metrics.to_prometheus()


class _NullTelemetry(Telemetry):
    """Fully-disabled telemetry: shared null tracer + null metrics."""

    def __init__(self):
        self.tracer = NULL_TRACER
        self.metrics = NULL_METRICS
        self.tracing = False
        self.metering = False


NULL_TELEMETRY = _NullTelemetry()

#: process-wide telemetry consulted by core layers (engine/bankgroup/
#: cluster) that have no service handle; NULL by default.
_GLOBAL: Telemetry = NULL_TELEMETRY


def get_telemetry() -> Telemetry:
    return _GLOBAL


def set_telemetry(tel: Optional[Telemetry]) -> Telemetry:
    """Install `tel` as the process-wide telemetry; returns the previous
    one so callers can restore it (`None` resets to `NULL_TELEMETRY`)."""
    global _GLOBAL
    prev = _GLOBAL
    _GLOBAL = tel if tel is not None else NULL_TELEMETRY
    return prev


__all__ = [
    "Telemetry",
    "NULL_TELEMETRY",
    "get_telemetry",
    "set_telemetry",
]
