"""Structured tracer: per-query span trees + modeled timelines, exported as
Chrome trace-event JSON (loadable in `chrome://tracing` / Perfetto).

Two clocks share one trace:

  * **wall events** (pid `WALL_PID`) — `begin`/`end`/`span` record real
    `time.perf_counter` durations of serving stages (parse -> plan/cache ->
    bind -> group -> dispatch -> readout), nested by stack discipline on
    one thread track;
  * **modeled events** (pid `MODEL_PID`) — `model_event` places duration
    events on *virtual* tracks at modeled-nanosecond timestamps: the
    scheduler's per-chip bus / per-bank compute timeline, per-query
    latency summaries, and the cluster's tree-psum reduction hops. The
    modeled clock starts at 0 per batch epoch.

Every emitted event carries ``name``/``ph``/``ts``/``pid``/``tid`` (the
schema `validate_chrome_trace` enforces and tests/test_obs.py pins down);
``ts`` is microseconds as the trace-event spec requires, so modeled
nanoseconds are divided by 1e3 on the way out.

`NULL_TRACER` is the disabled twin: `tracing` is False and every method is
a no-op. Instrumentation sites must guard anything that allocates (kwargs
dicts, f-strings) behind ``if tracer.tracing:`` so the disabled serving
path stays allocation-free — the contract `benchmarks/obs_overhead.py`
gates at < 3% overhead.
"""
from __future__ import annotations

import contextlib
import json
import pathlib
import time
from typing import Dict, List, Tuple, Union

WALL_PID = 1
MODEL_PID = 2

Json = Dict[str, Union[str, int, float, dict]]


class Tracer:
    """Records Chrome trace events; single-threaded stack discipline."""

    tracing = True

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self.reset()

    def reset(self) -> None:
        """Drop all recorded events and restart the wall clock at 0."""
        self.events: List[Json] = []
        self._open = 0                  # B events awaiting their E
        self._tids: Dict[Tuple[int, str], int] = {}
        self._t0 = self._clock()
        self._meta(WALL_PID, "process_name", name="serving (wall clock)")
        self._meta(MODEL_PID, "process_name", name="modeled DRAM timeline")
        self._tid(WALL_PID, "serve")    # the one real thread

    # -- plumbing ------------------------------------------------------------

    def _now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _meta(self, pid: int, kind: str, tid: int = 0, **args) -> None:
        self.events.append({"name": kind, "ph": "M", "ts": 0.0,
                            "pid": pid, "tid": tid, "args": args})

    def _tid(self, pid: int, track: str) -> int:
        """Stable per-(pid, track-name) thread id + its metadata event."""
        key = (pid, track)
        tid = self._tids.get(key)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[key] = tid
            self._meta(pid, "thread_name", tid=tid, name=track)
        return tid

    # -- wall-clock spans ----------------------------------------------------

    def begin(self, name: str, **args) -> None:
        self._open += 1
        self.events.append({"name": name, "ph": "B", "ts": self._now_us(),
                            "pid": WALL_PID, "tid": self._tids[(WALL_PID,
                                                                "serve")],
                            "args": args})

    def end(self, **args) -> None:
        if self._open <= 0:
            raise ValueError("Tracer.end() without a matching begin()")
        self._open -= 1
        self.events.append({"name": "", "ph": "E", "ts": self._now_us(),
                            "pid": WALL_PID, "tid": self._tids[(WALL_PID,
                                                                "serve")],
                            "args": args})

    @contextlib.contextmanager
    def span(self, name: str, **args):
        self.begin(name, **args)
        try:
            yield self
        finally:
            self.end()

    def instant(self, name: str, **args) -> None:
        self.events.append({"name": name, "ph": "i", "ts": self._now_us(),
                            "pid": WALL_PID,
                            "tid": self._tids[(WALL_PID, "serve")],
                            "s": "t", "args": args})

    # -- modeled timeline ----------------------------------------------------

    def model_event(self, name: str, ts_ns: float, dur_ns: float,
                    track: str, **args) -> None:
        """A duration ("X") event at modeled time on a named virtual track
        (e.g. ``chip0/bus``, ``chip0/bank3``, ``reduce``)."""
        self.events.append({"name": name, "ph": "X", "ts": ts_ns / 1e3,
                            "dur": dur_ns / 1e3, "pid": MODEL_PID,
                            "tid": self._tid(MODEL_PID, track),
                            "args": args})

    def counter_event(self, name: str, ts_ns: float, track: str,
                      **values) -> None:
        """A counter ("C") sample at modeled time: Chrome renders each
        named series (queue depth, occupancy, ...) as a stacked area
        chart over the timeline. Values must be numeric."""
        self.events.append({"name": name, "ph": "C", "ts": ts_ns / 1e3,
                            "pid": MODEL_PID,
                            "tid": self._tid(MODEL_PID, track),
                            "args": values})

    # -- export --------------------------------------------------------------

    def export(self) -> Json:
        """The Chrome trace payload (open spans are NOT auto-closed)."""
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}


class NullTracer:
    """Disabled tracer: every method is a cheap no-op."""

    tracing = False
    events: List[Json] = []

    def reset(self) -> None:
        pass

    def begin(self, name: str, **args) -> None:
        pass

    def end(self, **args) -> None:
        pass

    def span(self, name: str, **args):
        return _NULL_CM

    def instant(self, name: str, **args) -> None:
        pass

    def model_event(self, name: str, ts_ns: float, dur_ns: float,
                    track: str, **args) -> None:
        pass

    def counter_event(self, name: str, ts_ns: float, track: str,
                      **values) -> None:
        pass

    def export(self) -> Json:
        return {"traceEvents": [], "displayTimeUnit": "ms"}


class _ReusableNullCM:
    """A single shared no-op context manager (no per-use allocation)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CM = _ReusableNullCM()
NULL_TRACER = NullTracer()


def validate_chrome_trace(payload: Json) -> None:
    """Raise ValueError unless `payload` is schema-valid trace-event JSON.

    Enforced: a ``traceEvents`` list; every event has ``name``/``ph``/
    ``ts``/``pid``/``tid`` with numeric non-negative ``ts``; ``X`` events
    carry a non-negative ``dur``; ``C`` counter samples carry an args
    dict of numeric series values; ``B``/``E`` events balance with LIFO
    discipline per ``(pid, tid)`` track. This is the schema test the
    acceptance criteria (and any trace consumer) rely on.
    """
    events = payload.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("payload has no traceEvents list")
    stacks: Dict[Tuple, int] = {}
    for i, ev in enumerate(events):
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i} missing {field!r}: {ev}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} has bad ts {ev['ts']!r}")
        ph = ev["ph"]
        key = (ev["pid"], ev["tid"])
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"X event {i} has bad dur: {ev}")
        elif ph == "C":
            args = ev.get("args")
            if (not isinstance(args, dict) or not args
                    or not all(isinstance(v, (int, float))
                               for v in args.values())):
                raise ValueError(f"C event {i} needs numeric args: {ev}")
        elif ph == "B":
            stacks[key] = stacks.get(key, 0) + 1
        elif ph == "E":
            depth = stacks.get(key, 0)
            if depth <= 0:
                raise ValueError(f"E event {i} closes nothing on {key}")
            stacks[key] = depth - 1
    unbalanced = {k: d for k, d in stacks.items() if d}
    if unbalanced:
        raise ValueError(f"unclosed B events per track: {unbalanced}")


def write_chrome_trace(payload: Json, path) -> pathlib.Path:
    """Validate and write a trace payload to `path` as JSON."""
    validate_chrome_trace(payload)
    p = pathlib.Path(path)
    p.write_text(json.dumps(payload) + "\n")
    return p
