"""Unified observability layer: tracing, metrics, Chrome-trace export.

See `repro.obs.telemetry` for the facade the serving stack threads
through (`QueryService(telemetry=...)`), `repro.obs.trace` for the
span/timeline tracer and trace-event schema validator, and
`repro.obs.metrics` for the counter/gauge/histogram registry backing
`QueryService.stats()` and the Prometheus snapshot.
"""
from repro.obs.metrics import (
    HISTOGRAM_CAP,
    NULL_METRICS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullMetrics,
)
from repro.obs.telemetry import (
    NULL_TELEMETRY,
    Telemetry,
    get_telemetry,
    set_telemetry,
)
from repro.obs.trace import (
    MODEL_PID,
    NULL_TRACER,
    WALL_PID,
    NullTracer,
    Tracer,
    validate_chrome_trace,
    write_chrome_trace,
)

__all__ = [
    "HISTOGRAM_CAP",
    "NULL_METRICS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullMetrics",
    "NULL_TELEMETRY",
    "Telemetry",
    "get_telemetry",
    "set_telemetry",
    "MODEL_PID",
    "NULL_TRACER",
    "WALL_PID",
    "NullTracer",
    "Tracer",
    "validate_chrome_trace",
    "write_chrome_trace",
]
