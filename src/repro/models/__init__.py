from repro.models.registry import (ModelBundle, batch_logical_specs, build,
                                   input_specs)
