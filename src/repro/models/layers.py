"""Core transformer layers, pure JAX (no flax): norms, RoPE, GQA attention.

Conventions
-----------
* Params are plain pytrees (nested dicts of jnp arrays). Every init fn takes a
  PRNG key and returns (params, logical_specs) where logical_specs mirrors the
  param tree with tuples of *logical axis names* (resolved to mesh axes by
  `repro.dist.sharding`).
* Activations are (batch, seq, d_model) in cfg.dtype; softmax/statistics in
  f32.
* Training attention is a chunked (flash-style) online-softmax over KV blocks
  so the (S, S) logits matrix is never materialized — required for the 32k
  prefill shapes to fit HBM.
* Decode attention addresses a pre-allocated KV cache with
  `dynamic_update_slice` at the current position.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Dict[str, Any]

INIT_STD = 0.02


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, dtype, std: float = INIT_STD):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype) -> Tuple[jax.Array, Tuple]:
    return jnp.ones((dim,), dtype), ("embed",)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]   # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# GQA attention
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, d_model: Optional[int] = None
              ) -> Tuple[Params, Params]:
    D = d_model or cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, hd), dt),
        "wk": dense_init(ks[1], (D, KV, hd), dt),
        "wv": dense_init(ks[2], (D, KV, hd), dt),
        "wo": dense_init(ks[3], (H, hd, D), dt,
                         std=INIT_STD / np.sqrt(2 * max(cfg.n_layers, 1))),
    }
    s = {
        "wq": ("fsdp", "heads", "head_dim"),
        "wk": ("fsdp", "kv_heads", "head_dim"),
        "wv": ("fsdp", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KV, hd), dt)
        p["bv"] = jnp.zeros((KV, hd), dt)
        s["bq"] = ("heads", "head_dim")
        s["bk"] = ("kv_heads", "head_dim")
        s["bv"] = ("kv_heads", "head_dim")
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
        s["q_norm"] = ("head_dim",)
        s["k_norm"] = ("head_dim",)
    return p, s


def _project_qkv(p: Params, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


NEG_INF = -1e30

# When enabled (the production default for train/prefill cells), each
# q-chunk row of the online-softmax attention is wrapped in jax.checkpoint,
# so the backward pass recomputes that row's (qc, kc) score/prob blocks
# instead of keeping every block live — a flash-attention-style backward in
# pure JAX. Peak per-layer attention memory drops from O(S^2) to
# O(S * kv_chunk) at the cost of ~1 extra attention forward in the backward.
import contextlib

_ATTN_REMAT = {"on": False}
_ATTN_BACKEND = {"name": "chunked"}   # chunked | flash (Pallas kernel)


@contextlib.contextmanager
def attention_remat(enabled: bool = True):
    prev = _ATTN_REMAT["on"]
    _ATTN_REMAT["on"] = enabled
    try:
        yield
    finally:
        _ATTN_REMAT["on"] = prev


@contextlib.contextmanager
def attention_backend(name: str):
    """'chunked' (pure-jnp online softmax) or 'flash' (Pallas TPU kernel,
    kernels/flashattn.py — q+k+v+o HBM traffic only)."""
    prev = _ATTN_BACKEND["name"]
    _ATTN_BACKEND["name"] = name
    try:
        yield
    finally:
        _ATTN_BACKEND["name"] = prev


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True,
                      q_chunk: int = 512, kv_chunk: int = 512) -> jax.Array:
    if _ATTN_BACKEND["name"] == "flash":
        from repro.kernels.flashattn import flash_attention
        return flash_attention(q, k, v, causal=causal,
                               block_q=q_chunk, block_k=kv_chunk)
    return _chunked_attention(q, k, v, causal=causal,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)


def _chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       causal: bool = True,
                       q_chunk: int = 512, kv_chunk: int = 512) -> jax.Array:
    """Flash-style online-softmax attention; never materializes (S, S).

    q: (B, Sq, H, hd);  k, v: (B, Sk, KV, hd) with H % KV == 0.
    Returns (B, Sq, H, hd). Causal assumes Sq == Sk and aligned positions.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad ragged sequence lengths (e.g. 1600 vision patches vs 512 chunks);
    # padded key positions are masked below, padded query rows sliced off.
    Sq_orig, Sk_orig = Sq, Sk
    pad_q, pad_k = (-Sq) % q_chunk, (-Sk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        Sq += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        Sk += pad_k
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = 1.0 / np.sqrt(hd)

    qb = q.reshape(B, nq, q_chunk, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 3, 2, 4)
    # qb: (nq, B, KV, G, qc, hd);  kb/vb: (nk, B, KV, kc, hd)

    def q_block(carry, q_in):
        from repro.dist.sharding import match_vma
        q_i, qidx = q_in   # (B, KV, G, qc, hd)
        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        m0, l0, a0 = match_vma((m0, l0, a0), q_i)

        def kv_block(c, kv_in):
            m, l, acc = c
            k_j, v_j, kidx = kv_in
            s = jnp.einsum("bkgqd,bksd->bkgqs", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            kpos = kidx * kv_chunk + jnp.arange(kv_chunk)
            if causal:
                qpos = qidx * q_chunk + jnp.arange(q_chunk)
                mask = (qpos[:, None] >= kpos[None, :]) & \
                    (kpos[None, :] < Sk_orig)
                s = jnp.where(mask, s, NEG_INF)
            elif pad_k:
                s = jnp.where(kpos[None, :] < Sk_orig, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            prob = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + prob.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", prob.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (kb, vb, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.astype(q.dtype)

    body = (jax.checkpoint(q_block,
                           policy=jax.checkpoint_policies.nothing_saveable)
            if _ATTN_REMAT["on"] else q_block)
    _, ob = jax.lax.scan(body, None, (qb, jnp.arange(nq)))
    # ob: (nq, B, KV, G, qc, hd) -> (B, S, H, hd)
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    return out[:, :Sq_orig]


def attention_train(p: Params, x: jax.Array, cfg: ModelConfig,
                    positions: Optional[jax.Array] = None,
                    causal: bool = True,
                    q_chunk: int = 512, kv_chunk: int = 512) -> jax.Array:
    """Self-attention over a full sequence (train / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = chunked_attention(q, k, v, causal=causal,
                          q_chunk=q_chunk, kv_chunk=kv_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def cross_attention_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    """Separate q (from decoder) and kv (from encoder/vision) projections."""
    return attn_init(key, cfg)


def cross_attention(p: Params, x: jax.Array, memory: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """x: (B, Sq, D) queries; memory: (B, Sm, D) keys/values. No RoPE/causal."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    o = chunked_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---- decode path ---------------------------------------------------------

def kv_cache_init(cfg: ModelConfig, n_layers: int, batch: int, max_len: int
                  ) -> Tuple[Params, Params]:
    """KV sheets use a flattened (KV*hd) trailing dim so tensor-parallel
    sharding works even when n_kv_heads < mesh model size (e.g. kv=8 on a
    16-way model axis: 8*128=1024 divides 16; GSPMD re-expresses the merged
    sharding as kv-major x head-dim-minor through the reshape)."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim_
    dt = _dtype(cfg)
    cache = {
        "k": jnp.zeros((n_layers, batch, max_len, KV * hd), dt),
        "v": jnp.zeros((n_layers, batch, max_len, KV * hd), dt),
    }
    specs = {"k": ("layers", "batch", "kv_seq", "kv_flat"),
             "v": ("layers", "batch", "kv_seq", "kv_flat")}
    return cache, specs


def attention_decode(p: Params, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, pos: jax.Array, cfg: ModelConfig
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, D); cache_{k,v}: (B, S_max, KV*hd);
    pos: scalar current position. Returns (out, new_k, new_v)."""
    B, _, _ = x.shape
    S_max = cache_k.shape[1]
    KV, hd = cfg.n_kv_heads, cfg.head_dim_
    H = cfg.n_heads
    G = H // KV
    positions = jnp.full((B, 1), pos)
    q, k, v = _project_qkv(p, x, cfg, positions)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.reshape(B, 1, KV * hd), (0, pos, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.reshape(B, 1, KV * hd), (0, pos, 0))
    k4 = cache_k.reshape(B, S_max, KV, hd)
    v4 = cache_v.reshape(B, S_max, KV, hd)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k4,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    valid = jnp.arange(S_max)[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1).astype(v4.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", prob, v4)
    out = jnp.einsum("bhk,hkd->bd", o.reshape(B, H, hd), p["wo"])[:, None, :]
    return out, cache_k, cache_v


# --------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# --------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None
             ) -> Tuple[Params, Params]:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    out_std = INIT_STD / np.sqrt(2 * max(cfg.n_layers, 1))
    if cfg.mlp_kind == "swiglu":
        # gate and up fused on the output dim: (D, 2, F)
        p = {"wi": dense_init(k1, (D, 2, F), dt),
             "wo": dense_init(k2, (F, D), dt, std=out_std)}
        s = {"wi": ("fsdp", None, "mlp"), "wo": ("mlp", "fsdp")}
    else:
        p = {"wi": dense_init(k1, (D, F), dt),
             "wo": dense_init(k2, (F, D), dt, std=out_std)}
        s = {"wi": ("fsdp", "mlp"), "wo": ("mlp", "fsdp")}
    return p, s


def mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp_kind == "swiglu":
        h = jnp.einsum("bsd,dcf->bscf", x, p["wi"])
        gate, up = h[:, :, 0], h[:, :, 1]
        a = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"])
        a = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", a, p["wo"])


# --------------------------------------------------------------------------
# Embedding / LM head / loss
# --------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    V, D = cfg.padded_vocab, cfg.d_model
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": dense_init(k1, (V, D), dt),
         "head": dense_init(k2, (D, V), dt)}
    s = {"tok": ("vocab", "fsdp"), "head": ("fsdp", "vocab")}
    return p, s


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(p: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("bsd,dv->bsv", x, p["head"])


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None,
                 z_loss: float = 1e-4) -> jax.Array:
    """Mean cross-entropy over valid positions, f32, with z-loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll + z_loss * jnp.square(lse)
    if mask is not None:
        return (loss * mask).sum() / jnp.maximum(mask.sum(), 1)
    return loss.mean()
