"""Decoder-only transformer stack (dense + MoE families) and shared stack
machinery (stacked-layer init, remat'd `lax.scan` over layers, LM loss).

Layer topology is kept scan-homogeneous by grouping: a MoE model with
`moe_every = k` scans over "super-layers" of (k-1 dense + 1 MoE) blocks, and
leading `n_dense_layers` dense blocks are unrolled (they are few). This keeps
the HLO O(1) in depth at 61-100 layers.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import moe as M

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# stacked init helper
# --------------------------------------------------------------------------

def init_stacked(key, n: int, init_fn: Callable) -> Tuple[Params, Params]:
    """Stack `n` independently-initialized copies of init_fn's params along a
    new leading 'layers' axis. init_fn: key -> (params, specs)."""
    _, specs = init_fn(jax.random.PRNGKey(0))
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    specs = jax.tree.map(lambda s: ("layers",) + s, specs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return params, specs


def remat_policy(name: str = "block"):
    """Activation-checkpoint policy for the scanned layer body."""
    if name == "full":            # save nothing; recompute everything
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":            # save matmul outputs with batch dims
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


# --------------------------------------------------------------------------
# dense / MoE block
# --------------------------------------------------------------------------

def dense_block_init(key, cfg: ModelConfig, d_ff: Optional[int] = None
                     ) -> Tuple[Params, Params]:
    k1, k2 = jax.random.split(key)
    attn_p, attn_s = L.attn_init(k1, cfg)
    mlp_p, mlp_s = L.mlp_init(k2, cfg, d_ff=d_ff)
    p = {"ln1": jnp.ones((cfg.d_model,), L._dtype(cfg)), "attn": attn_p,
         "ln2": jnp.ones((cfg.d_model,), L._dtype(cfg)), "mlp": mlp_p}
    s = {"ln1": ("embed",), "attn": attn_s, "ln2": ("embed",), "mlp": mlp_s}
    return p, s


def dense_block(p: Params, x: jax.Array, cfg: ModelConfig,
                q_chunk: int = 512, kv_chunk: int = 512) -> jax.Array:
    x = constrain(x, "batch", "seq", "embed_act")
    h = x + L.attention_train(p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                              cfg, q_chunk=q_chunk, kv_chunk=kv_chunk)
    h = h + L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg)
    return h


def moe_block_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    k1, k2 = jax.random.split(key)
    attn_p, attn_s = L.attn_init(k1, cfg)
    moe_p, moe_s = M.moe_init(k2, cfg)
    p = {"ln1": jnp.ones((cfg.d_model,), L._dtype(cfg)), "attn": attn_p,
         "ln2": jnp.ones((cfg.d_model,), L._dtype(cfg)), "moe": moe_p}
    s = {"ln1": ("embed",), "attn": attn_s, "ln2": ("embed",), "moe": moe_s}
    return p, s


def moe_block(p: Params, x: jax.Array, cfg: ModelConfig,
              q_chunk: int = 512, kv_chunk: int = 512
              ) -> Tuple[jax.Array, jax.Array]:
    x = constrain(x, "batch", "seq", "embed_act")
    h = x + L.attention_train(p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                              cfg, q_chunk=q_chunk, kv_chunk=kv_chunk)
    y, aux = M.moe_ffn(p["moe"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg)
    return h + y, aux


# --------------------------------------------------------------------------
# decode blocks
# --------------------------------------------------------------------------

def dense_block_decode(p: Params, x: jax.Array, ck: jax.Array, cv: jax.Array,
                       pos: jax.Array, cfg: ModelConfig):
    a, ck, cv = L.attention_decode(
        p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), ck, cv, pos, cfg)
    h = x + a
    h = h + L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg)
    return h, ck, cv


def moe_block_decode(p: Params, x: jax.Array, ck: jax.Array, cv: jax.Array,
                     pos: jax.Array, cfg: ModelConfig):
    a, ck, cv = L.attention_decode(
        p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), ck, cv, pos, cfg)
    h = x + a
    y, _ = M.moe_ffn(p["moe"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg)
    return h + y, ck, cv


# --------------------------------------------------------------------------
# dense / MoE model
# --------------------------------------------------------------------------

def transformer_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 4)
    emb_p, emb_s = L.embed_init(ks[0], cfg)
    p: Params = {"embed": emb_p,
                 "final_norm": jnp.ones((cfg.d_model,), L._dtype(cfg))}
    s: Params = {"embed": emb_s, "final_norm": ("embed",)}
    if cfg.family == "moe":
        n_lead = cfg.n_dense_layers
        if n_lead:
            lead_p, lead_s = init_stacked(
                ks[1], n_lead,
                lambda k: dense_block_init(k, cfg,
                                           d_ff=cfg.dense_d_ff or cfg.d_ff))
            p["lead"], s["lead"] = lead_p, lead_s
        n_groups = (cfg.n_layers - n_lead) // cfg.moe_every
        group_dense = cfg.moe_every - 1

        def group_init(k):
            kd, km = jax.random.split(k)
            gp, gs = {}, {}
            if group_dense:
                dp, ds = init_stacked(
                    kd, group_dense,
                    lambda kk: dense_block_init(kk, cfg,
                                                d_ff=cfg.dense_d_ff or cfg.d_ff))
                gp["dense"], gs["dense"] = dp, ds
            mp, ms = moe_block_init(km, cfg)
            gp["moe"], gs["moe"] = mp, ms
            return gp, gs

        gp, gs = init_stacked(ks[2], n_groups, group_init)
        p["groups"], s["groups"] = gp, gs
    else:
        lp, ls = init_stacked(ks[1], cfg.n_layers,
                              lambda k: dense_block_init(k, cfg))
        p["layers"], s["layers"] = lp, ls
    return p, s


def _chunks_for(cfg: ModelConfig, seq: int) -> Tuple[int, int]:
    c = min(512, seq)
    return c, c


def transformer_apply(params: Params, tokens: jax.Array, cfg: ModelConfig,
                      remat: str = "block") -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S) -> (hidden (B, S, D), aux_loss)."""
    qc, kc = _chunks_for(cfg, tokens.shape[1])
    x = L.embed(params["embed"], tokens)
    x = constrain(x, "batch", "seq", "embed_act")
    aux = jnp.zeros((), jnp.float32)
    policy = remat_policy(remat)
    if cfg.family == "moe":
        for i in range(cfg.n_dense_layers):
            lead_i = jax.tree.map(lambda a: a[i], params["lead"])
            x = dense_block(lead_i, x, cfg, qc, kc)

        @functools.partial(jax.checkpoint, policy=policy)
        def g_body(h, gp):
            if "dense" in gp:
                n_d = jax.tree.leaves(gp["dense"])[0].shape[0]
                for j in range(n_d):
                    dj = jax.tree.map(lambda a: a[j], gp["dense"])
                    h = dense_block(dj, h, cfg, qc, kc)
            h, a = moe_block(gp["moe"], h, cfg, qc, kc)
            return h, a

        x, auxs = jax.lax.scan(g_body, x, params["groups"])
        aux = aux + auxs.sum()
    else:
        @functools.partial(jax.checkpoint, policy=policy)
        def body(h, lp):
            return dense_block(lp, h, cfg, qc, kc), None

        x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


MOE_AUX_WEIGHT = 0.01


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            apply_fn=None, remat: str = "block"
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    apply_fn = apply_fn or transformer_apply
    x, aux = apply_fn(params, batch["tokens"], cfg, remat=remat)
    logits = L.lm_logits(params["embed"], x)
    logits = constrain(logits, "batch", "seq", "vocab")
    xent = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
    loss = xent + MOE_AUX_WEIGHT * aux
    return loss, {"xent": xent, "aux": aux}


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------

def transformer_prefill(params: Params, tokens: jax.Array, cfg: ModelConfig
                        ) -> Tuple[jax.Array, Params]:
    """Prefill run: returns (last-position logits (B, V), kv cache filled up
    to S). Cache layout matches kv_cache_init (layer-major)."""
    qc, kc = _chunks_for(cfg, tokens.shape[1])
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(S)[None, :]

    def run_block(p, h):
        xn = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
        q, k, v = L._project_qkv(p["attn"], xn, cfg, positions)
        o = L.chunked_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc)
        h = h + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        if "moe" in p:
            y, _ = M.moe_ffn(p["moe"], L.rmsnorm(h, p["ln2"], cfg.norm_eps),
                             cfg)
        else:
            y = L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg)
        # flat (KV*hd) cache layout — see kv_cache_init
        return h + y, k.reshape(B, S, -1), v.reshape(B, S, -1)

    ks, vs = [], []
    if cfg.family == "moe":
        for i in range(cfg.n_dense_layers):
            li = jax.tree.map(lambda a: a[i], params["lead"])
            x, k, v = run_block(li, x)
            ks.append(k); vs.append(v)

        def g_body(h, gp):
            outs_k, outs_v = [], []
            if "dense" in gp:
                n_d = jax.tree.leaves(gp["dense"])[0].shape[0]
                for j in range(n_d):
                    dj = jax.tree.map(lambda a: a[j], gp["dense"])
                    h, k, v = run_block(dj, h)
                    outs_k.append(k); outs_v.append(v)
            h, k, v = run_block(gp["moe"], h)
            outs_k.append(k); outs_v.append(v)
            return h, (jnp.stack(outs_k), jnp.stack(outs_v))

        x, (gk, gv) = jax.lax.scan(g_body, x, params["groups"])
        # gk: (n_groups, per_group, B, S, KV, hd) -> (L', B, S, KV, hd)
        gk = gk.reshape(-1, *gk.shape[2:])
        gv = gv.reshape(-1, *gv.shape[2:])
        cache_k = jnp.concatenate([jnp.stack(ks), gk]) if ks else gk
        cache_v = jnp.concatenate([jnp.stack(vs), gv]) if vs else gv
    else:
        def body(h, lp):
            h, k, v = run_block(lp, h)
            return h, (k, v)

        x, (cache_k, cache_v) = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x[:, -1:])[:, 0]
    return logits, {"k": cache_k, "v": cache_v}


def transformer_decode_step(params: Params, token: jax.Array, cache: Params,
                            pos: jax.Array, cfg: ModelConfig
                            ) -> Tuple[jax.Array, Params]:
    """One greedy decode step. token: (B,) int32; cache: {"k","v"} stacked
    (L, B, S_max, KV, hd); pos: scalar. Returns (logits (B, V), new cache)."""
    x = L.embed(params["embed"], token[:, None])
    x = constrain(x, "batch", None, "embed_act")

    if cfg.family == "moe":
        li = 0
        ck, cv = cache["k"], cache["v"]
        new_k, new_v = [], []
        for i in range(cfg.n_dense_layers):
            lp = jax.tree.map(lambda a: a[i], params["lead"])
            x, k1, v1 = dense_block_decode(lp, x, ck[li], cv[li], pos, cfg)
            new_k.append(k1); new_v.append(v1)
            li += 1
        n_groups = jax.tree.leaves(params["groups"])[0].shape[0]
        per_group = cfg.moe_every
        gk = ck[li:].reshape(n_groups, per_group, *ck.shape[1:])
        gv = cv[li:].reshape(n_groups, per_group, *cv.shape[1:])

        def g_body(h, xs):
            gp, gck, gcv = xs
            nk, nv = [], []
            j = 0
            if "dense" in gp:
                n_d = jax.tree.leaves(gp["dense"])[0].shape[0]
                for jj in range(n_d):
                    dj = jax.tree.map(lambda a: a[jj], gp["dense"])
                    h, k1, v1 = dense_block_decode(dj, h, gck[j], gcv[j],
                                                   pos, cfg)
                    nk.append(k1); nv.append(v1)
                    j += 1
            h, k1, v1 = moe_block_decode(gp["moe"], h, gck[j], gcv[j],
                                         pos, cfg)
            nk.append(k1); nv.append(v1)
            return h, (jnp.stack(nk), jnp.stack(nv))

        x, (gk2, gv2) = jax.lax.scan(g_body, x, (params["groups"], gk, gv))
        gk2 = gk2.reshape(-1, *gk2.shape[2:])
        gv2 = gv2.reshape(-1, *gv2.shape[2:])
        cache_k = jnp.concatenate([jnp.stack(new_k), gk2]) if new_k else gk2
        cache_v = jnp.concatenate([jnp.stack(new_v), gv2]) if new_v else gv2
    else:
        def body(h, xs):
            lp, ck_l, cv_l = xs
            h, k1, v1 = dense_block_decode(lp, h, ck_l, cv_l, pos, cfg)
            return h, (k1, v1)

        x, (cache_k, cache_v) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x)[:, 0]
    return logits, {"k": cache_k, "v": cache_v}
