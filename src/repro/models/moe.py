"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is the sort-based (MegaBlocks/MaxText-style "dropping") formulation:
tokens are ranked within their expert group via a stable sort of the routed
expert ids; tokens beyond `capacity_factor * T * k / E` per expert are dropped
(their combine weight contribution is zero). Expert weights carry an
("experts", ...) leading axis sharded over the mesh "model" axis (expert
parallelism); token->expert scatter/gather across that axis lowers to
all-to-all style collectives under GSPMD.

An auxiliary load-balancing loss (Switch-style) is returned alongside the
output so the trainer can add it to the LM loss.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models.layers import dense_init, mlp, mlp_init, _dtype

Params = Dict[str, Any]

# Explicit dispatch-buffer sharding constraints. Perf-pass finding
# (EXPERIMENTS.md §Perf): for architectures whose attention/GSPMD
# propagation loses the expert sharding (llama4-maverick: 40 heads % 16 != 0
# poisons downstream propagation -> expert einsums replicate, 11x waste),
# forcing P(experts->model) recovers it; for kimi-k2 (64 heads, clean
# propagation) the same constraint forces a worse scatter resharding. Hence
# opt-in per cell plan rather than unconditional.
import contextlib

_MOE_CONSTRAIN = {"on": False}


@contextlib.contextmanager
def moe_constraints(enabled: bool = True):
    prev = _MOE_CONSTRAIN["on"]
    _MOE_CONSTRAIN["on"] = enabled
    try:
        yield
    finally:
        _MOE_CONSTRAIN["on"] = prev


def _c(x, *names):
    return constrain(x, *names) if _MOE_CONSTRAIN["on"] else x


def moe_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    out_std = 0.02 / np.sqrt(2 * max(cfg.n_layers, 1))
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "wi": dense_init(ks[1], (E, D, 2, F), dt),          # fused gate+up
        "wo": dense_init(ks[2], (E, F, D), dt, std=out_std),
    }
    s = {
        "router": ("fsdp", None),
        "wi": ("experts", "fsdp", None, "mlp"),
        "wo": ("experts", "mlp", "fsdp"),
    }
    if cfg.n_shared_experts:
        sp, ss = mlp_init(ks[3], cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
        p["shared"] = sp
        s["shared"] = ss
    return p, s


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, (cap + 7) // 8 * 8)


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y: (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = expert_capacity(cfg, T)
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    gate, idx = jax.lax.top_k(probs, k)                      # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch-style aux loss: E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                   # mean router prob
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (T * k))                                        # routed fraction
    aux = E * jnp.sum(me * ce)

    # ---- capacity-based dispatch -------------------------------------
    flat_e = idx.reshape(-1)                                  # (T*k,)
    sort_i = jnp.argsort(flat_e, stable=True)                 # (T*k,)
    sorted_e = flat_e[sort_i]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[sorted_e]                # rank in expert
    keep = pos < C
    dest_c = jnp.where(keep, pos, C)                          # C = drop slot
    src_tok = sort_i // k                                     # token of slot

    buf = jnp.zeros((E, C + 1, D), x.dtype)
    buf = buf.at[sorted_e, dest_c].set(xt[src_tok], mode="drop")
    buf = buf[:, :C]

    # ---- expert FFN (SwiGLU), experts axis model-sharded ---------------
    # Explicit constraints: without them GSPMD loses the expert sharding
    # through the scatter and REPLICATES the expert einsums on every chip
    # (observed in the baseline dry-run: useful-flops ratio 0.004 on
    # llama4-maverick prefill). See EXPERIMENTS.md §Perf iteration B1.
    buf = _c(buf, "experts", None, None)
    h = jnp.einsum("ecd,edgf->ecgf", buf, p["wi"])
    h = _c(h, "experts", None, None, "mlp")
    act = jax.nn.silu(h[:, :, 0].astype(jnp.float32)).astype(x.dtype) \
        * h[:, :, 1]
    yb = jnp.einsum("ecf,efd->ecd", act, p["wo"])
    yb = _c(yb, "experts", None, None)
    yb = jnp.concatenate([yb, jnp.zeros((E, 1, D), yb.dtype)], axis=1)

    # ---- combine -------------------------------------------------------
    y_sorted = yb[sorted_e, dest_c] * keep[:, None].astype(yb.dtype)
    inv = jnp.argsort(sort_i)
    y_flat = y_sorted[inv].reshape(T, k, D)
    y = (y_flat * gate[..., None].astype(yb.dtype)).sum(axis=1)
    y = y.reshape(B, S, D)
    y = _c(y, "batch", "seq", "embed_act")

    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], x, cfg)
    return y, aux
