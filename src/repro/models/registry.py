"""Model registry: one uniform `ModelBundle` API over all assigned families.

    bundle = build(cfg)
    params = bundle.init(key)
    loss, metrics = bundle.loss(params, batch)
    logits, cache = bundle.prefill(params, batch)
    logits, cache = bundle.decode_step(params, token, cache, pos)

`bundle.abstract()` returns (ShapeDtypeStruct param tree, logical-spec tree)
WITHOUT allocating — this is what the multi-pod dry-run lowers against.
`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input of a given (arch x shape) cell, including the stub modality frontends.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import layers as L
from repro.models import transformer as TF
from repro.models import vision as VI

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable            # key -> params
    abstract: Callable        # () -> (ShapeDtypeStruct tree, logical specs)
    loss: Callable            # (params, batch) -> (loss, metrics)
    prefill: Callable         # (params, batch) -> (logits, cache)
    decode_step: Callable     # (params, token, cache, pos) -> (logits, cache)
    cache_init: Callable      # (batch, max_len) -> (cache, cache_specs)


def _family_init(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return TF.transformer_init
    if cfg.family in ("ssm", "hybrid"):
        return HY.hybrid_init
    if cfg.family == "encdec":
        return ED.encdec_init
    if cfg.family == "vlm":
        return VI.vlm_init
    raise ValueError(cfg.family)


def build(cfg: ModelConfig, remat: str = "block") -> ModelBundle:
    init_raw = _family_init(cfg)

    def init(key):
        return init_raw(key, cfg)[0]

    def abstract():
        cap = {}

        def f(key):
            p, s = init_raw(key, cfg)
            cap["specs"] = s
            return p
        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, cap["specs"]

    if cfg.family in ("dense", "moe"):
        def loss(params, batch):
            return TF.lm_loss(params, batch, cfg, remat=remat)

        def prefill(params, batch):
            return TF.transformer_prefill(params, batch["tokens"], cfg)

        def decode_step(params, token, cache, pos):
            return TF.transformer_decode_step(params, token, cache, pos, cfg)

        def cache_init(batch, max_len):
            return L.kv_cache_init(cfg, cfg.n_layers, batch, max_len)

    elif cfg.family in ("ssm", "hybrid"):
        def loss(params, batch):
            return TF.lm_loss(params, batch, cfg, apply_fn=HY.hybrid_apply,
                              remat=remat)

        def prefill(params, batch):
            return HY.hybrid_prefill(params, batch["tokens"], cfg)

        def decode_step(params, token, cache, pos):
            return HY.hybrid_decode_step(params, token, cache, pos, cfg)

        def cache_init(batch, max_len):
            return HY.hybrid_cache_init(cfg, batch, max_len)

    elif cfg.family == "encdec":
        def loss(params, batch):
            def apply_fn(p, t, c, remat="block"):
                return ED.encdec_apply(p, t, c, frames=batch["frames"],
                                       remat=remat)
            return TF.lm_loss(params, batch, cfg, apply_fn=apply_fn,
                              remat=remat)

        def prefill(params, batch):
            return ED.encdec_prefill(params, batch["tokens"], cfg,
                                     frames=batch["frames"])

        def decode_step(params, token, cache, pos):
            return ED.encdec_decode_step(params, token, cache, pos, cfg)

        def cache_init(batch, max_len):
            return ED.encdec_cache_init(cfg, batch, max_len)

    elif cfg.family == "vlm":
        def loss(params, batch):
            def apply_fn(p, t, c, remat="block"):
                return VI.vlm_apply(p, t, c, patches=batch["patches"],
                                    remat=remat)
            return TF.lm_loss(params, batch, cfg, apply_fn=apply_fn,
                              remat=remat)

        def prefill(params, batch):
            return VI.vlm_prefill(params, batch["tokens"], cfg,
                                  patches=batch["patches"])

        def decode_step(params, token, cache, pos):
            return VI.vlm_decode_step(params, token, cache, pos, cfg)

        def cache_init(batch, max_len):
            return VI.vlm_cache_init(cfg, batch, max_len)

    return ModelBundle(cfg=cfg, init=init, abstract=abstract, loss=loss,
                       prefill=prefill, decode_step=decode_step,
                       cache_init=cache_init)


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------

def _frontend_spec(cfg: ModelConfig, batch: int):
    Df = ED._frontend_dim(cfg)
    shape = (batch, cfg.n_frontend_tokens, Df)
    name = "frames" if cfg.frontend == "audio" else "patches"
    return name, jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig
                ) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell.

    train  -> {tokens, labels, mask(, frames|patches)}
    prefill-> {tokens(, frames|patches)}
    decode -> {token, cache, pos}  (one new token, cache of length seq_len)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
               "labels": jax.ShapeDtypeStruct((B, S), i32),
               "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        if cfg.frontend:
            name, spec = _frontend_spec(cfg, B)
            out[name] = spec
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend:
            name, spec = _frontend_spec(cfg, B)
            out[name] = spec
        return out
    # decode: one token against a cache of size S
    bundle = build(cfg)
    cache = jax.eval_shape(lambda: bundle.cache_init(B, S)[0])
    return {"token": jax.ShapeDtypeStruct((B,), i32),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), i32)}


def batch_logical_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Logical sharding names for each input in input_specs."""
    if shape.kind == "train":
        out = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
               "mask": ("batch", "seq")}
        if cfg.frontend:
            name = "frames" if cfg.frontend == "audio" else "patches"
            out[name] = ("batch", None, None)
        return out
    if shape.kind == "prefill":
        out = {"tokens": ("batch", "seq")}
        if cfg.frontend:
            name = "frames" if cfg.frontend == "audio" else "patches"
            out[name] = ("batch", None, None)
        return out
    bundle = build(cfg)
    # cache specs come from cache_init's second return; get them statically:
    cap = {}

    def f():
        c, s = bundle.cache_init(shape.global_batch, shape.seq_len)
        cap["s"] = s
        return c
    jax.eval_shape(f)
    return {"token": ("batch",), "cache": cap["s"], "pos": None}
