"""Encoder-decoder family (SeamlessM4T-medium backbone).

The audio frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings (B, n_frontend_tokens, frontend_dim); the model
owns only a linear adapter into d_model. Encoder blocks are bidirectional
self-attention; decoder blocks are causal self-attention + cross-attention to
the encoder output + MLP.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models.transformer import (dense_block_init, init_stacked,
                                      remat_policy)

Params = Dict[str, Any]


def _frontend_dim(cfg: ModelConfig) -> int:
    return getattr(cfg, "frontend_dim", 0) or cfg.d_model


def dec_block_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    k1, k2 = jax.random.split(key)
    p, s = dense_block_init(k1, cfg)
    xp, xs = L.cross_attention_init(k2, cfg)
    p["ln_x"] = jnp.ones((cfg.d_model,), L._dtype(cfg))
    p["cross"] = xp
    s["ln_x"] = ("embed",)
    s["cross"] = xs
    return p, s


def encdec_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 5)
    emb_p, emb_s = L.embed_init(ks[0], cfg)
    Df = _frontend_dim(cfg)
    p: Params = {
        "embed": emb_p,
        "frontend_proj": L.dense_init(ks[1], (Df, cfg.d_model), L._dtype(cfg)),
        "final_norm": jnp.ones((cfg.d_model,), L._dtype(cfg)),
        "enc_norm": jnp.ones((cfg.d_model,), L._dtype(cfg)),
    }
    s: Params = {"embed": emb_s, "frontend_proj": (None, "embed"),
                 "final_norm": ("embed",), "enc_norm": ("embed",)}
    ep, es = init_stacked(ks[2], cfg.n_enc_layers,
                          lambda k: dense_block_init(k, cfg))
    dp, ds = init_stacked(ks[3], cfg.n_layers,
                          lambda k: dec_block_init(k, cfg))
    p["enc"], s["enc"] = ep, es
    p["dec"], s["dec"] = dp, ds
    return p, s


def encode(params: Params, frames: jax.Array, cfg: ModelConfig,
           remat: str = "block") -> jax.Array:
    """frames: (B, Sf, Df) stub embeddings -> (B, Sf, D) encoder output."""
    x = jnp.einsum("bsf,fd->bsd", frames.astype(L._dtype(cfg)),
                   params["frontend_proj"])
    x = constrain(x, "batch", "seq", "embed_act")

    @functools.partial(jax.checkpoint, policy=remat_policy(remat))
    def body(h, lp):
        # bidirectional: same block, causal=False via explicit call
        h2 = h + L.attention_train(lp["attn"],
                                   L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                   cfg, causal=False)
        h2 = h2 + L.mlp(lp["mlp"], L.rmsnorm(h2, lp["ln2"], cfg.norm_eps), cfg)
        return h2, None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def dec_block(p: Params, x: jax.Array, memory: jax.Array, cfg: ModelConfig,
              qc: int = 512) -> jax.Array:
    x = constrain(x, "batch", "seq", "embed_act")
    h = x + L.attention_train(p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                              cfg, q_chunk=qc, kv_chunk=qc)
    h = h + L.cross_attention(p["cross"],
                              L.rmsnorm(h, p["ln_x"], cfg.norm_eps),
                              memory, cfg)
    h = h + L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg)
    return h


def encdec_apply(params: Params, tokens: jax.Array, cfg: ModelConfig,
                 frames: jax.Array = None, remat: str = "block"
                 ) -> Tuple[jax.Array, jax.Array]:
    memory = encode(params, frames, cfg, remat)
    x = L.embed(params["embed"], tokens)
    qc = min(512, tokens.shape[1])

    @functools.partial(jax.checkpoint, policy=remat_policy(remat))
    def body(h, lp):
        return dec_block(lp, h, memory, cfg, qc), None

    x, _ = jax.lax.scan(body, x, params["dec"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def encdec_cache_init(cfg: ModelConfig, batch: int, max_len: int
                      ) -> Tuple[Params, Params]:
    selfc, selfs = L.kv_cache_init(cfg, cfg.n_layers, batch, max_len)
    Sf = cfg.n_frontend_tokens
    KV, hd = cfg.n_kv_heads, cfg.head_dim_
    dt = L._dtype(cfg)
    cache = {"self": selfc,
             "cross_k": jnp.zeros((cfg.n_layers, batch, Sf, KV * hd), dt),
             "cross_v": jnp.zeros((cfg.n_layers, batch, Sf, KV * hd), dt)}
    specs = {"self": selfs,
             "cross_k": ("layers", "batch", None, "kv_flat"),
             "cross_v": ("layers", "batch", None, "kv_flat")}
    return cache, specs


def encdec_prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
                   frames: jax.Array = None) -> Tuple[jax.Array, Params]:
    memory = encode(params, frames, cfg)
    B, Sq = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(Sq)[None, :]
    qc = min(512, Sq)

    def body(h, lp):
        xn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L._project_qkv(lp["attn"], xn, cfg, positions)
        o = L.chunked_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=qc)
        h = h + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        xk = jnp.einsum("bsd,dhk->bshk", memory, lp["cross"]["wk"])
        xv = jnp.einsum("bsd,dhk->bshk", memory, lp["cross"]["wv"])
        h = h + L.cross_attention(lp["cross"],
                                  L.rmsnorm(h, lp["ln_x"], cfg.norm_eps),
                                  memory, cfg)
        h = h + L.mlp(lp["mlp"], L.rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
        Sm = xk.shape[1]
        return h, (k.reshape(B, Sq, -1), v.reshape(B, Sq, -1),
                   xk.reshape(B, Sm, -1), xv.reshape(B, Sm, -1))

    x, (ck, cv, xk, xv) = jax.lax.scan(body, x, params["dec"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x[:, -1:])[:, 0]
    return logits, {"self": {"k": ck, "v": cv}, "cross_k": xk, "cross_v": xv}


def _cross_decode(p: Params, x: jax.Array, xk: jax.Array, xv: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    """Single-token cross-attention against precomputed memory K/V
    (flat (Sm, KV*hd) cache layout)."""
    B = x.shape[0]
    KV, hd, H = cfg.n_kv_heads, cfg.head_dim_, cfg.n_heads
    G = H // KV
    Sm = xk.shape[1]
    xk = xk.reshape(B, Sm, KV, hd)
    xv = xv.reshape(B, Sm, KV, hd)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, 0]
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, xk,
                   preferred_element_type=jnp.float32) / jnp.sqrt(1.0 * hd)
    prob = jax.nn.softmax(s, axis=-1).astype(xv.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", prob, xv)
    return jnp.einsum("bhk,hkd->bd", o.reshape(B, H, hd), p["wo"])[:, None]


def encdec_decode_step(params: Params, token: jax.Array, cache: Params,
                       pos: jax.Array, cfg: ModelConfig
                       ) -> Tuple[jax.Array, Params]:
    x = L.embed(params["embed"], token[:, None])

    def body(h, xs):
        lp, ck, cv, xk, xv = xs
        a, ck, cv = L.attention_decode(
            lp["attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps), ck, cv, pos, cfg)
        h = h + a
        h = h + _cross_decode(lp["cross"],
                              L.rmsnorm(h, lp["ln_x"], cfg.norm_eps),
                              xk, xv, cfg)
        h = h + L.mlp(lp["mlp"], L.rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
        return h, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        body, x, (params["dec"], cache["self"]["k"], cache["self"]["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x)[:, 0]
    return logits, {"self": {"k": ck, "v": cv},
                    "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
