"""SSM (mamba2) and hybrid (zamba2) model families.

mamba2: a pure stack of SSM mixer blocks (no MLP, no attention) — O(S)
training compute and O(1)/token decode, which is why the long_500k cell runs
for this family.

zamba2: a mamba2 backbone where ONE shared transformer block (attention+MLP,
single parameter set) is applied after every `attn_every` SSM layers
(9 applications for 54L/6). Each application has its own KV-cache sheet at
decode time (shared weights, distinct activations). The paper's
concat-with-embedding + per-application LoRA is simplified to an additive
residual application of the shared block; noted in DESIGN.md.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import (dense_block_init, init_stacked,
                                      remat_policy)

Params = Dict[str, Any]


def ssm_block_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    p_s, s_s = S.ssm_init(key, cfg)
    p = {"ln": jnp.ones((cfg.d_model,), L._dtype(cfg)), "ssm": p_s}
    s = {"ln": ("embed",), "ssm": s_s}
    return p, s


def ssm_block(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    y, _ = S.ssm_forward(p["ssm"], L.rmsnorm(x, p["ln"], cfg.norm_eps), cfg)
    return x + y


def hybrid_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 4)
    emb_p, emb_s = L.embed_init(ks[0], cfg)
    p: Params = {"embed": emb_p,
                 "final_norm": jnp.ones((cfg.d_model,), L._dtype(cfg))}
    s: Params = {"embed": emb_s, "final_norm": ("embed",)}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every

        def group_init(k):
            return init_stacked(k, cfg.attn_every,
                                lambda kk: ssm_block_init(kk, cfg))

        gp, gs = init_stacked(ks[1], n_groups, group_init)
        p["groups"], s["groups"] = gp, gs
        sp, ss = dense_block_init(ks[2], cfg)   # the ONE shared block
        p["shared"], s["shared"] = sp, ss
    else:
        lp, ls = init_stacked(ks[1], cfg.n_layers,
                              lambda k: ssm_block_init(k, cfg))
        p["layers"], s["layers"] = lp, ls
    return p, s


def hybrid_apply(params: Params, tokens: jax.Array, cfg: ModelConfig,
                 remat: str = "block") -> Tuple[jax.Array, jax.Array]:
    from repro.models.transformer import dense_block
    x = L.embed(params["embed"], tokens)
    x = constrain(x, "batch", "seq", "embed_act")
    policy = remat_policy(remat)
    if cfg.family == "hybrid":
        shared = params["shared"]
        qc = min(512, tokens.shape[1])

        @functools.partial(jax.checkpoint, policy=policy)
        def g_body(h, gp):
            def s_body(hh, sp):
                return ssm_block(sp, hh, cfg), None
            h, _ = jax.lax.scan(s_body, h, gp)
            h = dense_block(shared, h, cfg, qc, qc)
            return h, None

        x, _ = jax.lax.scan(g_body, x, params["groups"])
    else:
        @functools.partial(jax.checkpoint, policy=policy)
        def body(h, lp):
            return ssm_block(lp, h, cfg), None

        x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def hybrid_cache_init(cfg: ModelConfig, batch: int, max_len: int
                      ) -> Tuple[Params, Params]:
    cache, specs = {}, {}
    sc, ss = S.ssm_cache_init(cfg, cfg.n_layers, batch)
    cache["ssm"], specs["ssm"] = sc, ss
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        kc, kss = L.kv_cache_init(cfg, n_groups, batch, max_len)
        cache["attn"], specs["attn"] = kc, kss
    return cache, specs


def _ssm_block_prefill(p: Params, x: jax.Array, cfg: ModelConfig):
    y, (state, conv) = S.ssm_forward(
        p["ssm"], L.rmsnorm(x, p["ln"], cfg.norm_eps), cfg, return_cache=True)
    return x + y, state, conv


def _ssm_block_decode(p: Params, x: jax.Array, state, conv, cfg: ModelConfig):
    y, state, conv = S.ssm_decode_step(
        p["ssm"], L.rmsnorm(x, p["ln"], cfg.norm_eps), state, conv, cfg)
    return x + y, state, conv


def hybrid_prefill(params: Params, tokens: jax.Array, cfg: ModelConfig
                   ) -> Tuple[jax.Array, Params]:
    B, Sq = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(Sq)[None, :]
    if cfg.family == "hybrid":
        shared = params["shared"]
        qc = min(512, Sq)

        def g_body(h, gp):
            def s_body(hh, sp):
                hh, st, cv = _ssm_block_prefill(sp, hh, cfg)
                return hh, (st, cv)
            h, (states, convs) = jax.lax.scan(s_body, h, gp)
            xn = L.rmsnorm(h, shared["ln1"], cfg.norm_eps)
            q, k, v = L._project_qkv(shared["attn"], xn, cfg, positions)
            o = L.chunked_attention(q, k, v, causal=True, q_chunk=qc,
                                    kv_chunk=qc)
            h = h + jnp.einsum("bshk,hkd->bsd", o, shared["attn"]["wo"])
            h = h + L.mlp(shared["mlp"],
                          L.rmsnorm(h, shared["ln2"], cfg.norm_eps), cfg)
            return h, (states, convs, k.reshape(B, Sq, -1),
                       v.reshape(B, Sq, -1))

        x, (st, cv, ks, vs) = jax.lax.scan(g_body, x, params["groups"])
        cache = {"ssm": {"state": st.reshape(-1, *st.shape[2:]),
                         "conv": cv.reshape(-1, *cv.shape[2:])},
                 "attn": {"k": ks, "v": vs}}
    else:
        def body(h, lp):
            h, st, cv = _ssm_block_prefill(lp, h, cfg)
            return h, (st, cv)

        x, (st, cv) = jax.lax.scan(body, x, params["layers"])
        cache = {"ssm": {"state": st, "conv": cv}}
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x[:, -1:])[:, 0]
    return logits, cache


def hybrid_decode_step(params: Params, token: jax.Array, cache: Params,
                       pos: jax.Array, cfg: ModelConfig
                       ) -> Tuple[jax.Array, Params]:
    x = L.embed(params["embed"], token[:, None])
    if cfg.family == "hybrid":
        shared = params["shared"]
        n_groups = cfg.n_layers // cfg.attn_every
        st = cache["ssm"]["state"].reshape(
            n_groups, cfg.attn_every, *cache["ssm"]["state"].shape[1:])
        cv = cache["ssm"]["conv"].reshape(
            n_groups, cfg.attn_every, *cache["ssm"]["conv"].shape[1:])

        def g_body(h, xs):
            gp, g_st, g_cv, ck, vk = xs

            def s_body(hh, sxs):
                sp, st_l, cv_l = sxs
                hh, st_l, cv_l = _ssm_block_decode(sp, hh, st_l, cv_l, cfg)
                return hh, (st_l, cv_l)

            h, (n_st, n_cv) = jax.lax.scan(s_body, h, (gp, g_st, g_cv))
            from repro.models.transformer import dense_block_decode
            h, ck, vk = dense_block_decode(shared, h, ck, vk, pos, cfg)
            return h, (n_st, n_cv, ck, vk)

        x, (n_st, n_cv, ks, vs) = jax.lax.scan(
            g_body, x, (params["groups"], st, cv,
                        cache["attn"]["k"], cache["attn"]["v"]))
        cache = {"ssm": {"state": n_st.reshape(-1, *n_st.shape[2:]),
                         "conv": n_cv.reshape(-1, *n_cv.shape[2:])},
                 "attn": {"k": ks, "v": vs}}
    else:
        def body(h, xs):
            lp, st_l, cv_l = xs
            h, st_l, cv_l = _ssm_block_decode(lp, h, st_l, cv_l, cfg)
            return h, (st_l, cv_l)

        x, (n_st, n_cv) = jax.lax.scan(
            body, x, (params["layers"], cache["ssm"]["state"],
                      cache["ssm"]["conv"]))
        cache = {"ssm": {"state": n_st, "conv": n_cv}}
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x)[:, 0]
    return logits, cache
