"""VLM family (Llama-3.2-Vision backbone): decoder-only LM where every
`cross_attn_every`-th layer carries an extra cross-attention sub-block over
precomputed image patch embeddings (vision frontend is a STUB per the
assignment — `input_specs()` provides the patches).

Scan topology: groups of (cross_attn_every - 1) self-attention layers followed
by 1 [self + cross + mlp] layer, so 100 layers lower as 20 scanned groups.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models.encdec import _cross_decode, dec_block_init, _frontend_dim
from repro.models.transformer import (dense_block, dense_block_decode,
                                      dense_block_init, init_stacked,
                                      remat_policy)

Params = Dict[str, Any]


def vlm_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 4)
    emb_p, emb_s = L.embed_init(ks[0], cfg)
    Df = _frontend_dim(cfg)
    p: Params = {
        "embed": emb_p,
        "frontend_proj": L.dense_init(ks[1], (Df, cfg.d_model), L._dtype(cfg)),
        "final_norm": jnp.ones((cfg.d_model,), L._dtype(cfg)),
    }
    s: Params = {"embed": emb_s, "frontend_proj": (None, "embed"),
                 "final_norm": ("embed",)}
    n_groups = cfg.n_layers // cfg.cross_attn_every
    n_self = cfg.cross_attn_every - 1

    def group_init(k):
        k1, k2 = jax.random.split(k)
        gp, gs = {}, {}
        if n_self:
            sp, ss = init_stacked(k1, n_self,
                                  lambda kk: dense_block_init(kk, cfg))
            gp["self"], gs["self"] = sp, ss
        cp, cs = dec_block_init(k2, cfg)      # self + cross + mlp
        gp["cross"], gs["cross"] = cp, cs
        return gp, gs

    gp, gs = init_stacked(ks[2], n_groups, group_init)
    p["groups"], s["groups"] = gp, gs
    return p, s


def vlm_apply(params: Params, tokens: jax.Array, cfg: ModelConfig,
              patches: jax.Array = None, remat: str = "block"
              ) -> Tuple[jax.Array, jax.Array]:
    from repro.models.encdec import dec_block
    memory = jnp.einsum("bsf,fd->bsd", patches.astype(L._dtype(cfg)),
                        params["frontend_proj"])
    x = L.embed(params["embed"], tokens)
    x = constrain(x, "batch", "seq", "embed_act")
    qc = min(512, tokens.shape[1])

    @functools.partial(jax.checkpoint, policy=remat_policy(remat))
    def g_body(h, gp):
        if "self" in gp:
            def s_body(hh, sp):
                return dense_block(sp, hh, cfg, qc, qc), None
            h, _ = jax.lax.scan(s_body, h, gp["self"])
        h = dec_block(gp["cross"], h, memory, cfg, qc)
        return h, None

    x, _ = jax.lax.scan(g_body, x, params["groups"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def vlm_cache_init(cfg: ModelConfig, batch: int, max_len: int
                   ) -> Tuple[Params, Params]:
    selfc, selfs = L.kv_cache_init(cfg, cfg.n_layers, batch, max_len)
    n_groups = cfg.n_layers // cfg.cross_attn_every
    Sp = cfg.n_frontend_tokens
    KV, hd = cfg.n_kv_heads, cfg.head_dim_
    dt = L._dtype(cfg)
    cache = {"self": selfc,
             "cross_k": jnp.zeros((n_groups, batch, Sp, KV * hd), dt),
             "cross_v": jnp.zeros((n_groups, batch, Sp, KV * hd), dt)}
    specs = {"self": selfs,
             "cross_k": ("layers", "batch", None, "kv_flat"),
             "cross_v": ("layers", "batch", None, "kv_flat")}
    return cache, specs


def vlm_prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
                patches: jax.Array = None) -> Tuple[jax.Array, Params]:
    memory = jnp.einsum("bsf,fd->bsd", patches.astype(L._dtype(cfg)),
                        params["frontend_proj"])
    B, Sq = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(Sq)[None, :]
    qc = min(512, Sq)

    def run_self(p, h):
        xn = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
        q, k, v = L._project_qkv(p["attn"], xn, cfg, positions)
        o = L.chunked_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=qc)
        h = h + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        return h, k.reshape(B, Sq, -1), v.reshape(B, Sq, -1)

    def g_body(h, gp):
        sk, sv = [], []
        if "self" in gp:
            n_s = jax.tree.leaves(gp["self"])[0].shape[0]
            for j in range(n_s):
                sp = jax.tree.map(lambda a: a[j], gp["self"])
                h, k, v = run_self(sp, h)
                h = h + L.mlp(sp["mlp"],
                              L.rmsnorm(h, sp["ln2"], cfg.norm_eps), cfg)
                sk.append(k); sv.append(v)
        cp = gp["cross"]
        h, k, v = run_self(cp, h)
        sk.append(k); sv.append(v)
        xk = jnp.einsum("bsd,dhk->bshk", memory, cp["cross"]["wk"])
        xv = jnp.einsum("bsd,dhk->bshk", memory, cp["cross"]["wv"])
        Sm = xk.shape[1]
        h = h + L.cross_attention(cp["cross"],
                                  L.rmsnorm(h, cp["ln_x"], cfg.norm_eps),
                                  memory, cfg)
        h = h + L.mlp(cp["mlp"], L.rmsnorm(h, cp["ln2"], cfg.norm_eps), cfg)
        return h, (jnp.stack(sk), jnp.stack(sv),
                   xk.reshape(B, Sm, -1), xv.reshape(B, Sm, -1))

    x, (gk, gv, xk, xv) = jax.lax.scan(g_body, x, params["groups"])
    ck = gk.reshape(-1, *gk.shape[2:])
    cv = gv.reshape(-1, *gv.shape[2:])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x[:, -1:])[:, 0]
    return logits, {"self": {"k": ck, "v": cv}, "cross_k": xk, "cross_v": xv}


def vlm_decode_step(params: Params, token: jax.Array, cache: Params,
                    pos: jax.Array, cfg: ModelConfig
                    ) -> Tuple[jax.Array, Params]:
    x = L.embed(params["embed"], token[:, None])
    n_groups = cfg.n_layers // cfg.cross_attn_every
    per_group = cfg.cross_attn_every
    ck = cache["self"]["k"].reshape(n_groups, per_group,
                                    *cache["self"]["k"].shape[1:])
    cv = cache["self"]["v"].reshape(n_groups, per_group,
                                    *cache["self"]["v"].shape[1:])

    def g_body(h, xs):
        gp, g_ck, g_cv, xk, xv = xs
        nk, nv = [], []
        j = 0
        if "self" in gp:
            n_s = jax.tree.leaves(gp["self"])[0].shape[0]
            for jj in range(n_s):
                sp = jax.tree.map(lambda a: a[jj], gp["self"])
                h, k1, v1 = dense_block_decode(sp, h, g_ck[j], g_cv[j],
                                               pos, cfg)
                nk.append(k1); nv.append(v1)
                j += 1
        cp = gp["cross"]
        a, k1, v1 = L.attention_decode(
            cp["attn"], L.rmsnorm(h, cp["ln1"], cfg.norm_eps),
            g_ck[j], g_cv[j], pos, cfg)
        h = h + a
        nk.append(k1); nv.append(v1)
        h = h + _cross_decode(cp["cross"],
                              L.rmsnorm(h, cp["ln_x"], cfg.norm_eps),
                              xk, xv, cfg)
        h = h + L.mlp(cp["mlp"], L.rmsnorm(h, cp["ln2"], cfg.norm_eps), cfg)
        return h, (jnp.stack(nk), jnp.stack(nv))

    x, (gk, gv) = jax.lax.scan(
        g_body, x, (params["groups"], ck, cv,
                    cache["cross_k"], cache["cross_v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x)[:, 0]
    return logits, {"self": {"k": gk.reshape(-1, *gk.shape[2:]),
                             "v": gv.reshape(-1, *gv.shape[2:])},
                    "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
