"""Mamba2 / SSD (state-space duality) mixer, pure JAX [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks; within a chunk the recurrence is computed as a (masked, decayed)
attention-like quadratic form, and chunk-final states are propagated by a
`lax.scan` over chunks. This is O(S * chunk) instead of O(S^2) — the reason
`long_500k` is runnable for the SSM/hybrid architectures.

Decode is the O(1)-per-token linear recurrence over the cached state
(B, H, head_dim, N) plus a rolling depthwise-conv cache.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm, _dtype

Params = Dict[str, Any]


def ssm_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    D = cfg.d_model
    Din, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    K = cfg.ssm_conv
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    # in_proj emits [z (Din), x (Din), B (N), C (N), dt (H)]
    p = {
        "in_proj": dense_init(ks[0], (D, 2 * Din + 2 * N + H), dt),
        "conv_w": dense_init(ks[1], (K, Din + 2 * N), dt, std=0.1),
        "conv_b": jnp.zeros((Din + 2 * N,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((Din,), dt),
        "out_proj": dense_init(ks[2], (Din, D), dt,
                               std=0.02 / np.sqrt(2 * max(cfg.n_layers, 1))),
    }
    s = {
        "in_proj": ("fsdp", "ssm_inner"),
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "a_log": ("ssm_heads",),
        "d_skip": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "fsdp"),
    }
    return p, s


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    Din, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :Din]
    xBC = zxbcdt[..., Din:2 * Din + 2 * N]
    dt = zxbcdt[..., 2 * Din + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. xBC: (B, S, C); w: (K, C)."""
    K, C = w.shape
    x = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    # windows via K shifted adds (K is 4: cheaper than conv_general for TPU)
    S = xBC.shape[1]
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(K):
        out = out + x[:, i:i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xBC.dtype)


def _segsum(dA: jax.Array) -> jax.Array:
    """dA: (..., c) log-decays -> (..., c, c) lower-tri cumulative sums."""
    c = dA.shape[-1]
    cum = jnp.cumsum(dA, axis=-1)
    seg = cum[..., :, None] - cum[..., None, :]   # sum over (j, i]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                Bm: jax.Array, Cm: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) post-softplus; a_log: (H,) with A=-exp(a_log)
    Bm, Cm: (B, S, N) (single B/C group, broadcast over heads)
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    S_orig = S
    pad = (-S) % chunk
    if pad:
        # zero-pad the tail: dt=0 => decay exp(0)=1 and zero input, so the
        # final state is untouched by padded positions; y tail is sliced off.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // chunk
    A = -jnp.exp(a_log)                                     # (H,)
    dA = dt * A                                             # (B, S, H) log-decay
    xr = x.reshape(Bsz, nc, chunk, H, P)
    dtr = dt.reshape(Bsz, nc, chunk, H)
    dAr = dA.reshape(Bsz, nc, chunk, H).transpose(0, 1, 3, 2)  # (B,nc,H,c)
    Br = Bm.reshape(Bsz, nc, chunk, N)
    Cr = Cm.reshape(Bsz, nc, chunk, N)

    cum = jnp.cumsum(dAr, axis=-1)                          # (B,nc,H,c)
    # ---- intra-chunk (quadratic within chunk) ----
    L = jnp.exp(_segsum(dAr))                               # (B,nc,H,c,c)
    scores = jnp.einsum("bzin,bzjn->bzij", Cr, Br)          # (B,nc,c,c)
    att = scores[:, :, None] * L * dtr.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bzhij,bzjhp->bzihp", att.astype(x.dtype), xr)

    # ---- chunk-final states ----
    decay_to_end = jnp.exp(cum[..., -1:] - cum)             # (B,nc,H,c)
    states = jnp.einsum("bzjn,bzhj,bzjh,bzjhp->bzhpn",
                        Br, decay_to_end.astype(x.dtype), dtr.astype(x.dtype), xr)

    # ---- inter-chunk recurrence over chunk states ----
    chunk_decay = jnp.exp(cum[..., -1])                     # (B,nc,H)

    def step(s, inputs):
        st, dec = inputs                                    # (B,H,P,N), (B,H)
        s_new = s * dec[..., None, None].astype(s.dtype) + st
        return s_new, s                                     # emit state *before*

    from repro.dist.sharding import match_vma
    s0 = (jnp.zeros((Bsz, H, P, N), x.dtype) if init_state is None
          else init_state.astype(x.dtype))
    s0 = match_vma(s0, x)
    final, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B,nc,H,P,N)

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(cum).transpose(0, 1, 3, 2)           # (B,nc,c,H)
    y_inter = jnp.einsum("bzin,bzih,bzhpn->bzihp",
                         Cr, in_decay.astype(x.dtype), prev_states)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)[:, :S_orig]
    return y, final.astype(jnp.float32)


def ssm_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                init_state: Optional[jax.Array] = None,
                return_cache: bool = False):
    """Full-sequence Mamba2 mixer. x: (B, S, D) -> (y, final_state) or, with
    return_cache, (y, (final_state, conv_tail)) for decode continuation."""
    Din, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    K = cfg.ssm_conv
    z, xBC, dt_raw = _split_proj(cfg, jnp.einsum("bsd,de->bse", x, p["in_proj"]))
    conv_tail = xBC[:, x.shape[1] - (K - 1):, :]   # raw pre-conv window tail
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :Din]
    Bm = xBC[..., Din:Din + N]
    Cm = xBC[..., Din + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(*xs.shape[:-1], H, P)
    y, state = ssd_chunked(xh, dt, p["a_log"], Bm, Cm, cfg.ssm_chunk,
                           init_state)
    y = y + xh * p["d_skip"][:, None].astype(x.dtype)
    y = y.reshape(*xs.shape[:-1], Din)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_cache:
        return out, (state, conv_tail)
    return out, state


# --------------------------------------------------------------------------
# Decode path (O(1) per token)
# --------------------------------------------------------------------------

def ssm_cache_init(cfg: ModelConfig, n_layers: int, batch: int
                   ) -> Tuple[Params, Params]:
    Din, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    K = cfg.ssm_conv
    dt = _dtype(cfg)
    cache = {
        "state": jnp.zeros((n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, K - 1, Din + 2 * N), dt),
    }
    specs = {"state": ("layers", "batch", "ssm_heads", None, None),
             "conv": ("layers", "batch", None, "ssm_inner")}
    return cache, specs


def ssm_decode_step(p: Params, x: jax.Array, state: jax.Array,
                    conv_cache: jax.Array, cfg: ModelConfig
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, 1, D); state: (B, H, P, N); conv_cache: (B, K-1, C).
    Returns (y: (B, 1, D), new_state, new_conv_cache)."""
    Din, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    z, xBC, dt_raw = _split_proj(cfg, jnp.einsum("bsd,de->bse", x, p["in_proj"]))
    xBC = xBC[:, 0]                                          # (B, C)
    window = jnp.concatenate([conv_cache, xBC[:, None]], axis=1)  # (B, K, C)
    conv = (window.astype(jnp.float32) * p["conv_w"].astype(jnp.float32)
            ).sum(axis=1) + p["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(conv).astype(x.dtype)
    xs, Bm, Cm = (xBC[..., :Din], xBC[..., Din:Din + N], xBC[..., Din + N:])
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt * A)                                     # (B, H)
    xh = xs.reshape(-1, H, P).astype(jnp.float32)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32), xh)
    state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + xh * p["d_skip"][:, None]
    y = y.reshape(-1, 1, Din).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm"], cfg.norm_eps)
    return (jnp.einsum("bse,ed->bsd", y, p["out_proj"]),
            state, window[:, 1:].astype(conv_cache.dtype))
