from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES, ARCH_IDS,
                                LONG_CONTEXT_ARCHS, get_config, reduced, cells)
