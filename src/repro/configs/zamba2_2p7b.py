"""Zamba2-2.7B: Mamba2 backbone + one shared attention block applied
periodically [arXiv:2411.15242]. 54L d_model=2560, attn 32H, ssm_state=64."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,   # shared block applied every 6 mamba layers (9 times)
)
