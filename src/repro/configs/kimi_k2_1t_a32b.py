"""Kimi K2: trillion-param MoE, 384 experts top-8 + 1 shared
[arXiv:2501.kimi2, paper-table]. 61L d_model=7168 64H kv=8, expert d_ff=2048.
Layer 0 dense (DeepSeek-V3 style)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,            # per-expert
    vocab_size=163840,
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    n_dense_layers=1,
    dense_d_ff=16384,
    rope_theta=50_000.0,
)
