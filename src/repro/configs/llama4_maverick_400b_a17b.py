"""Llama-4 Maverick: 400B MoE, 128 experts top-1 + shared, alternating
dense/MoE layers [hf:meta-llama/Llama-4]. 48L d_model=5120 40H kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,            # per-expert
    vocab_size=202048,
    n_experts=128,
    top_k=1,
    n_shared_experts=1,
    moe_every=2,          # interleaved: every other layer is MoE
    dense_d_ff=16384,
    rope_theta=500_000.0,
)
