"""SeamlessM4T-medium: encoder-decoder, audio frontend stubbed
[arXiv:2308.11596]. 12L enc + 12L dec, d_model=1024, 16H, d_ff=4096."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,          # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    mlp_kind="gelu",
    frontend="audio",
    n_frontend_tokens=1024,   # precomputed speech frames per sample (stub)
    rope_theta=10_000.0,
)
