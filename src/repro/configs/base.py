"""Config system: model architectures x input shapes.

Every assigned architecture is a `ModelConfig` in its own module
(`repro.configs.<arch_id>`); `get_config(arch_id)` resolves them and
`reduced(cfg)` shrinks any config to a CPU-smoke-testable size of the same
family. Input shapes are the four assigned global shapes; `cells()`
enumerates the (arch x shape) dry-run grid with the documented skips.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

VOCAB_PAD = 2048  # pad vocab to a multiple (sharding divisibility; standard)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    mlp_kind: str = "swiglu"    # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_every: int = 1          # every k-th layer is MoE (1 = all)
    n_dense_layers: int = 0     # leading dense layers (DeepSeek/Kimi style)
    dense_d_ff: int = 0         # d_ff of the dense (non-expert) layers
    capacity_factor: float = 1.25
    # SSM (Mamba2/SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # hybrid (zamba-style shared attention block)
    attn_every: int = 0         # apply the shared attn block every k ssm layers
    # enc-dec
    n_enc_layers: int = 0
    frontend: str = ""          # 'audio' | 'vision': modality stub (input_specs)
    n_frontend_tokens: int = 0  # frames / image patches per sample
    frontend_dim: int = 0       # stub embedding dim (0 -> d_model)
    # vlm
    cross_attn_every: int = 0   # every k-th decoder layer cross-attends

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        return (self.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD * VOCAB_PAD

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ---- parameter counting (for 6*N*D model flops) -----------------------
    def param_count(self, active_only: bool = False) -> int:
        D, H, KV, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim_
        embed = self.padded_vocab * D * 2  # in + out (untied)
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D

        def mlp_params(ff, kind=self.mlp_kind):
            return (3 if kind == "swiglu" else 2) * D * ff

        def moe_layer(active):
            n_e = (self.top_k + self.n_shared_experts) if active else \
                (self.n_experts + self.n_shared_experts)
            return n_e * mlp_params(self.d_ff) + D * self.n_experts

        total = embed
        if self.family in ("dense",):
            total += self.n_layers * (attn + mlp_params(self.d_ff))
        elif self.family == "moe":
            n_moe, n_dense = self.moe_layer_counts()
            total += self.n_layers * attn
            total += n_moe * moe_layer(active_only)
            total += n_dense * mlp_params(self.dense_d_ff or self.d_ff)
        elif self.family == "ssm":
            total += self.n_layers * self.ssm_layer_params()
        elif self.family == "hybrid":
            total += self.n_layers * self.ssm_layer_params()
            total += attn + mlp_params(self.d_ff)  # ONE shared block
        elif self.family == "encdec":
            total += (self.n_enc_layers + self.n_layers) * \
                (attn + mlp_params(self.d_ff))
            total += self.n_layers * attn  # decoder cross-attention
        elif self.family == "vlm":
            n_cross = self.n_layers // max(self.cross_attn_every, 1)
            n_self = self.n_layers - n_cross
            total += n_self * (attn + mlp_params(self.d_ff))
            total += n_cross * (2 * attn + mlp_params(self.d_ff))
        return total

    def ssm_layer_params(self) -> int:
        D, Din, N = self.d_model, self.d_inner, self.ssm_state
        H = self.n_ssm_heads
        in_proj = D * (2 * Din + 2 * N + H)  # z, x, B, C, dt
        conv = self.ssm_conv * (Din + 2 * N)
        out = Din * D
        return in_proj + conv + out + 2 * H  # + A, D per head

    def moe_layer_counts(self) -> Tuple[int, int]:
        """(n_moe_layers, n_dense_layers)."""
        n_moe = 0
        for i in range(self.n_layers):
            if i >= self.n_dense_layers and \
                    (i - self.n_dense_layers) % self.moe_every == 0:
                n_moe += 1
        return n_moe, self.n_layers - n_moe

    def is_moe_layer(self, i: int) -> bool:
        return (self.family == "moe" and i >= self.n_dense_layers
                and (i - self.n_dense_layers) % self.moe_every == 0)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "zamba2_2p7b",
    "seamless_m4t_medium",
    "qwen3_8b",
    "deepseek_67b",
    "qwen1p5_110b",
    "qwen3_0p6b",
    "kimi_k2_1t_a32b",
    "llama4_maverick_400b_a17b",
    "llama_3p2_vision_90b",
    "mamba2_1p3b",
]

# long_500k needs sub-quadratic context handling; run only for SSM/hybrid
# (see DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = {"zamba2_2p7b", "mamba2_1p3b"}


def get_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink to a same-family smoke-test config (CPU, one step)."""
    changes = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
    if cfg.family == "moe":
        changes.update(n_experts=8, top_k=min(cfg.top_k, 2),
                       n_dense_layers=min(cfg.n_dense_layers, 1),
                       dense_d_ff=256 if cfg.dense_d_ff else 0)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.attn_every:
        changes.update(attn_every=2)
    if cfg.n_enc_layers:
        changes.update(n_enc_layers=2)
    if cfg.cross_attn_every:
        changes.update(cross_attn_every=2)
    if cfg.n_frontend_tokens:
        changes.update(n_frontend_tokens=16)
    return dataclasses.replace(cfg, **changes)


def cells(include_skips: bool = False) -> List[Tuple[str, str]]:
    """The dry-run grid: (arch_id, shape_name)."""
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                if include_skips:
                    out.append((arch, shape + ":SKIP"))
                continue
            out.append((arch, shape))
    return out
