"""Llama-3.2-Vision-90B: decoder with cross-attention image layers every 5th
layer; vision frontend stubbed [hf:meta-llama/Llama-3.2-11B-Vision].
100L d_model=8192 64H kv=8 d_ff=28672 vocab=128256."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    frontend="vision",
    n_frontend_tokens=1600,   # precomputed patch embeddings (stub)
    rope_theta=500_000.0,
)
