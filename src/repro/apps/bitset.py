"""Bit vectors vs red-black trees for the set data structure (paper §8.3).

k-ary union / intersection / difference over sets drawn from a bounded
domain (2^19 in the paper). Functional path: ops.setops.BitSet. The model
compares three implementations: RB-tree (pointer-chasing, O(n log n)),
SIMD bitset (bandwidth-bound over the whole domain), Buddy (row-wide ops in
DRAM). Buddy shifts the crossover vs RB-trees down to tiny sets (~64 of 2^19).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence

import jax.numpy as jnp

from repro.apps.cost import DEFAULT_APP_SYSTEM, AppSystem

DOMAIN = 1 << 19  # paper's element domain

# RB-tree cost per element insert/visit: cache-resident benchmark loop
# (the paper's microbenchmark re-runs the op), so ~2 ns fixed work plus
# ~0.8 ns per tree level of compare+follow. Calibrated so the paper's two
# qualitative claims hold: RB-tree wins at 16-element sets, Buddy wins >= 3x
# on average from 64 elements up.
RB_NS_BASE = 2.0
RB_NS_PER_LEVEL = 0.8


def rbtree_setop_ns(k_sets: int, elems_per_set: int) -> float:
    total = k_sets * elems_per_set
    depth = max(1.0, math.log2(max(total, 2)))
    return total * (RB_NS_BASE + RB_NS_PER_LEVEL * depth)


def bitset_setop_ns(k_sets: int, domain: int = DOMAIN,
                    sys: AppSystem = DEFAULT_APP_SYSTEM) -> float:
    """(k-1) chained bitwise passes over the whole domain."""
    return (k_sets - 1) * sys.cpu_bitwise_ns("and", domain)


def buddy_setop_ns(k_sets: int, domain: int = DOMAIN,
                   sys: AppSystem = DEFAULT_APP_SYSTEM) -> float:
    """(k-1) chained Buddy ops (dependent chain; rows spread over banks)."""
    return (k_sets - 1) * sys.buddy_op_ns("and", domain, dependent=True)


@dataclasses.dataclass
class SetOpComparison:
    rbtree_ns: float
    bitset_ns: float
    buddy_ns: float

    @property
    def buddy_vs_rbtree(self) -> float:
        return self.rbtree_ns / self.buddy_ns

    @property
    def buddy_vs_bitset(self) -> float:
        return self.bitset_ns / self.buddy_ns


def compare(k_sets: int, elems_per_set: int, domain: int = DOMAIN,
            sys: AppSystem = DEFAULT_APP_SYSTEM) -> SetOpComparison:
    return SetOpComparison(
        rbtree_ns=rbtree_setop_ns(k_sets, elems_per_set),
        bitset_ns=bitset_setop_ns(k_sets, domain, sys),
        buddy_ns=buddy_setop_ns(k_sets, domain, sys),
    )


def figure12_grid(k_sets: int = 15,
                  sizes: Sequence[int] = (16, 64, 256, 1024, 4096, 16384)
                  ) -> Dict[int, SetOpComparison]:
    return {m: compare(k_sets, m) for m in sizes}


# ---------------------------------------------------------------------------
# Service-client path: k-ary set algebra served by repro.service
# ---------------------------------------------------------------------------

_SET_OPS = {"union": " | ", "intersection": " & "}


def setop_via_service(element_lists, domain: int, op: str = "intersection",
                      n_banks: int = 8):
    """§8.3 k-ary set op as a *service client*: one catalog query.

    Each element list becomes a registered bitvector `s{i}`; the k-ary
    union/intersection/difference is a single query expression, so the
    whole merge compiles to one fused AAP program instead of k-1 calls.
    Returns (result BitSet, QueryResult, functional-reference BitSet) —
    the first and last are bit-identical (asserted by tests).
    """
    from repro.core.bitplane import BitVector
    from repro.ops.setops import BitSet
    from repro.service import (MATERIALIZE, QueryService,
                               ServiceConfig)

    sets = [BitSet.from_elements(jnp.asarray(e), domain)
            for e in element_lists]
    svc = QueryService(ServiceConfig(n_banks=n_banks))
    for i, s in enumerate(sets):
        svc.register(f"s{i}", s.bits, group="sets")
    names = [f"s{i}" for i in range(len(sets))]
    if op == "difference":
        text = names[0] + "".join(f" & ~{n}" for n in names[1:])
        ref = sets[0].difference(*sets[1:])
    elif op in _SET_OPS:
        text = _SET_OPS[op].join(names)
        ref = (sets[0].union(*sets[1:]) if op == "union"
               else sets[0].intersection(*sets[1:]))
    else:
        raise ValueError(f"unknown set op {op!r}")
    r = svc.query(text, mode=MATERIALIZE)
    result = BitSet(BitVector(jnp.asarray(r.value), domain))
    return result, r, ref
