"""BitWeaving-V column scans (paper §8.2).

'select count(*) from T where c1 <= val <= c2' over a b-bit column of r rows.
Functional path: vertical layout + the fused scan kernel (ops.predicate).
Cost model: baseline SIMD BitWeaving streams all b planes through the cache
hierarchy; Buddy executes the per-plane bitwise update ops in DRAM. Bitcount
runs on the CPU for both (streaming popcount).
"""
from __future__ import annotations

from typing import Dict

import jax

from repro.apps.cost import DEFAULT_APP_SYSTEM, AppSystem
from repro.ops.predicate import VerticalColumn


def scan_query(values: jax.Array, n_bits: int, c1: int, c2: int):
    """Functional count(*) via the fused kernel; returns (count, bitvector)."""
    col = VerticalColumn.encode(values, n_bits)
    bv = col.scan(c1, c2)
    return bv.popcount(), bv


def buddy_ops_per_plane(c1: int, c2: int, n_bits: int) -> int:
    """Exact bulk-op count of the BitWeaving-V predicate update per plane.

    Per constant c, bit j: c_j = 1 -> 2 ops (andnot + or into lt; and into
    eq), c_j = 0 -> 1 op (andnot into eq). Summed over both constants.
    """
    total = 0
    for c in (c1, c2):
        for j in range(n_bits):
            total += 2 if (c >> j) & 1 else 1
    return total


def scan_time_ns(r_rows: int, n_bits: int, c1: int, c2: int, use_buddy: bool,
                 sys: AppSystem = DEFAULT_APP_SYSTEM) -> float:
    plane_bytes = r_rows / 8
    ws = plane_bytes * n_bits
    cache_resident = ws <= sys.l2_bytes
    if use_buddy:
        n_ops = buddy_ops_per_plane(c1, c2, n_bits)
        # independent row-slices spread over banks; ops within the scan are
        # a dependent chain per plane but planes pipeline -> row-parallel
        t_scan = n_ops * sys.buddy_op_ns("and", r_rows, dependent=False)
    else:
        # SIMD predicate evaluation is a single streaming pass over planes
        # (compute overlaps memory); cache-resident when it fits in L2.
        t_scan = sys.cpu_stream_ns(ws, cache_resident)
    # count(*) popcount over the result bitvector (CPU, streaming)
    t_cnt = sys.cpu_bitcount_ns(r_rows, streaming=True,
                                cache_resident=cache_resident)
    return t_scan + t_cnt


def speedup(r_rows: int, n_bits: int, c1: int | None = None,
            c2: int | None = None,
            sys: AppSystem = DEFAULT_APP_SYSTEM) -> float:
    if c1 is None:
        c1 = (1 << n_bits) // 4
    if c2 is None:
        c2 = 3 * (1 << n_bits) // 4
    return scan_time_ns(r_rows, n_bits, c1, c2, False, sys) / \
        scan_time_ns(r_rows, n_bits, c1, c2, True, sys)


def speedup_grid(sys: AppSystem = DEFAULT_APP_SYSTEM) -> Dict:
    """Fig. 11 grid: b x r."""
    out = {}
    for b in (1, 2, 4, 8, 12, 16, 20, 24, 28, 32):
        for r in (1 << 20, 1 << 23, 1 << 25):
            out[(b, r)] = speedup(r, b, sys=sys)
    return out
