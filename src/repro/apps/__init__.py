"""Paper §8 applications: bitmap indices, BitWeaving scans, bitvector sets."""
from repro.apps.cost import AppSystem, DEFAULT_APP_SYSTEM
