"""Shared cost model for the §8 application studies (Gem5 replaced by an
analytical model — see DESIGN.md §8 'honest gaps').

System under test mirrors the paper's Table 4: DDR4-2400, 1 channel, 16
banks. Baseline CPU bulk-bitwise streaming is bandwidth-bound; bitcount is a
popcnt dependency chain. Buddy executes AAP programs at DDR3-1600-class
timing, one op per bank concurrently for independent rows, serialized for
dependent op chains.

Calibrated constants (each justified in comments; paper-reported end-to-end
speedups then *derive*): see benchmarks/fig10/11/12 for the validation.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import compiler, timing


@dataclasses.dataclass(frozen=True)
class AppSystem:
    # DDR4-2400 x64: 19.2 GB/s peak.
    peak_bw_gbps: float = 19.2
    rmw_efficiency: float = 0.54    # read-modify-write streams w/ RFO
    stream_efficiency: float = 0.80 # pure streaming reads
    l2_bytes: int = 2 * 1024 * 1024
    l2_bw_gbps: float = 50.0
    # popcnt loop: ~0.8 bytes/cycle effective at 4 GHz when cache-resident is
    # irrelevant (dependency chain) -> ~3 GB/s; memory-streaming variant used
    # by BitWeaving baselines hits the stream bandwidth instead.
    bitcount_chain_gbps: float = 3.0
    banks: int = 16
    row_bits: int = 65536  # 8 KB row

    # -- baseline CPU -------------------------------------------------------
    def cpu_bitwise_ns(self, op: str, n_bits: int) -> float:
        bytes_out = n_bits / 8
        traffic = timing.bytes_moved_per_output_byte(op)
        ws = bytes_out * traffic
        bw = self.l2_bw_gbps if ws <= self.l2_bytes else \
            self.peak_bw_gbps * self.rmw_efficiency
        return bytes_out * traffic / bw

    def cpu_stream_ns(self, n_bytes: float, cache_resident: bool = False
                      ) -> float:
        bw = self.l2_bw_gbps if cache_resident else \
            self.peak_bw_gbps * self.stream_efficiency
        return n_bytes / bw

    def cpu_bitcount_ns(self, n_bits: int, streaming: bool = False,
                        cache_resident: bool = False) -> float:
        if streaming:
            return self.cpu_stream_ns(n_bits / 8, cache_resident)
        return (n_bits / 8) / self.bitcount_chain_gbps

    # -- Buddy --------------------------------------------------------------
    def buddy_op_ns(self, op: str, n_bits: int, dependent: bool = True
                    ) -> float:
        """One bulk op over an n_bits-wide operand.

        The operand spans ceil(n_bits/row_bits) DRAM rows; row-slices are
        independent, so they spread over the banks. `dependent` chains (the
        common case inside a query) cannot overlap *across* ops.
        """
        srcs = ["D0"] if op in ("not", "copy") else ["D0", "D1"]
        prog = compiler.op_program(op if op != "copy" else "copy", srcs, "D2")
        lat = timing.program_latency_ns(prog)
        rows = max(1, math.ceil(n_bits / self.row_bits))
        waves = math.ceil(rows / self.banks)
        return waves * lat if dependent else rows * lat / self.banks


DEFAULT_APP_SYSTEM = AppSystem()
