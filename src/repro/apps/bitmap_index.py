"""Bitmap-index analytics (paper §8.1).

The workload is the paper's real-application query [21]: per-user activity
bitmaps tracked per day, plus attribute bitmaps (e.g. gender). The query

  "How many unique users were active every week for the past n weeks?
   How many male users were active each of the past n weeks?"

executes 6n ORs (7 daily bitmaps -> weekly), 2n-1 ANDs, n+1 bitcounts.
Functional execution runs on the packed ops layer (validated vs numpy);
end-to-end time comes from apps.cost for baseline CPU vs Buddy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.apps.cost import DEFAULT_APP_SYSTEM, AppSystem
from repro.ops.bitwise import bitwise_and, bitwise_or
from repro.ops.popcount import popcount_words


@dataclasses.dataclass
class UserDatabase:
    """m users; daily activity bitmaps for 7n days; gender bitmap."""

    daily: jax.Array       # (n_weeks, 7, m_words) uint32
    male: jax.Array        # (m_words,) uint32
    m_users: int

    @classmethod
    def synthetic(cls, key, m_users: int, n_weeks: int,
                  p_active: float = 0.3) -> "UserDatabase":
        from repro.core.bitplane import pack_bits

        k1, k2 = jax.random.split(key)
        act = jax.random.bernoulli(k1, p_active, (n_weeks, 7, m_users))
        male = jax.random.bernoulli(k2, 0.5, (m_users,))
        return cls(pack_bits(act), pack_bits(male), m_users)


def weekly_active_query(db: UserDatabase) -> Tuple[jax.Array, jax.Array, Dict]:
    """Returns (n_active_every_week, per-week male actives, op counts)."""
    n_weeks = db.daily.shape[0]
    ops = {"or": 0, "and": 0, "bitcount": 0}

    weekly: List[jax.Array] = []
    for w in range(n_weeks):
        acc = db.daily[w, 0]
        for d in range(1, 7):
            acc = bitwise_or(acc, db.daily[w, d])
            ops["or"] += 1
        weekly.append(acc)

    every_week = weekly[0]
    for w in range(1, n_weeks):
        every_week = bitwise_and(every_week, weekly[w])
        ops["and"] += 1
    n_every = popcount_words(every_week)
    ops["bitcount"] += 1

    male_counts = []
    for w in range(n_weeks):
        mw = bitwise_and(weekly[w], db.male)
        ops["and"] += 1
        male_counts.append(popcount_words(mw))
        ops["bitcount"] += 1

    assert ops["or"] == 6 * n_weeks
    assert ops["and"] == 2 * n_weeks - 1
    assert ops["bitcount"] == n_weeks + 1
    return n_every, jnp.stack(male_counts), ops


# ---------------------------------------------------------------------------
# Service-client path: the same query served by repro.service
# ---------------------------------------------------------------------------


def week_or(w: int, prefix: str = "") -> str:
    """The 7-day OR-tree query template for week `w`.

    One definition shared by the app client below and the synthetic stream
    (`repro.service.workload`): the plan-cache sharing between those two
    paths depends on the template staying structurally identical.
    """
    return "(" + " | ".join(f"{prefix}w{w}d{d}" for d in range(7)) + ")"


def build_query_service(db: UserDatabase, n_banks: int = 8):
    """Register the database's bitmaps in a fresh `QueryService` catalog.

    Daily activity bitmaps become rows `w{week}d{day}`, the attribute
    bitmap becomes `male`; all co-located in one allocator affinity group
    (they participate in every query together — §6.2.4 placement).
    """
    from repro.service import QueryService, ServiceConfig

    svc = QueryService(ServiceConfig(n_banks=n_banks))
    n_weeks = db.daily.shape[0]
    for w in range(n_weeks):
        for d in range(7):
            svc.register(f"w{w}d{d}", db.daily[w, d], db.m_users,
                         group="bitmaps")
    svc.register("male", db.male, db.m_users, group="bitmaps")
    return svc


def weekly_active_query_service(db: UserDatabase, svc=None, n_banks: int = 8
                                ) -> Tuple[int, jax.Array, Dict]:
    """§8.1 query as a *service client*: one batch of catalog queries.

    The n+1 aggregates go through the planner/plan-cache/scheduler stack
    instead of direct functional calls — same workload, service path. The
    per-week male filters share one canonical plan, so n-1 of them are plan
    cache hits inside a single batch. Results are bit-identical to
    `weekly_active_query` (asserted by tests/test_service.py).

    Returns (n_active_every_week, per-week male actives, service stats).
    """
    from repro.service import Query

    if svc is None:
        svc = build_query_service(db, n_banks)
    n_weeks = db.daily.shape[0]
    every = " & ".join(week_or(w) for w in range(n_weeks))
    batch = [Query(every, tenant="analytics")]
    batch += [Query(f"{week_or(w)} & male", tenant="analytics")
              for w in range(n_weeks)]
    rep = svc.query_batch(batch)
    n_every = rep.results[0].value
    male_counts = jnp.asarray([r.value for r in rep.results[1:]])
    return n_every, male_counts, svc.stats()


# ---------------------------------------------------------------------------
# End-to-end time model (Fig. 10)
# ---------------------------------------------------------------------------


def query_time_ns(m_users: int, n_weeks: int, use_buddy: bool,
                  sys: AppSystem = DEFAULT_APP_SYSTEM) -> float:
    n_or = 6 * n_weeks
    n_and = 2 * n_weeks - 1
    n_cnt = n_weeks + 1
    if use_buddy:
        t_ops = n_or * sys.buddy_op_ns("or", m_users) \
            + n_and * sys.buddy_op_ns("and", m_users)
    else:
        t_ops = n_or * sys.cpu_bitwise_ns("or", m_users) \
            + n_and * sys.cpu_bitwise_ns("and", m_users)
    # bitcount stays on the CPU in both systems (§8.1)
    t_cnt = n_cnt * sys.cpu_bitcount_ns(m_users)
    return t_ops + t_cnt


def speedup(m_users: int, n_weeks: int,
            sys: AppSystem = DEFAULT_APP_SYSTEM) -> float:
    return query_time_ns(m_users, n_weeks, False, sys) / \
        query_time_ns(m_users, n_weeks, True, sys)
