"""Shared TPU peak-hardware constants (single source of truth).

TPU v5e per-chip numbers (assignment-specified). Both the dry-run roofline
analysis (`launch.roofline`) and the measured-bandwidth benchmark
(`benchmarks/vm_stream.py`) price against these — deduplicating them here
keeps the modeled and measured fractions-of-roofline on one denominator.
"""
from __future__ import annotations

PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
