"""XOR-based encryption primitives (paper §8.4.2).

One-time-pad / stream-cipher XOR is the canonical bandwidth-bound bitwise
workload: ciphertext = plaintext ^ keystream, one fused pass. The keystream
generator is a counter-mode xorshift PRF (not cryptographically strong — it
demonstrates the data path the paper targets, where the XOR of multi-KB
blocks dominates, e.g. optical XOR encryption [26] and visual crypto [66]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.ops.bitwise import bitwise_xor


def keystream(key: jax.Array, shape, dtype=jnp.uint32) -> jax.Array:
    """Counter-mode xorshift* stream: words[i] = mix(key, i)."""
    n = 1
    for s in shape:
        n *= s
    ctr = jnp.arange(n, dtype=jnp.uint32)
    k = jnp.asarray(key, jnp.uint32)
    x = ctr + k * jnp.uint32(0x9E3779B9)
    x ^= x >> 16
    x *= jnp.uint32(0x21F0AAAD)
    x ^= x >> 15
    x *= jnp.uint32(0x735A2D97)
    x ^= x >> 15
    return x.reshape(shape).astype(dtype)


def xor_encrypt(plaintext: jax.Array, key: jax.Array) -> jax.Array:
    """plaintext: packed uint32 words; involution (decrypt == encrypt)."""
    ks = keystream(key, plaintext.shape)
    return bitwise_xor(plaintext, ks)


def xor_decrypt(ciphertext: jax.Array, key: jax.Array) -> jax.Array:
    """Inverse of `xor_encrypt` — the same XOR pass (involution, §8.4.2)."""
    return xor_encrypt(ciphertext, key)
