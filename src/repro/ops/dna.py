"""Bit-parallel DNA sequence matching (paper §8.4.3).

Bases pack 2 bits/base into two parallel bit-planes (lo, hi). Exact-match
read mapping a la bit-parallel filters (Shifted-Hamming-Distance family
[15, 71]): a read of length L against a genome of length G evaluates

    match[i] = AND_j  eq_j[i + j],   eq_j = (genome base == read[j])

where each eq_j is one or two bulk bitwise ops over the whole genome plane
and the AND-accumulation over shifted planes is L more — exactly the
row-wide workload Buddy accelerates. Mismatch tolerance (<= t) accumulates
eq-counts with the carry-save majority kernel instead of the AND chain.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.bitplane import BitVector, pack_bits
from repro.kernels import ref

# A=0 C=1 G=2 T=3
_BASE = {"A": 0, "C": 1, "G": 2, "T": 3}


def encode(seq) -> Tuple[jax.Array, jax.Array, int]:
    """Sequence (str or int array) -> (lo_plane, hi_plane, n) packed."""
    if isinstance(seq, str):
        vals = jnp.asarray([_BASE[c] for c in seq], jnp.uint32)
    else:
        vals = jnp.asarray(seq, jnp.uint32)
    lo = pack_bits((vals & 1).astype(bool))
    hi = pack_bits(((vals >> 1) & 1).astype(bool))
    return lo, hi, int(vals.shape[0])


def shift_down(words: jax.Array, k: int) -> jax.Array:
    """Packed funnel shift: out bit i = in bit (i + k)  (k >= 0)."""
    nw = words.shape[-1]
    wshift, bshift = divmod(k, 32)
    w = jnp.roll(words, -wshift, axis=-1)
    if wshift:
        w = w.at[..., nw - wshift:].set(0)
    if bshift:
        hi = jnp.concatenate(
            [w[..., 1:], jnp.zeros_like(w[..., :1])], axis=-1)
        w = (w >> jnp.uint32(bshift)) | (hi << jnp.uint32(32 - bshift))
    return w


def base_equality(lo: jax.Array, hi: jax.Array, base: int) -> jax.Array:
    """Packed eq-plane: genome[i] == base (2 bulk ops per plane)."""
    l = lo if (base & 1) else ~lo
    h = hi if (base >> 1) & 1 else ~hi
    return l & h


def find_matches(genome, read) -> BitVector:
    """Exact-match start positions of `read` in `genome` (packed)."""
    g_lo, g_hi, n = encode(genome)
    read_vals = [_BASE[c] for c in read] if isinstance(read, str) else list(read)
    L = len(read_vals)
    acc = jnp.full_like(g_lo, 0xFFFFFFFF)
    for j, b in enumerate(read_vals):
        eq = base_equality(g_lo, g_hi, int(b))
        acc = acc & shift_down(eq, j)
    valid = n - L + 1
    bv = BitVector(acc, max(valid, 0))
    return BitVector(acc & bv._mask(), max(valid, 0))


def find_matches_with_mismatches(genome, read, max_mismatch: int) -> BitVector:
    """Start positions with <= max_mismatch mismatches: count eq-planes with
    the generalized-TRA majority (threshold = L - max_mismatch)."""
    g_lo, g_hi, n = encode(genome)
    read_vals = [_BASE[c] for c in read] if isinstance(read, str) else list(read)
    L = len(read_vals)
    planes = jnp.stack([
        shift_down(base_equality(g_lo, g_hi, int(b)), j)
        for j, b in enumerate(read_vals)])
    acc = ref.majority_k(planes, threshold=L - max_mismatch)
    valid = n - L + 1
    bv = BitVector(acc, max(valid, 0))
    return BitVector(acc & bv._mask(), max(valid, 0))
