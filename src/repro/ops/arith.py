"""Bit-serial arithmetic over `VerticalColumn` operands (SIMDRAM-style).

The deployable API of the arithmetic layer: element-wise ADD / SUB
(two's-complement, wrapping modulo 2**n_bits), constant and column
LESS-THAN predicates, and SUM aggregation — all over the vertical layout of
`ops.predicate.VerticalColumn`, so a column transposes once and every
arithmetic op after that is bit-plane streaming.

Two execution paths per op, bit-identical (tests/test_arith.py):

  * the fast path (`add_columns`, ...) dispatches size-aware between the
    pure-jnp oracle (`kernels.ref`) and the fused Pallas ripple kernels
    (`kernels.arith`) — one VMEM pass, carry in registers;
  * the in-DRAM path (`add_columns_dram`, ...) lowers to the maj3+xor AAP
    microprograms of `core.arith_compiler` and executes them through
    `core.engine` — on one subarray or word-sharded across banks via
    `n_banks=` (`core.bankgroup`). By default the microprogram runs on the
    lowered register-machine VM (`core.lowering`); `backend="pallas"`
    selects the megakernel (`kernels.vm`, whole plane resident in VMEM for
    the program) and `backend="interp"` the micro-op interpreter oracle.

Tail lanes of a column (padding up to a multiple of 32 values) may hold
garbage after an arithmetic op; every consumer here masks through
`BitVector`/`tail_mask` before counting or comparing, so results over the
`n_values` logical lanes are exact.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import arith_compiler, engine
from repro.core.bitplane import BitVector, tail_mask
from repro.ops.predicate import VerticalColumn

_KERNEL_MIN = 1 << 16  # bits of plane data before the Pallas path pays off

_A_PREFIX, _B_PREFIX, _OUT_PREFIX = "X", "Y", "S"


def _check_pair(a: VerticalColumn, b: VerticalColumn) -> None:
    if a.n_bits != b.n_bits:
        raise ValueError(f"width mismatch: {a.n_bits} vs {b.n_bits} bits")
    if a.n_values != b.n_values:
        raise ValueError(
            f"length mismatch: {a.n_values} vs {b.n_values} values")


def _use_kernel(planes: jax.Array, use_kernel: Optional[bool]) -> bool:
    if use_kernel is not None:
        return use_kernel
    return planes.size * 32 >= _KERNEL_MIN


def _mask(col: VerticalColumn) -> jax.Array:
    return jnp.asarray(tail_mask(col.n_values))


# ---------------------------------------------------------------------------
# fast path: ref oracle <-> Pallas ripple kernels
# ---------------------------------------------------------------------------


def _add(a: VerticalColumn, b: VerticalColumn, sub: bool,
         use_kernel: Optional[bool]) -> VerticalColumn:
    _check_pair(a, b)
    if _use_kernel(a.planes, use_kernel):
        from repro.kernels import ops as kops

        planes = kops.bitserial_add(a.planes, b.planes, sub=sub)
    else:
        from repro.kernels import ref

        planes = ref.bitserial_add(a.planes, b.planes, sub=sub)
    return VerticalColumn(planes, a.n_bits, a.n_values)


def add_columns(a: VerticalColumn, b: VerticalColumn,
                use_kernel: Optional[bool] = None) -> VerticalColumn:
    """(a + b) mod 2**n_bits, element-wise over the vertical layout."""
    return _add(a, b, False, use_kernel)


def sub_columns(a: VerticalColumn, b: VerticalColumn,
                use_kernel: Optional[bool] = None) -> VerticalColumn:
    """(a - b) mod 2**n_bits — exact for unsigned and two's-complement."""
    return _add(a, b, True, use_kernel)


def lt_columns(a: VerticalColumn, b: VerticalColumn,
               use_kernel: Optional[bool] = None) -> BitVector:
    """Packed predicate bitvector of element-wise unsigned `a < b`."""
    _check_pair(a, b)
    if _use_kernel(a.planes, use_kernel):
        from repro.kernels import ops as kops

        words = kops.bitserial_lt(a.planes, b.planes)
    else:
        from repro.kernels import ref

        words = ref.bitserial_lt(a.planes, b.planes)
    return BitVector(words & _mask(a), a.n_values)


def lt_const(col: VerticalColumn, k: int,
             use_kernel: Optional[bool] = None) -> BitVector:
    """Packed predicate bitvector of `v < k` (unsigned compare).

    Trivial bounds short-circuit (k <= 0 -> all-false, k >= 2**n ->
    all-true); in range this is the BitWeaving scan 0 <= v <= k-1, riding
    the existing fused between-scan kernel.
    """
    if k <= 0:
        return BitVector.zeros(col.n_values)
    if k >= (1 << col.n_bits):
        return BitVector.ones(col.n_values)
    return col.scan(0, k - 1, use_kernel)


def weighted_plane_sum(planes: jax.Array, mask: jax.Array) -> int:
    """sum_j 2**j * popcount(planes[j] & mask), accumulated in Python ints
    (a 2**31 plane weight would overflow jnp's default int32 lattice)."""
    from repro.ops.popcount import popcount_words

    counts = popcount_words(planes & mask[None, :], axis=-1)
    return sum(int(c) << j for j, c in enumerate(counts))


def sum_column(col: VerticalColumn) -> int:
    """SUM(col) over the logical lanes: sum_j 2**j * popcount(plane_j)."""
    return weighted_plane_sum(col.planes, _mask(col))


# ---------------------------------------------------------------------------
# in-DRAM path: AAP microprograms through the engine / bank group
# ---------------------------------------------------------------------------


def _plane_state(col: VerticalColumn, prefix: str) -> dict:
    return {f"{prefix}{j}": col.planes[j] for j in range(col.n_bits)}


def _engine_kw(backend: str) -> dict:
    """Map the public `backend` knob onto `engine.execute` arguments."""
    if backend == "interp":
        return {"lowered": False}
    if backend in ("scan", "pallas"):
        return {"lowered": True, "backend": backend}
    raise ValueError(f"unknown backend {backend!r}; "
                     "expected 'scan', 'pallas', or 'interp'")


def _add_dram(a: VerticalColumn, b: VerticalColumn, sub: bool,
              n_banks: int, backend: str) -> VerticalColumn:
    _check_pair(a, b)
    res = arith_compiler.ripple_add_program(
        a.n_bits, _A_PREFIX, _B_PREFIX, _OUT_PREFIX, sub=sub)
    data = {**_plane_state(a, _A_PREFIX), **_plane_state(b, _B_PREFIX)}
    out = engine.execute(res.program, data, outputs=res.outputs,
                         n_banks=n_banks, **_engine_kw(backend))
    return VerticalColumn(jnp.stack([out[o] for o in res.outputs]),
                          a.n_bits, a.n_values)


def add_columns_dram(a: VerticalColumn, b: VerticalColumn,
                     n_banks: int = 1,
                     backend: str = "scan") -> VerticalColumn:
    """ADD through the maj3+xor AAP microprogram on the simulated machine."""
    return _add_dram(a, b, False, n_banks, backend)


def sub_columns_dram(a: VerticalColumn, b: VerticalColumn,
                     n_banks: int = 1,
                     backend: str = "scan") -> VerticalColumn:
    """SUB (a + ~b + 1) through the AAP microprogram."""
    return _add_dram(a, b, True, n_banks, backend)


def lt_columns_dram(a: VerticalColumn, b: VerticalColumn,
                    n_banks: int = 1, backend: str = "scan") -> BitVector:
    """Element-wise `a < b` as one fused single-output AAP program."""
    _check_pair(a, b)
    res = arith_compiler.compile_lt_columns(a.n_bits, "OUT",
                                            _A_PREFIX, _B_PREFIX)
    data = {**_plane_state(a, _A_PREFIX), **_plane_state(b, _B_PREFIX)}
    out = engine.execute(res.program, data, outputs=["OUT"],
                         n_banks=n_banks, **_engine_kw(backend))["OUT"]
    return BitVector(out & _mask(a), a.n_values)


def lt_const_dram(col: VerticalColumn, k: int, n_banks: int = 1,
                  backend: str = "scan") -> BitVector:
    """`v < k` as a fused AAP program (trivial bounds short-circuit)."""
    if k <= 0:
        return BitVector.zeros(col.n_values)
    if k >= (1 << col.n_bits):
        return BitVector.ones(col.n_values)
    res = arith_compiler.compile_lt_const(col.n_bits, k, "OUT", _A_PREFIX)
    assert res is not None
    out = engine.execute(res.program, _plane_state(col, _A_PREFIX),
                         outputs=["OUT"], n_banks=n_banks,
                         **_engine_kw(backend))["OUT"]
    return BitVector(out & _mask(col), col.n_values)


def sum_column_dram(col: VerticalColumn, n_banks: int = 1,
                    backend: str = "scan") -> int:
    """SUM via the plane-readout program (planes staged through the engine,
    host-side weighted bitcount — the paper's §8.1 split)."""
    res = arith_compiler.plane_readout_program(col.n_bits, _A_PREFIX,
                                               _OUT_PREFIX)
    out = engine.execute(res.program, _plane_state(col, _A_PREFIX),
                         outputs=res.outputs, n_banks=n_banks,
                         **_engine_kw(backend))
    planes = jnp.stack([out[o] for o in res.outputs])
    return weighted_plane_sum(planes, _mask(col))
