"""Bloom filters on packed bitvectors (paper §8.4.4 approximate statistics).

Batch insert/query are scatter/gather over one packed row; merging filters
(the expensive distributed aggregation) is a bulk OR — a Buddy op. Used by
the data pipeline for streaming dedup statistics.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.bitplane import BitVector
from repro.ops.bitwise import bitwise_or


def _hashes(keys: jax.Array, k: int, m_bits: int) -> jax.Array:
    """k hash positions per key: double hashing h1 + i*h2 (Kirsch-Mitzenmacher)."""
    keys = jnp.asarray(keys, jnp.uint32)
    h1 = keys * jnp.uint32(0x9E3779B1)
    h1 = (h1 ^ (h1 >> 15)) * jnp.uint32(0x85EBCA77)
    h1 = h1 ^ (h1 >> 13)
    h2 = keys * jnp.uint32(0xC2B2AE3D)
    h2 = (h2 ^ (h2 >> 16)) | jnp.uint32(1)  # odd
    i = jnp.arange(k, dtype=jnp.uint32)
    return ((h1[:, None] + i[None, :] * h2[:, None]) % jnp.uint32(m_bits)
            ).astype(jnp.int32)


@dataclasses.dataclass
class BloomFilter:
    """Bloom filter over an m-bit packed row (paper §8.4.4 "approximate
    statistics").

    `bits` is the filter's backing bitvector (one subarray row in the
    paper's deployment); `k` is the number of hash probes per key.
    Membership updates are scatter/gather; the distributed-aggregation
    path (`merge`) is a bulk OR, i.e. one Buddy AAP program per 8 KB row.
    """

    bits: BitVector
    k: int

    @classmethod
    def create(cls, m_bits: int, k: int = 4) -> "BloomFilter":
        """Empty filter of `m_bits` bits with `k` probes per key."""
        return cls(BitVector.zeros(m_bits), k)

    def insert(self, keys: jax.Array) -> "BloomFilter":
        """Set the k probe bits of every key (functional — returns a new
        filter; duplicates are harmless)."""
        pos = _hashes(keys, self.k, self.bits.n_bits).reshape(-1)
        flat = jnp.zeros((self.bits.n_bits,), jnp.uint8).at[pos].set(1)
        from repro.core.bitplane import pack_bits

        new = bitwise_or(self.bits.words, pack_bits(flat))
        return BloomFilter(BitVector(new, self.bits.n_bits), self.k)

    def query(self, keys: jax.Array) -> jax.Array:
        """Possibly-present (True) vs definitely-absent (False) per key."""
        pos = _hashes(keys, self.k, self.bits.n_bits)
        w = self.bits.words[pos // 32]
        present = (w >> (pos % 32).astype(jnp.uint32)) & 1
        return present.all(axis=1)

    def merge(self, *others: "BloomFilter") -> "BloomFilter":
        """Union of filters — bulk OR (the Buddy-accelerated path)."""
        words = self.bits.words
        for o in others:
            assert o.k == self.k and o.bits.n_bits == self.bits.n_bits
            words = bitwise_or(words, o.bits.words)
        return BloomFilter(BitVector(words, self.bits.n_bits), self.k)

    def fill_ratio(self) -> jax.Array:
        """Fraction of set bits — drives the false-positive-rate estimate
        fpr ~= fill_ratio ** k."""
        return self.bits.popcount() / self.bits.n_bits
