"""BitWeaving-style predicate evaluation over integer columns (paper §8.2).

`scan(column, lo, hi)` evaluates lo <= v <= hi for every value and returns a
packed result bitvector — the core of the paper's database-scan workload.
Columns are stored/cached in the vertical layout so repeated scans skip the
transpose.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bitplane import BitVector
from repro.ops.transpose import to_vertical

_KERNEL_MIN = 1 << 16


@dataclasses.dataclass
class VerticalColumn:
    """An integer column in BitWeaving-V layout."""

    planes: jax.Array   # (n_bits, n//32) uint32
    n_bits: int
    n_values: int

    @classmethod
    def encode(cls, values: jax.Array, n_bits: int) -> "VerticalColumn":
        values = jnp.asarray(values, jnp.uint32)
        n = values.shape[0]
        pad = (-n) % 32
        if pad:
            # pad with sentinel > any real value so range predicates exclude it
            values = jnp.concatenate(
                [values, jnp.full((pad,), (1 << n_bits) - 1, jnp.uint32)])
        return cls(to_vertical(values, n_bits), n_bits, n)

    def scan(self, lo: int, hi: int, use_kernel: Optional[bool] = None
             ) -> BitVector:
        """Packed bitvector of lo <= v <= hi."""
        big = (self.planes.size >= _KERNEL_MIN // 32 if use_kernel is None
               else use_kernel)
        if big:
            from repro.kernels import ops as kops

            words = kops.bitweaving_scan(self.planes, int(lo), int(hi),
                                         self.n_bits)
        else:
            from repro.kernels import ref

            words = ref.bitweaving_scan(self.planes, int(lo), int(hi),
                                        self.n_bits)
        bv = BitVector(words, self.n_values)
        # mask tail padding
        return BitVector(words & bv._mask(), self.n_values)


def scan_count(values: jax.Array, n_bits: int, lo: int, hi: int) -> jax.Array:
    """select count(*) from T where lo <= val <= hi (one-shot)."""
    col = VerticalColumn.encode(values, n_bits)
    return col.scan(lo, hi).popcount()
