"""BitWeaving-style predicate evaluation over integer columns (paper §8.2).

`scan(column, lo, hi)` evaluates lo <= v <= hi for every value and returns a
packed result bitvector — the core of the paper's database-scan workload.
Columns are stored/cached in the vertical layout so repeated scans skip the
transpose.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bitplane import BitVector
from repro.ops.transpose import to_vertical

_KERNEL_MIN = 1 << 16


def between_scan(planes: jax.Array, lo: int, hi: int, n_bits: int,
                 use_kernel: Optional[bool] = None) -> jax.Array:
    """Packed result words of lo <= v <= hi over vertical bit planes.

    The public seam over `kernels.bitweaving`'s fused between-scan: one
    streaming pass that keeps all four comparison states in registers
    (vs the unfused reference, `kernels.ref.bitweaving_scan`, which walks
    the planes once per bound). Dispatches to the Pallas kernel for large
    columns and the jnp reference otherwise; bit-identical either way
    (tests/test_ops.py). The service's `range_scan` re-derives this fused
    program through the cost-based optimizer pipeline.
    """
    planes = jnp.asarray(planes, jnp.uint32)
    big = (planes.size >= _KERNEL_MIN // 32 if use_kernel is None
           else use_kernel)
    if big:
        from repro.kernels import ops as kops

        return kops.bitweaving_scan(planes, int(lo), int(hi), n_bits)
    from repro.kernels import ref

    return ref.bitweaving_scan(planes, int(lo), int(hi), n_bits)


@dataclasses.dataclass
class VerticalColumn:
    """An integer column in BitWeaving-V layout."""

    planes: jax.Array   # (n_bits, n//32) uint32
    n_bits: int
    n_values: int

    @classmethod
    def encode(cls, values: jax.Array, n_bits: int) -> "VerticalColumn":
        """Transpose `values` (< 2**n_bits) into vertical bit planes.

        Tail positions are padded with an out-of-range sentinel so range
        predicates never select them.
        """
        values = jnp.asarray(values, jnp.uint32)
        n = values.shape[0]
        pad = (-n) % 32
        if pad:
            # pad with sentinel > any real value so range predicates exclude it
            values = jnp.concatenate(
                [values, jnp.full((pad,), (1 << n_bits) - 1, jnp.uint32)])
        return cls(to_vertical(values, n_bits), n_bits, n)

    def scan(self, lo: int, hi: int, use_kernel: Optional[bool] = None
             ) -> BitVector:
        """Packed bitvector of lo <= v <= hi."""
        words = between_scan(self.planes, lo, hi, self.n_bits, use_kernel)
        bv = BitVector(words, self.n_values)
        # mask tail padding
        return BitVector(words & bv._mask(), self.n_values)


def scan_count(values: jax.Array, n_bits: int, lo: int, hi: int) -> jax.Array:
    """select count(*) from T where lo <= val <= hi (one-shot)."""
    col = VerticalColumn.encode(values, n_bits)
    return col.scan(lo, hi).popcount()


# ---------------------------------------------------------------------------
# In-DRAM lowering: the range predicate as a fusable expression DAG
# ---------------------------------------------------------------------------


def range_scan_expr(n_bits: int, lo: int, hi: int, plane_prefix: str = "P"):
    """The predicate lo <= v <= hi as a boolean expression DAG over plane
    rows `P0..P{n_bits-1}` (LSB-first, one D-group row per bit plane).

    This is the multi-term-predicate path of the fusing compiler: feed the
    returned `Expr` to `core.compiler.compile_expr_fused` and the whole
    scan lowers to ONE minimized AAP program (constants folded at build
    time, shared eq-prefixes CSE'd, `eq & ~P` terms fused to ANDNOT).
    Semantics match `kernels.ref.bitweaving_scan` bit-for-bit (asserted by
    tests/test_compiler.py).
    """
    from repro.core.compiler import Expr

    planes = [Expr.of(f"{plane_prefix}{j}") for j in range(n_bits)]

    def cmp_const(c: int):
        """(lt, eq) exprs vs constant c, MSB->LSB; None folds 0/1 consts."""
        lt, eq = None, None
        for j in range(n_bits - 1, -1, -1):
            pj = planes[j]
            if (c >> j) & 1:
                term = ~pj if eq is None else eq & ~pj
                lt = term if lt is None else lt | term
                eq = pj if eq is None else eq & pj
            else:
                eq = ~pj if eq is None else eq & ~pj
        return lt, eq

    lt_lo, _ = cmp_const(lo)           # v <  lo
    lt_hi, eq_hi = cmp_const(hi)       # v <  hi, v == hi
    le_hi = eq_hi if lt_hi is None else lt_hi | eq_hi
    if lt_lo is None:                  # lo == 0: lower bound always holds
        return le_hi
    return le_hi & ~lt_lo


def compile_range_scan(n_bits: int, lo: int, hi: int, dst: str = "OUT",
                       plane_prefix: str = "P"):
    """Fused AAP program for the range scan (see `range_scan_expr`)."""
    from repro.core.compiler import compile_expr_fused

    return compile_expr_fused(range_scan_expr(n_bits, lo, hi, plane_prefix),
                              dst)
