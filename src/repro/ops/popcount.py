"""Population count on packed uint32 words (the CPU-side `bitcount` the
paper keeps on the processor — here TPU-resident so results never leave HBM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_M1 = jnp.uint32(0x55555555)
_M2 = jnp.uint32(0x33333333)
_M4 = jnp.uint32(0x0F0F0F0F)
_H01 = jnp.uint32(0x01010101)


def popcount_u32(w: jax.Array) -> jax.Array:
    """SWAR popcount per word (Hacker's Delight 5-2). Returns uint32."""
    w = w.astype(jnp.uint32)
    w = w - ((w >> 1) & _M1)
    w = (w & _M2) + ((w >> 2) & _M2)
    w = (w + (w >> 4)) & _M4
    return (w * _H01) >> 24


def popcount_words(words: jax.Array, axis=None) -> jax.Array:
    """Total set bits (sum over `axis`, default all)."""
    per_word = popcount_u32(words).astype(jnp.int32)
    return per_word.sum() if axis is None else per_word.sum(axis=axis)
