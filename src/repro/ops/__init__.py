"""TPU-native bulk bitwise operations — the deployable fast path."""
from repro.ops.bitwise import (bitwise_and, bitwise_or, bitwise_xor,
                               bitwise_not, bitwise_nand, bitwise_nor,
                               bitwise_xnor, majority3, andnot)
from repro.ops.popcount import popcount_words, popcount_u32
from repro.ops.transpose import to_vertical, from_vertical
from repro.ops.predicate import VerticalColumn, scan_count
from repro.ops.arith import (add_columns, sub_columns, lt_columns, lt_const,
                             sum_column, add_columns_dram, sub_columns_dram,
                             lt_columns_dram, lt_const_dram, sum_column_dram)
from repro.ops.setops import BitSet
from repro.ops.masked_init import masked_init, masked_fill_constant, field_mask
from repro.ops.bloom import BloomFilter
from repro.ops.crypto import xor_encrypt, xor_decrypt, keystream
