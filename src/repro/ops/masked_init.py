"""Bulk masked initialization (paper §8.4.1).

Clears/sets a specific field across an array of packed records without moving
the data to the processor: out = (data & ~mask) | (value & mask), one fused
pass. `field_mask` builds the row-wide mask for a (offset, width) field of a
fixed-stride record — e.g. zeroing the alpha channel of an RGBA image.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.ops.bitwise import bitwise_and, bitwise_or, bitwise_not


def field_mask(record_bits: int, offset: int, width: int, n_records: int
               ) -> jax.Array:
    """Packed mask with `width` bits set at `offset` of each record."""
    total = record_bits * n_records
    bit_idx = np.arange(total) % record_bits
    bits = (bit_idx >= offset) & (bit_idx < offset + width)
    from repro.core.bitplane import pack_bits

    return pack_bits(jnp.asarray(bits))


def masked_init(data: jax.Array, mask: jax.Array, value: jax.Array
                ) -> jax.Array:
    """out = (data & ~mask) | (value & mask) on packed uint32 words."""
    keep = bitwise_and(data, bitwise_not(mask))
    put = bitwise_and(value, mask)
    return bitwise_or(keep, put)


def masked_fill_constant(data: jax.Array, mask: jax.Array, bit: int
                         ) -> jax.Array:
    """Set all masked bits to a constant 0/1 (the common graphics case —
    maps to two Buddy ops: and with ~mask, or with mask)."""
    if bit:
        return bitwise_or(data, mask)
    return bitwise_and(data, bitwise_not(mask))
