"""Bitvector set data structure (paper §8.3): constant-time insert/lookup,
bulk union/intersection/difference as row-wide bitwise ops."""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp

from repro.core.bitplane import BitVector, n_words


@dataclasses.dataclass
class BitSet:
    """Set over domain [0, domain) as a packed bitvector."""

    bits: BitVector

    @classmethod
    def empty(cls, domain: int) -> "BitSet":
        return cls(BitVector.zeros(domain))

    @classmethod
    def from_elements(cls, elems: jax.Array, domain: int) -> "BitSet":
        """Duplicate-safe: scatter 1s at bit granularity, then pack."""
        elems = jnp.asarray(elems, jnp.int32)
        bits = jnp.zeros((domain,), jnp.uint8).at[elems].set(1)
        from repro.core.bitplane import pack_bits

        return cls(BitVector(pack_bits(bits), domain))

    @property
    def domain(self) -> int:
        return self.bits.n_bits

    def insert(self, e) -> "BitSet":
        w = self.bits.words.at[e // 32].set(
            self.bits.words[e // 32] | (jnp.uint32(1) << (e % 32)))
        return BitSet(BitVector(w, self.domain))

    def contains(self, e) -> jax.Array:
        return (self.bits.words[e // 32] >> (e % 32)) & 1

    def union(self, *others: "BitSet") -> "BitSet":
        out = self.bits
        for o in others:
            out = out | o.bits
        return BitSet(out)

    def intersection(self, *others: "BitSet") -> "BitSet":
        out = self.bits
        for o in others:
            out = out & o.bits
        return BitSet(out)

    def difference(self, *others: "BitSet") -> "BitSet":
        out = self.bits.words
        for o in others:
            out = out & ~o.bits.words
        return BitSet(BitVector(out, self.domain))

    def cardinality(self) -> jax.Array:
        return self.bits.popcount()

    def to_elements(self) -> jax.Array:
        return jnp.nonzero(self.bits.to_bits())[0]
