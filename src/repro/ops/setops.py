"""Bitvector set data structure (paper §8.3): constant-time insert/lookup,
bulk union/intersection/difference as row-wide bitwise ops.

The bulk merges accept `banks > 1` to run over the bank-parallel path
(`core.bankgroup` word-sharding + the bank-gridded kernel) — same results,
N-bank schedule; this is the set-operation workload of Fig. 12 scaled the
way §7 scales Fig. 9.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.bitplane import BitVector
from repro.ops.bitwise import andnot, bitwise_and, bitwise_or


@dataclasses.dataclass
class BitSet:
    """Set over domain [0, domain) as a packed bitvector."""

    bits: BitVector

    @classmethod
    def empty(cls, domain: int) -> "BitSet":
        return cls(BitVector.zeros(domain))

    @classmethod
    def from_elements(cls, elems: jax.Array, domain: int) -> "BitSet":
        """Duplicate-safe: scatter 1s at bit granularity, then pack."""
        elems = jnp.asarray(elems, jnp.int32)
        bits = jnp.zeros((domain,), jnp.uint8).at[elems].set(1)
        from repro.core.bitplane import pack_bits

        return cls(BitVector(pack_bits(bits), domain))

    @property
    def domain(self) -> int:
        return self.bits.n_bits

    def insert(self, e) -> "BitSet":
        w = self.bits.words.at[e // 32].set(
            self.bits.words[e // 32] | (jnp.uint32(1) << (e % 32)))
        return BitSet(BitVector(w, self.domain))

    def contains(self, e) -> jax.Array:
        return (self.bits.words[e // 32] >> (e % 32)) & 1

    def union(self, *others: "BitSet", banks: int = 1) -> "BitSet":
        """Multi-way set union — one bulk OR per operand."""
        if banks > 1:
            return self._merge("or", others, banks)
        out = self.bits
        for o in others:
            out = out | o.bits
        return BitSet(out)

    def intersection(self, *others: "BitSet", banks: int = 1) -> "BitSet":
        """Multi-way set intersection — one bulk AND per operand."""
        if banks > 1:
            return self._merge("and", others, banks)
        out = self.bits
        for o in others:
            out = out & o.bits
        return BitSet(out)

    def difference(self, *others: "BitSet", banks: int = 1) -> "BitSet":
        """Set difference — one fused ANDNOT per operand."""
        if banks > 1:
            return self._merge("andnot", others, banks)
        out = self.bits.words
        for o in others:
            out = out & ~o.bits.words
        return BitSet(BitVector(out, self.domain))

    def _merge(self, op: str, others: Sequence["BitSet"],
               banks: int) -> "BitSet":
        fn = {"or": bitwise_or, "and": bitwise_and, "andnot": andnot}[op]
        out = self.bits.words
        for o in others:
            out = fn(out, o.bits.words, banks=banks)
        return BitSet(BitVector(out, self.domain))

    def cardinality(self) -> jax.Array:
        return self.bits.popcount()

    def to_elements(self) -> jax.Array:
        return jnp.nonzero(self.bits.to_bits())[0]
