"""Bulk bitwise operations on packed words — the deployable fast path.

Dispatches to the fused Pallas kernel for large row-shaped operands and falls
back to jnp elementwise ops otherwise. Semantics are identical to running the
paper's AAP programs through `core.engine` (asserted by tests); latency/energy
accounting comes from `core.timing` / `core.energy`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# Threshold below which kernel dispatch isn't worth it (and interpret-mode
# Pallas on CPU is slow for tests anyway).
_KERNEL_MIN_WORDS = 1 << 14


def _use_kernel(x: jax.Array, force: Optional[bool]) -> bool:
    if force is not None:
        return force
    return x.ndim == 2 and x.size >= _KERNEL_MIN_WORDS


def _dispatch(op: str, *args: jax.Array, use_kernel: Optional[bool] = None,
              banks: int = 1):
    """Route one bulk op: banked kernel grid, flat kernel, or jnp fallback.

    `banks > 1` shards the operands word-wise across a bank grid
    (`core.bankgroup` partitioning + the bank-gridded Pallas kernel) — the
    software analog of running the op in `banks` DRAM banks concurrently.
    Results are bit-identical across every path.
    """
    args = tuple(jnp.asarray(a, jnp.uint32) for a in args)
    if banks > 1:
        from repro.kernels import ops as kops

        return kops.bitwise_banked(op, *args, n_banks=banks)
    if _use_kernel(args[0], use_kernel):
        from repro.kernels import ops as kops

        return kops.bitwise(op, *args)
    from repro.kernels import ref

    return ref.bitwise(op, *args)


def bitwise_and(a, b, **kw):
    return _dispatch("and", a, b, **kw)


def bitwise_or(a, b, **kw):
    return _dispatch("or", a, b, **kw)


def bitwise_xor(a, b, **kw):
    return _dispatch("xor", a, b, **kw)


def bitwise_not(a, **kw):
    return _dispatch("not", a, **kw)


def bitwise_nand(a, b, **kw):
    return _dispatch("nand", a, b, **kw)


def bitwise_nor(a, b, **kw):
    return _dispatch("nor", a, b, **kw)


def bitwise_xnor(a, b, **kw):
    return _dispatch("xnor", a, b, **kw)


def majority3(a, b, c, **kw):
    """Triple-row activation: the paper's native primitive."""
    return _dispatch("maj3", a, b, c, **kw)


def andnot(a, b, **kw):
    """a & ~b (bitmap difference; one fused pass)."""
    return _dispatch("andnot", a, b, **kw)
