"""Layout conversion between horizontal integers and BitWeaving-V planes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_KERNEL_MIN_VALUES = 1 << 16


def to_vertical(values: jax.Array, n_bits: int, use_kernel=None) -> jax.Array:
    """(n,) integer column -> (n_bits, n//32) vertical bit planes (LSB first)."""
    values = jnp.asarray(values, jnp.uint32)
    big = values.size >= _KERNEL_MIN_VALUES if use_kernel is None else use_kernel
    if big:
        from repro.kernels import ops as kops

        return kops.bit_transpose(values, n_bits)
    from repro.kernels import ref

    return ref.bit_transpose(values, n_bits)


def from_vertical(planes: jax.Array, n_bits: int, use_kernel=None) -> jax.Array:
    planes = jnp.asarray(planes, jnp.uint32)
    big = planes.size >= _KERNEL_MIN_VALUES // 32 if use_kernel is None else use_kernel
    if big:
        from repro.kernels import ops as kops

        return kops.bit_untranspose(planes, n_bits)
    from repro.kernels import ref

    return ref.bit_untranspose(planes, n_bits)
