"""KV/SSM cache utilities for serving."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def extend_cache(cache: Dict[str, Any], extra: int) -> Dict[str, Any]:
    """Pad the sequence axis of attention KV sheets by `extra` slots so a
    prefill-produced cache (length S) can absorb `extra` decoded tokens.
    SSM state/conv caches and cross-attention caches are fixed-size and pass
    through untouched."""
    out: Dict[str, Any] = {}
    for k, v in cache.items():
        if isinstance(v, dict):
            out[k] = extend_cache(v, extra)
        elif k in ("k", "v"):
            # (L, B, S, KV*hd): pad axis 2
            out[k] = jnp.pad(v, [(0, 0), (0, 0), (0, extra), (0, 0)])
        else:
            out[k] = v
    return out


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
