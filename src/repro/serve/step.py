"""Serving: jit'd decode step + batched greedy/temperature generation loop."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.serve.kvcache import extend_cache


def make_serve_step(bundle) -> Callable:
    """serve_step(params, token, cache, pos) -> (logits, cache). This is the
    function the decode_* dry-run cells lower."""

    def serve_step(params, token, cache, pos):
        return bundle.decode_step(params, token, cache, pos)

    return serve_step


def generate(bundle, params, batch: Dict[str, Any], max_new: int,
             temperature: float = 0.0, key: Optional[jax.Array] = None
             ) -> jax.Array:
    """Prefill + scan decode loop. batch holds 'tokens' (B, S) prompts (plus
    frontend inputs where applicable). Returns (B, max_new) generated ids."""
    S = batch["tokens"].shape[1]
    logits, cache = jax.jit(bundle.prefill)(params, batch)
    cache = extend_cache(cache, max_new)
    if key is None:
        key = jax.random.PRNGKey(0)

    def pick(logits, k):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, logits / temperature, axis=-1
                                      ).astype(jnp.int32)

    tok0 = pick(logits, key)

    @jax.jit
    def loop(params, tok0, cache, key):
        def body(carry, i):
            tok, cache, key = carry
            key, sub = jax.random.split(key)
            logits, cache = bundle.decode_step(params, tok, cache, S + i)
            nxt = pick(logits, sub)
            return (nxt, cache, key), tok

        (_, cache, _), toks = jax.lax.scan(
            body, (tok0, cache, key), jnp.arange(max_new))
        return toks

    toks = loop(params, tok0, cache, key)      # (max_new, B)
    return toks.T
