from repro.serve.kvcache import extend_cache
from repro.serve.step import generate, make_serve_step
