"""Multi-chip sharded execution: the bank axis stretched across devices.

The paper scales bulk-bitwise throughput by running one broadcast AAP
sequence on many banks at once (`core.bankgroup`); the follow-up in-DRAM
bulk-bitwise execution engine (Seshadri & Mutlu, 2019) extends the same
argument across chips and ranks — every chip adds buses, banks, and sense
amplifiers, so throughput scales with the number of chips as long as
operands never cross a chip boundary. `ChipCluster` is that layer:

  * a bulk operand's words are partitioned over ``max_chips * n_banks``
    **slots** (`shard_words`, the two-level generalization of
    `bankgroup.shard_words`): leading axes ``(n_chips, local_banks)``,
    where the chip axis is laid onto a JAX device mesh via the
    ``"chip"``/``"bank"`` logical rules of `dist.sharding` and the bank
    axis stays chip-local;
  * programs execute under `shard_map`: every chip runs the lowered
    register-machine VM (`core.lowering`, or the Pallas megakernel) over
    its local ``(local_banks, ..., words)`` plane block — one broadcast
    opcode table, per-chip data, zero cross-chip traffic during compute;
  * result readout is **gather-free per shard**: output rows come back
    still sharded over the chip mesh (``out_specs`` keep the chip axis),
    and reductions (`popcounts`) run as a recursive-doubling **tree psum**
    over the chip axis, so only scalars ever cross chips.

The placement granularity is fixed at creation: words are padded to
``max_chips * n_banks`` slots regardless of the *current* chip count, so an
elastic rescale (service layer, `dist.elastic.plan_rescale`) is a pure
re-layout — a chip cluster of C chips sweeps ``max_chips // C`` slot groups
sequentially (the `sweeps` of the rescale plan's ``grad_accum``), and the
bits held by every slot are invariant across rescales.

Everything runs on forced host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) bit-identically to
the single-chip oracle (tests/test_cluster.py, tests/test_property_cluster.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core import bankgroup, lowering
from repro.core.commands import Program
from repro.core.engine import BuddyError, RowState, _check_outputs
from repro.core.timing import DDR3_1600, DramTiming
from repro.dist.sharding import CLUSTER_RULES, resolve_spec
from repro.obs.telemetry import get_telemetry

CHIP_AXIS = "chip"
DEFAULT_PLACEMENT_CHIPS = 8


def _shard_map(f, mesh, in_specs, out_specs):
    """`shard_map` across jax versions (experimental vs top-level API).

    Replication checking is disabled: bodies mix per-shard outputs with
    tree-psum'd (replicated) scalars, which the static rep checker of
    older jax cannot type through `ppermute`.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    try:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:   # jax >= 0.6 renamed check_rep -> check_vma
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)


def tree_psum(x: jax.Array, axis_name: str, n: int) -> jax.Array:
    """All-reduce sum over `axis_name` as a recursive-doubling tree.

    log2(n) `ppermute` stages, each pairing shard i with shard i^step —
    the butterfly the 2019 execution engine's inter-chip reduction network
    implements in hardware. Falls back to `lax.psum` when `n` is not a
    power of two (XLA's all-reduce is itself tree-scheduled).
    """
    if n == 1:
        return x
    if n & (n - 1):
        return jax.lax.psum(x, axis_name)
    step = 1
    while step < n:
        perm = [(i, i ^ step) for i in range(n)]
        x = x + jax.lax.ppermute(x, axis_name, perm)
        step *= 2
    return x


class ClusterError(BuddyError):
    pass


@dataclasses.dataclass
class ChipCluster:
    """N chips x M banks as one sharded execution domain.

    ``mesh`` is a 1-D device mesh named `"chip"`; `max_chips * n_banks`
    is the fixed word-slot count every operand is partitioned into
    (`slots`), of which each chip holds ``local_banks = sweeps * n_banks``
    contiguous slot rows. ``n_chips`` must divide ``max_chips`` so the
    re-layout stays a reshape.
    """

    mesh: Mesh
    n_chips: int
    n_banks: int
    max_chips: int

    def __post_init__(self):
        if self.max_chips % self.n_chips:
            raise ClusterError(
                f"n_chips {self.n_chips} must divide placement granularity "
                f"max_chips {self.max_chips}")
        self._exec_cache: Dict[Tuple, object] = {}

    @classmethod
    def create(cls, n_chips: int, n_banks: int = 8,
               max_chips: Optional[int] = None,
               devices: Optional[Sequence] = None) -> "ChipCluster":
        """Build a cluster over the first `n_chips` available devices.

        CI hosts have no accelerators: force multiple host devices with
        ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before
        jax is imported). `max_chips` defaults to the smallest multiple of
        `n_chips` that is >= 8, so rescales across 1/2/4/8 chips stay pure
        re-layouts of one placement.
        """
        if devices is None:
            devices = jax.devices()
        if n_chips < 1:
            raise ClusterError(f"n_chips must be >= 1, got {n_chips}")
        if len(devices) < n_chips:
            raise ClusterError(
                f"need {n_chips} devices but only {len(devices)} are "
                f"visible; on CPU hosts set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n_chips} before "
                f"importing jax")
        if max_chips is None:
            max_chips = n_chips * math.ceil(DEFAULT_PLACEMENT_CHIPS
                                            / n_chips)
        mesh = Mesh(np.asarray(devices[:n_chips]), (CHIP_AXIS,))
        return cls(mesh=mesh, n_chips=n_chips, n_banks=n_banks,
                   max_chips=max_chips)

    # -- layout --------------------------------------------------------------

    @property
    def sweeps(self) -> int:
        """Sequential slot groups per chip (the rescale plan's accum)."""
        return self.max_chips // self.n_chips

    @property
    def local_banks(self) -> int:
        """Slot rows resident on one chip: sweeps x physical banks."""
        return self.sweeps * self.n_banks

    @property
    def slots(self) -> int:
        """Total word-shard slots; invariant across rescale."""
        return self.max_chips * self.n_banks

    def spec(self, ndim: int):
        """PartitionSpec of a ``(chip, bank, ...)`` tensor on this mesh,
        resolved through the `dist.sharding` logical-axis rules."""
        names = (CHIP_AXIS, "bank") + (None,) * (ndim - 2)
        shape = (self.n_chips, self.local_banks) + (1,) * (ndim - 2)
        return resolve_spec(shape, names, self.mesh, CLUSTER_RULES)

    def sharding(self, ndim: int) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(ndim))

    def shard_words(self, x: jax.Array) -> jax.Array:
        """(..., W) operand -> (n_chips, local_banks, ..., W/slots), with
        the chip axis laid onto the device mesh.

        Words zero-pad up to a multiple of `slots` (zero words are inert
        for every bitwise program; `unshard_words` strips them), so uneven
        word counts shard on every layout.
        """
        s = bankgroup.shard_words(x, self.slots)        # (slots, ..., w)
        s = s.reshape((self.n_chips, self.local_banks) + s.shape[1:])
        return jax.device_put(s, self.sharding(s.ndim))

    def unshard_words(self, x: jax.Array, n_words: int) -> jax.Array:
        """Inverse of `shard_words`: gather shards back to (..., W)."""
        merged = x.reshape((self.slots,) + x.shape[2:])
        return bankgroup.unshard_words(merged, n_words)

    def local_words(self, n_words: int) -> int:
        """Per-slot word count after padding `n_words` to the slot grid."""
        return (n_words + self.slots - 1) // self.slots

    # -- sharded execution ---------------------------------------------------

    def _sharded_vm(self, lp: lowering.LoweredProgram,
                    in_names: Tuple[str, ...], out_names: Tuple[str, ...],
                    shapes: Tuple[Tuple[int, ...], ...], backend: str,
                    mask_ndim: Optional[int]):
        """Jitted shard_map dispatch, memoized per (program, binding).

        ``mask_ndim is None``: returns the output rows still sharded over
        the chip mesh (gather-free readout — ``out_specs`` keep the chip
        axis). Otherwise the body also popcounts each mask-ANDed output
        row and tree-psums the counts over the chip axis, so only
        ``(n_outputs,) + batch`` scalars leave the shards.
        """
        key = (id(lp), in_names, out_names, shapes, backend, mask_ndim)
        hit = self._exec_cache.get(key)
        if hit is not None:
            return hit
        local_words = max(s[-1] for s in shapes)
        in_specs = tuple(self.spec(len(s)) for s in shapes)
        out_ndim = max(len(s) for s in shapes)

        def run_local(vals):
            local = dict(zip(in_names, vals))
            out = lowering.execute_lowered(
                lp, local, row_words=local_words,
                outputs=list(out_names), backend=backend)
            return tuple(out[o] for o in out_names)

        if mask_ndim is None:
            body = run_local
            specs = (in_specs,)
            out_specs = (self.spec(out_ndim),) * len(out_names)
        else:
            def body(vals, mask):
                # fused count epilogue: the VM dispatch popcounts each
                # mask-ANDed output row in place (in VMEM on the pallas
                # backend — no output plane reaches HBM), then the shard
                # dims of (1, local_banks, ...) sum away and the chip
                # axis tree-reduces, keeping any inner batch (query) axes
                per_bank = lowering.execute_lowered(
                    lp, dict(zip(in_names, vals)), row_words=local_words,
                    outputs=list(out_names), backend=backend,
                    reduce="popcount", mask=mask)
                counts = []
                for o in out_names:
                    c = per_bank[o].sum(axis=(0, 1))       # local slots
                    counts.append(tree_psum(c, CHIP_AXIS, self.n_chips))
                return tuple(counts)
            specs = (in_specs, self.spec(mask_ndim))
            out_specs = (resolve_spec((), (), self.mesh, CLUSTER_RULES),
                         ) * len(out_names)
        fn = jax.jit(_shard_map(body, self.mesh, in_specs=specs,
                                out_specs=out_specs))
        if len(self._exec_cache) > 256:
            self._exec_cache.clear()
        self._exec_cache[key] = fn
        return fn

    def run_lowered(self, lp: lowering.LoweredProgram, sharded: RowState,
                    outputs: Sequence[str], backend: str = "scan"
                    ) -> Dict[str, jax.Array]:
        """Execute a lowered program over already-sharded rows.

        Every row of `sharded` carries the (chip, bank) leading axes from
        `shard_words`; returns the requested output rows **still sharded**
        (chip axis intact) — call `unshard_words` only when a flat vector
        is actually needed.

        Wall-span-traced when a tracing telemetry is installed
        process-wide (`repro.obs.set_telemetry`; the scheduler installs
        one per dispatch window).
        """
        tel = get_telemetry()
        if tel.tracing:
            with tel.tracer.span("cluster.run_lowered",
                                 n_chips=self.n_chips, n_banks=self.n_banks,
                                 n_cmds=lp.n_cmds, backend=backend):
                return self._run_lowered(lp, sharded, outputs, backend)
        return self._run_lowered(lp, sharded, outputs, backend)

    def _run_lowered(self, lp: lowering.LoweredProgram, sharded: RowState,
                     outputs: Sequence[str], backend: str
                     ) -> Dict[str, jax.Array]:
        names = tuple(sorted(sharded))
        shapes = tuple(tuple(sharded[k].shape) for k in names)
        fn = self._sharded_vm(lp, names, tuple(outputs), shapes, backend,
                              mask_ndim=None)
        out = fn(tuple(sharded[k] for k in names))
        return dict(zip(tuple(outputs), out))

    def popcounts(self, lp: lowering.LoweredProgram, sharded: RowState,
                  outputs: Sequence[str], mask_shards: jax.Array,
                  backend: str = "scan") -> np.ndarray:
        """Masked popcount of each output row, tree-psum'd across chips.

        `mask_shards` is the catalog tail mask pushed through
        `shard_words` (padding slots are all-zero there, so pad words
        never count); singleton axes are inserted so it broadcasts over
        any inner batch (query) axes. Returns ``(n_outputs,) + batch``
        int counts — the only values that cross the chip boundary.

        Traced like `run_lowered`; the span also records the tree-psum
        reduction depth (``psum_hops`` — recursive doubling over the chip
        axis, `tree_psum`).
        """
        tel = get_telemetry()
        if tel.tracing:
            hops = int(math.ceil(math.log2(self.n_chips))) \
                if self.n_chips > 1 else 0
            with tel.tracer.span("cluster.popcounts",
                                 n_chips=self.n_chips, n_banks=self.n_banks,
                                 n_cmds=lp.n_cmds, backend=backend,
                                 psum_hops=hops):
                return self._popcounts(lp, sharded, outputs, mask_shards,
                                       backend)
        return self._popcounts(lp, sharded, outputs, mask_shards, backend)

    def _popcounts(self, lp: lowering.LoweredProgram, sharded: RowState,
                   outputs: Sequence[str], mask_shards: jax.Array,
                   backend: str) -> np.ndarray:
        names = tuple(sorted(sharded))
        shapes = tuple(tuple(sharded[k].shape) for k in names)
        sample_ndim = max(len(s) for s in shapes)
        mask = mask_shards.reshape(
            mask_shards.shape[:2] + (1,) * (sample_ndim - 3)
            + mask_shards.shape[-1:])
        fn = self._sharded_vm(lp, names, tuple(outputs), shapes, backend,
                              mask_ndim=mask.ndim)
        counts = fn(tuple(sharded[k] for k in names), mask)
        return np.asarray(jnp.stack(counts))

    def execute(self, program: Program, data: RowState,
                outputs: Optional[List[str]] = None,
                backend: str = "scan") -> RowState:
        """Cluster-parallel analog of `bankgroup.execute_banked`.

        Flat (..., W) operand rows are partitioned over chips x banks, the
        program runs once per shard under `shard_map`, and the requested
        outputs come back reassembled to their original width —
        bit-identical to `engine.execute(program, data)` for every
        program, chip count, and backend.
        """
        lp = lowering.lower(program)
        if outputs is not None:
            _check_outputs(outputs, set(lp.row_names) | set(data), program)
        n_words = int(next(iter(data.values())).shape[-1])
        sharded = {k: self.shard_words(jnp.asarray(v, jnp.uint32))
                   for k, v in data.items()}
        if outputs is None:
            out_names = [n for n in lp.row_names if n != lowering.SINK]
            out_names += [k for k in sharded if k not in out_names]
        else:
            out_names = list(outputs)
        out = self.run_lowered(lp, sharded, out_names, backend=backend)
        return {k: self.unshard_words(v, n_words) for k, v in out.items()}


_CLUSTER_CACHE: Dict[Tuple, ChipCluster] = {}


def get_cluster(n_chips: int, n_banks: int = 8,
                max_chips: Optional[int] = None) -> ChipCluster:
    """Memoized `ChipCluster.create` — the backing for one-shot dispatch
    (`engine.execute(..., n_chips=C)`), so repeated calls reuse one mesh
    and its jitted shard_map executables."""
    key = (n_chips, n_banks, max_chips, len(jax.devices()))
    cl = _CLUSTER_CACHE.get(key)
    if cl is None:
        cl = _CLUSTER_CACHE[key] = ChipCluster.create(
            n_chips, n_banks=n_banks, max_chips=max_chips)
    return cl


# ---------------------------------------------------------------------------
# Controller schedule across chips
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClusterSchedule:
    """Makespan of a bulk op split across chips (each chip: its own
    internal bus + banks, `bankgroup.pipeline_latency_ns`) plus the
    log2-depth inter-chip reduction tree for aggregate readout."""

    n_blocks: int
    n_chips: int
    n_banks: int
    compute_ns: float      # slowest chip's pipelined makespan
    reduce_ns: float       # ceil(log2 C) tree stages
    total_ns: float


def cluster_latency_ns(n_blocks: int, n_chips: int, n_banks: int,
                       program: Program,
                       timing: DramTiming = DDR3_1600,
                       xfer_ns_per_block: Optional[float] = None
                       ) -> ClusterSchedule:
    """Modeled makespan of `n_blocks` row-block ops over C chips x M banks.

    Blocks split round-robin across chips; each chip pipelines its share
    over its own internal bus and banks (transfers serialize *per chip*,
    not globally — the cross-chip seam is the whole scaling argument), and
    an aggregate readout pays one reduction-tree traversal of depth
    ceil(log2 C), one AAP-time per stage.
    """
    per_chip = [len(r) for r in
                bankgroup.partition_blocks(n_blocks, n_chips)]
    compute = max(
        (bankgroup.pipeline_latency_ns(
            blocks, n_banks, program, timing, xfer_ns_per_block).total_ns
         for blocks in per_chip if blocks),
        default=0.0)
    if xfer_ns_per_block is None:
        xfer_ns_per_block = timing.aap_ns
    reduce = math.ceil(math.log2(n_chips)) * xfer_ns_per_block \
        if n_chips > 1 else 0.0
    return ClusterSchedule(
        n_blocks=n_blocks, n_chips=n_chips, n_banks=n_banks,
        compute_ns=compute, reduce_ns=reduce, total_ns=compute + reduce)


def cluster_throughput_gbps(n_blocks: int, n_chips: int, n_banks: int,
                            program: Program,
                            timing: DramTiming = DDR3_1600) -> float:
    """End-to-end GB/s of output for a multi-block op on the cluster."""
    sched = cluster_latency_ns(n_blocks, n_chips, n_banks, program, timing)
    if sched.total_ns == 0.0:
        return 0.0
    return n_blocks * timing.row_bytes / sched.total_ns
