"""Packed bit-plane tensors — the TPU analogue of a DRAM row.

Buddy-RAM operates on 8 KB DRAM rows (65536 bits across a rank). On TPU we
represent a "row" as a vector of uint32 words, 32 bits per lane (LSB-first).
All bulk bitwise operations in this framework run on this packed layout, which
is what gives the 32x density win over byte-per-bool layouts.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
WORD_DTYPE = jnp.uint32

# Geometry of the paper's subarray: 8 KB row across a rank = 65536 bits.
ROW_BYTES = 8192
ROW_BITS = ROW_BYTES * 8
ROW_WORDS = ROW_BITS // WORD_BITS  # 2048


def n_words(n_bits: int) -> int:
    return (n_bits + WORD_BITS - 1) // WORD_BITS


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a bool/int {0,1} array along the last axis into uint32 words.

    bits: (..., n) -> (..., ceil(n/32)) uint32, LSB-first within each word.
    """
    n = bits.shape[-1]
    nw = n_words(n)
    pad = nw * WORD_BITS - n
    b = bits.astype(WORD_DTYPE)
    if pad:
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    b = b.reshape(b.shape[:-1] + (nw, WORD_BITS))
    shifts = jnp.arange(WORD_BITS, dtype=WORD_DTYPE)
    return (b << shifts).sum(axis=-1).astype(WORD_DTYPE)


def unpack_bits(words: jax.Array, n_bits: int) -> jax.Array:
    """Inverse of pack_bits: (..., nw) uint32 -> (..., n_bits) bool."""
    shifts = jnp.arange(WORD_BITS, dtype=WORD_DTYPE)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD_BITS,))
    return bits[..., :n_bits].astype(jnp.bool_)


def tail_mask(n_bits: int) -> np.ndarray:
    """uint32 mask vector zeroing the padding bits of the final word."""
    nw = n_words(n_bits)
    m = np.full((nw,), 0xFFFFFFFF, dtype=np.uint32)
    rem = n_bits % WORD_BITS
    if rem:
        m[-1] = np.uint32((1 << rem) - 1)
    return m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BitVector:
    """A length-tagged packed bitvector (1-D logical bit array).

    `words` may have leading batch dims; the last axis is packed words.
    """

    words: jax.Array
    n_bits: int

    def tree_flatten(self):
        return (self.words,), self.n_bits

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_bits(cls, bits: jax.Array) -> "BitVector":
        return cls(pack_bits(bits), bits.shape[-1])

    @classmethod
    def zeros(cls, n_bits: int, batch: Tuple[int, ...] = ()) -> "BitVector":
        return cls(jnp.zeros(batch + (n_words(n_bits),), WORD_DTYPE), n_bits)

    @classmethod
    def ones(cls, n_bits: int, batch: Tuple[int, ...] = ()) -> "BitVector":
        w = jnp.broadcast_to(
            jnp.asarray(tail_mask(n_bits)), batch + (n_words(n_bits),)
        )
        return cls(w, n_bits)

    # -- views -------------------------------------------------------------
    def to_bits(self) -> jax.Array:
        return unpack_bits(self.words, self.n_bits)

    def popcount(self) -> jax.Array:
        from repro.ops.popcount import popcount_words

        return popcount_words(self.words)

    # -- logical ops (jnp fast path; kernels used via repro.ops) -----------
    def _mask(self) -> jax.Array:
        return jnp.asarray(tail_mask(self.n_bits))

    def __and__(self, o: "BitVector") -> "BitVector":
        return BitVector(self.words & o.words, self.n_bits)

    def __or__(self, o: "BitVector") -> "BitVector":
        return BitVector(self.words | o.words, self.n_bits)

    def __xor__(self, o: "BitVector") -> "BitVector":
        return BitVector(self.words ^ o.words, self.n_bits)

    def __invert__(self) -> "BitVector":
        return BitVector(~self.words & self._mask(), self.n_bits)

    def majority(self, b: "BitVector", c: "BitVector") -> "BitVector":
        """Triple-row activation: MAJ(self, b, c) = AB + BC + CA."""
        a, bw, cw = self.words, b.words, c.words
        return BitVector((a & bw) | (bw & cw) | (cw & a), self.n_bits)
