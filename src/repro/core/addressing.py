"""Row-address grouping (paper §5.1, Table 2).

Each subarray's row-address space splits into three groups:

  B-group ("bitwise"): 16 reserved addresses B0..B15 controlling 8 physical
      wordlines — four designated rows T0..T3 (TRA operands) and the d-/n-
      wordlines of two dual-contact-cell rows DCC0/DCC1.
  C-group ("control"): C0 (all zeros), C1 (all ones), pre-initialized.
  D-group ("data"): everything else (1006 of 1024 rows) — what the OS sees.

The published Table 2 loses the overline typography on n-wordlines; the
mapping below is reconstructed so every Fig. 8 program is correct (verified by
`tests/test_engine.py` against jnp oracles):

  B0..B3  -> single d-wordline of T0..T3
  B4 / B6 -> d-wordline of DCC0 / DCC1
  B5 / B7 -> n-wordline of DCC0 / DCC1   (captures NOT of the sensed value)
  B8  -> {DCC0.n, T0.d}    B9  -> {DCC1.n, T1.d}
  B10 -> {T2.d, T3.d}      B11 -> {T0.d, T3.d}
  B12 -> {T0,T1,T2}.d      B13 -> {T1,T2,T3}.d
  B14 -> {DCC0.d, T1, T2}  B15 -> {DCC1.d, T0, T3}

Area accounting (paper §5.4): B-group = 4 designated rows + 2 DCC rows (each
DCC ~ 2 cells => 4 row-equivalents) and C-group = 2 rows => 10 row-equivalents
per 1024-row subarray ~= 1% capacity loss.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

# Physical wordline identifiers. For DCC rows, (row, polarity) where polarity
# 'd' connects the cell to the bitline and 'n' to bitline-bar.
D_WL = "d"
N_WL = "n"

T0, T1, T2, T3 = "T0", "T1", "T2", "T3"
DCC0, DCC1 = "DCC0", "DCC1"
C0, C1 = "C0", "C1"

B_GROUP_ROWS = (T0, T1, T2, T3, DCC0, DCC1)
C_GROUP_ROWS = (C0, C1)

# Address -> list of (row, polarity). Reconstructed Table 2.
B_ADDRESS_MAP: Dict[str, Tuple[Tuple[str, str], ...]] = {
    "B0": ((T0, D_WL),),
    "B1": ((T1, D_WL),),
    "B2": ((T2, D_WL),),
    "B3": ((T3, D_WL),),
    "B4": ((DCC0, D_WL),),
    "B5": ((DCC0, N_WL),),
    "B6": ((DCC1, D_WL),),
    "B7": ((DCC1, N_WL),),
    "B8": ((DCC0, N_WL), (T0, D_WL)),
    "B9": ((DCC1, N_WL), (T1, D_WL)),
    "B10": ((T2, D_WL), (T3, D_WL)),
    "B11": ((T0, D_WL), (T3, D_WL)),
    "B12": ((T0, D_WL), (T1, D_WL), (T2, D_WL)),
    "B13": ((T1, D_WL), (T2, D_WL), (T3, D_WL)),
    "B14": ((DCC0, D_WL), (T1, D_WL), (T2, D_WL)),
    "B15": ((DCC1, D_WL), (T0, D_WL), (T3, D_WL)),
}


@dataclasses.dataclass(frozen=True)
class SubarrayGeometry:
    """Geometry of one subarray (paper defaults; tests shrink these)."""

    n_rows: int = 1024          # physical rows incl. reserved
    row_bits: int = 65536       # 8 KB per row across the rank
    n_b_group_row_equiv: int = 8  # 4 designated + 2 DCC rows (2 cells each)

    @property
    def n_data_rows(self) -> int:
        # 1024 - (8 B-group row equivalents + 2 C-group rows)
        return self.n_rows - self.n_b_group_row_equiv - len(C_GROUP_ROWS)

    @property
    def row_words(self) -> int:
        return self.row_bits // 32

    @property
    def row_bytes(self) -> int:
        return self.row_bits // 8

    @property
    def capacity_loss(self) -> float:
        """Fraction of rows unavailable to the OS (paper: ~1%)."""
        return 1.0 - self.n_data_rows / self.n_rows


def resolve(addr: str) -> Tuple[Tuple[str, str], ...]:
    """Resolve a row address to its raised wordlines.

    D-group / C-group addresses raise a single d-wordline of that row.
    """
    if addr in B_ADDRESS_MAP:
        return B_ADDRESS_MAP[addr]
    return ((addr, D_WL),)


def is_b_group(addr: str) -> bool:
    return addr in B_ADDRESS_MAP


def is_c_group(addr: str) -> bool:
    return addr in C_GROUP_ROWS


def is_d_group(addr: str) -> bool:
    return not is_b_group(addr) and not is_c_group(addr)


def wordlines_raised(addr: str) -> int:
    return len(resolve(addr))


def data_addresses(geom: SubarrayGeometry) -> List[str]:
    return [f"D{i}" for i in range(geom.n_data_rows)]
