"""DRAM energy model (paper §7, Table 3).

Buddy energy is *derived from command counts*: each ACTIVATE costs E_ACT
(scaled +22% per additional simultaneously-raised wordline, per the paper's
analysis), each PRECHARGE costs E_PRE. The DDR3 interface baseline is modeled
as channel+DRAM energy per byte moved. Constants are calibrated once from the
Rambus power model's activate/precharge split so that the derived per-op
numbers land on Table 3; the table itself is never hard-coded.

  Table 3 (nJ/KB):        not   and/or  nand/nor  xor/xnor
    DDR3                  93.7  137.9   137.9     137.9
    Buddy (derived here)  ~1.6  ~3.2    ~4.0      ~5.5
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.commands import Activate, Program
from repro.core.addressing import wordlines_raised
from repro.core.timing import bytes_moved_per_output_byte


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    # Per-command energies for one 8KB row operation (nJ). Rambus DRAM power
    # model split: activation (wordline + sensing + restore) dominates.
    e_activate_nj: float = 2.72
    e_precharge_nj: float = 0.93
    extra_wordline_factor: float = 0.22   # +22% per additional wordline (§7)
    # DDR3 interface: DRAM access + channel I/O energy per KB moved.
    ddr3_channel_nj_per_kb: float = 46.0
    row_kb: float = 8.0


DEFAULT_ENERGY = EnergyModel()


def program_energy_nj(prog: Program, model: EnergyModel = DEFAULT_ENERGY) -> float:
    """Total energy of one program execution (operates on one 8KB row)."""
    e = 0.0
    for op in prog.micro_ops():
        if isinstance(op, Activate):
            n_wl = wordlines_raised(op.addr)
            e += model.e_activate_nj * (1.0 + model.extra_wordline_factor * (n_wl - 1))
        else:  # precharge
            e += model.e_precharge_nj
    return e


def programs_energy_nj(progs, model: EnergyModel = DEFAULT_ENERGY):
    """Batched `program_energy_nj` with a shared per-address memo.

    `wordlines_raised` resolves the same B/T/DCC addresses for every
    program in a plan batch; memoizing the per-ACTIVATE energy by address
    makes costing a whole plan-group one dictionary walk per command. Used
    by the cost-based optimizer (`service.optimizer`) and the optimizer
    benchmark.
    """
    act_nj: Dict[str, float] = {}
    out = []
    for prog in progs:
        e = 0.0
        for op in prog.micro_ops():
            if isinstance(op, Activate):
                nj = act_nj.get(op.addr)
                if nj is None:
                    n_wl = wordlines_raised(op.addr)
                    nj = model.e_activate_nj * (
                        1.0 + model.extra_wordline_factor * (n_wl - 1))
                    act_nj[op.addr] = nj
                e += nj
            else:
                e += model.e_precharge_nj
        out.append(e)
    return out


def buddy_energy_nj_per_kb(op: str, model: EnergyModel = DEFAULT_ENERGY) -> float:
    from repro.core import compiler

    srcs = ["D0"] if op == "not" else ["D0", "D1"]
    prog = compiler.op_program(op, srcs, "D2")
    return program_energy_nj(prog, model) / model.row_kb


def ddr3_energy_nj_per_kb(op: str, model: EnergyModel = DEFAULT_ENERGY) -> float:
    """Baseline: all operands cross the channel (read srcs + write dst)."""
    return model.ddr3_channel_nj_per_kb * bytes_moved_per_output_byte(op)


def energy_table(model: EnergyModel = DEFAULT_ENERGY) -> Dict[str, Dict[str, float]]:
    ops = ["not", "and", "or", "nand", "nor", "xor", "xnor"]
    out: Dict[str, Dict[str, float]] = {}
    for op in ops:
        ddr3 = ddr3_energy_nj_per_kb(op, model)
        buddy = buddy_energy_nj_per_kb(op, model)
        out[op] = {"ddr3": ddr3, "buddy": buddy, "reduction": ddr3 / buddy}
    return out
