"""TRA reliability: seeded per-cell/per-row error model + mitigation.

Triple-row activation is an analog mechanism. The 2024 characterization of
off-the-shelf DDR4 parts ("Functionally-Complete Boolean Logic in Real DRAM
Chips", arXiv:2402.18736) measured that MAJ-of-3 success rates are

  * **per-cell**: individual cells flip with different probabilities
    (process variation), modeled here as an i.i.d. per-bit flip drawn from
    a seeded PRNG;
  * **per-pattern**: the *operand data pattern* matters — mixed patterns
    (one or two charged cells among the three sensed) sit closer to the
    sense amplifier's metastable point and fail orders of magnitude more
    often than unanimous all-0/all-1 patterns (`pattern_scale`, indexed by
    the number of charged operands);
  * **spatially variable**: rows differ systematically (`row_sigma`, a
    deterministic lognormal factor hashed from the sensed row triple); and
  * **temperature-dependent**: error rates grow with temperature
    (`temperature_c` / `temp_coeff` around `NOMINAL_C`).

`error_planes` compiles a `LoweredProgram`'s opcode table plus a PRNG key
into per-command, per-pattern-class XOR masks that the lowered VMs apply
**at TRA compute time** (`core.lowering._vm_exec`, `kernels.vm`), not on
final outputs — faulty sensed values propagate through the rest of the
program exactly like real analog failures would. The masks are indexed by
command position, so `core.lowering._Layout` row renumbering never changes
which faults land where, and a fixed key yields bit-identical fault
patterns on the scan VM and the Pallas megakernel (tests/test_errors.py).

Mitigation (SIMDRAM, arXiv:2012.11890, treats these margins as first-class
deployability constraints):

  * `execute_voted` — run the program k (odd) times with independent fault
    draws and take a bitwise majority over the replicas' output planes,
    reusing the native MAJ-of-k kernel (`kernels.majority`, the lifted TRA
    primitive). Any fault confined to a single replica is corrected.
  * `execute_ecc` — dual-modular redundancy with a vote tie-break: run
    twice, accept on agreement (2x cost), run a third replica and majority
    vote on disagreement (3x). The catalog side of ECC (XOR parity planes
    over registered vectors) lives in `service.catalog`.

Both are surfaced as `QueryService(reliability=ReliabilityConfig(...))`
modes with modeled AAP/latency/energy overhead (`service.scheduler`,
`benchmarks/reliability.py`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lowering
from repro.core.lowering import KIND_TRA, LoweredProgram

#: characterization nominal temperature (°C): `temp_coeff` scales the flip
#: probability exponentially around this point
NOMINAL_C = 50.0

#: number of operand pattern classes: 0, 1, 2, or 3 charged cells sensed
N_PATTERNS = 4

RELIABILITY_MODES = ("none", "vote", "ecc")


@dataclasses.dataclass(frozen=True)
class TRAErrorModel:
    """Per-cell/per-row/per-pattern TRA flip-probability model.

    ``p_flip`` is the base per-bit flip probability of a TRA at the
    nominal temperature on a median row under the worst pattern class;
    ``pattern_scale[k]`` scales it for k charged operands (mixed patterns
    1/2 dominate, matching the 2402.18736 measurements); ``row_sigma`` is
    the std-dev of the deterministic lognormal spatial factor hashed from
    the sensed row triple; temperature scales everything by
    ``exp(temp_coeff * (temperature_c - NOMINAL_C))``.
    """

    p_flip: float = 1e-3
    pattern_scale: Tuple[float, float, float, float] = (0.05, 1.0, 1.0, 0.05)
    row_sigma: float = 0.5
    temperature_c: float = NOMINAL_C
    temp_coeff: float = 0.03

    def __post_init__(self):
        if not 0.0 <= self.p_flip <= 1.0:
            raise ValueError(f"p_flip {self.p_flip} outside [0, 1]")
        if len(self.pattern_scale) != N_PATTERNS:
            raise ValueError("pattern_scale needs one factor per pattern "
                             f"class (4), got {len(self.pattern_scale)}")

    def row_factors(self, table: np.ndarray) -> np.ndarray:
        """Deterministic per-command spatial factor (lognormal, median 1).

        Hashed from the sensed row triple, so commands activating the same
        physical rows share their factor — the model's stand-in for "this
        subarray region is weak" spatial variation.
        """
        src = np.asarray(table)[:, 1:4].astype(np.uint64)
        h = ((src[:, 0] * np.uint64(73856093))
             ^ (src[:, 1] * np.uint64(19349663))
             ^ (src[:, 2] * np.uint64(83492791)))
        out = np.empty(len(h), np.float64)
        for i, hi in enumerate(h):
            z = float(np.random.default_rng(int(hi)).standard_normal())
            out[i] = math.exp(self.row_sigma * z)
        return out

    def flip_probs(self, table: np.ndarray) -> np.ndarray:
        """(n_cmds, 4) per-command, per-pattern-class flip probabilities.

        Rows of non-TRA commands (single-wordline senses) are exactly
        zero: only the analog triple-row majority can fail.
        """
        table = np.asarray(table)
        temp = math.exp(self.temp_coeff * (self.temperature_c - NOMINAL_C))
        probs = (self.p_flip * temp
                 * self.row_factors(table)[:, None]
                 * np.asarray(self.pattern_scale, np.float64)[None, :])
        probs[(table[:, 0] & KIND_TRA) == 0] = 0.0
        return np.clip(probs, 0.0, 1.0).astype(np.float32)


def error_planes(table: np.ndarray, key: jax.Array,
                 batch: Tuple[int, ...], row_words: int,
                 model: TRAErrorModel) -> jax.Array:
    """Seeded XOR fault masks: ``(n_cmds, 4) + batch + (row_words,)``.

    Plane ``[i, k]`` flips the bits of command i's sensed value wherever
    the operand pattern at that bit position has k charged cells — the VMs
    select the matching class per bit at run time (data-dependent), so the
    same mask tensor reproduces the same physical fault pattern whatever
    data flows through. ``p_flip == 0`` short-circuits to exact zeros,
    which is what makes rate-0 injection bit-identical to the clean path.
    """
    table = np.asarray(table)
    n_cmds = int(table.shape[0])
    shape = (n_cmds, N_PATTERNS) + tuple(batch) + (row_words,)
    probs = model.flip_probs(table)
    if not probs.any():
        return jnp.zeros(shape, jnp.uint32)
    p = jnp.asarray(probs).reshape(
        (n_cmds, N_PATTERNS) + (1,) * (len(batch) + 2))
    u = jax.random.uniform(key, shape + (32,), dtype=jnp.float32)
    bits = (u < p).astype(jnp.uint32)
    weights = jnp.left_shift(jnp.uint32(1),
                             jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


def single_fault_planes(table: np.ndarray, batch: Tuple[int, ...],
                        row_words: int, cmd: int, word: int,
                        bit: int) -> jax.Array:
    """A deterministic one-bit fault: flip bit `bit` of word `word` of
    command `cmd`'s sensed value, whatever the operand pattern is (all
    four pattern planes carry the bit, so exactly one flip happens iff the
    command is a TRA). The property suite's injection primitive."""
    table = np.asarray(table)
    planes = np.zeros((int(table.shape[0]), N_PATTERNS) + tuple(batch)
                      + (row_words,), np.uint32)
    if table[cmd, 0] & KIND_TRA:
        planes[(cmd, slice(None)) + (Ellipsis, word)] = np.uint32(1) << bit
    return jnp.asarray(planes)


# ---------------------------------------------------------------------------
# Injected / mitigated execution over lowered programs
# ---------------------------------------------------------------------------


def _plane_batch(data: Dict[str, jax.Array]) -> Tuple[Tuple[int, ...], int]:
    """The (batch, row_words) `execute_lowered` will derive for `data`."""
    shapes = [tuple(jnp.asarray(v).shape) for v in data.values()]
    return (tuple(np.broadcast_shapes(*(s[:-1] for s in shapes))),
            int(max(s[-1] for s in shapes)))


def execute_injected(lp: LoweredProgram, data: Dict[str, jax.Array],
                     outputs: Optional[List[str]] = None,
                     backend: str = "scan",
                     model: Optional[TRAErrorModel] = None,
                     key: Optional[jax.Array] = None
                     ) -> Dict[str, jax.Array]:
    """One execution with seeded TRA faults injected at compute time."""
    model = model or TRAErrorModel(p_flip=0.0)
    if key is None:
        key = jax.random.PRNGKey(0)
    batch, row_words = _plane_batch(data)
    errs = error_planes(lp.table, key, batch, row_words, model)
    return lowering.execute_lowered(lp, data, outputs=outputs,
                                    backend=backend, errors=errs)


def vote_outputs(replicas: Sequence[Dict[str, jax.Array]],
                 outputs: Sequence[str]) -> Dict[str, jax.Array]:
    """Bitwise per-plane majority across replica output dicts.

    Reuses the MAJ-of-k carry-save-adder kernel (`kernels.majority`) — the
    paper's TRA primitive lifted to k operands — so the vote itself is the
    same packed bit-plane machinery as the computation it protects.
    """
    from repro.kernels.majority import majority_kernel

    k = len(replicas)
    voted: Dict[str, jax.Array] = {}
    for o in outputs:
        stack = jnp.stack([jnp.asarray(r[o], jnp.uint32) for r in replicas])
        flat = stack.reshape(k, -1, stack.shape[-1])
        voted[o] = majority_kernel(flat).reshape(stack.shape[1:])
    return voted


def _corrected_bits(replicas: Sequence[Dict[str, jax.Array]],
                    voted: Dict[str, jax.Array],
                    outputs: Sequence[str]) -> int:
    """Total replica bits the vote overrode (faults the mitigation fixed)."""
    total = 0
    for o in outputs:
        v = np.asarray(voted[o], np.uint32)
        for r in replicas:
            diff = np.asarray(r[o], np.uint32) ^ v
            total += int(np.unpackbits(diff.view(np.uint8)).sum())
    return total


def execute_voted(lp: LoweredProgram, data: Dict[str, jax.Array],
                  outputs: List[str], backend: str = "scan",
                  model: Optional[TRAErrorModel] = None,
                  key: Optional[jax.Array] = None,
                  k: int = 3,
                  stats_out: Optional[Dict[str, int]] = None
                  ) -> Dict[str, jax.Array]:
    """Majority-vote execution: k independent fault draws, bitwise vote.

    Corrects every fault confined to a single replica (any number of bit
    flips, any command) — the property the test suite pins down.

    `stats_out` (optional dict) receives mitigation accounting when given:
    ``replicas`` run and ``corrected_bits`` (replica output bits the vote
    overrode). The counting pass costs a host-side diff per output plane,
    so it only runs when a dict is supplied — telemetry-off dispatches pay
    nothing.
    """
    if k < 3 or k % 2 == 0:
        raise ValueError(f"vote needs an odd k >= 3, got {k}")
    if key is None:
        key = jax.random.PRNGKey(0)
    replicas = [execute_injected(lp, data, outputs=outputs, backend=backend,
                                 model=model, key=jax.random.fold_in(key, r))
                for r in range(k)]
    out = vote_outputs(replicas, outputs)
    for name in replicas[0]:            # pass-through rows need no vote
        out.setdefault(name, replicas[0][name])
    if stats_out is not None:
        stats_out["replicas"] = k
        stats_out["tiebreaks"] = 0
        stats_out["corrected_bits"] = _corrected_bits(replicas, out, outputs)
    return out


def execute_ecc(lp: LoweredProgram, data: Dict[str, jax.Array],
                outputs: List[str], backend: str = "scan",
                model: Optional[TRAErrorModel] = None,
                key: Optional[jax.Array] = None,
                stats_out: Optional[Dict[str, int]] = None
                ) -> Tuple[Dict[str, jax.Array], int]:
    """Dual-modular redundancy with a vote tie-break.

    Two replicas that agree are accepted (2x cost — the common case when
    faults are rare); a disagreement triggers a third replica and a
    bitwise majority (3x). Returns (outputs, replicas_run). `stats_out`
    (optional dict) receives ``replicas``, ``tiebreaks`` (0 or 1) and
    ``corrected_bits`` as in `execute_voted`.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    a = execute_injected(lp, data, outputs=outputs, backend=backend,
                         model=model, key=jax.random.fold_in(key, 0))
    b = execute_injected(lp, data, outputs=outputs, backend=backend,
                         model=model, key=jax.random.fold_in(key, 1))
    if all(np.array_equal(np.asarray(a[o]), np.asarray(b[o]))
           for o in outputs):
        if stats_out is not None:
            stats_out["replicas"] = 2
            stats_out["tiebreaks"] = 0
            stats_out["corrected_bits"] = 0
        return a, 2
    c = execute_injected(lp, data, outputs=outputs, backend=backend,
                         model=model, key=jax.random.fold_in(key, 2))
    out = vote_outputs([a, b, c], outputs)
    for name in a:
        out.setdefault(name, a[name])
    if stats_out is not None:
        stats_out["replicas"] = 3
        stats_out["tiebreaks"] = 1
        stats_out["corrected_bits"] = _corrected_bits([a, b, c], out, outputs)
    return out, 3


@dataclasses.dataclass(frozen=True)
class ReliabilityConfig:
    """How a `QueryService` computes through TRA faults.

    ``mode``:
      * ``"none"`` — trust the analog majority (the paper's assumption);
      * ``"vote"`` — every TRA-bearing plan-group runs ``k`` times with
        independent fault draws and output planes are bitwise-voted;
      * ``"ecc"`` — dual-run compare with vote tie-break, plus a catalog
        XOR-parity integrity check per batch (`Catalog.verify_parity`).

    ``model`` draws the injected faults (None = fault-free replicas: pure
    mitigation-overhead measurement); ``seed`` roots the per-group PRNG
    chain, so a served batch is reproducible fault-for-fault.
    """

    mode: str = "none"
    k: int = 3
    model: Optional[TRAErrorModel] = None
    seed: int = 0

    def __post_init__(self):
        if self.mode not in RELIABILITY_MODES:
            raise ValueError(f"unknown reliability mode {self.mode!r}; "
                             f"expected one of {RELIABILITY_MODES}")
        if self.k < 3 or self.k % 2 == 0:
            raise ValueError(f"replica count k must be odd >= 3, "
                             f"got {self.k}")
