"""DDR3 timing model for Buddy command sequences (paper §5.3, §7).

Derived, not hard-coded: latency of an operation = f(command counts) with
DDR3-1600 (8-8-8) parameters. The paper's headline numbers fall out:

  naive AAP      = 2*tRAS + tRP             = 80 ns
  optimized AAP  = tRAS + t_overlap + tRP   = 49 ns   (split row decoder)
  AP             = tRAS + tRP               = 45 ns

Throughput of an op = row_bytes / latency(program), scaling linearly with the
number of banks (each Buddy op is contained in one bank) up to the tFAW
activation-power constraint (§5.4).

Baselines (Skylake / GTX 745) are modeled as bandwidth-bound streaming:
throughput = effective_bandwidth / bytes_moved_per_output_byte, with
effective bandwidths calibrated once against the paper's own reported
speedup ranges (§7) and documented here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.commands import Program


@dataclasses.dataclass(frozen=True)
class DramTiming:
    """DDR3-1600 8-8-8 (JEDEC [30]) — times in ns."""

    tRAS: float = 35.0
    tRP: float = 10.0
    tRCD: float = 10.0
    t_overlap_margin: float = 4.0   # §5.3: second ACTIVATE finishes 4ns after tRAS
    tFAW: float = 30.0              # four-activate window
    row_bytes: int = 8192
    split_decoder: bool = True      # the §5.3 optimization

    @property
    def aap_ns(self) -> float:
        if self.split_decoder:
            return self.tRAS + self.t_overlap_margin + self.tRP  # 49 ns
        return 2 * self.tRAS + self.tRP  # 80 ns

    @property
    def ap_ns(self) -> float:
        return self.tRAS + self.tRP  # 45 ns


DDR3_1600 = DramTiming()


def program_latency_ns(prog: Program, timing: DramTiming = DDR3_1600) -> float:
    return prog.n_aap * timing.aap_ns + prog.n_ap * timing.ap_ns


def programs_latency_ns(progs, timing: DramTiming = DDR3_1600):
    """Batched `program_latency_ns`: one cost query for a whole plan set.

    The cost-based optimizer (`service.optimizer`) prices every candidate
    of a plan-group batch in one call; the timing parameters are resolved
    once instead of per program.
    """
    aap, ap = timing.aap_ns, timing.ap_ns
    return [p.n_aap * aap + p.n_ap * ap for p in progs]


def program_activates(prog: Program) -> int:
    return 2 * prog.n_aap + prog.n_ap


def buddy_throughput_gbps(prog: Program, banks: int = 1,
                          timing: DramTiming = DDR3_1600,
                          respect_tfaw: bool = False) -> float:
    """GB/s of *output* produced (one row of output per program execution).

    Buddy ops in different banks proceed concurrently (§1); with B banks the
    ACTIVATE issue rate is B * activates/program / latency. tFAW caps the
    rate at 4 activates per tFAW window.
    """
    lat = program_latency_ns(prog, timing)
    tput = banks * timing.row_bytes / lat  # bytes/ns == GB/s
    if respect_tfaw:
        act_rate = banks * program_activates(prog) / lat  # activates/ns
        max_rate = 4.0 / timing.tFAW
        if act_rate > max_rate:
            tput *= max_rate / act_rate
    return tput


# ---------------------------------------------------------------------------
# Baseline systems (paper §7): bandwidth-bound bulk bitwise ops.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BaselineSystem:
    """A processor whose bulk-bitwise throughput is memory-bandwidth bound.

    effective_bw_gbps is the *achieved streaming* bandwidth. Calibration
    (documented in benchmarks/fig9_throughput.py): Skylake 2ch DDR3-2133 has
    34.1 GB/s peak; achieved read-modify-write streaming with RFO lands at
    ~54%. GTX 745 has 28.8 GB/s peak (128-bit DDR3-1800); GPUs stream at
    ~90% of peak. These two scalars are the only fitted constants, chosen so
    the modeled Buddy-vs-baseline ratios land inside the paper's reported
    ranges (3.8-9.1x vs Skylake, 2.7-6.4x vs GTX; abstract 10.9-25.6x for
    4 banks) — then *every* per-op number is derived.
    """

    name: str
    peak_bw_gbps: float
    efficiency: float

    @property
    def effective_bw_gbps(self) -> float:
        return self.peak_bw_gbps * self.efficiency


SKYLAKE = BaselineSystem("skylake-i7", peak_bw_gbps=34.1, efficiency=0.54)
GTX745 = BaselineSystem("gtx-745", peak_bw_gbps=28.8, efficiency=0.90)


def bytes_moved_per_output_byte(op: str) -> int:
    """Channel traffic for out = op(in...) in a cache-based system.

    Unary (not/copy): read src + write dst (write-allocate RFO read of dst is
    ~overlapped for streaming stores) -> 2. Binary: read 2 srcs + write -> 3.
    """
    return 2 if op in ("not", "copy") else 3


def baseline_throughput_gbps(op: str, system: BaselineSystem) -> float:
    return system.effective_bw_gbps / bytes_moved_per_output_byte(op)


def throughput_table(banks_list=(1, 2, 4),
                     respect_tfaw: bool = False) -> Dict[str, Dict[str, float]]:
    """Fig. 9: throughput (GB/s) per op for baselines and Buddy @ N banks."""
    from repro.core import compiler

    ops = ["not", "and", "or", "nand", "nor", "xor", "xnor"]
    table: Dict[str, Dict[str, float]] = {}
    for op in ops:
        row: Dict[str, float] = {
            "skylake": baseline_throughput_gbps(op, SKYLAKE),
            "gtx745": baseline_throughput_gbps(op, GTX745),
        }
        srcs = ["D0"] if op == "not" else ["D0", "D1"]
        prog = compiler.op_program(op, srcs, "D2")
        for b in banks_list:
            row[f"buddy_{b}bank"] = buddy_throughput_gbps(
                prog, banks=b, respect_tfaw=respect_tfaw)
        table[op] = row
    return table
