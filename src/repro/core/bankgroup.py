"""Multi-bank parallel execution of AAP programs (paper §1, §5.4, §7).

A Buddy operation is contained entirely inside one subarray, so every bank
(and every subarray within a bank) can run its own program concurrently —
this internal parallelism is where the paper's 10.9x-25.6x 4-bank numbers
come from. This module is the software seam for that scaling lever:

  * `BankGroup` holds N independent `Subarray` states as ONE stacked pytree
    (every named row gains a leading bank axis) and dispatches a compiled
    program across all banks with `jax.vmap` — one traced execution, N banks
    of data, exactly the SIMD-across-banks shape of the hardware.
  * `shard_words` / `unshard_words` partition a bulk operand's row-blocks
    across banks (pad-to-even split on the word axis) and reassemble
    results.
  * `pipeline_latency_ns` models the controller schedule: per-block operand
    placement ("inter-bank copy" over the shared internal bus, serialized)
    overlapped with per-bank AAP compute (parallel) — a classic software
    pipeline whose makespan the benchmark (benchmarks/fig9_throughput.py)
    reports for 1 vs N banks.

The functional result of banked execution is bit-identical to single-bank
execution (asserted by tests/test_bankgroup.py); only the schedule differs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core import addressing
from repro.core.commands import Program
from repro.core.engine import RowState, Subarray
from repro.core.timing import DDR3_1600, DramTiming, program_latency_ns
from repro.obs.telemetry import get_telemetry


def shard_words(x: jax.Array, n_banks: int) -> jax.Array:
    """Split a (..., W) operand into per-bank word slices: (B, ..., W/B).

    W is zero-padded up to a multiple of `n_banks` — zero words are inert
    for every bitwise program and `unshard_words` strips them back off.
    """
    x = jnp.asarray(x, jnp.uint32)
    w = x.shape[-1]
    pad = (-w) % n_banks
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    per = x.shape[-1] // n_banks
    split = x.reshape(x.shape[:-1] + (n_banks, per))
    # bank axis leads: (B, ..., W/B)
    return jnp.moveaxis(split, -2, 0)


def unshard_words(x: jax.Array, n_words: int) -> jax.Array:
    """Inverse of `shard_words`: (B, ..., W/B) -> (..., n_words)."""
    merged = jnp.moveaxis(x, 0, -2)
    flat = merged.reshape(merged.shape[:-2] + (-1,))
    return flat[..., :n_words]


@dataclasses.dataclass
class BankGroup:
    """N subarrays (one per bank) as a single stacked row-state pytree.

    `rows[name]` has shape (n_banks, ..., row_words): bank b's subarray is
    the slice `rows[name][b]`. All banks share one program counter — the
    memory controller broadcasts the same AAP sequence and each bank applies
    it to its own data (how bulk ops actually scale across banks; per-bank
    distinct programs would just be a second `BankGroup`).
    """

    rows: RowState
    n_banks: int
    row_words: int

    @classmethod
    def create(cls, n_banks: int, row_words: int,
               data: Optional[RowState] = None) -> "BankGroup":
        """Build a group whose per-bank rows are already bank-sliced.

        `data` values must carry the leading bank axis (use `shard_words`
        to produce them from flat operands).
        """
        sub = Subarray.create(row_words, None, batch=(n_banks,))
        rows = dict(sub.rows)
        if data:
            for k, v in data.items():
                v = jnp.asarray(v, jnp.uint32)
                if v.shape[0] != n_banks:
                    raise ValueError(
                        f"row {k!r}: leading axis {v.shape[0]} != n_banks "
                        f"{n_banks}; shard operands with shard_words()")
                rows[k] = v
        return cls(rows=rows, n_banks=n_banks, row_words=row_words)

    @classmethod
    def from_flat(cls, n_banks: int, data: RowState) -> "BankGroup":
        """Partition flat (..., W) operand rows across banks and build."""
        sharded = {k: shard_words(v, n_banks) for k, v in data.items()}
        row_words = next(iter(sharded.values())).shape[-1]
        return cls.create(n_banks, row_words, sharded)

    def run(self, program: Program, lowered: bool = True,
            backend: str = "scan") -> "BankGroup":
        """Execute one program on every bank concurrently.

        D-group rows the program references but no bank holds yet
        (destinations, temps) are created as zero rows, as in
        `engine.execute`.

        With ``lowered=True`` (default) the program is compiled once to a
        `core.lowering.LoweredProgram` and the banks execute as ONE plane
        tensor ``(n_rows, n_banks, ..., row_words)`` through the scan VM or
        Pallas megakernel — the bank axis is just a batch axis of the plane,
        no per-row vmap over the dict. ``lowered=False`` keeps the vmapped
        micro-op interpreter (the oracle).
        """
        if lowered:
            from repro.core import lowering

            lp = lowering.lower(program)
            # align narrow rows on the bank axis before the plane build:
            # built-in B/C rows are (B, W) while batched operands may be
            # (B, ..., W); right-aligned broadcasting inside the plane
            # would pair the bank axis with a batch axis, so give every
            # row the full rank with singleton batch dims after the bank
            # axis (exactly what the vmapped interpreter's per-bank
            # broadcast does)
            ndim = max(v.ndim for v in self.rows.values())
            rows_in = {
                k: (v if v.ndim == ndim else
                    v.reshape(v.shape[:1] + (1,) * (ndim - v.ndim)
                              + v.shape[1:]))
                for k, v in self.rows.items()
            }
            out = lowering.execute_lowered(
                lp, rows_in, row_words=self.row_words, backend=backend)
            rows = dict(self.rows)
            written = set(lp.writes)
            for name, v in out.items():
                if name in written or name not in rows:
                    rows[name] = v
            return BankGroup(rows=rows, n_banks=self.n_banks,
                             row_words=self.row_words)
        stacked = dict(self.rows)
        # widest row shape wins: batched operands are (B, ..., W) while the
        # built-in B/C rows are (B, W)
        shape = max((v.shape for v in stacked.values()), key=len)
        for a in program.activates():
            for r, _ in addressing.resolve(a):
                if r not in stacked:
                    stacked[r] = jnp.zeros(shape, jnp.uint32)

        def one_bank(rows: RowState) -> RowState:
            sub = Subarray(rows=rows, row_words=self.row_words)
            return sub.run(program).rows

        rows = jax.vmap(one_bank)(stacked)
        return BankGroup(rows=rows, n_banks=self.n_banks,
                         row_words=self.row_words)

    def read(self, addr: str) -> jax.Array:
        """Per-bank view of a row: (n_banks, ..., row_words)."""
        return self.rows[addr]

    def gather(self, addr: str, n_words: Optional[int] = None) -> jax.Array:
        """Reassemble a row's bank slices into one flat (..., W) vector."""
        v = self.rows[addr]
        if n_words is None:
            n_words = v.shape[0] * v.shape[-1]
        return unshard_words(v, n_words)


def execute_banked(program: Program, data: RowState, n_banks: int,
                   outputs: Optional[List[str]] = None,
                   lowered: bool = True, backend: str = "scan",
                   reduce: Optional[str] = None,
                   mask: Optional[jax.Array] = None) -> RowState:
    """Bank-parallel analog of `engine.execute`.

    Flat (..., W) operand rows are partitioned word-wise across `n_banks`
    banks, the program runs on all banks in one dispatch (the lowered VM by
    default — the bank axis is a batch axis of the plane tensor — or the
    vmapped interpreter with ``lowered=False``), and the requested output
    rows come back reassembled to their original width. Bit-identical to
    `engine.execute(program, data)` for every program and backend.

    ``reduce="popcount"`` (lowered only) requests the fused count epilogue
    instead: each output maps to its total popcount across all banks —
    computed per bank inside the VM dispatch (in VMEM on the pallas
    backend) and summed over the bank axis, so no output plane is ever
    gathered. ``mask`` optionally ANDs a per-word ``(W,)`` mask first; the
    word padding `shard_words` adds is always masked off, so programs that
    drive pad words to 1 never miscount.

    Wall-span-traced when a tracing telemetry is installed process-wide
    (`repro.obs.set_telemetry`); the default no-op sink costs one branch.
    """
    tel = get_telemetry()
    if tel.tracing:
        with tel.tracer.span("bankgroup.execute", n_banks=n_banks,
                             n_aaps=program.n_aap, backend=backend,
                             lowered=lowered):
            return _execute_banked(program, data, n_banks, outputs,
                                   lowered, backend, reduce, mask)
    return _execute_banked(program, data, n_banks, outputs, lowered, backend,
                           reduce, mask)


def _execute_banked(program: Program, data: RowState, n_banks: int,
                    outputs: Optional[List[str]],
                    lowered: bool, backend: str,
                    reduce: Optional[str] = None,
                    mask: Optional[jax.Array] = None) -> RowState:
    n_words = next(iter(data.values())).shape[-1]
    sharded = {k: shard_words(jnp.asarray(v, jnp.uint32), n_banks)
               for k, v in data.items()}
    row_words = next(iter(sharded.values())).shape[-1]
    if reduce is not None and not lowered:
        raise ValueError("reduce= requires lowered=True (the fused count "
                         "epilogue lives in the lowered VM dispatch)")
    if lowered:
        from repro.core import lowering
        from repro.core.engine import _check_outputs

        lp = lowering.lower(program)
        if outputs is not None:
            _check_outputs(outputs, set(lp.row_names) | set(sharded),
                           program)
        if reduce is not None:
            # per-bank fused counts, then one sum over the bank axis —
            # the pad words shard_words appended carry a zero mask
            base = (jnp.full((n_words,), 0xFFFFFFFF, jnp.uint32)
                    if mask is None else jnp.asarray(mask, jnp.uint32))
            mask_sh = shard_words(base, n_banks)
            counts = lowering.execute_lowered(
                lp, sharded, row_words, outputs, backend=backend,
                reduce="popcount", mask=mask_sh)
            names = outputs if outputs is not None else list(counts)
            totals = {k: counts[k].sum(axis=0) for k in names}
            if reduce == "popcount":
                return totals
            return lowering.weight_counts(
                jnp.stack([totals[k] for k in names]))
        out_rows = lowering.execute_lowered(lp, sharded, row_words, outputs,
                                            backend=backend)
        names = outputs if outputs is not None else list(out_rows)
        return {k: unshard_words(out_rows[k], n_words) for k in names}
    group = BankGroup.create(n_banks, row_words, sharded)
    out = group.run(program, lowered=False)  # creates missing dst/temp rows
    names = outputs if outputs is not None else list(out.rows)
    return {k: unshard_words(out.rows[k], n_words) for k in names}


# ---------------------------------------------------------------------------
# Controller schedule: overlap inter-bank operand copy with compute
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BankSchedule:
    """Makespan of a bulk op split into row-blocks across banks.

    `copy_ns` is the serialized inter-bank transfer (the shared internal
    bus moves one row-block at a time); `compute_ns` sums per-bank program
    time; `total_ns` is the pipelined makespan with copy overlapped under
    compute of other banks.
    """

    n_blocks: int
    n_banks: int
    copy_ns: float
    compute_ns: float
    total_ns: float

    @property
    def serial_ns(self) -> float:
        """The no-overlap baseline: every block pays copy then compute."""
        return self.copy_ns + self.compute_ns


def partition_blocks(n_blocks: int, n_banks: int) -> List[range]:
    """Round-robin-balanced contiguous assignment of row-blocks to banks."""
    base, extra = divmod(n_blocks, n_banks)
    out: List[range] = []
    start = 0
    for b in range(n_banks):
        size = base + (1 if b < extra else 0)
        out.append(range(start, start + size))
        start += size
    return out


def pipeline_latency_ns(n_blocks: int, n_banks: int, program: Program,
                        timing: DramTiming = DDR3_1600,
                        xfer_ns_per_block: Optional[float] = None
                        ) -> BankSchedule:
    """Event-driven makespan of `n_blocks` row-block ops over `n_banks`.

    Model: placing one row-block's operands in its bank costs one
    inter-bank RowClone-PSM-ish transfer (`xfer_ns_per_block`, default one
    serialized AAP) on the shared bus; the bank then executes the compiled
    program (`program_latency_ns`) independently. Transfers serialize,
    compute overlaps — so N banks hide compute behind the transfer stream
    and the makespan drops from n*(x+c) toward n*x + c.
    """
    if xfer_ns_per_block is None:
        xfer_ns_per_block = timing.aap_ns
    exec_ns = program_latency_ns(program, timing)
    bus_free = 0.0
    bank_free = [0.0] * n_banks
    makespan = 0.0
    for blk in range(n_blocks):
        b = blk % n_banks
        start_xfer = max(bus_free, bank_free[b])
        bus_free = start_xfer + xfer_ns_per_block
        done = bus_free + exec_ns
        bank_free[b] = done
        makespan = max(makespan, done)
    return BankSchedule(
        n_blocks=n_blocks, n_banks=n_banks,
        copy_ns=n_blocks * xfer_ns_per_block,
        compute_ns=n_blocks * exec_ns,
        total_ns=makespan,
    )


def banked_throughput_gbps(n_blocks: int, n_banks: int, program: Program,
                           timing: DramTiming = DDR3_1600) -> float:
    """End-to-end GB/s of output for a multi-block bulk op (Fig. 9 e2e)."""
    sched = pipeline_latency_ns(n_blocks, n_banks, program, timing)
    if sched.total_ns == 0.0:
        return 0.0
    return n_blocks * timing.row_bytes / sched.total_ns
