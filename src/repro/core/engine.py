"""Functional simulator of the Buddy subarray (paper §3-§5 semantics).

Executes AAP/AP command programs against a subarray state with the *exact*
hardware semantics, including the destructive nature of triple-row activation
(all connected cells are overwritten with the sensed result, Fig. 4 state 3)
and the negation capture of dual-contact-cell n-wordlines (Fig. 6).

The state is a dict of packed uint32 row vectors (a JAX pytree), so a whole
program executes as traced jnp bitwise ops and can live under jit/vmap. The
"analog" sensing rule is digital majority — `core.spice` justifies this
abstraction against Eq. 1 charge sharing with process variation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import addressing
from repro.core.addressing import D_WL, resolve
from repro.core.commands import Activate, Precharge, Program
from repro.obs.telemetry import get_telemetry

RowState = Dict[str, jax.Array]


class BuddyError(RuntimeError):
    pass


def _maj3(a, b, c):
    return (a & b) | (b & c) | (c & a)


@dataclasses.dataclass
class Subarray:
    """One subarray: named rows -> packed uint32 vectors (same shape each).

    `rows` always contains T0..T3, DCC0, DCC1, C0, C1 plus any D-group rows
    the caller installs. C0/C1 are pre-initialized (paper §3.5).
    """

    rows: RowState
    row_words: int
    strict: bool = True  # raise on analog-undefined sequences

    @classmethod
    def create(cls, row_words: int, data: Optional[RowState] = None,
               batch: Tuple[int, ...] = ()) -> "Subarray":
        shape = batch + (row_words,)
        zeros = jnp.zeros(shape, jnp.uint32)
        ones = jnp.full(shape, 0xFFFFFFFF, jnp.uint32)
        rows: RowState = {
            "T0": zeros, "T1": zeros, "T2": zeros, "T3": zeros,
            "DCC0": zeros, "DCC1": zeros,
            "C0": zeros, "C1": ones,
        }
        if data:
            for k, v in data.items():
                rows[k] = jnp.asarray(v, jnp.uint32)
        return cls(rows=rows, row_words=row_words)

    # -- micro-op semantics -------------------------------------------------

    def run(self, program: Program) -> "Subarray":
        """Execute a program; returns the post-state (functional)."""
        rows = dict(self.rows)
        sense: Optional[jax.Array] = None  # latched bitline value, None = precharged

        for op in program.micro_ops():
            if isinstance(op, Precharge):
                sense = None
                continue
            assert isinstance(op, Activate)
            wls = resolve(op.addr)
            for r, _ in wls:
                if r not in rows:
                    raise BuddyError(f"activate of unknown row {r!r}")

            if sense is None:
                # First ACTIVATE after precharge: charge sharing + sensing.
                if len(wls) == 2 and self.strict:
                    # Dual addresses (B8-B11) sense two cells: ties are
                    # analog-undefined; hardware only uses them as the second
                    # ACTIVATE of an AAP.
                    raise BuddyError(
                        f"{op.addr} raises 2 wordlines from precharged state; "
                        "majority of 2 is undefined on disagreement")
                # Effective bitline contribution: cells on bitline-bar
                # (n-wordline) contribute their complement.
                vals = [rows[r] if pol == D_WL else ~rows[r] for r, pol in wls]
                if len(vals) == 1:
                    sense = vals[0]
                elif len(vals) == 3:
                    sense = _maj3(*vals)  # TRA (§3.1)
                else:
                    sense = vals[0]
                # Sense amplification restores/overwrites every raised cell
                # with the (polarity-adjusted) result — TRA is destructive.
                for r, pol in wls:
                    rows[r] = sense if pol == D_WL else ~sense
            else:
                # Second ACTIVATE while the bank is active (split decoder,
                # §5.3): the sense amps force the raised cells to the
                # already-latched result.
                for r, pol in wls:
                    rows[r] = sense if pol == D_WL else ~sense

        return Subarray(rows=rows, row_words=self.row_words, strict=self.strict)

    # -- convenience --------------------------------------------------------

    def read(self, addr: str) -> jax.Array:
        return self.rows[addr]

    def write(self, addr: str, value: jax.Array) -> "Subarray":
        rows = dict(self.rows)
        rows[addr] = jnp.asarray(value, jnp.uint32)
        return Subarray(rows=rows, row_words=self.row_words, strict=self.strict)


def _check_outputs(outputs: List[str], available, program: Program) -> None:
    """Outputs must name rows the execution produces — not a bare KeyError."""
    missing = [k for k in outputs if k not in available]
    if missing:
        from repro.core import lowering

        produced = lowering.lower(program).writes
        raise BuddyError(
            f"outputs {missing} are never written and not present in the "
            f"input data; the program writes rows {list(produced)}")


def execute(program: Program, data: RowState, row_words: Optional[int] = None,
            outputs: Optional[List[str]] = None, n_banks: int = 1,
            n_chips: int = 1, lowered: bool = True,
            backend: str = "scan") -> RowState:
    """One-shot helper: run `program` over `data` rows, return named rows.

    Rows referenced by the program but missing from `data` (e.g. destination
    or temp rows) are implicitly created as zero rows.

    `n_banks > 1` partitions each operand row word-wise across that many
    independent subarray states and executes the program on all of them in
    one vmapped dispatch (see `core.bankgroup`) — bit-identical results,
    bank-parallel schedule. `n_chips > 1` additionally lays a leading chip
    axis onto the JAX device mesh and executes per-chip shards under
    `shard_map` (`core.cluster`, lowered VM only) — still bit-identical.

    By default the program is compiled to a `core.lowering.LoweredProgram`
    and executed by the constant-size scan VM (``backend="scan"``) or the
    Pallas megakernel (``backend="pallas"``); ``lowered=False`` falls back
    to the micro-op interpreter above (the oracle — bit-identical by
    construction, re-traced per program).

    Executions are wall-span-traced when a tracing `repro.obs.Telemetry`
    is installed process-wide (`set_telemetry`; the scheduler does so per
    dispatch) — the default is the no-op sink, costing one attribute load.
    """
    tel = get_telemetry()
    if tel.tracing:
        with tel.tracer.span("engine.execute", n_aaps=program.n_aap,
                             n_banks=n_banks, n_chips=n_chips,
                             backend=backend, lowered=lowered):
            return _execute(program, data, row_words, outputs, n_banks,
                            n_chips, lowered, backend)
    return _execute(program, data, row_words, outputs, n_banks, n_chips,
                    lowered, backend)


def _execute(program: Program, data: RowState, row_words: Optional[int],
             outputs: Optional[List[str]], n_banks: int, n_chips: int,
             lowered: bool, backend: str) -> RowState:
    if n_chips > 1:
        from repro.core import cluster

        if not lowered:
            raise ValueError(
                "n_chips > 1 dispatches through the lowered VM; the "
                "micro-op interpreter is single-process (lowered=False)")
        if row_words is not None:
            raise ValueError(
                "row_words cannot be overridden with n_chips > 1: the "
                "sharded layout derives per-slot widths from the data rows")
        cl = cluster.get_cluster(n_chips, n_banks)
        return cl.execute(program, data, outputs, backend=backend)
    if n_banks > 1:
        from repro.core import bankgroup

        return bankgroup.execute_banked(program, data, n_banks, outputs,
                                        lowered=lowered, backend=backend)
    if lowered:
        from repro.core import lowering

        lp = lowering.lower(program)
        if outputs is not None:
            _check_outputs(outputs, set(lp.row_names) | set(data), program)
        return lowering.execute_lowered(lp, data, row_words, outputs,
                                        backend=backend)
    if row_words is None:
        row_words = next(iter(data.values())).shape[-1]
    sample = jnp.asarray(next(iter(data.values())))
    batch = sample.shape[:-1]
    full: RowState = dict(data)
    for addr in program.activates():
        for r, _ in resolve(addr):
            if r not in full and r not in addressing.B_GROUP_ROWS \
                    and r not in addressing.C_GROUP_ROWS:
                full[r] = jnp.zeros(batch + (row_words,), jnp.uint32)
    sub = Subarray.create(row_words, full, batch=batch)
    out = sub.run(program)
    if outputs is None:
        return out.rows
    _check_outputs(outputs, out.rows, program)
    return {k: out.rows[k] for k in outputs}
