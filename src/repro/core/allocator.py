"""Row-aligned, subarray-aware allocation (paper §6.2.4 OS support).

The OS maps pages likely to participate in bitwise ops so that (1) they are
row-aligned and (2) co-located in the same subarray, enabling all-FPM staging.
This module provides that placement logic for the simulator/cost model: a
simple bump allocator over (bank, subarray, data-row) coordinates with an
affinity-group API — allocations in one group land in one subarray while
capacity lasts, spilling to sibling subarrays otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.addressing import SubarrayGeometry
from repro.core.rowclone import CopyMode, classify_copy


@dataclasses.dataclass(frozen=True)
class RowHandle:
    name: str
    bank: int
    subarray: int
    row: int            # D-group index within the subarray
    n_rows: int = 1     # multi-row allocations are contiguous


@dataclasses.dataclass
class DramAllocator:
    n_banks: int = 16
    subarrays_per_bank: int = 64
    geometry: SubarrayGeometry = dataclasses.field(default_factory=SubarrayGeometry)

    def __post_init__(self):
        self._cursor: Dict[Tuple[int, int], int] = {}
        self._groups: Dict[str, Tuple[int, int]] = {}
        self._handles: Dict[str, RowHandle] = {}
        self._next_sub = 0

    def _free_rows(self, bank: int, sub: int) -> int:
        return self.geometry.n_data_rows - self._cursor.get((bank, sub), 0)

    def _pick_subarray(self, group: Optional[str], n_rows: int) -> Tuple[int, int]:
        if group is not None and group in self._groups:
            bank, sub = self._groups[group]
            if self._free_rows(bank, sub) >= n_rows:
                return bank, sub
        # round-robin across (bank, subarray) to spread bank-level parallelism
        for _ in range(self.n_banks * self.subarrays_per_bank):
            idx = self._next_sub
            self._next_sub = (self._next_sub + 1) % (
                self.n_banks * self.subarrays_per_bank)
            bank, sub = divmod(idx, self.subarrays_per_bank)
            if self._free_rows(bank, sub) >= n_rows:
                if group is not None:
                    self._groups[group] = (bank, sub)
                return bank, sub
        raise MemoryError("DRAM allocator exhausted")

    def alloc(self, name: str, n_bits: int, group: Optional[str] = None) -> RowHandle:
        """Allocate ceil(n_bits/row_bits) contiguous rows, row-aligned."""
        n_rows = max(1, -(-n_bits // self.geometry.row_bits))
        bank, sub = self._pick_subarray(group, n_rows)
        row = self._cursor.get((bank, sub), 0)
        self._cursor[(bank, sub)] = row + n_rows
        h = RowHandle(name, bank, sub, row, n_rows)
        self._handles[name] = h
        return h

    def handle(self, name: str) -> RowHandle:
        return self._handles[name]

    def copy_mode(self, src: str, dst: str) -> CopyMode:
        a, b = self._handles[src], self._handles[dst]
        return classify_copy(a.subarray, a.bank, b.subarray, b.bank)

    def psm_copies_for_op(self, srcs: List[str], dst: str) -> int:
        """How many of the operand/result movements need PSM (§6.2.2)."""
        subs = {(self._handles[s].bank, self._handles[s].subarray) for s in srcs}
        subs.add((self._handles[dst].bank, self._handles[dst].subarray))
        # all in one subarray -> 0 PSM; each extra distinct subarray costs one
        return len(subs) - 1
