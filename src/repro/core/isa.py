"""The `bop` instruction layer (paper §6.2): dispatch Buddy vs CPU.

bop(dst, src1, [src2], size): the microarchitecture checks row alignment and
size, counts required RowClone-PSM staging copies, and executes on Buddy
unless (a) operands are misaligned/too small or (b) 3 PSM copies are needed
(where the CPU path is faster, §3.5). This module implements that dispatch
against the allocator's placement and executes both paths functionally so
results are bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import compiler, engine, timing
from repro.core.allocator import DramAllocator
from repro.core.rowclone import op_latency_with_placement
from repro.core.timing import DDR3_1600


@dataclasses.dataclass
class BopResult:
    value: jax.Array          # packed uint32 result
    path: str                 # 'buddy' | 'cpu'
    latency_ns: float
    n_psm: int


_JNP_OPS = {
    "not": lambda a: ~a,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "nand": lambda a, b: ~(a & b),
    "nor": lambda a, b: ~(a | b),
    "xor": lambda a, b: a ^ b,
    "xnor": lambda a, b: ~(a ^ b),
    "maj3": lambda a, b, c: (a & b) | (b & c) | (c & a),
}


class BuddyDevice:
    """Holds named packed rows + their DRAM placement; executes bop()s."""

    def __init__(self, allocator: Optional[DramAllocator] = None,
                 row_bits: Optional[int] = None):
        self.alloc = allocator or DramAllocator()
        if row_bits is not None:
            geom = dataclasses.replace(self.alloc.geometry, row_bits=row_bits)
            self.alloc.geometry = geom
        self.rows: Dict[str, jax.Array] = {}

    @property
    def row_bits(self) -> int:
        return self.alloc.geometry.row_bits

    def store(self, name: str, words: jax.Array, group: Optional[str] = None):
        assert words.shape[-1] * 32 == self.row_bits, \
            f"bop operands must be row-sized ({self.row_bits} bits)"
        self.alloc.alloc(name, self.row_bits, group=group)
        self.rows[name] = jnp.asarray(words, jnp.uint32)

    def bop(self, op: str, dst: str, srcs: List[str],
            group: Optional[str] = None) -> BopResult:
        if dst not in self.rows:
            self.store(dst, jnp.zeros_like(self.rows[srcs[0]]), group=group)
        n_psm = self.alloc.psm_copies_for_op(srcs, dst)
        use_cpu = n_psm >= 3  # §6.2.2 dispatch rule
        if use_cpu:
            value = _JNP_OPS[op](*[self.rows[s] for s in srcs])
            lat = _cpu_latency_ns(op, self.row_bits)
            path = "cpu"
        else:
            prog = compiler.op_program(op, srcs, dst)
            out = engine.execute(prog, {s: self.rows[s] for s in srcs},
                                 outputs=[dst])
            value = out[dst]
            lat = op_latency_with_placement(
                n_fpm_aap=prog.n_aap, n_psm_copies=n_psm,
                aap_ns=DDR3_1600.aap_ns) + prog.n_ap * DDR3_1600.ap_ns
            path = "buddy"
        self.rows[dst] = value
        return BopResult(value=value, path=path, latency_ns=lat, n_psm=n_psm)


def _cpu_latency_ns(op: str, row_bits: int) -> float:
    bytes_out = row_bits // 8
    gbps = timing.baseline_throughput_gbps(op, timing.SKYLAKE)
    return bytes_out / gbps
