"""Charge-sharing model of triple-row activation (paper §3.1-§3.3, Table 1).

The container has no SPICE, so we model the analog physics at the level the
paper itself derives (Eq. 1) plus a calibrated sense-amplifier latency model:

  1. Charge sharing: with per-cell capacitances C_i (process variation) and
     bitline capacitance C_b, the post-sharing bitline deviation is
         delta = (sum_i V_i C_i + C_b*VDD/2) / (sum_i C_i + C_b) - VDD/2.
     Eq. 1 is the special case C_i = C_c: delta = (2k-3)C_c/(6C_c+2C_b)*VDD.
  2. Sensing: an RC-style latency t_sense = tau * ln(VDD/2 / |delta|) plus a
     restore term that is larger when driving cells to VDD than to 0
     (matching the paper's 20.9 ns charged vs 13.5 ns empty single-cell
     activations).
  3. Failure: the amplifier has a logic-1-biased offset under multi-wordline
     activation, so a "0"-majority TRA fails when delta > -delta_margin.
     Calibrated so the first failure appears at +-25% variation for the
     1s0w0w case and nowhere else — exactly Table 1's structure.

All constants below are physical values from the paper (C_c = 22 fF, 55nm
DDR3 Rambus model) or calibrated once against Table 1; the Monte-Carlo and
the latency table are then *derived*.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SpiceParams:
    c_cell_ff: float = 22.0      # cell capacitance (paper §3.3)
    c_bitline_ff: float = 85.0   # bitline capacitance (Rambus 55nm class)
    vdd: float = 1.2
    tau_ns: float = 1.82          # sense-amp RC constant (calibrated)
    t_restore_0_ns: float = 14.8  # drive bitline+cells to 0
    t_restore_1_ns: float = 20.7  # drive to VDD (slower, cf. 20.9 vs 13.5 ns)
    sense_offset_frac: float = 0.024  # logic-1-biased offset (fraction of VDD)


DEFAULT_SPICE = SpiceParams()


def bitline_deviation(cell_values: jax.Array, cell_caps_ff: jax.Array,
                      p: SpiceParams = DEFAULT_SPICE) -> jax.Array:
    """Generalized Eq. 1: deviation after charge sharing (volts).

    cell_values: (..., k) in {0,1}; cell_caps_ff: (..., k).
    """
    q_cells = (cell_values * cell_caps_ff).sum(-1) * p.vdd
    q_bl = p.c_bitline_ff * p.vdd / 2.0
    c_tot = cell_caps_ff.sum(-1) + p.c_bitline_ff
    return (q_cells + q_bl) / c_tot - p.vdd / 2.0


def eq1_deviation(k: int, p: SpiceParams = DEFAULT_SPICE) -> float:
    """Paper Eq. 1 (no variation)."""
    cc, cb = p.c_cell_ff, p.c_bitline_ff
    return (2 * k - 3) * cc / (6 * cc + 2 * cb) * p.vdd


def sense(delta: jax.Array, p: SpiceParams = DEFAULT_SPICE) -> jax.Array:
    """Sensed logic value: amplifier has a +offset bias under TRA."""
    return (delta + p.sense_offset_frac * p.vdd) > 0


def tra_latency_ns(delta: jax.Array, result: jax.Array,
                   p: SpiceParams = DEFAULT_SPICE) -> jax.Array:
    """Activation latency: sense time grows as |delta| shrinks, plus the
    restore time of the final value."""
    mag = jnp.maximum(jnp.abs(delta), 1e-6)
    t_sense = p.tau_ns * jnp.log(p.vdd / 2.0 / mag)
    t_restore = jnp.where(result, p.t_restore_1_ns, p.t_restore_0_ns)
    return t_sense + t_restore


# --------------------------------------------------------------------------
# Table 1 reproduction: strong/weak cell cases under +-variation.
# --------------------------------------------------------------------------

# (name, values (strong first), expected majority)
TABLE1_CASES: List[Tuple[str, Tuple[int, int, int], int]] = [
    ("0s0w0w", (0, 0, 0), 0),
    ("1s0w0w", (1, 0, 0), 0),
    ("0s1w1w", (0, 1, 1), 1),
    ("1s1w1w", (1, 1, 1), 1),
]

VARIATIONS = (0.0, 0.05, 0.10, 0.15, 0.20, 0.25)


def table1_entry(values: Tuple[int, int, int], variation: float,
                 p: SpiceParams = DEFAULT_SPICE) -> Dict[str, float]:
    """Deterministic worst case: strong cell at C(1+v), weak at C(1-v),
    with the strong cell opposing the majority (paper's adversarial setup)."""
    caps = jnp.array([p.c_cell_ff * (1 + variation),
                      p.c_cell_ff * (1 - variation),
                      p.c_cell_ff * (1 - variation)])
    vals = jnp.array(values, jnp.float32)
    delta = bitline_deviation(vals, caps, p)
    expected = int(np.sum(values) >= 2)
    result = bool(sense(delta, p))
    lat = float(tra_latency_ns(delta, jnp.asarray(result), p))
    return {
        "delta_v": float(delta),
        "latency_ns": lat,
        "result": result,
        "expected": expected,
        "fails": result != expected,
    }


def table1(p: SpiceParams = DEFAULT_SPICE) -> Dict[str, Dict[float, Dict]]:
    return {
        name: {v: table1_entry(vals, v, p) for v in VARIATIONS}
        for name, vals, _ in TABLE1_CASES
    }


def monte_carlo_tra(key: jax.Array, n_trials: int, variation_sigma: float,
                    p: SpiceParams = DEFAULT_SPICE) -> Dict[str, jax.Array]:
    """Randomized reliability check: sample cell capacitances with Gaussian
    process variation and random stored values; report failure rate of TRA
    (digital-majority mismatch) — the justification for `core.engine`'s
    digital abstraction."""
    kv, kc = jax.random.split(key)
    values = jax.random.bernoulli(kv, 0.5, (n_trials, 3)).astype(jnp.float32)
    caps = p.c_cell_ff * (
        1.0 + variation_sigma * jax.random.normal(kc, (n_trials, 3)))
    caps = jnp.clip(caps, p.c_cell_ff * 0.5, p.c_cell_ff * 1.5)
    delta = bitline_deviation(values, caps, p)
    sensed = sense(delta, p)
    expected = values.sum(-1) >= 2
    fail = sensed != expected
    return {
        "failure_rate": fail.mean(),
        "n_fail": fail.sum(),
        "mean_latency_ns": tra_latency_ns(delta, sensed, p).mean(),
    }
